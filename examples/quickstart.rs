//! Quickstart: the 5-minute tour of the MoS framework.
//!
//! 1. Parameter accounting on the real LLaMA2-7B geometry (Table 2 column).
//! 2. Build a MoS adapter: pools + index router, inspect its structure.
//! 3. Train it on a synthetic task (PJRT artifacts if present, else host).
//! 4. Evaluate and print the paper-style metric.
//! 5. Serve a tenant through the coordinator's typed request lifecycle.
//!
//! Run: cargo run --release --example quickstart

use mos::adapter::params::{fmt_params, trainable_params};
use mos::adapter::{init_params, mos::router::build_router};
use mos::config::{presets, MethodCfg};
use mos::coordinator::{
    GenOptions, HostEngine, Registry, Server, ServerCfg, TenantSpec,
};
use mos::data::tasks::{Task, TaskKind};
use mos::runtime::{Manifest, Runtime};
use mos::train::host::HostBackend;
use mos::train::pjrt::PjrtBackend;
use mos::train::{final_loss, run};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // ---- 1. parameter accounting ------------------------------------
    let llama = presets::llama2_7b();
    println!("== MoS quickstart ==\n");
    println!("On LLaMA2-7B geometry (paper Table 2 budgets):");
    for (name, mc) in [
        ("LoRA r=2 ", MethodCfg::lora(2)),
        ("LoRA r=16", MethodCfg::lora(16)),
        ("MoS  4/8 ", MethodCfg::mos(8, 2, 2, 1)),
    ] {
        println!(
            "  {name}: {:>8} trainable params",
            fmt_params(trainable_params(&llama, &mc))
        );
    }

    // ---- 2. adapter anatomy ------------------------------------------
    let cfg = presets::tiny();
    let mc = MethodCfg::mos(8, 2, 2, 1); // rank 8, 2 shards/vector, e=2, 1 private
    let params = init_params(&cfg, &mc, 0);
    let router = build_router(&cfg, &mc, 0);
    println!(
        "\nMoS adapter on the tiny preset: rank={} shards/vector={} \
         pool={} shards/side/layer-type",
        mc.r,
        mc.l,
        mc.pool_shards(cfg.blocks)
    );
    println!(
        "  q-projection A-pool: {:?}; index matrix (block 0, (r x l)): {:?}",
        params["q.pool_a"].shape(),
        &router.indices("q", "idx_a").i32s().unwrap()[..mc.r * mc.l],
    );

    // ---- 3. train ------------------------------------------------------
    let steps = 150;
    let task = TaskKind::Recall;
    let manifest_dir = Manifest::default_dir();
    println!("\ntraining on '{}' for {steps} steps...", task.name());
    let result = if manifest_dir.join("manifest.json").exists() {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load(&manifest_dir)?;
        let mut be = PjrtBackend::load(&rt, &manifest, "tiny", &mc, 0)?;
        println!("  (backend: AOT artifacts via PJRT — python is offline)");
        run(&mut be, || Task::new(task, 0), steps, 2e-2, 24, 50)?
    } else {
        let mut be = HostBackend::new(&cfg, &mc, 0);
        println!("  (backend: host oracle — run `make artifacts` for PJRT)");
        run(&mut be, || Task::new(task, 0), steps, 2e-2, 24, 50)?
    };

    // ---- 4. report -------------------------------------------------------
    println!(
        "\nresults: final_loss={:.3}, EM={:.1}% on {} held-out '{}' \
         examples ({:.1}s train)",
        final_loss(&result.losses, 10),
        result.report.score,
        result.report.n,
        task.name(),
        result.train_seconds,
    );
    // ---- 5. serve --------------------------------------------------------
    // one-line tenant lifecycle: spec -> register -> submit with options
    let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
    let mut server = Server::new(Arc::clone(&registry), ServerCfg::default());
    server.register("quickstart", TenantSpec::mos(8, 2, 2, 1).seed(0))?;
    let cfg2 = cfg.clone();
    server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
    let handle = server.submit(
        "quickstart",
        "hello",
        GenOptions::sample(0.8, 8, 42).max_new_tokens(16),
    )?;
    let resp = handle.wait()?;
    println!(
        "\nserved one sampled request (id {}, seed 42): {:?} \
         ({} tokens in {:?})",
        resp.id, resp.text, resp.tokens, resp.latency
    );
    server.shutdown();

    println!(
        "\nnext: examples/multi_tenant_serving.rs (the serving coordinator) \
         and examples/train_e2e.rs (the full-stack driver)."
    );
    Ok(())
}
