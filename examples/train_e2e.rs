//! End-to-end driver — proves the full three-layer stack composes:
//!
//!   L1 pallas kernels (verified vs ref.py at build time)
//!     -> L2 jax model, AOT-lowered to HLO text by `make artifacts`
//!       -> L3 rust: this driver loads the train-step artifact via PJRT,
//!          streams synthetic-task batches through it, logs the loss
//!          curve, evaluates by batched greedy decoding through the fwd
//!          artifact, and saves a servable checkpoint.
//!
//! Presets: `small` (default, ~5.7M-param base, minutes on 1 CPU core) or
//! `base` (~100M-param base — the paper-scale driver; see EXPERIMENTS.md
//! §E2E for a recorded run):
//!
//!   cargo run --release --example train_e2e -- [--preset base]
//!       [--steps 300] [--task arith] [--method lora|mos] [--lr 2e-2]
//!
//! The loss curve is written to `e2e_loss_<preset>.csv`.

use mos::config::MethodCfg;
use mos::data::tasks::{Task, TaskKind};
use mos::runtime::{Manifest, Runtime};
use mos::train::checkpoint::Checkpoint;
use mos::train::pjrt::PjrtBackend;
use mos::train::{final_loss, run, Backend};
use mos::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let preset = args.str("preset", "small");
    let steps = args.usize("steps", 300)?;
    let lr = args.f64("lr", 2e-2)?;
    let kind = TaskKind::parse(&args.str("task", "recall"))
        .ok_or_else(|| anyhow::anyhow!("unknown task"))?;
    let seed = args.u64("seed", 0)?;
    let method = args.str("method", "mos");

    let manifest = Manifest::load(&Manifest::default_dir()).map_err(|e| {
        anyhow::anyhow!("{e} — run `make artifacts` (and `make artifacts-base` for --preset base)")
    })?;
    anyhow::ensure!(
        manifest.presets.contains_key(&preset),
        "preset '{preset}' has no artifacts; run `make artifacts{}`",
        if preset == "base" { "-base" } else { "" }
    );
    let cfg = manifest.presets[&preset].clone();
    let mc = match (method.as_str(), preset.as_str()) {
        ("mos", "base") => MethodCfg::mos(8, 4, 2, 1),
        ("mos", _) => MethodCfg::mos(8, 2, 2, 1),
        ("lora", "small") => MethodCfg::lora(4),
        ("lora", _) => MethodCfg::lora(2),
        (m, _) => anyhow::bail!("method '{m}' not lowered for this preset"),
    };

    println!(
        "== end-to-end driver ==\npreset={preset}: {} base params, L={} h={} seq={} batch={}",
        mos::adapter::params::fmt_params(cfg.base_param_count()),
        cfg.blocks,
        cfg.hidden,
        cfg.seq,
        cfg.batch
    );
    println!(
        "method={} ({} trainable params), task={}, steps={steps}",
        mc.tag(),
        mos::adapter::params::fmt_params(
            mos::adapter::params::trainable_params(&cfg, &mc)
        ),
        kind.name()
    );

    let t0 = std::time::Instant::now();
    let rt = Runtime::cpu()?;
    println!("loading + compiling artifacts (one-time)...");
    let mut be = PjrtBackend::load(&rt, &manifest, &preset, &mc, seed)?;
    println!("  compiled in {:.1}s", t0.elapsed().as_secs_f64());

    let result = run(
        &mut be,
        || Task::new(kind, seed),
        steps,
        lr,
        32,
        (steps / 12).max(1),
    )?;

    // loss curve to CSV for plotting
    let csv_path = format!("e2e_loss_{preset}.csv");
    let mut csv = String::from("step,loss\n");
    for (i, l) in result.losses.iter().enumerate() {
        csv.push_str(&format!("{},{}\n", i + 1, l));
    }
    std::fs::write(&csv_path, csv)?;

    println!(
        "\n== results ==\nloss: {:.4} (first 10) -> {:.4} (last 10); curve in {csv_path}",
        final_loss(&result.losses[..10.min(result.losses.len())], 10),
        final_loss(&result.losses, 10),
    );
    println!(
        "eval: {}={:.2} on {} held-out '{}' examples",
        match result.report.metric {
            mos::data::tasks::Metric::F1 => "F1",
            mos::data::tasks::Metric::PassAt1 => "pass@1",
            _ => "EM",
        },
        result.report.score,
        result.report.n,
        kind.name()
    );
    println!(
        "train time: {:.1}s ({:.2} s/step, {:.0} tok/s)",
        result.train_seconds,
        result.train_seconds / steps as f64,
        (steps * cfg.batch * cfg.seq) as f64 / result.train_seconds
    );

    let ckpt_dir = format!("ckpt_e2e_{preset}");
    Checkpoint {
        preset: preset.clone(),
        mc: mc.clone(),
        router_seed: seed,
        params: be.params().clone(),
        aux: be.aux.clone(),
    }
    .save(std::path::Path::new(&ckpt_dir))?;
    println!("servable checkpoint saved to {ckpt_dir}/");
    println!(
        "serve it: Checkpoint::load(..) -> \
         server.register(id, TenantSpec::from_checkpoint(ck)) \
         (see examples/multi_tenant_serving.rs and DESIGN.md §Serving API)"
    );
    Ok(())
}
