//! Multi-tenant serving — the paper's motivating scenario, end to end:
//! many customized models (tenants) share one frozen base; each tenant is
//! a MoS adapter (pools + router indices). The coordinator batches per
//! tenant, materializes factors once per tenant (precompute cache), and
//! enforces a memory budget with LRU eviction.
//!
//! Also contrasts the capacity story: the same budget holds ~8x fewer
//! LoRA-r8-quality tenants than MoS tenants (the intro's TB-scale claim
//! scaled down).
//!
//! Run: cargo run --release --example multi_tenant_serving

use mos::adapter::params::{fmt_bytes, serving_bytes};
use mos::adapter::{init_params, mos::router::build_router};
use mos::config::{presets, MethodCfg};
use mos::coordinator::server::HostEngine;
use mos::coordinator::{Registry, Server, Tenant};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn mk_tenant(cfg: &mos::config::ModelCfg, id: String, seed: u64) -> Tenant {
    let mc = MethodCfg::mos(8, 2, 2, 1);
    Tenant {
        id,
        mc: mc.clone(),
        params: init_params(cfg, &mc, seed),
        aux: build_router(cfg, &mc, seed).into_bank(),
        router_seed: seed,
    }
}

fn main() -> anyhow::Result<()> {
    let mut cfg = presets::tiny();
    cfg.batch = 8;
    let n_tenants = 12;
    let n_requests = 48;

    // ---- capacity story -------------------------------------------------
    let mos_bytes = serving_bytes(&cfg, &MethodCfg::mos(8, 2, 2, 1), 4);
    let lora_bytes = serving_bytes(&cfg, &MethodCfg::lora(8), 4);
    println!(
        "per-tenant serving state: MoS {} vs LoRA-r8 {} ({:.1}x)",
        fmt_bytes(mos_bytes),
        fmt_bytes(lora_bytes),
        lora_bytes as f64 / mos_bytes as f64
    );

    // budget deliberately tight: fits all 12 MoS tenants but would fit
    // only 3 LoRA-r8 tenants
    let capacity = mos_bytes * n_tenants + 1024;
    println!(
        "ledger capacity {} -> {} MoS tenants vs {} LoRA-r8 tenants resident\n",
        fmt_bytes(capacity),
        capacity / mos_bytes,
        capacity / lora_bytes
    );

    // ---- register tenants -------------------------------------------------
    let registry = Arc::new(Registry::new(cfg.clone(), capacity));
    for i in 0..n_tenants {
        let evicted = registry
            .register(mk_tenant(&cfg, format!("user-{i:02}"), i as u64))?;
        assert!(evicted.is_empty());
    }
    println!(
        "registered {n_tenants} tenants; ledger used {}",
        fmt_bytes(registry.ledger.lock().unwrap().used())
    );

    // ---- serve traffic ---------------------------------------------------
    let mut server = Server::new(
        Arc::clone(&registry),
        cfg.batch,
        Duration::from_millis(5),
        n_tenants,
    );
    let cfg2 = cfg.clone();
    server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));

    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| {
            server.submit(
                &format!("user-{:02}", i % n_tenants),
                &format!("q:{:02}", i % 24),
            )
        })
        .collect();
    let mut ok = 0;
    for rx in rxs {
        if rx.recv_timeout(Duration::from_secs(300))?.ok {
            ok += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nserved {ok}/{n_requests} requests across {n_tenants} tenants \
         in {dt:.1}s ({:.2} req/s, {} tokens)",
        n_requests as f64 / dt,
        server.metrics.generated_tokens.load(Ordering::Relaxed)
    );
    println!("metrics: {}", server.metrics.summary());
    let (hits, misses) = server.cache.stats();
    println!(
        "materialization cache: {misses} builds + {hits} hits \
         (precompute once per tenant — paper Limitations §C)"
    );

    // ---- eviction under pressure -----------------------------------------
    println!("\nregistering one more tenant than the budget allows...");
    let evicted = registry
        .register(mk_tenant(&cfg, "user-overflow".into(), 99))?;
    println!(
        "evicted (LRU): {evicted:?}; resident tenants now {}",
        registry.len()
    );
    server.shutdown();
    Ok(())
}
