//! Multi-tenant serving — the paper's motivating scenario, end to end:
//! many customized models (tenants) share one frozen base; each tenant is
//! a MoS adapter (pools + router indices). The coordinator batches per
//! tenant with round-robin fairness, materializes factors once per tenant
//! version (precompute cache), bounds its queues with admission control,
//! and enforces a memory budget with LRU eviction.
//!
//! Also contrasts the capacity story: the same budget holds ~8x fewer
//! LoRA-r8-quality tenants than MoS tenants (the intro's TB-scale claim
//! scaled down), and tours the typed request lifecycle: per-request
//! GenOptions, response handles, cancellation, and queue-full shedding.
//!
//! Run: cargo run --release --example multi_tenant_serving

use mos::adapter::params::{fmt_bytes, serving_bytes};
use mos::config::{presets, MethodCfg};
use mos::coordinator::{
    Admission, GenOptions, HostEngine, Registry, ServeError, Server,
    ServerCfg, TenantSpec,
};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let mut cfg = presets::tiny();
    cfg.batch = 8;
    let n_tenants = 12;
    let n_requests = 48;

    // ---- capacity story -------------------------------------------------
    let mos_bytes = serving_bytes(&cfg, &MethodCfg::mos(8, 2, 2, 1), 4);
    let lora_bytes = serving_bytes(&cfg, &MethodCfg::lora(8), 4);
    println!(
        "per-tenant serving state: MoS {} vs LoRA-r8 {} ({:.1}x)",
        fmt_bytes(mos_bytes),
        fmt_bytes(lora_bytes),
        lora_bytes as f64 / mos_bytes as f64
    );

    // budget deliberately tight: fits all 12 MoS tenants but would fit
    // only 3 LoRA-r8 tenants
    let capacity = mos_bytes * n_tenants + 1024;
    println!(
        "ledger capacity {} -> {} MoS tenants vs {} LoRA-r8 tenants resident\n",
        fmt_bytes(capacity),
        capacity / mos_bytes,
        capacity / lora_bytes
    );

    // ---- register tenants (one-line specs, no Bank ritual) ---------------
    let registry = Arc::new(Registry::new(cfg.clone(), capacity));
    let mut server = Server::new(
        Arc::clone(&registry),
        ServerCfg {
            max_batch: cfg.batch,
            max_wait: Duration::from_millis(5),
            cache_capacity: n_tenants + 1,
            admission: Admission { per_tenant: 64, global: 256 },
        },
    );
    for i in 0..n_tenants {
        let evicted = server.register(
            &format!("user-{i:02}"),
            TenantSpec::mos(8, 2, 2, 1).seed(i as u64),
        )?;
        assert!(evicted.is_empty());
    }
    println!(
        "registered {} tenants; ledger used {}",
        server.tenant_ids().len(),
        fmt_bytes(registry.ledger.lock().unwrap().used())
    );

    // ---- serve traffic ---------------------------------------------------
    let cfg2 = cfg.clone();
    server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));

    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_requests)
        .map(|i| {
            // even requests decode greedily; odd ones sample with a
            // per-request seed (reproducible under batching)
            let opts = if i % 2 == 0 {
                GenOptions::greedy()
            } else {
                GenOptions::sample(0.8, 8, i as u64).max_new_tokens(24)
            };
            server.submit(
                &format!("user-{:02}", i % n_tenants),
                &format!("q:{:02}", i % 24),
                opts,
            )
        })
        .collect::<Result<_, ServeError>>()?;
    let mut ok = 0;
    for h in handles {
        match h.wait_timeout(Duration::from_secs(300)) {
            Some(Ok(_)) => ok += 1,
            Some(Err(e)) => println!("request failed: {e}"),
            None => anyhow::bail!("request timed out"),
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nserved {ok}/{n_requests} requests across {n_tenants} tenants \
         in {dt:.1}s ({:.2} req/s, {} tokens)",
        n_requests as f64 / dt,
        server.metrics.generated_tokens.load(Ordering::Relaxed)
    );
    println!("metrics: {}", server.metrics.summary());
    let (hits, misses) = server.cache.stats();
    println!(
        "materialization cache: {misses} builds + {hits} hits \
         (precompute once per tenant version — paper Limitations §C)"
    );

    // ---- streaming delivery ----------------------------------------------
    // tokens arrive through the handle as the KV-cached decode loop emits
    // them (one single-position step per token); `wait` semantics are
    // unchanged and the final text always equals the streamed tokens
    let h = server.submit(
        "user-01",
        "q:stream-me",
        GenOptions::greedy().max_new_tokens(16),
    )?;
    let streamed: Vec<i32> = h.tokens().collect();
    let resp = h.wait()?;
    println!(
        "\nstreamed {} tokens incrementally; final text {:?} (ttft p50 {:.1}ms)",
        streamed.len(),
        resp.text,
        server.metrics.ttft_percentile_us(50.0) / 1e3,
    );

    // ---- request lifecycle: cancellation ---------------------------------
    let doomed = server.submit(
        "user-00",
        "q:never-mind",
        GenOptions::greedy().deadline(Duration::from_secs(5)),
    )?;
    doomed.cancel();
    match doomed.wait() {
        Err(ServeError::Cancelled) => {
            println!("\ncancelled request {} dropped before any engine ran it", doomed.id())
        }
        other => println!("\nunexpected cancel outcome: {other:?}"),
    }

    // ---- eviction under pressure -----------------------------------------
    println!("\nregistering one more tenant than the budget allows...");
    let evicted =
        server.register("user-overflow", TenantSpec::mos(8, 2, 2, 1).seed(99))?;
    println!(
        "evicted (LRU): {evicted:?}; resident tenants now {}",
        registry.len()
    );
    server.shutdown();
    Ok(())
}
