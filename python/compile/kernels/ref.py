"""Pure-jnp reference oracle for the MoS kernels (L1 correctness ground truth).

Notation follows the paper (Sec. 3):
  - A^k in R^{r x h} is built from an A-pool of shards, pool_a in R^{n_a x s_a}
    with shard width s_a = h // l, via an index matrix idx_a in N^{r x l}:
        A[i, j*s_a:(j+1)*s_a] = pool_a[idx_a[i, j]]
  - B^k in R^{o x r} is built column-wise from a B-pool, pool_b in R^{n_b x s_b}
    with s_b = o // l, via idx_b in N^{r x l}:
        B[j*s_b:(j+1)*s_b, i] = pool_b[idx_b[i, j]]
  - The adapted forward pass is  y = x @ W0^T + scale * (x @ A^T) @ B^T.

These functions are the oracle that the pallas kernels in mos_kernels.py and the
Rust `adapter::mos::materialize` module are tested against.
"""

from __future__ import annotations

import jax.numpy as jnp


def materialize_a(pool_a: jnp.ndarray, idx_a: jnp.ndarray) -> jnp.ndarray:
    """Gather + concat shards into the dense low-rank matrix A (r x h).

    pool_a: (n_a, s_a) shard pool.
    idx_a:  (r, l) int32 indices into the pool.
    returns (r, l * s_a).
    """
    r, l = idx_a.shape
    gathered = pool_a[idx_a.reshape(-1)]  # (r*l, s_a)
    return gathered.reshape(r, l * pool_a.shape[1])


def materialize_b(pool_b: jnp.ndarray, idx_b: jnp.ndarray) -> jnp.ndarray:
    """Gather + concat shards into the dense low-rank matrix B (o x r).

    pool_b: (n_b, s_b) shard pool.
    idx_b:  (r, l) int32 indices into the pool.
    returns (l * s_b, r): column i is the concat of shards idx_b[i, :].
    """
    r, l = idx_b.shape
    gathered = pool_b[idx_b.reshape(-1)]  # (r*l, s_b)
    return gathered.reshape(r, l * pool_b.shape[1]).T


def mos_delta(pool_a, idx_a, pool_b, idx_b) -> jnp.ndarray:
    """Dense weight update Delta W = B A (o x h). Eq. (4)/(5) of the paper."""
    a = materialize_a(pool_a, idx_a)
    b = materialize_b(pool_b, idx_b)
    return b @ a


def mos_apply(x, pool_a, idx_a, pool_b, idx_b, scale=1.0) -> jnp.ndarray:
    """Routed low-rank product y = scale * (x @ A^T) @ B^T  (m x o).

    This is the serving hot path: it never materializes Delta W.
    """
    a = materialize_a(pool_a, idx_a)  # (r, h)
    b = materialize_b(pool_b, idx_b)  # (o, r)
    t = x @ a.T  # (m, r)
    return scale * (t @ b.T)


def lora_apply(x, a, b, scale=1.0) -> jnp.ndarray:
    """Vanilla LoRA path for the same shapes: a (r,h), b (o,r)."""
    return scale * ((x @ a.T) @ b.T)
