"""L1 Pallas kernels for Mixture of Shards (MoS).

Two kernels implement the paper's hot spot — index-routed shard gather/concat
and the fused routed low-rank product:

  * ``shard_gather``        pool (n, s) + idx (r, l)  ->  dense (r, l*s)
  * ``mos_apply_fused``     x (m, h), pools, indices  ->  y (m, o) = (x A^T) B^T
                            without ever materializing A or B in HBM.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the correctness path and the TPU
mapping is documented/estimated in DESIGN.md §Perf.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  - grid = (r, l): each cell copies / contracts one shard. The pool stays in
    HBM ("ANY"); BlockSpec streams one (1, s) shard tile into VMEM per cell.
  - shard width ``s`` should be a multiple of the 128-lane VPU width; the
    fused kernel's per-cell contraction (m, s) @ (s, 1) is MXU-friendly when
    m is padded to 8/128 sublane/lane tiles.
  - accumulation happens in a f32 VMEM scratch of shape (m, r) — double
    buffering of pool tiles comes free from the pallas pipeline since the
    index map only depends on the grid coordinates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# shard_gather: materialize a dense low-rank matrix from pool + index matrix.
# ---------------------------------------------------------------------------


def _gather_kernel(idx_ref, pool_ref, out_ref):
    """Grid cell (i, j): copy pool[idx[i, j]] into out[i, j*s:(j+1)*s]."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    shard = idx_ref[i, j]
    out_ref[0, :] = pool_ref[shard, :]


def shard_gather(pool: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Dense (r, l*s) matrix from pool (n, s) and idx (r, l); pallas kernel.

    Matches ``ref.materialize_a(pool, idx)``.
    """
    n, s = pool.shape
    r, l = idx.shape
    return pl.pallas_call(
        _gather_kernel,
        grid=(r, l),
        in_specs=[
            # Index matrix: small, fully resident.
            pl.BlockSpec(idx.shape, lambda i, j: (0, 0)),
            # Pool stays whole; the kernel picks the row dynamically.
            pl.BlockSpec(pool.shape, lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, l * s), pool.dtype),
        interpret=True,
    )(idx, pool)


# ---------------------------------------------------------------------------
# mos_apply_fused: y = (x @ A^T) @ B^T with A/B routed from pools on the fly.
# ---------------------------------------------------------------------------


def _apply_a_kernel(idx_ref, x_ref, pool_ref, t_ref):
    """Grid cell (i, j): t[:, i] += x[:, j*s:(j+1)*s] @ pool[idx[i, j]].

    Accumulates the routed contraction t = x @ A^T one shard at a time.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    shard = pool_ref[idx_ref[i, j], :]  # (s,)
    partial = x_ref[...] @ shard  # (m,)

    @pl.when(j == 0)
    def _init():
        t_ref[:, 0] = partial

    @pl.when(j != 0)
    def _acc():
        t_ref[:, 0] += partial


def _apply_b_kernel(idx_ref, t_ref, pool_ref, y_ref):
    """Grid cell (i, j): y[:, j*s:(j+1)*s] += t[:, i] * pool[idx[i, j]].

    Outer-product accumulation y = t @ B^T where column i of B is the concat
    of shards idx[i, :] (so B^T rows are shard-segmented).
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    shard = pool_ref[idx_ref[i, j], :]  # (s_b,)
    outer = t_ref[:, 0:1] * shard[None, :]  # (m, s_b)

    @pl.when(i == 0)
    def _init():
        y_ref[...] = outer

    @pl.when(i != 0)
    def _acc():
        y_ref[...] += outer


def mos_apply_fused(
    x: jnp.ndarray,
    pool_a: jnp.ndarray,
    idx_a: jnp.ndarray,
    pool_b: jnp.ndarray,
    idx_b: jnp.ndarray,
    scale: float = 1.0,
) -> jnp.ndarray:
    """Fused routed low-rank product; matches ``ref.mos_apply``.

    x: (m, h); pool_a: (n_a, h//l); idx_a/idx_b: (r, l); pool_b: (n_b, o//l).
    Returns (m, o). Neither A nor B is materialized in HBM.
    """
    m, h = x.shape
    n_a, s_a = pool_a.shape
    n_b, s_b = pool_b.shape
    r, l = idx_a.shape
    assert idx_b.shape == (r, l), (idx_b.shape, (r, l))
    assert l * s_a == h, (l, s_a, h)
    o = l * s_b

    # Stage 1: t = x @ A^T, grid over (rank, shard); x is streamed one
    # h-shard column block per cell, t accumulated per rank column.
    t = pl.pallas_call(
        _apply_a_kernel,
        grid=(r, l),
        in_specs=[
            pl.BlockSpec(idx_a.shape, lambda i, j: (0, 0)),
            pl.BlockSpec((m, s_a), lambda i, j: (0, j)),
            pl.BlockSpec(pool_a.shape, lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, 1), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, r), jnp.float32),
        interpret=True,
    )(idx_a, x.astype(jnp.float32), pool_a.astype(jnp.float32))

    # Stage 2: y = t @ B^T, grid over (rank, shard); y accumulated per
    # o-shard column block across ranks.
    y = pl.pallas_call(
        _apply_b_kernel,
        grid=(r, l),
        in_specs=[
            pl.BlockSpec(idx_b.shape, lambda i, j: (0, 0)),
            pl.BlockSpec((m, 1), lambda i, j: (0, i)),
            pl.BlockSpec(pool_b.shape, lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, s_b), lambda i, j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, o), jnp.float32),
        interpret=True,
    )(idx_b, t, pool_b.astype(jnp.float32))

    return (scale * y).astype(x.dtype)


# ---------------------------------------------------------------------------
# Tiled dense low-rank apply — used for the LoRA baseline inside the L2 model
# so both methods exercise a pallas path.
# ---------------------------------------------------------------------------


def _lowrank_kernel(x_ref, a_ref, b_ref, y_ref):
    t = x_ref[...] @ a_ref[...].T  # (m, r)
    y_ref[...] = t @ b_ref[...].T  # (m, o)


def lowrank_apply(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                  scale: float = 1.0) -> jnp.ndarray:
    """Dense y = scale * (x @ a^T) @ b^T as a single pallas kernel.

    x: (m, h), a: (r, h), b: (o, r) -> (m, o). Matches ``ref.lora_apply``.
    """
    m, h = x.shape
    r, _ = a.shape
    o, _ = b.shape
    y = pl.pallas_call(
        _lowrank_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
            pl.BlockSpec(a.shape, lambda i: (0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, o), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, o), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), a.astype(jnp.float32), b.astype(jnp.float32))
    return (scale * y).astype(x.dtype)
