"""AOT lowering: JAX model -> HLO text artifacts + manifest + weight banks.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` rust crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  manifest.json                      presets, artifact index, io specs
  bank_<preset>.bin                  frozen base weights + frozen aux tensors
  init_<preset>_<tag>.bin            adapter parameter initialization
  train_<tag>_<preset>.hlo.txt       (base,params,m,v,step,lr,data,aux)->(p,m,v,loss)
  fwd_<tag>_<preset>.hlo.txt         (base,params,aux,tokens)->(logits,)
  fwd_<tag>_<preset>_pallas.hlo.txt  forward with the L1 pallas gather inlined
  materialize_<preset>.hlo.txt       pallas shard-gather showcase kernel

Run: cd python && python -m compile.aot --out-dir ../artifacts [--presets tiny,small]
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile import pretrain
from compile.kernels import mos_kernels

jax.config.update("jax_platform_name", "cpu")

DT_F32, DT_I32 = 0, 1


# ---------------------------------------------------------------------------
# Weight-bank container (shared binary format with rust/src/util/bank.rs)
# ---------------------------------------------------------------------------


def write_bank(path: str, tensors: dict) -> None:
    """MOSBANK1: [magic][u32 n] then per tensor:
    [u16 name_len][name][u8 dtype][u8 ndim][u32 dims...][raw LE data]."""
    with open(path, "wb") as f:
        f.write(b"MOSBANK1")
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.asarray(arr)
            if arr.dtype == np.float32:
                dt = DT_F32
            elif arr.dtype == np.int32:
                dt = DT_I32
            else:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", dt, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype(arr.dtype).tobytes(order="C"))


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(
        shape, jnp.float32 if dtype == "f32" else jnp.int32
    )


def io_entry(name, shape, dtype, role):
    return {"name": name, "shape": list(shape), "dtype": dtype, "role": role}


def flat_train_io(cfg, mc):
    """Ordered input spec for the train_step artifact."""
    ins = []
    for name, shape in M.base_param_specs(cfg):
        ins.append(io_entry(name, shape, "f32", "base"))
    pspecs = M.adapter_param_specs(cfg, mc)
    for name, shape in pspecs:
        ins.append(io_entry(name, shape, "f32", "param"))
    for name, shape in pspecs:
        ins.append(io_entry(f"m.{name}", shape, "f32", "opt_m"))
    for name, shape in pspecs:
        ins.append(io_entry(f"v.{name}", shape, "f32", "opt_v"))
    ins.append(io_entry("step", (1,), "f32", "scalar"))
    ins.append(io_entry("lr", (1,), "f32", "scalar"))
    B, T = cfg.batch, cfg.seq
    ins.append(io_entry("tokens", (B, T), "i32", "data"))
    ins.append(io_entry("targets", (B, T), "i32", "data"))
    ins.append(io_entry("weight", (B, T), "f32", "data"))
    for name, shape, dt in M.aux_input_specs(cfg, mc):
        ins.append(io_entry(name, shape, dt, "aux"))
    outs = [io_entry(n, s, "f32", "param") for n, s in pspecs]
    outs += [io_entry(f"m.{n}", s, "f32", "opt_m") for n, s in pspecs]
    outs += [io_entry(f"v.{n}", s, "f32", "opt_v") for n, s in pspecs]
    outs.append(io_entry("loss", (1,), "f32", "loss"))
    return ins, outs


def flat_fwd_io(cfg, mc):
    ins = []
    for name, shape in M.base_param_specs(cfg):
        ins.append(io_entry(name, shape, "f32", "base"))
    for name, shape in M.adapter_param_specs(cfg, mc):
        ins.append(io_entry(name, shape, "f32", "param"))
    for name, shape, dt in M.aux_input_specs(cfg, mc):
        ins.append(io_entry(name, shape, dt, "aux"))
    B, T = cfg.batch, cfg.seq
    ins.append(io_entry("tokens", (B, T), "i32", "data"))
    outs = [io_entry("logits", (B, T, cfg.vocab), "f32", "logits")]
    return ins, outs


def build_train_fn(cfg, mc):
    pnames = [n for n, _ in M.adapter_param_specs(cfg, mc)]
    anames = [n for n, _, _ in M.aux_input_specs(cfg, mc)]
    bnames = [n for n, _ in M.base_param_specs(cfg)]

    def fn(*flat):
        it = iter(flat)
        base = {n: next(it) for n in bnames}
        params = {n: next(it) for n in pnames}
        m = {n: next(it) for n in pnames}
        v = {n: next(it) for n in pnames}
        step, lr = next(it), next(it)
        tokens, targets, weight = next(it), next(it), next(it)
        aux = {n: next(it) for n in anames}
        p2, m2, v2, loss = M.train_step(
            cfg, mc, base, params, m, v, step, lr, tokens, targets, weight, aux
        )
        out = [p2[n] for n in pnames] + [m2[n] for n in pnames]
        out += [v2[n] for n in pnames] + [loss]
        return tuple(out)

    return fn


def build_fwd_fn(cfg, mc, use_pallas=False):
    pnames = [n for n, _ in M.adapter_param_specs(cfg, mc)]
    anames = [n for n, _, _ in M.aux_input_specs(cfg, mc)]
    bnames = [n for n, _ in M.base_param_specs(cfg)]

    def fn(*flat):
        it = iter(flat)
        base = {n: next(it) for n in bnames}
        params = {n: next(it) for n in pnames}
        aux = {n: next(it) for n in anames}
        tokens = next(it)
        if use_pallas:
            # Route materialization through the L1 pallas shard-gather so the
            # kernel lowers into this HLO (correctness showcase; the fast
            # serving artifact uses the fused jnp.take path instead).
            orig = M._mos_materialize_stack

            def pallas_stack(pool, idx):
                L, r, l = idx.shape
                outs = [
                    mos_kernels.shard_gather(pool, idx[k]) for k in range(L)
                ]
                return jnp.stack(outs, axis=0)

            M._mos_materialize_stack = pallas_stack
            try:
                logits = M.forward(cfg, mc, base, params, aux, tokens)
            finally:
                M._mos_materialize_stack = orig
        else:
            logits = M.forward(cfg, mc, base, params, aux, tokens)
        return (logits,)

    return fn


# ---------------------------------------------------------------------------
# Artifact set
# ---------------------------------------------------------------------------


def method_cfgs(preset: str):
    """Adapter geometries lowered per preset (see DESIGN.md §3)."""
    mk = M.MethodCfg
    if preset == "tiny":
        return [
            mk("lora", r=2), mk("lora", r=8), mk("lora", r=16),
            # e=2 budget family: main MoS (r raised to 2e/4e, l=2), the
            # l-grid for Table 6, l=1 rows for pure-sharing/-vs, and the
            # subset-selection row (r4 of pool 8).
            mk("mos", r=4, l=2, e=2), mk("mos", r=8, l=2, e=2),
            mk("mos", r=8, l=1, e=2), mk("mos", r=4, l=1, e=2),
            mk("mos", r=8, l=4, e=2), mk("mos", r=8, l=8, e=2),
            mk("mos", r=8, l=16, e=2),
            # 4x budget family (paper's 16/32 rows)
            mk("mos", r=16, l=2, e=8),
            mk("vera", r=16), mk("tied", r=8),
            mk("prolora", r=8, m=4),
        ]
    if preset == "small":
        return [mk("lora", r=4), mk("mos", r=8, l=2, e=2)]
    if preset == "base":
        return [mk("mos", r=8, l=4, e=2)]
    raise ValueError(preset)


def gen_frozen_aux(cfg, mc, key):
    """Frozen aux tensors that live in the weight bank (vera matrices).

    MoS aux (indices, scales) is *runtime* state owned by the Rust router.
    """
    out = {}
    if mc.method == "vera":
        for t in M.LAYER_TYPES:
            o, i = cfg.dims(t)
            key, k1, k2 = jax.random.split(key, 3)
            out[f"{t}.frozen_a"] = jax.random.normal(k1, (mc.r, i)) * (
                i ** -0.5
            )
            out[f"{t}.frozen_b"] = jax.random.normal(k2, (o, mc.r)) * (
                mc.r ** -0.5
            )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--pretrain-steps", type=int, default=1200,
        help="full-param char-LM pretraining of the frozen base "
             "(0 disables; see compile/pretrain.py)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"presets": {}, "artifacts": []}
    for pname in args.presets.split(","):
        cfg = M.PRESETS[pname]
        manifest["presets"][pname] = {
            "vocab": cfg.vocab, "hidden": cfg.hidden, "blocks": cfg.blocks,
            "heads": cfg.heads, "ff": cfg.ff, "seq": cfg.seq,
            "batch": cfg.batch, "base_params": cfg.base_param_count(),
        }
        key = jax.random.PRNGKey(args.seed)
        key, bkey = jax.random.split(key)
        base = M.init_base(cfg, bkey)
        # scale the pretraining budget down for bigger presets (full-param
        # steps get expensive on CPU; the bank is built once)
        pt_scale = {"tiny": 1.0, "small": 0.33, "base": 0.08}.get(pname, 1.0)
        base = pretrain.pretrain_base(
            cfg, base, int(args.pretrain_steps * pt_scale), args.seed
        )
        bank = dict(base)

        for mc in method_cfgs(pname):
            tag = mc.tag()
            t0 = time.time()
            key, ikey, fkey = jax.random.split(key, 3)
            params = M.init_adapter(cfg, mc, ikey)
            write_bank(
                os.path.join(args.out_dir, f"init_{pname}_{tag}.bin"),
                {k: np.asarray(v) for k, v in params.items()},
            )
            bank.update(
                {k: np.asarray(v) for k, v in gen_frozen_aux(cfg, mc, fkey).items()}
            )

            # ---- train artifact
            ins, outs = flat_train_io(cfg, mc)
            in_specs = [spec(tuple(e["shape"]), e["dtype"]) for e in ins]
            lowered = jax.jit(build_train_fn(cfg, mc)).lower(*in_specs)
            fname = f"train_{tag}_{pname}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(to_hlo_text(lowered))
            manifest["artifacts"].append({
                "name": f"train_{tag}_{pname}", "file": fname,
                "kind": "train", "preset": pname, "method": mc.method,
                "r": mc.r, "l": mc.l, "e": mc.e, "m": mc.m,
                "alpha": mc.alpha, "inputs": ins, "outputs": outs,
            })

            # ---- forward artifact
            ins, outs = flat_fwd_io(cfg, mc)
            in_specs = [spec(tuple(e["shape"]), e["dtype"]) for e in ins]
            lowered = jax.jit(build_fwd_fn(cfg, mc)).lower(*in_specs)
            fname = f"fwd_{tag}_{pname}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(to_hlo_text(lowered))
            manifest["artifacts"].append({
                "name": f"fwd_{tag}_{pname}", "file": fname,
                "kind": "fwd", "preset": pname, "method": mc.method,
                "r": mc.r, "l": mc.l, "e": mc.e, "m": mc.m,
                "alpha": mc.alpha, "inputs": ins, "outputs": outs,
            })
            print(f"[aot] {pname}/{tag}: lowered train+fwd "
                  f"in {time.time()-t0:.1f}s", flush=True)

        # ---- pallas showcase artifacts (tiny only: interpret-mode pallas
        # is the correctness path; perf analysis is analytic, DESIGN.md §5)
        if pname == "tiny":
            mc = M.MethodCfg("mos", r=8, l=2, e=2)
            ins, outs = flat_fwd_io(cfg, mc)
            in_specs = [spec(tuple(e["shape"]), e["dtype"]) for e in ins]
            lowered = jax.jit(build_fwd_fn(cfg, mc, use_pallas=True)).lower(
                *in_specs
            )
            fname = f"fwd_{mc.tag()}_{pname}_pallas.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(to_hlo_text(lowered))
            manifest["artifacts"].append({
                "name": f"fwd_{mc.tag()}_{pname}_pallas", "file": fname,
                "kind": "fwd", "preset": pname, "method": mc.method,
                "r": mc.r, "l": mc.l, "e": mc.e, "m": mc.m,
                "alpha": mc.alpha, "inputs": ins, "outputs": outs,
            })

            n = mc.pool_shards(cfg)
            s = cfg.hidden // mc.l
            pool_s = spec((n, s))
            idx_s = spec((mc.r, mc.l), "i32")
            lowered = jax.jit(
                lambda p, i: (mos_kernels.shard_gather(p, i),)
            ).lower(pool_s, idx_s)
            fname = f"materialize_{pname}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(to_hlo_text(lowered))
            manifest["artifacts"].append({
                "name": f"materialize_{pname}", "file": fname,
                "kind": "materialize", "preset": pname, "method": "mos",
                "r": mc.r, "l": mc.l, "e": mc.e, "m": 1, "alpha": mc.alpha,
                "inputs": [io_entry("pool", (n, s), "f32", "param"),
                           io_entry("idx", (mc.r, mc.l), "i32", "aux")],
                "outputs": [io_entry("dense", (mc.r, cfg.hidden), "f32",
                                     "out")],
            })

        write_bank(os.path.join(args.out_dir, f"bank_{pname}.bin"), bank)
        print(f"[aot] {pname}: bank written ({len(bank)} tensors)", flush=True)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
