"""Base-model pretraining (build-time only).

The paper finetunes a *pretrained* LLaMA; adapters only steer an already
capable base. A random base breaks that premise — low-rank adapters then
have to learn everything through rank-r deltas and small ranks stall at the
uniform-loss floor. So `aot.py` pretrains each preset's base with a short
full-parameter char-LM phase on synthetic "format" text (copying, reversal,
key:value binding, small sums) before freezing it into the weight bank.
Content is randomized per sample, so no downstream task answer leaks; only
*formats and skills* (copy, bind, arithmetic surface forms) are taught —
the equivalent of generic instruction pretraining.

The charset below MUST match rust/src/data/tokenizer.rs (asserted in
python/tests/test_pretrain.py).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from compile import model as M

PAD, BOS, SEP, EOS = 0, 1, 2, 3
SPECIALS = 4
CHARSET = " abcdefghijklmnopqrstuvwxyz0123456789+-*/=:,.?()[]><#@!%&"
CHAR_TO_ID = {c: SPECIALS + i for i, c in enumerate(CHARSET)}


def encode(s: str) -> list:
    return [CHAR_TO_ID.get(c, CHAR_TO_ID["?"]) for c in s]


def render(prompt: str, completion: str, seq: int):
    """BOS prompt SEP completion EOS, PAD-filled; loss on completion+EOS.

    Mirrors rust Tokenizer::render (loss weight at positions predicting the
    completion and EOS)."""
    toks = [BOS] + encode(prompt) + [SEP]
    plen = len(toks)
    toks += encode(completion) + [EOS]
    if len(toks) > seq:
        return None
    weight = np.zeros(seq, np.float32)
    weight[plen - 1 : len(toks) - 1] = 1.0
    toks = toks + [PAD] * (seq - len(toks))
    return np.asarray(toks, np.int32), weight


# a fixed letter permutation for the pretraining key->value skill: values
# must *depend on the key* (so the base learns to attend to it) without
# leaking any downstream task's fact table (task tables are arbitrary).
_PERM = "qwertyuiopasdfghjklzxcvbnm"


def _permute(s: str) -> str:
    return "".join(_PERM[ord(c) - ord("a")] for c in s)


def sample_example(rng: np.random.Generator):
    """Format-teaching examples; completions are deterministic functions of
    the prompt (otherwise the base learns to ignore the prompt, which makes
    downstream adapter finetuning *harder* than on a random base)."""
    kind = rng.integers(0, 4)
    letters = "abcdefghijklmnopqrstuvwxyz"
    word = "".join(rng.choice(list(letters), rng.integers(3, 7)))
    if kind == 0:  # copy
        return word, word
    if kind == 1:  # reversal
        return f"rev:{word}", word[::-1]
    if kind == 2:  # key -> value binding via the fixed permutation
        key = word[:2]
        val = _permute(key) + _permute(key[:1])
        return f"q:{key}", val
    # small sums with the CoT-ish '#' marker
    a, b = int(rng.integers(1, 20)), int(rng.integers(1, 20))
    return f"{a}+{b}=", f"{a + b}#{a + b}"


def make_batch(rng, batch: int, seq: int):
    toks = np.zeros((batch, seq), np.int32)
    tgts = np.zeros((batch, seq), np.int32)
    wts = np.zeros((batch, seq), np.float32)
    i = 0
    while i < batch:
        p, c = sample_example(rng)
        r = render(p, c, seq)
        if r is None:
            continue
        t, w = r
        toks[i] = t
        tgts[i, :-1] = t[1:]
        wts[i] = w
        i += 1
    return jnp.asarray(toks), jnp.asarray(tgts), jnp.asarray(wts)


def pretrain_base(cfg: M.ModelCfg, base: dict, steps: int, seed: int,
                  lr: float = 3e-3, log_every: int = 200) -> dict:
    """Full-parameter AdamW pretraining of the base char-LM."""
    if steps == 0:
        return base
    rng = np.random.default_rng(seed)
    mc = M.MethodCfg("lora", r=1)  # adapters held at zero during pretraining

    zero_params = {
        n: jnp.zeros(s, jnp.float32)
        for n, s in M.adapter_param_specs(cfg, mc)
    }

    def loss_fn(base, toks, tgts, wts):
        return M.loss_fn(cfg, mc, base, zero_params, {}, toks, tgts, wts)

    @jax.jit
    def step_fn(base, m, v, step, toks, tgts, wts):
        loss, grads = jax.value_and_grad(loss_fn)(base, toks, tgts, wts)
        b1, b2, eps = 0.9, 0.999, 1e-8
        bc1 = 1.0 - b1 ** step
        bc2 = 1.0 - b2 ** step
        new_base, new_m, new_v = {}, {}, {}
        for k in base:
            g = grads[k]
            m2 = b1 * m[k] + (1 - b1) * g
            v2 = b2 * v[k] + (1 - b2) * g * g
            new_base[k] = base[k] - lr * (m2 / bc1) / (
                jnp.sqrt(v2 / bc2) + eps
            )
            new_m[k], new_v[k] = m2, v2
        return new_base, new_m, new_v, loss

    m = {k: jnp.zeros_like(x) for k, x in base.items()}
    v = {k: jnp.zeros_like(x) for k, x in base.items()}
    first = last = None
    for s in range(steps):
        toks, tgts, wts = make_batch(rng, cfg.batch, cfg.seq)
        base, m, v, loss = step_fn(base, m, v, jnp.float32(s + 1), toks,
                                   tgts, wts)
        if first is None:
            first = float(loss)
        last = float(loss)
        if log_every and (s % log_every == 0 or s + 1 == steps):
            print(f"[pretrain] step {s + 1}/{steps} loss {float(loss):.4f}",
                  flush=True)
    print(f"[pretrain] {cfg.name}: {first:.3f} -> {last:.3f} "
          f"({steps} steps)", flush=True)
    return {k: jnp.asarray(x) for k, x in base.items()}
