"""L2: JAX transformer LM with pluggable low-rank adaptation methods.

The model is a standard decoder-only transformer (RMSNorm, causal MHA, SwiGLU)
with the seven linear-layer types the paper adapts: q, k, v, o, gate, up, down.
Base weights are frozen inputs; each adaptation method contributes a
``materialize(params, aux) -> (A_stack, B_stack)`` that produces per-block
dense low-rank factors, after which a single method-agnostic scanned block
forward applies ``W0 x + (alpha/r) * B A x``.

Methods implemented (paper Sec. 2-4):
  lora     per-block trainable A (L,r,in), B (L,out,r)
  mos      trainable global shard pools per layer type + runtime index
           matrices (the router state, owned by the Rust coordinator) +
           frozen per-rank scales. Covers: pure sharing, random scaling,
           subset selection, MoS and all three ablations (-sp/-vs/-pd) purely
           through the *contents* of indices/scales/pool-partitioning.
  vera     frozen shared A/B + trainable scaling vectors d (L,r), b (L,out)
  tied     shared trainable A/B + per-block trainable scales u (L,r), v (L,out)
  prolora  per-block trainable chunks replicated m times with rotation

Everything is shape-static; ``aot.py`` lowers ``train_step`` and ``forward``
per (preset, method-geometry) to HLO text for the Rust runtime.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

LAYER_TYPES = ("q", "k", "v", "o", "gate", "up", "down")


@dataclass(frozen=True)
class ModelCfg:
    """Geometry of the base transformer."""

    name: str
    vocab: int
    hidden: int
    blocks: int
    heads: int
    ff: int
    seq: int
    batch: int

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def dims(self, layer_type: str) -> Tuple[int, int]:
        """(out_features, in_features) for a layer type."""
        h, f = self.hidden, self.ff
        return {
            "q": (h, h), "k": (h, h), "v": (h, h), "o": (h, h),
            "gate": (f, h), "up": (f, h), "down": (h, f),
        }[layer_type]

    def base_param_count(self) -> int:
        n = self.vocab * self.hidden  # tied embedding / lm head
        n += self.hidden  # final norm
        n += self.blocks * 2 * self.hidden  # per-block norms
        for t in LAYER_TYPES:
            o, i = self.dims(t)
            n += self.blocks * o * i
        return n


@dataclass(frozen=True)
class MethodCfg:
    """Adapter geometry. Interpretation of fields depends on ``method``.

    r       rank of each per-block low-rank matrix.
    l       shards per vector (mos only; 1 elsewhere).
    e       LoRA-equivalent budget rank: pools hold e*L vector-pairs' worth
            of parameters (mos), or the replication base (prolora: r/m == e).
    m       replication factor (prolora only).
    alpha   LoRA scaling numerator; effective scale = alpha / r.
    """

    method: str
    r: int
    l: int = 1
    e: int = 0
    m: int = 1
    alpha: float = 16.0

    def tag(self) -> str:
        bits = [self.method, f"r{self.r}"]
        if self.method == "mos":
            bits.append(f"l{self.l}")
            bits.append(f"e{self.e}")
        if self.method == "prolora":
            bits.append(f"m{self.m}")
        return "_".join(bits)

    def pool_shards(self, cfg: ModelCfg) -> int:
        """Number of shards per pool (mos): budget-matched to LoRA rank e.

        A LoRA of rank e over L blocks spends e*L*(in+out) params per layer
        type; a pool of n shards of width in/l (A side) spends n*in/l, so
        n = e*L*l reproduces the budget exactly on each side.
        """
        return self.e * cfg.blocks * self.l


# ---------------------------------------------------------------------------
# Parameter construction / specs
# ---------------------------------------------------------------------------


def adapter_param_specs(cfg: ModelCfg, mc: MethodCfg) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list of *trainable* adapter tensors."""
    specs: List[Tuple[str, Tuple[int, ...]]] = []
    L, r = cfg.blocks, mc.r
    for t in LAYER_TYPES:
        o, i = cfg.dims(t)
        if mc.method == "lora":
            specs.append((f"{t}.a", (L, r, i)))
            specs.append((f"{t}.b", (L, o, r)))
        elif mc.method == "mos":
            n = mc.pool_shards(cfg)
            assert i % mc.l == 0 and o % mc.l == 0, (t, i, o, mc.l)
            specs.append((f"{t}.pool_a", (n, i // mc.l)))
            specs.append((f"{t}.pool_b", (n, o // mc.l)))
        elif mc.method == "vera":
            specs.append((f"{t}.d", (L, r)))
            specs.append((f"{t}.bvec", (L, o)))
        elif mc.method == "tied":
            specs.append((f"{t}.a", (r, i)))
            specs.append((f"{t}.b", (o, r)))
            specs.append((f"{t}.u", (L, r)))
            specs.append((f"{t}.v", (L, o)))
        elif mc.method == "prolora":
            assert i % mc.m == 0 and o % mc.m == 0, (t, i, o, mc.m)
            specs.append((f"{t}.a0", (L, r, i // mc.m)))
            specs.append((f"{t}.b0", (L, o // mc.m, r)))
        else:
            raise ValueError(mc.method)
    return specs


def aux_input_specs(cfg: ModelCfg, mc: MethodCfg) -> List[Tuple[str, Tuple[int, ...], str]]:
    """Ordered (name, shape, dtype) list of non-trainable runtime inputs.

    For mos these are the router state (index matrices) and frozen per-rank
    scales; for vera the frozen shared matrices.
    """
    specs: List[Tuple[str, Tuple[int, ...], str]] = []
    L, r = cfg.blocks, mc.r
    for t in LAYER_TYPES:
        o, i = cfg.dims(t)
        if mc.method == "mos":
            specs.append((f"{t}.idx_a", (L, r, mc.l), "i32"))
            specs.append((f"{t}.idx_b", (L, r, mc.l), "i32"))
            specs.append((f"{t}.rank_scale", (L, r), "f32"))
        elif mc.method == "vera":
            specs.append((f"{t}.frozen_a", (r, i), "f32"))
            specs.append((f"{t}.frozen_b", (o, r), "f32"))
    return specs


def base_param_specs(cfg: ModelCfg) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list of frozen base-model tensors."""
    specs = [("embed", (cfg.vocab, cfg.hidden))]
    for t in LAYER_TYPES:
        o, i = cfg.dims(t)
        specs.append((f"w.{t}", (cfg.blocks, o, i)))
    specs.append(("norm_attn", (cfg.blocks, cfg.hidden)))
    specs.append(("norm_mlp", (cfg.blocks, cfg.hidden)))
    specs.append(("norm_final", (cfg.hidden,)))
    return specs


def init_base(cfg: ModelCfg, key) -> Dict[str, jnp.ndarray]:
    """Random frozen base model (stand-in for a pretrained LLM)."""
    out = {}
    for name, shape in base_param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.startswith("norm"):
            out[name] = jnp.ones(shape, jnp.float32)
        elif name == "embed":
            # std 0.1 so token identity is not drowned by the positional
            # encoding (added at 0.1 scale in forward)
            out[name] = jax.random.normal(sub, shape, jnp.float32) * 0.1
        else:
            fan_in = shape[-1]
            out[name] = jax.random.normal(sub, shape, jnp.float32) * (
                fan_in ** -0.5
            )
    return out


def init_adapter(cfg: ModelCfg, mc: MethodCfg, key) -> Dict[str, jnp.ndarray]:
    """Trainable adapter init following the paper (Sec. 3.5 Initialization).

    B-side tensors start at zero (delta == 0 at step 0); A-side tensors use
    Kaiming-uniform bounds matched to the *materialized* fan-in, as PRoLoRA
    does for replicated chunks and MoS does for pools.
    """
    out = {}
    for name, shape in adapter_param_specs(cfg, mc):
        key, sub = jax.random.split(key)
        t = name.split(".")[0]
        o, i = cfg.dims(t)
        kind = name.split(".")[1]
        if kind in ("b", "b0", "pool_b", "bvec"):
            out[name] = jnp.zeros(shape, jnp.float32)
        elif kind in ("d", "u"):
            out[name] = jnp.full(shape, 0.1, jnp.float32)
        elif kind == "v":
            # ones, not zeros: with B == 0 the delta is still zero at init,
            # but a zero v would also zero B's gradient (a dead saddle).
            out[name] = jnp.ones(shape, jnp.float32)
        else:  # a-side: uniform(-bound, bound) with materialized fan-in i
            bound = (1.0 / i) ** 0.5
            out[name] = jax.random.uniform(
                sub, shape, jnp.float32, -bound, bound
            )
    return out


# ---------------------------------------------------------------------------
# Materialization: params (+aux) -> per-block dense (A_stack, B_stack)
# ---------------------------------------------------------------------------


def _mos_materialize_stack(pool, idx):
    """pool (n,s), idx (L,r,l) -> (L, r, l*s) via gather+concat (rows)."""
    L, r, l = idx.shape
    g = jnp.take(pool, idx.reshape(-1), axis=0)  # (L*r*l, s)
    return g.reshape(L, r, l * pool.shape[1])


def materialize(cfg: ModelCfg, mc: MethodCfg, params: Dict, aux: Dict):
    """Returns dict t -> (A_stack (L,r,in), B_stack (L,out,r)).

    The per-rank scale (mos random-scaling / subset masks) is folded into the
    A side so the scanned block stays method-agnostic.
    """
    stacks = {}
    L = cfg.blocks
    for t in LAYER_TYPES:
        o, i = cfg.dims(t)
        if mc.method == "lora":
            a, b = params[f"{t}.a"], params[f"{t}.b"]
        elif mc.method == "mos":
            a = _mos_materialize_stack(params[f"{t}.pool_a"], aux[f"{t}.idx_a"])
            bt = _mos_materialize_stack(params[f"{t}.pool_b"], aux[f"{t}.idx_b"])
            b = jnp.swapaxes(bt, 1, 2)  # (L, o, r)
            a = a * aux[f"{t}.rank_scale"][:, :, None]
        elif mc.method == "vera":
            a = aux[f"{t}.frozen_a"][None] * params[f"{t}.d"][:, :, None]
            b = aux[f"{t}.frozen_b"][None] * params[f"{t}.bvec"][:, :, None]
        elif mc.method == "tied":
            a = params[f"{t}.a"][None] * params[f"{t}.u"][:, :, None]
            b = params[f"{t}.b"][None] * params[f"{t}.v"][:, :, None]
        elif mc.method == "prolora":
            a = _prolora_replicate_a(params[f"{t}.a0"], mc.m)
            b = _prolora_replicate_b(params[f"{t}.b0"], mc.m)
        else:
            raise ValueError(mc.method)
        stacks[t] = (a, b)
    return stacks


def _prolora_replicate_a(a0, m):
    """a0 (L, r, i/m) -> (L, r, i): m chunks, chunk j rotated j along rank.

    This reproduces PRoLoRA's replication + partial-rotation differentiation:
    identical chunks would collapse the effective rank, rotation restores it.
    """
    chunks = [jnp.roll(a0, shift=j, axis=1) for j in range(m)]
    return jnp.concatenate(chunks, axis=2)


def _prolora_replicate_b(b0, m):
    """b0 (L, o/m, r) -> (L, o, r) with rotation along rank axis."""
    chunks = [jnp.roll(b0, shift=j, axis=2) for j in range(m)]
    return jnp.concatenate(chunks, axis=1)


# ---------------------------------------------------------------------------
# Transformer forward
# ---------------------------------------------------------------------------


def _rmsnorm(x, g, eps=1e-6):
    return g * x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _adapted(x, w, ab, scale):
    """x (B,T,i) @ (w + scale * B A)^T without forming the dense delta."""
    a, b = ab  # (r, i), (o, r)
    y = jnp.einsum("bti,oi->bto", x, w)
    t = jnp.einsum("bti,ri->btr", x, a)
    return y + scale * jnp.einsum("btr,or->bto", t, b)


def forward(cfg: ModelCfg, mc: MethodCfg, base: Dict, params: Dict,
            aux: Dict, tokens: jnp.ndarray) -> jnp.ndarray:
    """Full forward pass: tokens (B,T) int32 -> logits (B,T,V)."""
    stacks = materialize(cfg, mc, params, aux)
    scale = mc.alpha / mc.r
    B, T = tokens.shape
    H, D = cfg.heads, cfg.head_dim

    x = jnp.take(base["embed"], tokens, axis=0)  # (B,T,h)
    # Rotary-free learned-position-free: use causal mask + depth; positions
    # come from a fixed sinusoidal bias added to the embedding.
    # positions at 0.1 scale: comparable to the 0.1-std token embeddings
    # (unit-scale sinusoids would drown token identity at this width)
    pos = _sinusoid(T, cfg.hidden) * 0.1
    x = x + pos[None]
    mask = jnp.tril(jnp.ones((T, T), jnp.float32))
    neg = jnp.float32(-1e9)

    per_block = {
        "wq": base["w.q"], "wk": base["w.k"], "wv": base["w.v"],
        "wo": base["w.o"], "wg": base["w.gate"], "wu": base["w.up"],
        "wd": base["w.down"],
        "na": base["norm_attn"], "nm": base["norm_mlp"],
    }
    for t in LAYER_TYPES:
        per_block[f"a.{t}"] = stacks[t][0]
        per_block[f"b.{t}"] = stacks[t][1]

    def block(x, p):
        hN = _rmsnorm(x, p["na"])
        q = _adapted(hN, p["wq"], (p["a.q"], p["b.q"]), scale)
        k = _adapted(hN, p["wk"], (p["a.k"], p["b.k"]), scale)
        v = _adapted(hN, p["wv"], (p["a.v"], p["b.v"]), scale)
        q = q.reshape(B, T, H, D).transpose(0, 2, 1, 3)
        k = k.reshape(B, T, H, D).transpose(0, 2, 1, 3)
        v = v.reshape(B, T, H, D).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhtd,bhsd->bhts", q, k) * (D ** -0.5)
        att = jnp.where(mask[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhts,bhsd->bhtd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(B, T, cfg.hidden)
        x = x + _adapted(ctx, p["wo"], (p["a.o"], p["b.o"]), scale)

        hN = _rmsnorm(x, p["nm"])
        g = _adapted(hN, p["wg"], (p["a.gate"], p["b.gate"]), scale)
        u = _adapted(hN, p["wu"], (p["a.up"], p["b.up"]), scale)
        f = jax.nn.silu(g) * u
        x = x + _adapted(f, p["wd"], (p["a.down"], p["b.down"]), scale)
        return x, ()

    x, _ = lax.scan(block, x, per_block)
    x = _rmsnorm(x, base["norm_final"])
    return jnp.einsum("bth,vh->btv", x, base["embed"])


@functools.lru_cache(maxsize=8)
def _sinusoid_cached(T, h):
    import numpy as np

    pos = np.arange(T)[:, None]
    dim = np.arange(h)[None, :]
    angle = pos / np.power(10000.0, (2 * (dim // 2)) / h)
    enc = np.where(dim % 2 == 0, np.sin(angle), np.cos(angle))
    return enc.astype("float32")


def _sinusoid(T, h):
    return jnp.asarray(_sinusoid_cached(T, h))


# ---------------------------------------------------------------------------
# Loss / train step (AdamW inside the artifact)
# ---------------------------------------------------------------------------


def loss_fn(cfg, mc, base, params, aux, tokens, targets, weight):
    """Masked next-token cross entropy. weight (B,T) zeroes out prompt/pad."""
    logits = forward(cfg, mc, base, params, aux, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(weight), 1.0)
    return -jnp.sum(tgt * weight) / denom


ADAM_B1, ADAM_B2, ADAM_EPS, WEIGHT_DECAY = 0.9, 0.999, 1e-8, 0.0


def train_step(cfg, mc, base, params, m, v, step, lr,
               tokens, targets, weight, aux):
    """One AdamW step on the adapter params; everything else is frozen.

    step: f32 (1,) 1-based step index; lr: f32 (1,).
    Returns (new_params, new_m, new_v, loss(1,)).
    """
    step = step.reshape(())
    lr = lr.reshape(())
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, mc, base, p, aux, tokens, targets, weight)
    )(params)
    new_p, new_m, new_v = {}, {}, {}
    bc1 = 1.0 - ADAM_B1 ** step
    bc2 = 1.0 - ADAM_B2 ** step
    for k in params:
        g = grads[k]
        m2 = ADAM_B1 * m[k] + (1 - ADAM_B1) * g
        v2 = ADAM_B2 * v[k] + (1 - ADAM_B2) * g * g
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + ADAM_EPS)
        new_p[k] = params[k] - lr * (upd + WEIGHT_DECAY * params[k])
        new_m[k], new_v[k] = m2, v2
    return new_p, new_m, new_v, loss.reshape(1)


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

PRESETS = {
    # bench workhorse: fast enough for table sweeps on 1 CPU core
    "tiny": ModelCfg("tiny", vocab=64, hidden=64, blocks=4, heads=4,
                     ff=160, seq=48, batch=16),
    # example scale
    "small": ModelCfg("small", vocab=96, hidden=256, blocks=8, heads=8,
                      ff=688, seq=96, batch=8),
    # ~100M-parameter end-to-end driver (examples/train_e2e.rs)
    "base": ModelCfg("base", vocab=2048, hidden=768, blocks=14, heads=12,
                     ff=2048, seq=64, batch=4),
}
