"""Pretraining phase: charset parity with the Rust tokenizer, render-mask
semantics, and that the char-LM loss actually decreases."""

import numpy as np
import pytest

from compile import model as M
from compile import pretrain


RUST_CHARSET = " abcdefghijklmnopqrstuvwxyz0123456789+-*/=:,.?()[]><#@!%&"


def test_charset_matches_rust_tokenizer():
    # must stay byte-identical to rust/src/data/tokenizer.rs::CHARSET
    assert pretrain.CHARSET == RUST_CHARSET
    assert (pretrain.PAD, pretrain.BOS, pretrain.SEP, pretrain.EOS) == (
        0, 1, 2, 3,
    )


def test_render_mask_matches_rust_semantics():
    toks, w = pretrain.render("q", "ans", 12)
    # BOS q SEP a n s EOS PAD...
    assert toks[0] == pretrain.BOS
    assert toks[2] == pretrain.SEP
    assert toks[6] == pretrain.EOS
    assert toks[7] == pretrain.PAD
    np.testing.assert_array_equal(
        w[:8], [0.0, 0.0, 1.0, 1.0, 1.0, 1.0, 0.0, 0.0]
    )
    assert pretrain.render("aaaaaaa", "bbbbbbb", 10) is None


def test_examples_fit_tiny_vocab():
    rng = np.random.default_rng(0)
    for _ in range(100):
        p, c = pretrain.sample_example(rng)
        for ch in p + c:
            assert ch in pretrain.CHAR_TO_ID, f"char {ch!r} not in charset"
        assert max(pretrain.encode(p + c)) < 64


def test_pretraining_reduces_loss():
    import jax

    cfg = M.ModelCfg("pt", vocab=64, hidden=32, blocks=2, heads=2, ff=48,
                     seq=32, batch=8)
    base = M.init_base(cfg, jax.random.PRNGKey(0))
    # measure loss before/after a short pretraining run
    rng = np.random.default_rng(1)
    toks, tgts, wts = pretrain.make_batch(rng, cfg.batch, cfg.seq)
    mc = M.MethodCfg("lora", r=1)
    zero = {n: np.zeros(s, np.float32)
            for n, s in M.adapter_param_specs(cfg, mc)}
    before = float(M.loss_fn(cfg, mc, base, zero, {}, toks, tgts, wts))
    base2 = pretrain.pretrain_base(cfg, base, steps=60, seed=0, log_every=0)
    after = float(M.loss_fn(cfg, mc, base2, zero, {}, toks, tgts, wts))
    assert after < before - 0.3, (before, after)
