"""L2 correctness: model shapes, materialization semantics per method,
training-step behaviour (loss decreases, frozen things stay frozen), and the
reductions between methods the paper's framing implies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelCfg("test", vocab=32, hidden=16, blocks=3, heads=2, ff=24,
                 seq=12, batch=4)


def setup(mc, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    base = M.init_base(CFG, k1)
    params = M.init_adapter(CFG, mc, k2)
    aux = make_aux(mc, k3)
    return base, params, aux


def make_aux(mc, key):
    aux = {}
    L, r = CFG.blocks, mc.r
    for t in M.LAYER_TYPES:
        o, i = CFG.dims(t)
        if mc.method == "mos":
            n = mc.pool_shards(CFG)
            key, ka, kb = jax.random.split(key, 3)
            aux[f"{t}.idx_a"] = jax.random.randint(
                ka, (L, r, mc.l), 0, n, jnp.int32
            )
            aux[f"{t}.idx_b"] = jax.random.randint(
                kb, (L, r, mc.l), 0, n, jnp.int32
            )
            aux[f"{t}.rank_scale"] = jnp.ones((L, r), jnp.float32)
        elif mc.method == "vera":
            key, ka, kb = jax.random.split(key, 3)
            aux[f"{t}.frozen_a"] = jax.random.normal(ka, (r, i)) * i ** -0.5
            aux[f"{t}.frozen_b"] = jax.random.normal(kb, (o, r)) * r ** -0.5
    return aux


def batch(seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(
        rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq)), jnp.int32
    )
    targets = jnp.roll(tokens, -1, axis=1)
    weight = jnp.ones((CFG.batch, CFG.seq), jnp.float32)
    return tokens, targets, weight


METHODS = [
    M.MethodCfg("lora", r=2),
    M.MethodCfg("mos", r=4, l=2, e=2),
    M.MethodCfg("vera", r=4),
    M.MethodCfg("tied", r=2),
    M.MethodCfg("prolora", r=4, m=2),
]


@pytest.mark.parametrize("mc", METHODS, ids=lambda m: m.method)
class TestForward:
    def test_logit_shape(self, mc):
        base, params, aux = setup(mc)
        tokens, _, _ = batch()
        logits = M.forward(CFG, mc, base, params, aux, tokens)
        assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_zero_init_matches_base(self, mc):
        """Paper Sec 3.5: B-side zero init => adapted model == base model."""
        base, params, aux = setup(mc)
        tokens, _, _ = batch()
        adapted = M.forward(CFG, mc, base, params, aux, tokens)
        zero = {k: jnp.zeros_like(v) for k, v in params.items()}
        base_out = M.forward(CFG, mc, base, zero, aux, tokens)
        np.testing.assert_allclose(adapted, base_out, rtol=1e-5, atol=1e-5)

    def test_causality(self, mc):
        """Changing a future token must not change past logits."""
        base, params, aux = setup(mc)
        # make the delta nonzero so adapters are actually on the path
        params = {
            k: (jnp.ones_like(v) * 0.05 if v.ndim else v)
            for k, v in params.items()
        }
        tokens, _, _ = batch()
        logits1 = M.forward(CFG, mc, base, params, aux, tokens)
        toks2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % CFG.vocab)
        logits2 = M.forward(CFG, mc, base, params, aux, toks2)
        np.testing.assert_allclose(
            logits1[:, :-1], logits2[:, :-1], rtol=1e-5, atol=1e-5
        )

    def test_loss_decreases(self, mc):
        base, params, aux = setup(mc)
        tokens, targets, weight = batch()
        m = {k: jnp.zeros_like(v) for k, v in params.items()}
        v = {k: jnp.zeros_like(v2) for k, v2 in params.items()}
        losses = []
        step_fn = jax.jit(
            lambda p, m, v, s: M.train_step(
                CFG, mc, base, p, m, v, s, jnp.asarray([2e-2]),
                tokens, targets, weight, aux,
            )
        )
        for i in range(25):
            params, m, v, loss = step_fn(params, m, v, jnp.asarray([i + 1.0]))
            losses.append(float(loss[0]))
        assert losses[-1] < losses[0] - 0.05, losses


class TestMaterialization:
    def test_mos_matches_ref_oracle(self):
        mc = M.MethodCfg("mos", r=4, l=2, e=2)
        base, params, aux = setup(mc)
        stacks = M.materialize(CFG, mc, params, aux)
        for t in M.LAYER_TYPES:
            a, b = stacks[t]
            for k in range(CFG.blocks):
                np.testing.assert_allclose(
                    a[k],
                    ref.materialize_a(
                        params[f"{t}.pool_a"], aux[f"{t}.idx_a"][k]
                    ),
                    rtol=1e-6,
                )
                np.testing.assert_allclose(
                    b[k],
                    ref.materialize_b(
                        params[f"{t}.pool_b"], aux[f"{t}.idx_b"][k]
                    ),
                    rtol=1e-6,
                )

    def test_rank_scale_folds_into_a(self):
        mc = M.MethodCfg("mos", r=4, l=2, e=2)
        base, params, aux = setup(mc)
        aux2 = dict(aux)
        for t in M.LAYER_TYPES:
            aux2[f"{t}.rank_scale"] = aux[f"{t}.rank_scale"] * 0.5
        s1 = M.materialize(CFG, mc, params, aux)
        s2 = M.materialize(CFG, mc, params, aux2)
        for t in M.LAYER_TYPES:
            np.testing.assert_allclose(s2[t][0], 0.5 * s1[t][0], rtol=1e-6)
            np.testing.assert_allclose(s2[t][1], s1[t][1], rtol=1e-6)

    def test_subset_selection_masks_rows(self):
        """rank_scale of 0 disables a rank — the boolean m_i of Eq. (3)."""
        mc = M.MethodCfg("mos", r=4, l=2, e=2)
        base, params, aux = setup(mc)
        tokens, _, _ = batch()
        # random pools so deltas are nonzero
        params = {k: jnp.asarray(np.random.default_rng(0).standard_normal(
            v.shape), jnp.float32) * 0.1 for k, v in params.items()}
        aux_off = dict(aux)
        for t in M.LAYER_TYPES:
            aux_off[f"{t}.rank_scale"] = jnp.zeros((CFG.blocks, mc.r))
        adapted = M.forward(CFG, mc, base, params, aux_off, tokens)
        zerop = {k: jnp.zeros_like(v) for k, v in params.items()}
        base_out = M.forward(CFG, mc, base, zerop, aux, tokens)
        np.testing.assert_allclose(adapted, base_out, rtol=1e-5, atol=1e-5)

    def test_vera_scaling_vectors(self):
        mc = M.MethodCfg("vera", r=4)
        base, params, aux = setup(mc)
        stacks = M.materialize(CFG, mc, params, aux)
        t = "q"
        a, b = stacks[t]
        k = 1
        want_a = aux[f"{t}.frozen_a"] * params[f"{t}.d"][k][:, None]
        np.testing.assert_allclose(a[k], want_a, rtol=1e-6)
        want_b = aux[f"{t}.frozen_b"] * params[f"{t}.bvec"][k][:, None]
        np.testing.assert_allclose(b[k], want_b, rtol=1e-6)

    def test_tied_shares_matrices_across_blocks(self):
        mc = M.MethodCfg("tied", r=2)
        base, params, aux = setup(mc)
        params = {k: jnp.abs(v) + 0.1 for k, v in params.items()}
        stacks = M.materialize(CFG, mc, params, aux)
        a, _ = stacks["q"]
        # rows of A differ across blocks only by the per-block scale u
        ratio01 = a[0] / a[1]
        expected = (params["q.u"][0] / params["q.u"][1])[:, None]
        np.testing.assert_allclose(
            ratio01, jnp.broadcast_to(expected, ratio01.shape), rtol=1e-5
        )

    def test_prolora_replication_structure(self):
        mc = M.MethodCfg("prolora", r=4, m=2)
        base, params, aux = setup(mc)
        stacks = M.materialize(CFG, mc, params, aux)
        a, b = stacks["q"]
        o, i = CFG.dims("q")
        assert a.shape == (CFG.blocks, mc.r, i)
        assert b.shape == (CFG.blocks, o, mc.r)
        half = i // 2
        # chunk 1 is chunk 0 rotated by 1 along the rank axis
        np.testing.assert_allclose(
            a[:, :, half:], jnp.roll(a[:, :, :half], 1, axis=1), rtol=1e-6
        )

    def test_mos_pure_sharing_identity_routing(self):
        """idx = arange, r = pool size, l=1: every block gets the same
        matrices — the paper's 'pure sharing' scheme."""
        mc = M.MethodCfg("mos", r=6, l=1, e=2)
        base, params, _ = setup(mc)
        n = mc.pool_shards(CFG)
        assert n == 6
        idx = jnp.broadcast_to(
            jnp.arange(n, dtype=jnp.int32)[None, :, None],
            (CFG.blocks, n, 1),
        )
        aux = {}
        for t in M.LAYER_TYPES:
            aux[f"{t}.idx_a"] = idx
            aux[f"{t}.idx_b"] = idx
            aux[f"{t}.rank_scale"] = jnp.ones((CFG.blocks, n))
        stacks = M.materialize(CFG, mc, params, aux)
        for t in M.LAYER_TYPES:
            a, b = stacks[t]
            for k in range(1, CFG.blocks):
                np.testing.assert_array_equal(a[0], a[k])
                np.testing.assert_array_equal(b[0], b[k])


class TestParamBudgets:
    def test_mos_pool_budget_matches_lora(self):
        """Pool param count == LoRA-rank-e param count, per layer type."""
        for l in (1, 2, 4):
            mc = M.MethodCfg("mos", r=8, l=l, e=2)
            n = mc.pool_shards(CFG)
            for t in M.LAYER_TYPES:
                o, i = CFG.dims(t)
                pool = n * (i // l) + n * (o // l)
                lora = CFG.blocks * mc.e * (i + o)
                assert pool == lora, (t, l)

    def test_adapter_param_counts_ordering(self):
        """VeRA < MoS(e=2) ≈ LoRA(r=2) < LoRA(r=8); tied < lora."""

        def count(mc):
            return sum(
                int(np.prod(s)) for _, s in M.adapter_param_specs(CFG, mc)
            )

        lora2 = count(M.MethodCfg("lora", r=2))
        mos2 = count(M.MethodCfg("mos", r=8, l=2, e=2))
        assert mos2 == lora2
        assert count(M.MethodCfg("vera", r=4)) < lora2
        assert count(M.MethodCfg("tied", r=2)) < lora2
        assert count(M.MethodCfg("lora", r=8)) == 4 * lora2
        assert count(M.MethodCfg("prolora", r=4, m=2)) == lora2


class TestTrainStep:
    def test_mos_grads_touch_only_routed_shards(self):
        """A pool shard never referenced by any index matrix must not move."""
        mc = M.MethodCfg("mos", r=2, l=1, e=2)
        base, params, aux = setup(mc)
        # nonzero pools: with B == 0 the A-side grad would be zero at step 1
        rng = np.random.default_rng(0)
        params = {
            k: jnp.asarray(rng.standard_normal(v.shape) * 0.05, jnp.float32)
            for k, v in params.items()
        }
        # route everything to shard 0 (A side) / shard 1 (B side) only
        for t in M.LAYER_TYPES:
            aux[f"{t}.idx_a"] = jnp.zeros((CFG.blocks, 2, 1), jnp.int32)
            aux[f"{t}.idx_b"] = jnp.ones((CFG.blocks, 2, 1), jnp.int32)
        tokens, targets, weight = batch()
        m = {k: jnp.zeros_like(v) for k, v in params.items()}
        v = {k: jnp.zeros_like(x) for k, x in params.items()}
        p2, _, _, _ = M.train_step(
            CFG, mc, base, params, m, v, jnp.asarray([1.0]),
            jnp.asarray([1e-2]), tokens, targets, weight, aux,
        )
        for t in M.LAYER_TYPES:
            pa, pa2 = params[f"{t}.pool_a"], p2[f"{t}.pool_a"]
            np.testing.assert_array_equal(pa[1:], pa2[1:])  # untouched rows
            assert not np.allclose(pa[0], pa2[0])  # routed row moved
            pb, pb2 = params[f"{t}.pool_b"], p2[f"{t}.pool_b"]
            np.testing.assert_array_equal(pb[2:], pb2[2:])
            np.testing.assert_array_equal(pb[0], pb2[0])
            assert not np.allclose(pb[1], pb2[1])

    def test_weight_mask_excludes_prompt(self):
        mc = M.MethodCfg("lora", r=2)
        base, params, aux = setup(mc)
        tokens, targets, _ = batch()
        w_all = jnp.ones((CFG.batch, CFG.seq))
        w_none = jnp.zeros((CFG.batch, CFG.seq))
        l_all = M.loss_fn(CFG, mc, base, params, aux, tokens, targets, w_all)
        l_none = M.loss_fn(CFG, mc, base, params, aux, tokens, targets,
                           w_none)
        assert float(l_none) == 0.0
        assert float(l_all) > 0.0
