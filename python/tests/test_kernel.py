"""L1 correctness: pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps the shard geometry (rank, shards-per-vector, shard widths,
pool sizes, batch) and dtypes; every case asserts allclose against ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mos_kernels, ref

jax.config.update("jax_platform_name", "cpu")


def make_case(seed, m, r, l, s_a, s_b, n_a, n_b, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, l * s_a)), dtype=dtype)
    pool_a = jnp.asarray(rng.standard_normal((n_a, s_a)) * 0.3, dtype=dtype)
    pool_b = jnp.asarray(rng.standard_normal((n_b, s_b)) * 0.3, dtype=dtype)
    idx_a = jnp.asarray(rng.integers(0, n_a, size=(r, l)), dtype=jnp.int32)
    idx_b = jnp.asarray(rng.integers(0, n_b, size=(r, l)), dtype=jnp.int32)
    return x, pool_a, idx_a, pool_b, idx_b


geometry = st.tuples(
    st.integers(0, 2**31 - 1),  # seed
    st.integers(1, 6),          # m
    st.integers(1, 8),          # r
    st.integers(1, 4),          # l
    st.sampled_from([1, 2, 3, 8]),   # s_a
    st.sampled_from([1, 2, 5, 8]),   # s_b
    st.integers(1, 24),         # n_a
    st.integers(1, 24),         # n_b
)


class TestShardGather:
    @settings(max_examples=40, deadline=None)
    @given(geometry)
    def test_matches_ref(self, geo):
        seed, m, r, l, s_a, s_b, n_a, n_b = geo
        _, pool_a, idx_a, _, _ = make_case(seed, m, r, l, s_a, s_b, n_a, n_b)
        got = mos_kernels.shard_gather(pool_a, idx_a)
        want = ref.materialize_a(pool_a, idx_a)
        np.testing.assert_allclose(got, want, rtol=0, atol=0)

    def test_exact_rows(self):
        pool = jnp.arange(12.0).reshape(6, 2)
        idx = jnp.asarray([[0, 5], [3, 3]], dtype=jnp.int32)
        out = mos_kernels.shard_gather(pool, idx)
        np.testing.assert_array_equal(
            np.asarray(out), [[0.0, 1.0, 10.0, 11.0], [6.0, 7.0, 6.0, 7.0]]
        )

    def test_b_materialization_is_transpose_of_gather(self):
        _, pool, idx, _, _ = make_case(7, 1, 4, 2, 3, 3, 9, 9)
        np.testing.assert_allclose(
            np.asarray(ref.materialize_b(pool, idx)),
            np.asarray(mos_kernels.shard_gather(pool, idx)).T,
        )

    def test_bf16_dtype_preserved(self):
        _, pool, idx, _, _ = make_case(1, 1, 3, 2, 8, 8, 16, 16)
        pool = pool.astype(jnp.bfloat16)
        out = mos_kernels.shard_gather(pool, idx)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(ref.materialize_a(pool, idx), np.float32),
        )


class TestMosApplyFused:
    @settings(max_examples=30, deadline=None)
    @given(geometry)
    def test_matches_ref(self, geo):
        seed, m, r, l, s_a, s_b, n_a, n_b = geo
        x, pool_a, idx_a, pool_b, idx_b = make_case(
            seed, m, r, l, s_a, s_b, n_a, n_b
        )
        got = mos_kernels.mos_apply_fused(x, pool_a, idx_a, pool_b, idx_b)
        want = ref.mos_apply(x, pool_a, idx_a, pool_b, idx_b)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_scale(self):
        x, pool_a, idx_a, pool_b, idx_b = make_case(3, 4, 3, 2, 4, 4, 12, 12)
        got = mos_kernels.mos_apply_fused(
            x, pool_a, idx_a, pool_b, idx_b, scale=0.25
        )
        want = ref.mos_apply(x, pool_a, idx_a, pool_b, idx_b, scale=0.25)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_zero_b_pool_gives_zero(self):
        """LoRA-style init: B pools start at zero => delta is exactly zero."""
        x, pool_a, idx_a, pool_b, idx_b = make_case(5, 2, 4, 2, 4, 4, 8, 8)
        out = mos_kernels.mos_apply_fused(
            x, pool_a, idx_a, jnp.zeros_like(pool_b), idx_b
        )
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_equivalent_to_dense_delta(self):
        """y must equal x @ (B A)^T computed via the dense materialization."""
        x, pool_a, idx_a, pool_b, idx_b = make_case(11, 3, 5, 2, 4, 6, 10, 14)
        delta = ref.mos_delta(pool_a, idx_a, pool_b, idx_b)  # (o, h)
        want = x @ delta.T
        got = mos_kernels.mos_apply_fused(x, pool_a, idx_a, pool_b, idx_b)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_pair_dissociation_changes_output(self):
        """Sanity: independent idx_a/idx_b differ from tied indices."""
        x, pool_a, idx_a, pool_b, idx_b = make_case(13, 2, 4, 2, 4, 4, 16, 16)
        tied = mos_kernels.mos_apply_fused(x, pool_a, idx_a, pool_b, idx_a)
        dissoc = mos_kernels.mos_apply_fused(x, pool_a, idx_a, pool_b, idx_b)
        assert not np.allclose(np.asarray(tied), np.asarray(dissoc))


class TestLowrankApply:
    @settings(max_examples=25, deadline=None)
    @given(
        st.integers(0, 2**31 - 1),
        st.integers(1, 6),
        st.integers(1, 8),
        st.integers(1, 16),
        st.integers(1, 16),
    )
    def test_matches_ref(self, seed, m, r, h, o):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((m, h)), jnp.float32)
        a = jnp.asarray(rng.standard_normal((r, h)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((o, r)), jnp.float32)
        got = mos_kernels.lowrank_apply(x, a, b)
        want = ref.lora_apply(x, a, b)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_mos_reduces_to_lora_when_l1_and_identity_routing(self):
        """With l=1 and idx = arange, MoS IS LoRA on the pool matrices."""
        rng = np.random.default_rng(0)
        r, h, o, m = 4, 6, 5, 3
        x = jnp.asarray(rng.standard_normal((m, h)), jnp.float32)
        a = jnp.asarray(rng.standard_normal((r, h)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((o, r)), jnp.float32)
        idx = jnp.arange(r, dtype=jnp.int32)[:, None]
        got = mos_kernels.mos_apply_fused(x, a, idx, b.T, idx)
        want = ref.lora_apply(x, a, b)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
