//! Table 5 (Appendix B.3): seed robustness — LoRA r8, LoRA r64-equivalent,
//! and MoS at the r8 budget, each over 4 seeds, reporting mean±std.
//!
//! Reproduction targets: (1) MoS's std is comparable to LoRA's (similar
//! stability); (2) MoS at the small budget reaches the big-LoRA average
//! (the 8x headline, seed-averaged).
//!
//! Run: cargo bench --bench table5_robustness   (forces 4 seeds)

use mos::adapter::params::{fmt_params, trainable_params};
use mos::bench::{BenchCtx, Table};
use mos::config::MethodCfg;
use mos::stats::{fmt_mean_std, mean, std_dev};

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::tiny();
    ctx.seeds = vec![0, 1, 2, 3]; // the paper's 4 seeds
    println!(
        "table5: backend={} steps={} seeds={:?}",
        ctx.backend_name(),
        ctx.steps,
        ctx.seeds
    );

    let configs: Vec<(&str, MethodCfg, &str)> = vec![
        ("LoRA r=2 (1x)", MethodCfg::lora(2), "44.79±0.86 (r8)"),
        ("LoRA r=8 (4x)", MethodCfg::lora(8), "45.41±0.85 (r64)"),
        ("MoS (1x budget)", MethodCfg::mos(8, 2, 2, 1), "45.38±0.73 (r16)"),
    ];

    let mut headers = vec!["method", "# param"];
    for t in &ctx.tasks {
        headers.push(t.name());
    }
    headers.extend(["avg mean±std", "paper mean±std"]);
    let mut table = Table::new(
        "Table 5 — seed robustness (4 seeds; paper: LLaMA3.2-3B)",
        &headers.iter().map(|s| &**s).collect::<Vec<_>>(),
    );

    for (name, mc, paper) in configs {
        // per-seed averages across tasks
        let mut per_task_means: Vec<String> = Vec::new();
        let mut seed_avgs: Vec<f64> = vec![0.0; ctx.seeds.len()];
        for &kind in &ctx.tasks {
            let mut scores = Vec::new();
            for (si, &seed) in ctx.seeds.iter().enumerate() {
                let r = ctx.run_cell(&mc, kind, seed)?;
                scores.push(r.report.score);
                seed_avgs[si] += r.report.score / ctx.tasks.len() as f64;
            }
            per_task_means.push(fmt_mean_std(&scores));
        }
        let mut row = vec![
            name.to_string(),
            fmt_params(trainable_params(&ctx.cfg, &mc)),
        ];
        row.extend(per_task_means);
        row.push(fmt_mean_std(&seed_avgs));
        row.push(paper.to_string());
        table.row(row);
        eprintln!(
            "[table5] {name}: {:.2}±{:.2}",
            mean(&seed_avgs),
            std_dev(&seed_avgs)
        );
    }
    table.print();
    Ok(())
}
