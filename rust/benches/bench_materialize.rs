//! Hot-path microbench: shard-gather materialization, the fused routed
//! apply, and the pooled shard-gather GEMM (the serving-side cost MoS adds
//! over vanilla LoRA), on host and — when artifacts exist — through the
//! AOT pallas `materialize` program and the pallas-gather forward
//! artifact. The pooled arm is the PR-6 serving path: the adapter GEMM
//! reads shard slices straight off the pool, so the dense tier's one-time
//! materialization is pure overhead — the crossover row reports how many
//! tokens dense would need to amortize it.
//!
//! Run: cargo bench --bench bench_materialize

use mos::adapter::mos::router::build_router;
use mos::adapter::mos::materialize::{apply_fused, factors};
use mos::adapter::{init_params, materialize, PooledAdapter};
use mos::bench::Table;
use mos::config::{presets, MethodCfg, LAYER_TYPES};
use mos::model::math::{gemm_gather_canon, Trans};
use mos::runtime::{Manifest, Runtime};
use mos::util::bank::Tensor;
use mos::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn time_n<F: FnMut()>(n: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..n {
        f();
    }
    t0.elapsed().as_secs_f64() / n as f64
}

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(
        "Materialization & routed-apply hot path",
        &["operation", "config", "mean time", "throughput"],
    );

    // 1) full-tenant materialization (all 7 layer types, all blocks)
    for (pname, cfg) in [("tiny", presets::tiny()), ("small", presets::small())] {
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let params = init_params(&cfg, &mc, 0);
        let aux = build_router(&cfg, &mc, 0).into_bank();
        let dt = time_n(20, || {
            for t in LAYER_TYPES {
                let f = materialize(&cfg, &mc, &params, &aux, t);
                std::hint::black_box(&f);
            }
        });
        let bytes: usize = LAYER_TYPES
            .iter()
            .map(|t| {
                let (o, i) = cfg.dims(t);
                cfg.blocks * mc.r * (i + o) * 4
            })
            .sum();
        table.row(vec![
            "tenant materialize (gather+concat)".into(),
            format!("{pname}, r=8 l=2"),
            format!("{:.3} ms", dt * 1e3),
            format!("{:.1} MB/s", bytes as f64 / dt / 1e6),
        ]);
    }

    // 2) fused routed apply vs dense-delta apply (per layer forward)
    let cfg = presets::small();
    let mc = MethodCfg::mos(8, 2, 2, 1);
    let mut params = init_params(&cfg, &mc, 0);
    let mut rng = Rng::new(0, 0);
    for t in LAYER_TYPES {
        let key = format!("{t}.pool_b");
        let old = params[&key].clone();
        params.insert(
            key,
            Tensor::from_f32(old.shape(), rng.normal_vec(old.len(), 0.1)),
        );
    }
    let aux = build_router(&cfg, &mc, 0).into_bank();
    let f = factors(&cfg, &mc, &params, &aux, "q");
    let (o, i) = cfg.dims("q");
    let m = 64;
    let x = rng.normal_vec(m * i, 1.0);
    let mut y = vec![0.0f32; m * o];
    let dt_fused = time_n(50, || {
        y.iter_mut().for_each(|v| *v = 0.0);
        apply_fused(&x, m, &f, 0, 1.0, &mut y);
        std::hint::black_box(&y);
    });
    let flops = 2.0 * m as f64 * mc.r as f64 * (i + o) as f64;
    table.row(vec![
        "fused routed apply (x->t->y)".into(),
        format!("small q-proj, m={m}"),
        format!("{:.3} ms", dt_fused * 1e3),
        format!("{:.2} GFLOP/s", flops / dt_fused / 1e9),
    ]);
    // dense delta path (materializes o*i then matmuls) for contrast
    let dt_dense = time_n(10, || {
        let delta = f.delta(0);
        let mut y2 = vec![0.0f32; m * o];
        mos::model::math::matmul_nt_acc(&x, &delta, &mut y2, m, i, o);
        std::hint::black_box(&y2);
    });
    table.row(vec![
        "dense ΔW apply (materialize+matmul)".into(),
        format!("small q-proj, m={m}"),
        format!("{:.3} ms", dt_dense * 1e3),
        format!(
            "{:.1}x slower than fused",
            dt_dense / dt_fused
        ),
    ]);

    // 2b) pooled shard-gather apply — the serving path: the adapter GEMM
    // reads shard slices straight off the pool (block 0 here), no
    // per-tenant factors anywhere
    let pooled = PooledAdapter::new(
        mc.clone(),
        Arc::new(params.clone()),
        Arc::new(aux.clone()),
    )?;
    let v = pooled.view("q");
    let scale = (mc.alpha / mc.r as f64) as f32;
    let (r, l) = (mc.r, mc.l);
    let per = r * l;
    let mut t = vec![0.0f32; m * r];
    let dt_pooled = time_n(50, || {
        y.iter_mut().for_each(|v| *v = 0.0);
        t.iter_mut().for_each(|v| *v = 0.0);
        gemm_gather_canon(
            m, r, i, 1.0, &x, v.pool_a, v.shard_w_a, &v.idx_a[..per], l,
            Some(&v.rank_scale[..r]), Trans::T, &mut t,
        );
        gemm_gather_canon(
            m, o, r, scale, &t, v.pool_b, v.shard_w_b, &v.idx_b[..per], l,
            None, Trans::N, &mut y,
        );
        std::hint::black_box(&y);
    });
    table.row(vec![
        "pooled shard-gather apply (x->t->y)".into(),
        format!("small q-proj, m={m}"),
        format!("{:.3} ms", dt_pooled * 1e3),
        format!("{:.2} GFLOP/s", flops / dt_pooled / 1e9),
    ]);
    // crossover: the dense tier pays a one-time per-layer materialization
    // and then serves from factors; the pooled tier starts serving at
    // token zero. Tokens until dense breaks even (never, if the gather
    // costs nothing extra per token):
    let dt_mat_q = time_n(20, || {
        let f = factors(&cfg, &mc, &params, &aux, "q");
        std::hint::black_box(&f);
    });
    let crossover = if dt_pooled > dt_fused {
        format!(
            "{:.0} tokens",
            dt_mat_q / (dt_pooled - dt_fused) * m as f64
        )
    } else {
        "never (pooled is not slower per token)".into()
    };
    table.row(vec![
        "dense-vs-pooled break-even".into(),
        "small q-proj".into(),
        format!("{:.3} ms materialize", dt_mat_q * 1e3),
        crossover,
    ]);

    // 3) AOT pallas materialize artifact (if built)
    if let Ok(manifest) = Manifest::load(&Manifest::default_dir()) {
        if manifest.artifacts.contains_key("materialize_tiny") {
            let rt = Runtime::cpu()?;
            let exe = rt.load(&manifest, "materialize_tiny")?;
            let tiny = presets::tiny();
            let mc2 = MethodCfg::mos(8, 2, 2, 0);
            let n = mc2.pool_shards(tiny.blocks);
            let s = tiny.hidden / mc2.l;
            let mut inputs = mos::util::bank::Bank::new();
            inputs.insert(
                "pool".into(),
                Tensor::from_f32(&[n, s], rng.normal_vec(n * s, 1.0)),
            );
            inputs.insert(
                "idx".into(),
                Tensor::from_i32(
                    &[mc2.r, mc2.l],
                    (0..mc2.r * mc2.l).map(|x| (x % n) as i32).collect(),
                ),
            );
            let dt = time_n(20, || {
                let out = exe.execute_bank(&inputs).unwrap();
                std::hint::black_box(&out);
            });
            table.row(vec![
                "AOT pallas shard_gather (PJRT)".into(),
                "tiny q-pool (one block)".into(),
                format!("{:.3} ms", dt * 1e3),
                "interpret-mode correctness path".into(),
            ]);
        }
    }

    table.print();
    println!(
        "\nnotes: materialization is per-tenant precompute (cached by the \
         coordinator; amortized to zero on the request path). The fused \
         apply is the no-materialization alternative for cold tenants."
    );
    Ok(())
}
