//! GEMM engine bench: the blocked, panel-packed, multithreaded engine in
//! `model::math` vs the seed's scalar kernels, swept over (m,k,n) shapes
//! from `presets::tiny()` up to serving scale. Emits `BENCH_gemm.json` so
//! the perf trajectory is tracked from PR to PR (ROADMAP.md §Perf).
//!
//! Run: cargo bench --bench bench_gemm   (or scripts/bench.sh)
//! Knobs: MOS_THREADS (engine pool width), MOS_GEMM_MS (per-case time
//! budget, default 200), MOS_BENCH_OUT (dir for BENCH_gemm.json, default .)

use mos::bench::Table;
use mos::config::presets;
use mos::model::math::{self, gemm_with, gemm_with_kernel, Kernel, Trans};
use mos::model::quant::{self, QuantMatrix};
use mos::util::json::Json;
use mos::util::rng::Rng;
use std::time::Instant;

/// The seed's scalar `matmul_nt` (contiguous multi-accumulator dots),
/// frozen here as the fixed baseline the engine is measured against.
fn seed_matmul_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for c in 0..chunks {
            let i = c * 8;
            s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
            s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
            s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
            s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
        }
        let mut tail = 0.0f32;
        for i in chunks * 8..n {
            tail += a[i] * b[i];
        }
        s0 + s1 + s2 + s3 + tail
    }
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            crow[j] += dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

struct Case {
    name: &'static str,
    m: usize,
    k: usize,
    n: usize,
    /// counts toward the serving-scale headline speedup
    serving_scale: bool,
}

fn cases() -> Vec<Case> {
    let t = presets::tiny();
    let s = presets::small();
    let b = presets::base();
    let case = |name, m, k, n, serving_scale| Case { name, m, k, n, serving_scale };
    vec![
        case("tiny qkv", t.batch * t.seq, t.hidden, t.hidden, false),
        case("tiny lm-head", t.batch * t.seq, t.hidden, t.vocab, false),
        case("small ffn", s.batch * s.seq, s.hidden, s.ff, false),
        case("base qkv", b.batch * b.seq, b.hidden, b.hidden, true),
        case("base ffn", b.batch * b.seq, b.hidden, b.ff, true),
        case("base lm-head", b.batch * b.seq, b.hidden, b.vocab, true),
        case("serving batch", 512, 1024, 1024, true),
        // memory-bound shapes: reported, excluded from the headline
        case("decode row", 1, 1024, 1024, false),
        case("low-rank r=8", b.batch * b.seq, b.hidden, 8, false),
    ]
}

/// Mean seconds per call after one calibration run, spending ~budget_ms.
fn time_secs<F: FnMut()>(budget_ms: f64, mut f: F) -> f64 {
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64();
    let reps = ((budget_ms / 1e3) / once.max(1e-9)).ceil().max(1.0).min(1e4) as usize;
    let t1 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t1.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let budget_ms: f64 = std::env::var("MOS_GEMM_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200.0);
    let threads = math::pool().workers();
    let kernel = math::selected_kernel();

    let mut table = Table::new(
        "GEMM engine (nt layout): seed scalar vs blocked tiers (f32 simd/scalar, int8)",
        &[
            "shape (m,k,n)",
            "case",
            "seed GF/s",
            "blocked 1t",
            "blocked mt",
            "scalar mt",
            "int8 mt",
            "speedup",
        ],
    );
    let mut json_cases = Vec::new();
    let mut serving_speedups = Vec::new();
    let mut all_speedups = Vec::new();
    let mut serving_simd_speedups = Vec::new();

    for case in cases() {
        let (m, k, n) = (case.m, case.k, case.n);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let mut rng = Rng::new(0xBE7C4, 0);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(n * k, 1.0); // (n, k): B^T storage
        let mut c = vec![0.0f32; m * n];

        // sanity: engine output matches the seed baseline
        c.fill(0.0);
        seed_matmul_nt(&a, &b, &mut c, m, k, n);
        let want = c.clone();
        c.fill(0.0);
        gemm_with(Some(math::pool()), m, n, k, 1.0, &a, Trans::N, &b, Trans::T, &mut c);
        let kf = k as f32;
        for (i, (&got, &exp)) in c.iter().zip(&want).enumerate() {
            assert!(
                (got - exp).abs() <= 1e-3 * kf.sqrt() + 1e-2 * exp.abs(),
                "{}: engine diverges from seed at {i}: {got} vs {exp}",
                case.name
            );
        }

        let seed_s = time_secs(budget_ms, || {
            c.fill(0.0);
            seed_matmul_nt(&a, &b, &mut c, m, k, n);
        });
        let b1_s = time_secs(budget_ms, || {
            c.fill(0.0);
            gemm_with(None, m, n, k, 1.0, &a, Trans::N, &b, Trans::T, &mut c);
        });
        let bmt_s = time_secs(budget_ms, || {
            c.fill(0.0);
            gemm_with(Some(math::pool()), m, n, k, 1.0, &a, Trans::N, &b, Trans::T, &mut c);
        });
        // the explicit-SIMD tentpole arm: selected kernel (what gemm_with
        // just ran) vs the scalar tile pinned, same pool — their ratio is
        // the microkernel's own win, fenced off from threading/blocking
        let scalar_s = time_secs(budget_ms, || {
            c.fill(0.0);
            gemm_with_kernel(
                Kernel::Scalar, Some(math::pool()),
                m, n, k, 1.0, &a, Trans::N, &b, Trans::T, &mut c,
            );
        });
        // int8 weight-only serving kernel on the same shape (weights = b,
        // quantized once as serving does; activations stay f32)
        let qb = QuantMatrix::quantize(n, k, &b);
        let mut ci = vec![0.0f32; m * n];
        quant::gemm_canon_q8(m, n, k, 1.0, &a, &qb.q, &qb.scale, &mut ci);
        for (i, (&got, &exp)) in ci.iter().zip(&want).enumerate() {
            assert!(
                (got - exp).abs() <= 5e-2 * kf.sqrt() + 5e-2 * exp.abs(),
                "{}: int8 kernel out of tolerance at {i}: {got} vs {exp}",
                case.name
            );
        }
        let int8_s = time_secs(budget_ms, || {
            ci.fill(0.0);
            quant::gemm_canon_q8(m, n, k, 1.0, &a, &qb.q, &qb.scale, &mut ci);
        });

        let (gf_seed, gf_b1, gf_mt) =
            (flops / seed_s / 1e9, flops / b1_s / 1e9, flops / bmt_s / 1e9);
        let (gf_scalar, gf_int8) =
            (flops / scalar_s / 1e9, flops / int8_s / 1e9);
        let speedup = seed_s / bmt_s;
        let simd_speedup = scalar_s / bmt_s;
        let int8_speedup = bmt_s / int8_s;
        if case.serving_scale {
            serving_speedups.push(speedup);
            serving_simd_speedups.push(simd_speedup);
        }
        all_speedups.push(speedup);

        table.row(vec![
            format!("{m}x{k}x{n}"),
            case.name.into(),
            format!("{gf_seed:.2}"),
            format!("{gf_b1:.2}"),
            format!("{gf_mt:.2}"),
            format!("{gf_scalar:.2}"),
            format!("{gf_int8:.2}"),
            format!("{speedup:.2}x"),
        ]);
        eprintln!(
            "[gemm] {} ({m}x{k}x{n}): {gf_seed:.2} -> {gf_mt:.2} GF/s \
             ({speedup:.2}x; {} vs scalar {simd_speedup:.2}x; int8 {gf_int8:.2})",
            case.name,
            kernel.name()
        );

        json_cases.push(Json::obj(vec![
            ("name", Json::str(case.name)),
            ("m", Json::num(m as f64)),
            ("k", Json::num(k as f64)),
            ("n", Json::num(n as f64)),
            ("serving_scale", Json::Bool(case.serving_scale)),
            ("seed_scalar_gflops", Json::num(gf_seed)),
            ("blocked_1t_gflops", Json::num(gf_b1)),
            ("blocked_mt_gflops", Json::num(gf_mt)),
            ("kernel_scalar_gflops", Json::num(gf_scalar)),
            ("int8_gflops", Json::num(gf_int8)),
            ("speedup_mt_vs_seed", Json::num(speedup)),
            ("simd_speedup_vs_scalar", Json::num(simd_speedup)),
            ("int8_speedup_vs_f32", Json::num(int8_speedup)),
        ]));
    }

    table.print();

    let geomean = (all_speedups.iter().map(|s| s.ln()).sum::<f64>()
        / all_speedups.len() as f64)
        .exp();
    let min_serving = serving_speedups
        .iter()
        .cloned()
        .fold(f64::INFINITY, f64::min);
    let min_simd_serving = serving_simd_speedups
        .iter()
        .cloned()
        .fold(f64::INFINITY, f64::min);
    println!(
        "\nthreads={threads}; kernel={}; serving-scale speedup (min) \
         {min_serving:.2}x, geomean over all shapes {geomean:.2}x, simd vs \
         scalar (min, serving scale) {min_simd_serving:.2}x (target: >= 4x \
         vs seed at serving scale on a multi-core box)",
        kernel.name()
    );

    let json = Json::obj(vec![
        ("bench", Json::str("gemm")),
        ("threads", Json::num(threads as f64)),
        ("kernel", Json::str(kernel.name())),
        ("budget_ms", Json::num(budget_ms)),
        ("cases", Json::Arr(json_cases)),
        (
            "headline",
            Json::obj(vec![
                ("min_speedup_serving_scale", Json::num(min_serving)),
                ("geomean_speedup", Json::num(geomean)),
                (
                    "min_simd_speedup_serving_scale",
                    Json::num(min_simd_serving),
                ),
            ]),
        ),
    ]);
    let out_dir = std::env::var("MOS_BENCH_OUT").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&out_dir).join("BENCH_gemm.json");
    std::fs::write(&path, json.to_string_pretty() + "\n")
        .expect("write BENCH_gemm.json");
    eprintln!("[gemm] wrote {}", path.display());
}
