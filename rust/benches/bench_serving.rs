//! Serving-system bench: coordinator throughput/latency under multi-tenant
//! traffic — KV-cached stepping vs full-window decoding, batching on vs
//! off, tenant-count sweep, cache effectiveness. This quantifies the
//! system claims around the paper (Sec. 3.6 low-cost switching; intro
//! scenario of many concurrent customized models) plus the PR-4 decode
//! rewrite: per-token cost O(step) instead of O(window · forward), and
//! time-to-first-token under continuous batching.
//!
//! Run: cargo bench --bench bench_serving
//! Knobs: MOS_SERVE_REQS (default 48), MOS_SERVE_TENANTS (default "1,4,16"),
//! MOS_BENCH_OUT (dir for BENCH_serving.json, default .)

use mos::bench::Table;
use mos::config::presets;
use mos::coordinator::{
    FullWindowEngine, GenOptions, HostEngine, Registry, Server, ServerCfg,
    TenantSpec,
};
use mos::util::json::Json;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn run_scenario(
    n_tenants: usize,
    n_requests: usize,
    max_batch: usize,
    kv_steps: bool,
) -> (f64, f64, f64, f64, f64) {
    let mut cfg = presets::tiny();
    cfg.batch = max_batch.max(1);
    let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
    let mut server = Server::new(
        Arc::clone(&registry),
        ServerCfg {
            max_batch,
            max_wait: Duration::from_millis(4),
            cache_capacity: n_tenants.max(4),
            ..ServerCfg::default()
        },
    );
    for i in 0..n_tenants {
        server
            .register(
                &format!("t{i}"),
                TenantSpec::mos(8, 2, 2, 1).seed(i as u64),
            )
            .unwrap();
    }
    let cfg2 = cfg.clone();
    if kv_steps {
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
    } else {
        server.start(1, move |_| FullWindowEngine(HostEngine::new(cfg2.clone(), 0)));
    }
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_requests)
        .map(|i| {
            server
                .submit(
                    &format!("t{}", i % n_tenants),
                    &format!("q:{:02}", i % 24),
                    GenOptions::greedy(),
                )
                .expect("submit")
        })
        .collect();
    for h in handles {
        h.wait_timeout(Duration::from_secs(300))
            .expect("response")
            .expect("request failed");
    }
    let dt = t0.elapsed().as_secs_f64();
    let rps = n_requests as f64 / dt;
    let p50 = server.metrics.percentile_us(50.0) / 1e3;
    let p95 = server.metrics.percentile_us(95.0) / 1e3;
    let ttft = server.metrics.ttft_percentile_us(50.0) / 1e3;
    let toks = server.metrics.generated_tokens.load(Ordering::Relaxed) as f64 / dt;
    server.shutdown();
    (rps, p50, p95, toks, ttft)
}

fn main() {
    let n_requests: usize = std::env::var("MOS_SERVE_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(48);
    let tenant_counts: Vec<usize> = std::env::var("MOS_SERVE_TENANTS")
        .unwrap_or_else(|_| "1,4,16".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();

    let mut table = Table::new(
        "Coordinator serving (tiny preset, host engine, 1 worker)",
        &[
            "tenants", "decode", "batching", "req/s", "p50 ms", "p95 ms",
            "ttft p50 ms", "tok/s",
        ],
    );
    let mut json_cases = Vec::new();
    for &nt in &tenant_counts {
        for (decode, kv) in [("kv_step", true), ("full_fwd", false)] {
            for (label, mb) in [("batched (8)", 8usize), ("unbatched (1)", 1)] {
                let (rps, p50, p95, toks, ttft) =
                    run_scenario(nt, n_requests, mb, kv);
                table.row(vec![
                    nt.to_string(),
                    decode.into(),
                    label.into(),
                    format!("{rps:.2}"),
                    format!("{p50:.0}"),
                    format!("{p95:.0}"),
                    format!("{ttft:.1}"),
                    format!("{toks:.0}"),
                ]);
                eprintln!(
                    "[serving] tenants={nt} {decode} {label}: {rps:.2} req/s \
                     ttft_p50={ttft:.1}ms"
                );
                json_cases.push(Json::obj(vec![
                    ("tenants", Json::num(nt as f64)),
                    ("decode", Json::str(decode)),
                    ("max_batch", Json::num(mb as f64)),
                    ("req_per_s", Json::num(rps)),
                    ("p50_ms", Json::num(p50)),
                    ("p95_ms", Json::num(p95)),
                    ("ttft_p50_ms", Json::num(ttft)),
                    ("tok_per_s", Json::num(toks)),
                ]));
            }
        }
    }
    table.print();
    println!(
        "\nreproduction target: per-tenant batching sustains throughput as \
         tenant count grows (low-cost switching — only adapter tensors \
         change per batch), batched >> unbatched, and the KV-cached step \
         path (kv_step) beats re-running full-window forwards per token \
         (full_fwd) on both tok/s and time-to-first-token."
    );

    let json = Json::obj(vec![
        ("bench", Json::str("serving")),
        ("requests", Json::num(n_requests as f64)),
        ("cases", Json::Arr(json_cases)),
    ]);
    let out_dir = std::env::var("MOS_BENCH_OUT").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&out_dir).join("BENCH_serving.json");
    std::fs::write(&path, json.to_string_pretty() + "\n")
        .expect("write BENCH_serving.json");
    eprintln!("[serving] wrote {}", path.display());
}
