//! Serving-system bench: coordinator throughput/latency under multi-tenant
//! traffic — KV-cached stepping vs full-window decoding, lean vs
//! full-forward prefill, batching on vs off, pooled vs dense-materialized
//! adapters, tenant-count sweep. This quantifies the system claims around
//! the paper (Sec. 3.6 low-cost switching; intro scenario of many
//! concurrent customized models), the PR-4 decode rewrite (per-token cost
//! O(step) instead of O(window · forward)), the PR-5 lean prefill
//! (inference-only forward: no backward cache, last-position-only logits,
//! arena-only hot path — `prefill_p50_ms` and the `alloc_mb`
//! counting-probe field track both), the PR-6 pooled serving path
//! (shard-gather GEMM straight off the registry's pools — `adapter_mb`
//! reports measured resident adapter bytes, pooled vs dense), and the
//! PR-7 paged KV pool (`kv` paged-vs-fixed arms: `kv_mb` reports peak
//! resident KV bytes, measured for the pool and analytic for the fixed
//! window; the `prefix=warm` arm repeats a shared system prefix so
//! copy-on-write page reuse shows up in `prefill_p50_ms`).
//!
//! Run: cargo bench --bench bench_serving
//! Knobs: MOS_SERVE_REQS (default 48), MOS_SERVE_TENANTS (default "1,4,16"),
//! MOS_BENCH_OUT (dir for BENCH_serving.json, default .)

use mos::bench::Table;
use mos::config::presets;
use mos::coordinator::{
    FullWindowEngine, GenOptions, HostEngine, KvStats, Registry, Server,
    ServerCfg, TenantSpec,
};
use mos::util::alloc;
use mos::util::json::Json;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

// every allocation in the scenario (all threads) flows through the
// counting probe — `alloc_mb` below is cumulative allocation churn, a
// peak-RSS proxy that makes "the lean path stopped allocating" visible
// in BENCH_serving.json
#[global_allocator]
static ALLOC_PROBE: alloc::CountingAlloc = alloc::CountingAlloc;

/// How a scenario builds its engine and shapes its prompts.
#[derive(Clone, Copy, PartialEq)]
enum Mode {
    /// Paged KV pool + lean prefill, distinct prompts (the default).
    KvLean,
    /// Paged KV pool, every request repeats a shared system prefix —
    /// copy-on-write page reuse makes repeat prefills warm.
    KvWarm,
    /// The warm arm's cold control: identical shared-prefix prompts but
    /// sharing disabled, so every prefill recomputes the prefix.
    KvCold,
    /// PR-4/5 fixed-window KV cache (paged-vs-fixed comparison arm).
    KvFixed,
    /// Fixed window + legacy full-forward prefill (comparison arm).
    KvFullPrefill,
    /// Full-window forward per generated token (fixed-graph engines).
    FullFwd,
}

impl Mode {
    fn decode(self) -> &'static str {
        match self {
            Mode::FullFwd => "full_fwd",
            _ => "kv_step",
        }
    }

    fn prefill(self) -> &'static str {
        match self {
            Mode::KvFullPrefill => "full_fwd_prefill",
            Mode::FullFwd => "n/a",
            _ => "lean",
        }
    }

    fn kv(self) -> &'static str {
        match self {
            Mode::KvLean | Mode::KvWarm | Mode::KvCold => "paged",
            Mode::KvFixed | Mode::KvFullPrefill => "fixed",
            Mode::FullFwd => "n/a",
        }
    }

    fn prefix(self) -> &'static str {
        match self {
            Mode::KvWarm => "warm",
            Mode::FullFwd => "n/a",
            _ => "cold",
        }
    }

    /// Whether requests repeat the shared system prefix ("shared") or use
    /// short distinct prompts ("uniq") — the warm/cold prefill ratio only
    /// compares like-for-like prompt shapes.
    fn prompts(self) -> &'static str {
        match self {
            Mode::KvWarm | Mode::KvCold => "shared",
            _ => "uniq",
        }
    }
}

struct ScenarioResult {
    rps: f64,
    p50: f64,
    p95: f64,
    toks: f64,
    ttft: f64,
    prefill_ms: f64,
    alloc_mb: f64,
    /// Measured resident adapter bytes across all cached tenants (MB).
    adapter_mb: f64,
    /// Measured resident frozen-base bytes under the scenario's
    /// representation (f32 bank, or int8 codes+scales plus f32 norms).
    base_mb: f64,
    /// Peak resident KV bytes (MB): measured from the pool's stats probe
    /// for the paged arms, analytic `bsz·seq·hidden·2·blocks·4` for the
    /// fixed window, 0 for full-forward decoding (no KV state).
    kv_mb: f64,
}

fn run_scenario(
    n_tenants: usize,
    n_requests: usize,
    max_batch: usize,
    mode: Mode,
    serve_dense: bool,
    serve_int8: bool,
) -> ScenarioResult {
    let mut cfg = presets::tiny();
    cfg.batch = max_batch.max(1);
    let registry = Arc::new(
        Registry::with_serve_mode(cfg.clone(), 1 << 30, serve_dense)
            .with_int8(serve_int8),
    );
    let mut server = Server::new(
        Arc::clone(&registry),
        ServerCfg {
            max_batch,
            max_wait: Duration::from_millis(4),
            cache_capacity: n_tenants.max(4),
            ..ServerCfg::default()
        },
    );
    for i in 0..n_tenants {
        server
            .register(
                &format!("t{i}"),
                TenantSpec::mos(8, 2, 2, 1).seed(i as u64),
            )
            .unwrap();
    }
    let cfg2 = cfg.clone();
    let probe = Arc::new(KvStats::default());
    let probe2 = Arc::clone(&probe);
    // int8 arms quantize the engine's base too (only the stepping modes
    // run quantized — the full-forward arms need the f32 base)
    let mk = move |cfg: &mos::config::ModelCfg| {
        let e = HostEngine::new(cfg.clone(), 0);
        if serve_int8 {
            e.serve_int8()
        } else {
            e
        }
    };
    match mode {
        Mode::KvLean | Mode::KvWarm => server.start(1, move |_| {
            mk(&cfg2).kv_stats(Arc::clone(&probe2))
        }),
        Mode::KvCold => server.start(1, move |_| {
            mk(&cfg2).no_prefix_share().kv_stats(Arc::clone(&probe2))
        }),
        Mode::KvFixed => server.start(1, move |_| mk(&cfg2).fixed_kv()),
        Mode::KvFullPrefill => server.start(1, move |_| {
            HostEngine::new(cfg2.clone(), 0).full_prefill()
        }),
        Mode::FullFwd => server.start(1, move |_| {
            FullWindowEngine(HostEngine::new(cfg2.clone(), 0))
        }),
    }
    // the worker owns its engine; probe base residency from a twin
    let base_mb = {
        let e = HostEngine::new(cfg.clone(), 0);
        let e = if serve_int8 { e.serve_int8() } else { e };
        e.base_resident_bytes() as f64 / 1e6
    };
    let bytes0 = alloc::total_bytes();
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_requests)
        .map(|i| {
            // warm arm: a shared system prefix spanning whole KV pages —
            // every repeat prefill within a tenant maps it copy-on-write
            let prompt = match mode {
                Mode::KvWarm | Mode::KvCold => {
                    format!("sys:{:024} q:{:02}", 7, i % 24)
                }
                _ => format!("q:{:02}", i % 24),
            };
            server
                .submit(
                    &format!("t{}", i % n_tenants),
                    &prompt,
                    GenOptions::greedy(),
                )
                .expect("submit")
        })
        .collect();
    for h in handles {
        h.wait_timeout(Duration::from_secs(300))
            .expect("response")
            .expect("request failed");
    }
    let dt = t0.elapsed().as_secs_f64();
    let alloc_mb = (alloc::total_bytes() - bytes0) as f64 / 1e6;
    // measured, not analytic: what the adapter cache actually holds after
    // serving the whole workload (every tenant warm)
    let adapter_mb = server.cache.resident_bytes() as f64 / 1e6;
    let kv_mb = match mode {
        Mode::KvLean | Mode::KvWarm | Mode::KvCold => {
            probe.peak_resident_bytes() as f64 / 1e6
        }
        Mode::KvFixed | Mode::KvFullPrefill => {
            // the fixed window pre-reserves bsz·seq·hidden K+V floats per
            // block whatever the occupancy — the bytes the pool replaces
            (cfg.batch * cfg.seq * cfg.hidden * 2 * cfg.blocks * 4) as f64
                / 1e6
        }
        Mode::FullFwd => 0.0,
    };
    let res = ScenarioResult {
        rps: n_requests as f64 / dt,
        p50: server.metrics.percentile_us(50.0) / 1e3,
        p95: server.metrics.percentile_us(95.0) / 1e3,
        toks: server.metrics.generated_tokens.load(Ordering::Relaxed) as f64
            / dt,
        ttft: server.metrics.ttft_percentile_us(50.0) / 1e3,
        prefill_ms: server.metrics.prefill_percentile_us(50.0) / 1e3,
        alloc_mb,
        adapter_mb,
        base_mb,
        kv_mb,
    };
    server.shutdown();
    res
}

/// Side-by-side tiny-preset accuracy probe for the int8 tier: prefill +
/// fixed-token decode through the fully quantized path (int8 base + int8
/// shard pool) vs the f32 pooled oracle. Returns
/// `(max |dlogit|, top-1 agreement)` — gated against the logit budget by
/// `scripts/check_bench.py`.
fn int8_accuracy() -> (f64, f64) {
    use mos::adapter::{PooledAdapter, QuantPooledAdapter};
    use mos::model::transformer::{
        decode_step_runs_base, infer_prefill_runs_base, init_base,
        quantize_base, AdapterBinding, AdapterRef, BaseRef, KvCache,
    };
    let mut cfg = presets::tiny();
    cfg.batch = 2;
    let base = init_base(&cfg, 0);
    let t = TenantSpec::mos(8, 2, 2, 1).seed(0).build(&cfg, "t").unwrap();
    let pooled = PooledAdapter::new(
        t.mc.clone(),
        Arc::clone(&t.params),
        Arc::clone(&t.aux),
    )
    .unwrap();
    let qpool = QuantPooledAdapter::quantize(&pooled);
    let qbase = quantize_base(&cfg, &base);
    let t_len = cfg.seq;
    let prompts: [&[i32]; 2] = [&[1, 9, 4, 2], &[1, 5, 6]];
    let mut window = vec![0i32; 2 * t_len];
    for (r, p) in prompts.iter().enumerate() {
        window[r * t_len..r * t_len + p.len()].copy_from_slice(p);
    }
    let last: Vec<usize> = prompts.iter().map(|p| p.len() - 1).collect();
    let runs_f = [AdapterBinding::new(2, &t.mc, AdapterRef::Pooled(&pooled))];
    let runs_q =
        [AdapterBinding::new(2, &t.mc, AdapterRef::PooledInt8(&qpool))];
    let mut cache_f = KvCache::new(&cfg, 2);
    let mut reference = infer_prefill_runs_base(
        &cfg, BaseRef::f32(&base), &runs_f, &window, &last, &mut cache_f,
        &[0, 1],
    );
    let mut cache_q = KvCache::new(&cfg, 2);
    let mut candidate = infer_prefill_runs_base(
        &cfg,
        BaseRef::int8(&base, &qbase),
        &runs_q,
        &window,
        &last,
        &mut cache_q,
        &[0, 1],
    );
    for (j, (ta, tb)) in [(9i32, 5i32), (2, 7), (4, 1), (8, 3)].iter().enumerate()
    {
        let entries = [(0usize, 4 + j, *ta), (1usize, 3 + j, *tb)];
        reference.extend(decode_step_runs_base(
            &cfg, BaseRef::f32(&base), &runs_f, &mut cache_f, &entries,
        ));
        candidate.extend(decode_step_runs_base(
            &cfg,
            BaseRef::int8(&base, &qbase),
            &runs_q,
            &mut cache_q,
            &entries,
        ));
    }
    let err = mos::model::quant::logit_error(&reference, &candidate, cfg.vocab);
    (err.max_abs as f64, err.top1_agree as f64)
}

fn main() {
    let n_requests: usize = std::env::var("MOS_SERVE_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(48);
    let tenant_counts: Vec<usize> = std::env::var("MOS_SERVE_TENANTS")
        .unwrap_or_else(|_| "1,4,16".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();

    let mut table = Table::new(
        "Coordinator serving (tiny preset, host engine, 1 worker)",
        &[
            "tenants", "decode", "prefill", "kv", "prefix", "prompts",
            "adapter", "batching", "req/s", "p50 ms", "p95 ms",
            "ttft p50 ms", "prefill p50 ms", "tok/s", "alloc MB",
            "adapter MB", "base MB", "kv MB",
        ],
    );
    let mut json_cases = Vec::new();
    for &nt in &tenant_counts {
        // (mode, max_batch, serve_dense, serve_int8): the pooled adapter
        // tier and the paged KV pool are the defaults; the dense /
        // fixed-window / warm arms pin the adapter memory gap, the KV
        // memory gap, and the shared-prefix prefill win side by side; the
        // int8 arm pins the quantized tier's adapter+base residency
        // against the f32 KvLean arm it mirrors
        let cases = [
            (Mode::KvLean, 8usize, false, false),
            (Mode::KvLean, 8, false, true),
            (Mode::KvWarm, 8, false, false),
            (Mode::KvCold, 8, false, false),
            (Mode::KvFixed, 8, false, false),
            (Mode::KvLean, 8, true, false),
            (Mode::KvLean, 1, false, false),
            (Mode::KvFullPrefill, 8, false, false),
            (Mode::FullFwd, 8, false, false),
            (Mode::FullFwd, 1, false, false),
        ];
        for (mode, mb, dense, int8) in cases {
            let label = if mb > 1 { "batched (8)" } else { "unbatched (1)" };
            let adapter = if dense {
                "dense"
            } else if int8 {
                "pooled_int8"
            } else {
                "pooled"
            };
            let r = run_scenario(nt, n_requests, mb, mode, dense, int8);
            table.row(vec![
                nt.to_string(),
                mode.decode().into(),
                mode.prefill().into(),
                mode.kv().into(),
                mode.prefix().into(),
                mode.prompts().into(),
                adapter.into(),
                label.into(),
                format!("{:.2}", r.rps),
                format!("{:.0}", r.p50),
                format!("{:.0}", r.p95),
                format!("{:.1}", r.ttft),
                format!("{:.2}", r.prefill_ms),
                format!("{:.0}", r.toks),
                format!("{:.1}", r.alloc_mb),
                format!("{:.3}", r.adapter_mb),
                format!("{:.3}", r.base_mb),
                format!("{:.3}", r.kv_mb),
            ]);
            eprintln!(
                "[serving] tenants={nt} {} prefill={} kv={} prefix={} \
                 adapter={adapter} {label}: {:.2} req/s ttft_p50={:.1}ms \
                 prefill_p50={:.2}ms alloc={:.1}MB adapter={:.3}MB \
                 base={:.3}MB kv={:.3}MB",
                mode.decode(),
                mode.prefill(),
                mode.kv(),
                mode.prefix(),
                r.rps,
                r.ttft,
                r.prefill_ms,
                r.alloc_mb,
                r.adapter_mb,
                r.base_mb,
                r.kv_mb,
            );
            json_cases.push(Json::obj(vec![
                ("tenants", Json::num(nt as f64)),
                ("decode", Json::str(mode.decode())),
                ("prefill", Json::str(mode.prefill())),
                ("kv", Json::str(mode.kv())),
                ("prefix", Json::str(mode.prefix())),
                ("prompts", Json::str(mode.prompts())),
                ("adapter", Json::str(adapter)),
                ("max_batch", Json::num(mb as f64)),
                ("req_per_s", Json::num(r.rps)),
                ("p50_ms", Json::num(r.p50)),
                ("p95_ms", Json::num(r.p95)),
                ("ttft_p50_ms", Json::num(r.ttft)),
                ("prefill_p50_ms", Json::num(r.prefill_ms)),
                ("tok_per_s", Json::num(r.toks)),
                ("alloc_mb", Json::num(r.alloc_mb)),
                ("adapter_mb", Json::num(r.adapter_mb)),
                ("base_mb", Json::num(r.base_mb)),
                ("kv_mb", Json::num(r.kv_mb)),
            ]));
        }
    }
    table.print();
    println!(
        "\nreproduction target: per-tenant batching sustains throughput as \
         tenant count grows (low-cost switching — only adapter tensors \
         change per batch), batched >> unbatched, the KV-cached step path \
         (kv_step) beats re-running full-window forwards per token \
         (full_fwd) on tok/s and time-to-first-token, the lean \
         inference-only prefill beats the legacy full-forward prefill on \
         prefill_p50_ms and allocation churn (alloc_mb), the pooled \
         adapter tier keeps measured resident adapter bytes (adapter_mb) \
         several-fold below the dense-materialized tier at matched \
         throughput, the paged KV pool keeps peak resident KV bytes \
         (kv_mb) well below the fixed window's slots×window slab at \
         identical logits, and warm shared-prefix prefills beat cold \
         ones on prefill_p50_ms by skipping already-resident positions. \
         The int8 tier (adapter=pooled_int8) keeps measured adapter+base \
         residency <= 0.35x the f32 pooled arm while staying inside the \
         logit-error budget (int8_accuracy below)."
    );

    let (max_abs_dlogit, top1_agree) = int8_accuracy();
    eprintln!(
        "[serving] int8 accuracy: max|dlogit|={max_abs_dlogit:.4} \
         (budget {}), top1_agree={top1_agree:.3} (budget {})",
        mos::model::quant::LOGIT_BUDGET_MAX_ABS,
        mos::model::quant::LOGIT_BUDGET_TOP1,
    );

    let json = Json::obj(vec![
        ("bench", Json::str("serving")),
        ("requests", Json::num(n_requests as f64)),
        (
            "int8_accuracy",
            Json::obj(vec![
                ("max_abs_dlogit", Json::num(max_abs_dlogit)),
                ("top1_agree", Json::num(top1_agree)),
                (
                    "budget_max_abs",
                    Json::num(mos::model::quant::LOGIT_BUDGET_MAX_ABS as f64),
                ),
                (
                    "budget_top1",
                    Json::num(mos::model::quant::LOGIT_BUDGET_TOP1 as f64),
                ),
            ]),
        ),
        ("cases", Json::Arr(json_cases)),
    ]);
    let out_dir = std::env::var("MOS_BENCH_OUT").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&out_dir).join("BENCH_serving.json");
    std::fs::write(&path, json.to_string_pretty() + "\n")
        .expect("write BENCH_serving.json");
    eprintln!("[serving] wrote {}", path.display());
}
