//! Table 6 (Appendix B.3): hyperparameter grid — shards-per-vector l in
//! {1,2,4,8,16} x private rank in {1,3,5,7} on the BBH proxy (`chain`).
//!
//! Pools use the 4x budget (e=8) so private_rank up to 7 < e is feasible,
//! matching the paper's 19.99M-budget grid. Reproduction targets: a broad
//! plateau of good configs; as l grows (more differentiation from
//! sharding), the optimal private rank drifts downward.
//!
//! Run: cargo bench --bench table6_grid
//! (host backend for l values without artifacts; seeds via MOS_BENCH_SEEDS)

use mos::bench::{BenchCtx, Table};
use mos::config::MethodCfg;
use mos::data::tasks::TaskKind;
use mos::stats::mean;

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::tiny();
    ctx.tasks = vec![TaskKind::Chain]; // the BBH proxy
    let ls = [1usize, 2, 4, 8, 16];
    let ps = [1usize, 3, 5, 7];
    println!(
        "table6: grid {}x{} on chain, backend={} steps={} seeds={}",
        ls.len(),
        ps.len(),
        ctx.backend_name(),
        ctx.steps,
        ctx.seeds.len()
    );

    let mut headers = vec!["shards/vec".to_string()];
    headers.extend(ps.iter().map(|p| format!("p={p}")));
    let mut table = Table::new(
        "Table 6 — shards-per-vector x private rank (chain task, e=8 budget; paper values 38.6-40.0 on BBH)",
        &headers.iter().map(|s| &**s).collect::<Vec<_>>(),
    );

    let mut best = (0.0f64, 0usize, 0usize);
    for &l in &ls {
        let mut row = vec![format!("{l}")];
        for &p in &ps {
            let mc = MethodCfg::mos(8, l, 8, p);
            let mut scores = Vec::new();
            for &seed in &ctx.seeds {
                let r = ctx.run_cell(&mc, TaskKind::Chain, seed)?;
                scores.push(r.report.score);
            }
            let m = mean(&scores);
            if m > best.0 {
                best = (m, l, p);
            }
            row.push(format!("{m:.1}"));
            eprintln!("[table6] l={l} p={p}: {m:.1}");
        }
        table.row(row);
    }
    table.print();
    println!(
        "\nbest cell: l={} private_rank={} ({:.1}); paper's best: l=4, p=5 (40.0)",
        best.1, best.2, best.0
    );
    Ok(())
}
