//! Intro figure (the 3.36 TB claim): adapter GPU memory vs number of
//! concurrently-served customized models, per method, on real LLaMA
//! geometries — plus the capacity view (tenants per fixed GPU budget),
//! which is where MoS's ~8x savings becomes serving capacity. The LLaMA
//! tables are analytic (those geometries don't fit a host run); a final
//! measured section registers real tenants on the tiny preset and checks
//! the formula against the bytes the serving stack actually keeps
//! resident — pooled (zero-copy shard views, the PR-6 default) vs the
//! dense materialized tier.
//!
//! Run: cargo bench --bench fig_memory_scaling

use mos::adapter::params::{fmt_bytes, multi_tenant_bytes, serving_bytes};
use mos::bench::Table;
use mos::config::{presets, MethodCfg};
use mos::coordinator::{Registry, TenantSpec};

fn main() {
    let geoms = [presets::llama2_7b(), presets::llama2_70b()];
    for cfg in &geoms {
        let methods: Vec<(&str, MethodCfg)> = vec![
            ("LoRA r=16", MethodCfg::lora(16)),
            ("LoRA r=64", MethodCfg::lora(64)),
            ("VeRA r=256", MethodCfg::vera(256)),
            ("PRoLoRA 4/8", MethodCfg::prolora(8, 4)),
            ("MoS 4/8 (e=2)", MethodCfg::mos(8, 2, 2, 1)),
            ("MoS 16/32 (e=8)", MethodCfg::mos(32, 2, 8, 1)),
        ];
        let tenants = [100usize, 1_000, 10_000, 100_000];
        let mut headers = vec!["method".to_string(), "per-tenant".into()];
        headers.extend(tenants.iter().map(|t| format!("{t} users")));
        let mut table = Table::new(
            &format!(
                "Memory scaling on {} (fp16 adapters; paper intro: 10k x LoRA-r16 on 70B ≈ 3.36 TB)",
                cfg.name
            ),
            &headers.iter().map(|s| &**s).collect::<Vec<_>>(),
        );
        for (name, mc) in &methods {
            let mut row = vec![
                name.to_string(),
                fmt_bytes(serving_bytes(cfg, mc, 2)),
            ];
            for &t in &tenants {
                row.push(fmt_bytes(multi_tenant_bytes(cfg, mc, t, 2)));
            }
            table.row(row);
        }
        table.print();

        // capacity view: tenants per 80 GB of adapter budget
        let budget = 80usize << 30;
        let mut cap = Table::new(
            &format!("Tenants per 80 GB adapter budget on {}", cfg.name),
            &["method", "resident tenants", "vs LoRA r=16"],
        );
        let lora16 = budget / serving_bytes(cfg, &MethodCfg::lora(16), 2);
        for (name, mc) in &methods {
            let n = budget / serving_bytes(cfg, mc, 2);
            cap.row(vec![
                name.to_string(),
                format!("{n}"),
                format!("{:.2}x", n as f64 / lora16 as f64),
            ]);
        }
        cap.print();
    }

    // measured section: the analytic tables above assume serving holds
    // exactly the pooled tensors. Register real tenants (tiny preset, f32
    // host copies) and read back what the ledger actually charged under
    // each serve mode — on the pooled path measured == analytic, bit for
    // bit; the dense tier shows what materialization would cost instead.
    let cfg = presets::tiny();
    let mc = MethodCfg::mos(8, 2, 2, 1);
    let n_tenants = 8usize;
    let mut measured = Table::new(
        &format!(
            "Measured resident adapter bytes on {} ({n_tenants} registered \
             MoS 4/8 e=2 tenants, f32 host copies)",
            cfg.name
        ),
        &["serve mode", "per-tenant", "total", "analytic per-tenant"],
    );
    let analytic = serving_bytes(&cfg, &mc, 4);
    for (label, dense) in [("pooled", false), ("dense", true)] {
        let reg = Registry::with_serve_mode(cfg.clone(), 1 << 30, dense);
        for i in 0..n_tenants {
            reg.register_spec(
                &format!("t{i}"),
                TenantSpec::mos(8, 2, 2, 1).seed(i as u64),
            )
            .expect("register tenant");
        }
        let total = reg.ledger.lock().unwrap().used();
        let per = total / n_tenants;
        measured.row(vec![
            label.to_string(),
            fmt_bytes(per),
            fmt_bytes(total),
            fmt_bytes(analytic),
        ]);
        if !dense {
            assert_eq!(
                per, analytic,
                "pooled resident bytes must equal serving_bytes exactly"
            );
        }
    }
    measured.print();

    println!(
        "\nreproduction target: LoRA r=16 x 10k users on 70B lands in the \
         multi-TB regime (paper: 3.36 TB) while MoS at the r=16-quality \
         budget (e=2) is ~8x smaller; the measured section confirms the \
         pooled serving path keeps exactly the analytic per-tenant bytes \
         resident (dense materialization is several-fold larger)."
    );
}
