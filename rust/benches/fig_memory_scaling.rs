//! Intro figure (the 3.36 TB claim): adapter GPU memory vs number of
//! concurrently-served customized models, per method, on real LLaMA
//! geometries — plus the capacity view (tenants per fixed GPU budget),
//! which is where MoS's ~8x savings becomes serving capacity.
//!
//! Run: cargo bench --bench fig_memory_scaling

use mos::adapter::params::{fmt_bytes, multi_tenant_bytes, serving_bytes};
use mos::bench::Table;
use mos::config::{presets, MethodCfg};

fn main() {
    let geoms = [presets::llama2_7b(), presets::llama2_70b()];
    for cfg in &geoms {
        let methods: Vec<(&str, MethodCfg)> = vec![
            ("LoRA r=16", MethodCfg::lora(16)),
            ("LoRA r=64", MethodCfg::lora(64)),
            ("VeRA r=256", MethodCfg::vera(256)),
            ("PRoLoRA 4/8", MethodCfg::prolora(8, 4)),
            ("MoS 4/8 (e=2)", MethodCfg::mos(8, 2, 2, 1)),
            ("MoS 16/32 (e=8)", MethodCfg::mos(32, 2, 8, 1)),
        ];
        let tenants = [100usize, 1_000, 10_000, 100_000];
        let mut headers = vec!["method".to_string(), "per-tenant".into()];
        headers.extend(tenants.iter().map(|t| format!("{t} users")));
        let mut table = Table::new(
            &format!(
                "Memory scaling on {} (fp16 adapters; paper intro: 10k x LoRA-r16 on 70B ≈ 3.36 TB)",
                cfg.name
            ),
            &headers.iter().map(|s| &**s).collect::<Vec<_>>(),
        );
        for (name, mc) in &methods {
            let mut row = vec![
                name.to_string(),
                fmt_bytes(serving_bytes(cfg, mc, 2)),
            ];
            for &t in &tenants {
                row.push(fmt_bytes(multi_tenant_bytes(cfg, mc, t, 2)));
            }
            table.row(row);
        }
        table.print();

        // capacity view: tenants per 80 GB of adapter budget
        let budget = 80usize << 30;
        let mut cap = Table::new(
            &format!("Tenants per 80 GB adapter budget on {}", cfg.name),
            &["method", "resident tenants", "vs LoRA r=16"],
        );
        let lora16 = budget / serving_bytes(cfg, &MethodCfg::lora(16), 2);
        for (name, mc) in &methods {
            let n = budget / serving_bytes(cfg, mc, 2);
            cap.row(vec![
                name.to_string(),
                format!("{n}"),
                format!("{:.2}x", n as f64 / lora16 as f64),
            ]);
        }
        cap.print();
    }
    println!(
        "\nreproduction target: LoRA r=16 x 10k users on 70B lands in the \
         multi-TB regime (paper: 3.36 TB) while MoS at the r=16-quality \
         budget (e=2) is ~8x smaller."
    );
}
