//! Table 3: scalability — LoRA vs PRoLoRA vs MoS at the rank-2 budget on a
//! *larger* model (paper: LLaMA2-13B; here: the `small` preset when its
//! artifacts exist, else a mid-size host geometry).
//!
//! Reproduction target: the ordering LoRA < PRoLoRA < MoS persists as the
//! base model grows (paper: 43.92 < 45.04 < 45.98 on MMLU/BBH/GSM).
//!
//! Run: cargo bench --bench table3_scale

use mos::adapter::params::{fmt_params, trainable_params};
use mos::bench::{BenchCtx, Table};
use mos::config::{presets, MethodCfg, ModelCfg};

fn mid_cfg() -> ModelCfg {
    // larger than tiny, still host-trainable in bench time
    ModelCfg {
        name: "mid".into(),
        vocab: 64,
        hidden: 96,
        blocks: 6,
        heads: 6,
        ff: 256,
        seq: 48,
        batch: 8,
        kv_heads: 6,
    }
}

fn main() -> anyhow::Result<()> {
    // prefer the small preset's artifacts; fall back to host mid geometry
    // (MOS_BENCH_BACKEND=host forces the mid geometry — small host steps
    // are too slow for bench budgets)
    let small_available = std::env::var("MOS_BENCH_BACKEND").as_deref()
        != Ok("host")
        && mos::runtime::Manifest::load(&mos::runtime::Manifest::default_dir())
            .map(|m| m.presets.contains_key("small"))
            .unwrap_or(false);
    let ctx = if small_available {
        BenchCtx::for_preset("small", presets::small())
    } else {
        BenchCtx::for_preset("mid", mid_cfg())
    };
    println!(
        "table3: scale preset={} backend={} steps={}",
        ctx.cfg.name,
        ctx.backend_name(),
        ctx.steps
    );

    // artifacts for small: lora_r4 (budget 2e) and mos e=2 (budget e) — the
    // budget asymmetry *favours LoRA*, so MoS >= LoRA is conservative.
    // PRoLoRA has no small artifact and host steps at small scale exceed
    // bench budgets; it is included only in the host/mid fallback.
    let configs: Vec<(&str, MethodCfg, f64)> = if small_available {
        vec![
            ("LoRA (2x budget)", MethodCfg::lora(4), 43.92),
            ("MoS (1x budget)", MethodCfg::mos(8, 2, 2, 1), 45.98),
        ]
    } else {
        vec![
            ("LoRA", MethodCfg::lora(2), 43.92),
            ("PRoLoRA", MethodCfg::prolora(8, 4), 45.04),
            ("MoS", MethodCfg::mos(8, 2, 2, 1), 45.98),
        ]
    };

    let mut headers = vec!["method", "# param"];
    for t in &ctx.tasks {
        headers.push(t.name());
    }
    headers.extend(["avg", "paper avg (13B)"]);
    let mut table = Table::new(
        "Table 3 — scalability (paper: LLaMA2-13B; here: larger preset, proxy tasks)",
        &headers.iter().map(|s| &**s).collect::<Vec<_>>(),
    );
    for (name, mc, paper) in configs {
        let s = ctx.run_method(&mc)?;
        let mut row = vec![
            name.to_string(),
            fmt_params(trainable_params(&ctx.cfg, &mc)),
        ];
        row.extend(s.per_task.iter().map(|v| format!("{v:.2}")));
        row.push(format!("{:.2}", s.avg));
        row.push(format!("{paper:.2}"));
        table.row(row);
        eprintln!("[table3] {name}: avg {:.2} ({:.1}s)", s.avg, s.train_seconds);
    }
    table.print();
    Ok(())
}
