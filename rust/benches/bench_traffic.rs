//! Traffic-replay bench: the load harness drives the serving stack with
//! the six named adversarial traffic shapes (steady Poisson, bursty,
//! diurnal ramp, hot-tenant Zipfian skew over a 1k+ tenant pooled tier,
//! cancel storm, tight-deadline mix), each expanded deterministically
//! from a seed by `loadgen::plan`. By default requests go straight into
//! `Server::submit`; with MOS_TRAFFIC_HTTP=1 they go through the HTTP
//! front door on a loopback socket instead — same shapes, same seeds,
//! plus the network edge (cancellations become connection drops).
//!
//! Emits BENCH_traffic.json with per-shape p50/p99 ttft and latency,
//! tok/s, and reject/expire/cancel counts — gated by
//! scripts/check_bench.py and rendered into the ROADMAP trajectory table
//! by scripts/perf_row.py --traffic.
//!
//! Run: cargo bench --bench bench_traffic
//! Knobs: MOS_TRAFFIC_REQS (default 32, per shape), MOS_TRAFFIC_SEED
//! (default 0), MOS_TRAFFIC_SHAPES (csv of shape names, default all six),
//! MOS_TRAFFIC_HTTP (1 = drive the front door), MOS_TRAFFIC_ZIPF_TENANTS
//! (default 1200), MOS_BENCH_OUT (dir for BENCH_traffic.json, default .)

use mos::bench::Table;
use mos::config::presets;
use mos::coordinator::{HostEngine, Registry, Server, ServerCfg};
use mos::frontend::{Frontend, FrontendCfg};
use mos::loadgen::{
    register_tenants, register_tenants_http, run_shape, HttpClient,
    InProcessClient, Shape, ShapeReport, TrafficCfg, ALL_SHAPES,
};
use mos::util::json::Json;
use std::sync::Arc;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// One shape = one fresh server (and, in HTTP mode, one fresh front
/// door): shapes must not share queue state or KV residue.
fn run_one(cfg: &TrafficCfg, over_http: bool) -> ShapeReport {
    let model = presets::tiny();
    let registry = Arc::new(Registry::new(model.clone(), 1 << 30));
    let mut server = Server::new(
        registry,
        ServerCfg {
            cache_capacity: cfg.tenants.clamp(64, 2048),
            ..ServerCfg::default()
        },
    );
    let model2 = model.clone();
    server.start(2, move |_| HostEngine::new(model2.clone(), 0));
    let server = Arc::new(server);
    if over_http {
        let mut fe = Frontend::start(
            Arc::clone(&server),
            "127.0.0.1:0",
            FrontendCfg::default(),
        )
        .expect("frontend bind");
        let addr = fe.local_addr();
        register_tenants_http(addr, cfg.tenants)
            .expect("tenant registration over HTTP");
        let report = run_shape(cfg, Arc::new(HttpClient::new(addr)));
        fe.shutdown();
        report
    } else {
        register_tenants(&server, cfg.tenants)
            .expect("tenant registration");
        let client = InProcessClient::new(Arc::clone(&server));
        run_shape(cfg, Arc::new(client))
    }
}

fn main() {
    let requests = env_usize("MOS_TRAFFIC_REQS", 32);
    let seed = env_usize("MOS_TRAFFIC_SEED", 0) as u64;
    let over_http = std::env::var("MOS_TRAFFIC_HTTP")
        .map(|v| v == "1")
        .unwrap_or(false);
    let zipf_tenants = env_usize("MOS_TRAFFIC_ZIPF_TENANTS", 1200);
    let shapes: Vec<Shape> = match std::env::var("MOS_TRAFFIC_SHAPES") {
        Ok(csv) => csv
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| Shape::parse(s).unwrap_or_else(|| {
                panic!("unknown shape '{s}' in MOS_TRAFFIC_SHAPES")
            }))
            .collect(),
        Err(_) => ALL_SHAPES.to_vec(),
    };

    let target = if over_http { "http" } else { "in_process" };
    eprintln!(
        "[traffic] target={target} requests/shape={requests} seed={seed}"
    );
    let mut table = Table::new(
        &format!("traffic replay ({target}, seed {seed})"),
        &[
            "shape", "reqs", "tenants", "ok", "rej", "exp", "cxl", "err",
            "ttft p50", "ttft p99", "lat p50", "lat p99", "tok/s",
        ],
    );
    let mut json_shapes = Vec::new();
    for shape in shapes {
        let mut cfg = TrafficCfg::named(shape, requests, seed);
        if shape == Shape::Zipf {
            cfg.tenants = zipf_tenants;
        }
        let r = run_one(&cfg, over_http);
        eprintln!(
            "[traffic] {} done: {}/{} ok, {} rej, {} exp, {} cxl, {} err, \
             ttft p50={:.1}ms p99={:.1}ms, {:.0} tok/s",
            r.shape,
            r.completed,
            r.requests,
            r.rejected,
            r.expired,
            r.cancelled,
            r.errors,
            r.ttft_p50_ms,
            r.ttft_p99_ms,
            r.tok_per_s,
        );
        table.row(vec![
            r.shape.clone(),
            r.requests.to_string(),
            r.tenants.to_string(),
            r.completed.to_string(),
            r.rejected.to_string(),
            r.expired.to_string(),
            r.cancelled.to_string(),
            r.errors.to_string(),
            format!("{:.1}", r.ttft_p50_ms),
            format!("{:.1}", r.ttft_p99_ms),
            format!("{:.1}", r.latency_p50_ms),
            format!("{:.1}", r.latency_p99_ms),
            format!("{:.0}", r.tok_per_s),
        ]);
        json_shapes.push(r.to_json());
    }
    table.print();
    println!(
        "\nreproduction target: the pooled tier absorbs every shape \
         without eviction thrash — the Zipfian arm serves a 1k+ tenant \
         universe from shared shard pools, bursts degrade to queueing \
         (rejects only past the admission bound, never errors), cancel \
         storms return admission slots and KV pages, and tight deadlines \
         expire cleanly at decode-step boundaries."
    );

    let json = Json::obj(vec![
        ("bench", Json::str("traffic")),
        ("seed", Json::num(seed as f64)),
        ("requests_per_shape", Json::num(requests as f64)),
        ("target", Json::str(target)),
        ("shapes", Json::Arr(json_shapes)),
    ]);
    let out_dir = std::env::var("MOS_BENCH_OUT").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&out_dir).join("BENCH_traffic.json");
    std::fs::write(&path, json.to_string_pretty() + "\n")
        .expect("write BENCH_traffic.json");
    eprintln!("[traffic] wrote {}", path.display());
}
