//! Traffic-replay bench: the load harness drives the serving stack with
//! the seven named adversarial traffic shapes (steady Poisson, bursty,
//! diurnal ramp, hot-tenant Zipfian skew over a 1k+ tenant pooled tier,
//! cancel storm, tight-deadline mix, weighted DWRR contention), each
//! expanded deterministically from a seed by `loadgen::plan`. By default
//! requests go straight into `Server::submit`; with MOS_TRAFFIC_HTTP=1
//! they go through the HTTP front door on a loopback socket instead —
//! same shapes, same seeds, plus the network edge (cancellations become
//! connection drops).
//!
//! The replay server runs with chunked prefill on (PR 9). The
//! prefill-contended shapes (bursty, deadline_mix — long prompts) also
//! run an unchunked control arm and record its ttft p99 alongside, so
//! scripts/check_bench.py can gate "chunked strictly beats one-shot".
//!
//! Emits BENCH_traffic.json with per-shape p50/p99 ttft and latency,
//! tok/s, and reject/expire/cancel counts — gated by
//! scripts/check_bench.py and rendered into the ROADMAP trajectory table
//! by scripts/perf_row.py --traffic.
//!
//! Run: cargo bench --bench bench_traffic [-- --shapes a,b --requests N
//!      --seed S --zipf-tenants N --prefill-chunk N]
//! Env fallbacks for the same knobs: MOS_TRAFFIC_SHAPES,
//! MOS_TRAFFIC_REQS, MOS_TRAFFIC_SEED, MOS_TRAFFIC_ZIPF_TENANTS,
//! MOS_TRAFFIC_CHUNK (0 = one-shot prefill), plus MOS_TRAFFIC_HTTP
//! (1 = drive the front door) and MOS_BENCH_OUT (dir for
//! BENCH_traffic.json, default .)

use mos::bench::Table;
use mos::config::presets;
use mos::coordinator::{HostEngine, Registry, Server, ServerCfg};
use mos::frontend::{Frontend, FrontendCfg};
use mos::loadgen::{
    register_tenants, register_tenants_http, run_shape, HttpClient,
    InProcessClient, Shape, ShapeReport, TrafficCfg, ALL_SHAPES,
};
use mos::util::cli::Args;
use mos::util::json::Json;
use std::sync::Arc;

/// CLI flag if given, else env var, else default — the PR-9 promotion
/// of the traffic knobs to proper flags, env still honored.
fn knob_usize(args: &Args, flag: &str, env: &str, default: usize) -> usize {
    if let Some(v) = args.get(flag) {
        return v
            .parse()
            .unwrap_or_else(|_| panic!("--{flag}: '{v}' is not an integer"));
    }
    std::env::var(env)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn knob_str(args: &Args, flag: &str, env: &str) -> Option<String> {
    args.get(flag)
        .map(str::to_string)
        .or_else(|| std::env::var(env).ok())
}

/// One shape = one fresh server (and, in HTTP mode, one fresh front
/// door): shapes must not share queue state or KV residue.
fn run_one(
    cfg: &TrafficCfg,
    over_http: bool,
    prefill_chunk: Option<usize>,
) -> ShapeReport {
    let model = presets::tiny();
    let registry = Arc::new(Registry::new(model.clone(), 1 << 30));
    let mut server = Server::new(
        registry,
        ServerCfg {
            cache_capacity: cfg.tenants.clamp(64, 2048),
            prefill_chunk,
            ..ServerCfg::default()
        },
    );
    let model2 = model.clone();
    server.start(2, move |_| HostEngine::new(model2.clone(), 0));
    let server = Arc::new(server);
    let mut report = if over_http {
        let mut fe = Frontend::start(
            Arc::clone(&server),
            "127.0.0.1:0",
            FrontendCfg::default(),
        )
        .expect("frontend bind");
        let addr = fe.local_addr();
        register_tenants_http(addr, cfg)
            .expect("tenant registration over HTTP");
        let report = run_shape(cfg, Arc::new(HttpClient::new(addr)));
        fe.shutdown();
        report
    } else {
        register_tenants(&server, cfg).expect("tenant registration");
        let client = InProcessClient::new(Arc::clone(&server));
        run_shape(cfg, Arc::new(client))
    };
    report.prefill_chunk = prefill_chunk;
    report
}

fn main() {
    let args = Args::from_env().expect("parse args");
    let requests = knob_usize(&args, "requests", "MOS_TRAFFIC_REQS", 32);
    let seed = knob_usize(&args, "seed", "MOS_TRAFFIC_SEED", 0) as u64;
    let over_http = std::env::var("MOS_TRAFFIC_HTTP")
        .map(|v| v == "1")
        .unwrap_or(false);
    let zipf_tenants =
        knob_usize(&args, "zipf-tenants", "MOS_TRAFFIC_ZIPF_TENANTS", 1200);
    let chunk =
        match knob_usize(&args, "prefill-chunk", "MOS_TRAFFIC_CHUNK", 8) {
            0 => None,
            n => Some(n),
        };
    let shapes: Vec<Shape> =
        match knob_str(&args, "shapes", "MOS_TRAFFIC_SHAPES") {
            Some(csv) => csv
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    Shape::parse(s).unwrap_or_else(|| {
                        panic!("unknown shape '{s}' in --shapes")
                    })
                })
                .collect(),
            None => ALL_SHAPES.to_vec(),
        };

    let target = if over_http { "http" } else { "in_process" };
    eprintln!(
        "[traffic] target={target} requests/shape={requests} seed={seed} \
         prefill_chunk={chunk:?}"
    );
    let mut table = Table::new(
        &format!("traffic replay ({target}, seed {seed})"),
        &[
            "shape", "reqs", "tenants", "ok", "rej", "exp", "cxl", "err",
            "ttft p50", "ttft p99", "ttft p99 1shot", "lat p50", "lat p99",
            "tok/s",
        ],
    );
    let mut json_shapes = Vec::new();
    for shape in shapes {
        let mut cfg = TrafficCfg::named(shape, requests, seed);
        if shape == Shape::Zipf {
            cfg.tenants = zipf_tenants;
        }
        let mut r = run_one(&cfg, over_http, chunk);
        // prefill-contended shapes: also run the one-shot control arm so
        // the CI gate can hold "chunked prefill lowers the ttft tail"
        let contended =
            matches!(shape, Shape::Bursty | Shape::DeadlineMix);
        if contended && chunk.is_some() {
            let control = run_one(&cfg, over_http, None);
            r.ttft_p99_unchunked_ms = Some(control.ttft_p99_ms);
        }
        eprintln!(
            "[traffic] {} done: {}/{} ok, {} rej, {} exp, {} cxl, {} err, \
             ttft p50={:.1}ms p99={:.1}ms (one-shot p99={}), {:.0} tok/s",
            r.shape,
            r.completed,
            r.requests,
            r.rejected,
            r.expired,
            r.cancelled,
            r.errors,
            r.ttft_p50_ms,
            r.ttft_p99_ms,
            r.ttft_p99_unchunked_ms
                .map(|v| format!("{v:.1}ms"))
                .unwrap_or_else(|| "n/a".into()),
            r.tok_per_s,
        );
        table.row(vec![
            r.shape.clone(),
            r.requests.to_string(),
            r.tenants.to_string(),
            r.completed.to_string(),
            r.rejected.to_string(),
            r.expired.to_string(),
            r.cancelled.to_string(),
            r.errors.to_string(),
            format!("{:.1}", r.ttft_p50_ms),
            format!("{:.1}", r.ttft_p99_ms),
            r.ttft_p99_unchunked_ms
                .map(|v| format!("{v:.1}"))
                .unwrap_or_else(|| "-".into()),
            format!("{:.1}", r.latency_p50_ms),
            format!("{:.1}", r.latency_p99_ms),
            format!("{:.0}", r.tok_per_s),
        ]);
        json_shapes.push(r.to_json());
    }
    table.print();
    println!(
        "\nreproduction target: the pooled tier absorbs every shape \
         without eviction thrash — the Zipfian arm serves a 1k+ tenant \
         universe from shared shard pools, bursts degrade to queueing \
         (rejects only past the admission bound, never errors), cancel \
         storms return admission slots and KV pages, tight deadlines \
         expire cleanly at decode-step boundaries, the weighted arm \
         splits served tokens by DWRR contract, and chunked prefill \
         holds the bursty/deadline ttft tail below the one-shot control."
    );

    let json = Json::obj(vec![
        ("bench", Json::str("traffic")),
        ("seed", Json::num(seed as f64)),
        ("requests_per_shape", Json::num(requests as f64)),
        ("target", Json::str(target)),
        (
            "prefill_chunk",
            Json::num(chunk.unwrap_or(0) as f64),
        ),
        ("shapes", Json::Arr(json_shapes)),
    ]);
    let out_dir = std::env::var("MOS_BENCH_OUT").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&out_dir).join("BENCH_traffic.json");
    std::fs::write(&path, json.to_string_pretty() + "\n")
        .expect("write BENCH_traffic.json");
    eprintln!("[traffic] wrote {}", path.display());
}
