//! Table 8 (Limitations §C): finetuning wallclock — LoRA vs MoS at the same
//! trainable budget and raised MoS rank. Paper: MoS costs only ~2.8% more
//! time than LoRA (the routing is index-based precompute, not an
//! activation-dependent MoE).
//!
//! Run: cargo bench --bench table8_time

use mos::bench::{BenchCtx, Table};
use mos::config::MethodCfg;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::tiny();
    println!(
        "table8: backend={} steps={} tasks={:?}",
        ctx.backend_name(),
        ctx.steps,
        ctx.tasks.iter().map(|t| t.name()).collect::<Vec<_>>()
    );

    let lora = ctx.run_method(&MethodCfg::lora(2))?;
    let mos_s = ctx.run_method(&MethodCfg::mos(8, 2, 2, 1))?;

    let mut table = Table::new(
        "Table 8 — finetuning time, equal trainable budget (paper: +2.80% for MoS)",
        &["method", "rank", "train seconds", "overhead vs LoRA"],
    );
    table.row(vec![
        "LoRA".into(),
        "2".into(),
        format!("{:.2}", lora.train_seconds),
        "—".into(),
    ]);
    let overhead =
        100.0 * (mos_s.train_seconds - lora.train_seconds) / lora.train_seconds;
    table.row(vec![
        "MoS".into(),
        "8".into(),
        format!("{:.2}", mos_s.train_seconds),
        format!("{overhead:+.2}% (paper: +2.80%)"),
    ]);
    table.print();
    println!(
        "\nnote: MoS raises the rank 4x at equal budget, so some overhead is \
         expected; the claim is that it stays small because routing is\n\
         frozen index gathers, not activation-dependent dispatch."
    );
    Ok(())
}
