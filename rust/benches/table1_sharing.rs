//! Table 1 (and Figure 1, measured): sharing & differentiation study.
//!
//! Paper (LLaMA2-7B, 5.00M trainable params): pure sharing at rank 64
//! underperforms LoRA r=2 on average; random scaling roughly recovers it;
//! subset selection surpasses LoRA. Here: tiny preset, budget e=2
//! (pure-sharing rank = e*L), synthetic proxy tasks. The *ordering*
//! LoRA ≈ pure < +rs < +ss is the reproduction target.
//!
//! Run: cargo bench --bench table1_sharing
//! Knobs: MOS_BENCH_STEPS / MOS_BENCH_TASKS / MOS_BENCH_SEEDS (bench/mod.rs)

use mos::adapter::params::{fmt_params, trainable_params};
use mos::bench::{rows, BenchCtx, Table};

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::tiny();
    println!(
        "table1: backend={} steps={} tasks={:?} seeds={}",
        ctx.backend_name(),
        ctx.steps,
        ctx.tasks.iter().map(|t| t.name()).collect::<Vec<_>>(),
        ctx.seeds.len()
    );

    let blocks = ctx.cfg.blocks;
    let configs = vec![
        ("LoRA", rows::lora(2), 34.98),
        ("Pure Sharing", rows::pure_sharing(blocks), 34.33),
        ("+ Random Scaling", rows::random_scaling(blocks), 34.77),
        ("+ Subset Selection", rows::subset_selection(), 36.12),
    ];

    let mut headers = vec!["method", "rank", "# param"];
    for t in &ctx.tasks {
        headers.push(t.name());
    }
    headers.extend(["avg", "paper avg", "final loss"]);
    let mut table = Table::new(
        "Table 1 — sharing & differentiation (paper: LLaMA2-7B; here: tiny preset, proxy tasks)",
        &headers.iter().map(|s| &**s).collect::<Vec<_>>(),
    );

    for (name, mc, paper_avg) in configs {
        let s = ctx.run_method(&mc)?;
        let mut row = vec![
            name.to_string(),
            mc.r.to_string(),
            fmt_params(trainable_params(&ctx.cfg, &mc)),
        ];
        row.extend(s.per_task.iter().map(|v| format!("{v:.2}")));
        row.push(format!("{:.2}", s.avg));
        row.push(format!("{paper_avg:.2}"));
        row.push(format!("{:.3}", s.final_loss));
        table.row(row);
        eprintln!("[table1] {name}: avg {:.2} ({:.1}s)", s.avg, s.train_seconds);
    }
    table.print();
    println!(
        "\nreproduction target: differentiation reverses pure sharing's \
         degradation (+ss >= pure, +ss >= lora); see EXPERIMENTS.md §Table1"
    );
    Ok(())
}
