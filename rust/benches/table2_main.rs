//! Table 2: main results — every method at the 1x (5.00M-equivalent) budget,
//! LoRA at raised budgets, MoS at 4x, and the three MoS ablations.
//!
//! The "# param" column is printed twice: measured on the tiny preset AND
//! analytically on the true LLaMA2-7B geometry, where it reproduces the
//! paper digit-for-digit (5.00M / 19.99M / 39.98M / 159.91M / 1.42M...).
//!
//! Run: cargo bench --bench table2_main

use mos::adapter::params::{fmt_params, trainable_params};
use mos::bench::{rows, BenchCtx, Table};
use mos::config::presets;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::tiny();
    let llama = presets::llama2_7b();
    println!(
        "table2: backend={} steps={} tasks={:?} seeds={}",
        ctx.backend_name(),
        ctx.steps,
        ctx.tasks.iter().map(|t| t.name()).collect::<Vec<_>>(),
        ctx.seeds.len()
    );

    // (display name, tiny config, llama-geometry config, paper avg)
    let configs: Vec<(&str, mos::config::MethodCfg, mos::config::MethodCfg, f64)> = vec![
        ("LoRA r2 (1x)", rows::lora(2), mos::config::MethodCfg::lora(2), 34.98),
        ("LoRA r8 (4x)", rows::lora(8), mos::config::MethodCfg::lora(8), 36.89),
        ("LoRA r16 (8x)", rows::lora(16), mos::config::MethodCfg::lora(16), 36.97),
        ("VeRA", rows::vera(), mos::config::MethodCfg::vera(256), 34.00),
        ("Tied LoRA", rows::tied(), mos::config::MethodCfg::tied(280), 35.26),
        ("PRoLoRA 4/8", rows::prolora(), mos::config::MethodCfg::prolora(8, 4), 36.03),
        ("MoS 4/8 (1x)", rows::mos_1x(), mos::config::MethodCfg::mos(8, 2, 2, 1), 36.39),
        ("MoS 16/32 (4x)", rows::mos_4x(), mos::config::MethodCfg::mos(32, 2, 8, 1), 37.63),
        ("MoS -sp", rows::mos_no_sp(), mos::config::MethodCfg::mos(32, 2, 8, 0), 36.54),
        ("MoS -vs", rows::mos_no_vs(), mos::config::MethodCfg::mos(32, 1, 8, 1), 37.22),
        ("MoS -pd", rows::mos_no_pd(), mos::config::MethodCfg::mos(32, 2, 8, 1), 36.54),
    ];

    let mut headers = vec!["method", "rank", "# param(tiny)", "# param(7B)"];
    for t in &ctx.tasks {
        headers.push(t.name());
    }
    headers.extend(["avg", "paper avg", "loss"]);
    let mut table = Table::new(
        "Table 2 — main results (paper: LLaMA2-7B instruction tuning; here: tiny preset, proxy tasks)",
        &headers.iter().map(|s| &**s).collect::<Vec<_>>(),
    );

    let mut mos_avg = 0.0;
    let mut lora_avg = 0.0;
    for (name, mc_tiny, mc_llama, paper) in configs {
        let s = ctx.run_method(&mc_tiny)?;
        if name.starts_with("MoS 4/8") {
            mos_avg = s.avg;
        }
        if name.starts_with("LoRA r2") {
            lora_avg = s.avg;
        }
        let mut row = vec![
            name.to_string(),
            mc_tiny.r.to_string(),
            fmt_params(trainable_params(&ctx.cfg, &mc_tiny)),
            fmt_params(trainable_params(&llama, &mc_llama)),
        ];
        row.extend(s.per_task.iter().map(|v| format!("{v:.2}")));
        row.push(format!("{:.2}", s.avg));
        row.push(format!("{paper:.2}"));
        row.push(format!("{:.3}", s.final_loss));
        table.row(row);
        eprintln!("[table2] {name}: avg {:.2} ({:.1}s)", s.avg, s.train_seconds);
    }
    table.print();
    println!(
        "\nreproduction targets: (1) MoS > LoRA at equal budget \
         (measured {mos_avg:.2} vs {lora_avg:.2}); (2) # param(7B) column \
         matches the paper exactly (verified in unit tests); (3) MoS 4x \
         ≈ LoRA 8x-32x — the ~8x parameter-savings headline."
    );
    Ok(())
}
