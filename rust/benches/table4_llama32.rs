//! Table 4 (Appendix B.2): the sharing/differentiation study repeated on a
//! second geometry (paper: LLaMA3.2-3B with pure-sharing rank 56 = e*L
//! for L=28; here: a second host geometry with different block count so
//! the pure-sharing rank differs from Table 1's).
//!
//! Reproduction target: same ordering as Table 1 on a different geometry —
//! pure <~ LoRA, +rs slightly above pure, +ss above LoRA.
//!
//! Run: cargo bench --bench table4_llama32

use mos::adapter::params::{fmt_params, trainable_params};
use mos::bench::{rows, BenchCtx, Table};


fn main() -> anyhow::Result<()> {
    // A genuinely different *pretrained* geometry would need its own AOT
    // bank; within the bench budget we rerun the study on the tiny preset
    // with disjoint router/task/data seeds instead — the paper's question
    // ("does the differentiation ordering survive a configuration
    // change?") is answered on the seed axis rather than the size axis
    // (documented in EXPERIMENTS.md §Table4).
    let mut ctx = BenchCtx::tiny();
    ctx.seeds = vec![7, 8];
    println!(
        "table4: second configuration (tiny preset, seeds {:?}) backend={} steps={}",
        ctx.seeds,
        ctx.backend_name(),
        ctx.steps
    );
    let blocks = ctx.cfg.blocks;
    let configs = vec![
        ("LoRA", rows::lora(2), 43.49),
        ("Pure Sharing", rows::pure_sharing(blocks), 43.23),
        ("+ Random Scaling", rows::random_scaling(blocks), 43.45),
        ("+ Subset Selection", rows::subset_selection(), 44.06),
    ];
    let mut headers = vec!["method", "rank", "# param"];
    for t in &ctx.tasks {
        headers.push(t.name());
    }
    headers.extend(["avg", "paper avg (3B)"]);
    let mut table = Table::new(
        "Table 4 — differentiation on a second geometry (paper: LLaMA3.2-3B)",
        &headers.iter().map(|s| &**s).collect::<Vec<_>>(),
    );
    for (name, mc, paper) in configs {
        let s = ctx.run_method(&mc)?;
        let mut row = vec![
            name.to_string(),
            mc.r.to_string(),
            fmt_params(trainable_params(&ctx.cfg, &mc)),
        ];
        row.extend(s.per_task.iter().map(|v| format!("{v:.2}")));
        row.push(format!("{:.2}", s.avg));
        row.push(format!("{paper:.2}"));
        table.row(row);
        eprintln!("[table4] {name}: avg {:.2} ({:.1}s)", s.avg, s.train_seconds);
    }
    table.print();
    Ok(())
}
