//! Table 7 (Appendix B.3): statistical significance of MoS vs LoRA at both
//! budgets — paired t-test over per-(task, seed) score pairs, plus Welch's
//! unpaired test. Paper: p < 0.05 at both 5.00M and 19.99M budgets.
//!
//! Run: cargo bench --bench table7_significance   (forces 4 seeds)

use mos::bench::{BenchCtx, Table};
use mos::config::MethodCfg;
use mos::stats::{mean, paired_t_test, welch_t_test};

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::tiny();
    ctx.seeds = vec![0, 1, 2, 3];
    println!(
        "table7: backend={} steps={} tasks={:?} seeds={:?}",
        ctx.backend_name(),
        ctx.steps,
        ctx.tasks.iter().map(|t| t.name()).collect::<Vec<_>>(),
        ctx.seeds
    );

    let budgets: Vec<(&str, MethodCfg, MethodCfg)> = vec![
        ("1x (5.00M-eq)", MethodCfg::lora(2), MethodCfg::mos(8, 2, 2, 1)),
        ("4x (19.99M-eq)", MethodCfg::lora(8), MethodCfg::mos(16, 2, 8, 1)),
    ];

    let mut table = Table::new(
        "Table 7 — significance of MoS vs LoRA (paper: p < 0.05 at both budgets)",
        &["budget", "lora mean", "mos mean", "paired t", "paired p", "welch p"],
    );

    for (name, lora, mos_cfg) in budgets {
        let mut lora_scores = Vec::new();
        let mut mos_scores = Vec::new();
        for &kind in &ctx.tasks {
            for &seed in &ctx.seeds {
                lora_scores.push(ctx.run_cell(&lora, kind, seed)?.report.score);
                mos_scores
                    .push(ctx.run_cell(&mos_cfg, kind, seed)?.report.score);
            }
        }
        let (t, _, p_paired) = paired_t_test(&mos_scores, &lora_scores);
        let (_, _, p_welch) = welch_t_test(&mos_scores, &lora_scores);
        table.row(vec![
            name.to_string(),
            format!("{:.2}", mean(&lora_scores)),
            format!("{:.2}", mean(&mos_scores)),
            format!("{t:.3}"),
            format!("{p_paired:.4}"),
            format!("{p_welch:.4}"),
        ]);
        eprintln!("[table7] {name}: paired p={p_paired:.4}");
    }
    table.print();
    Ok(())
}
