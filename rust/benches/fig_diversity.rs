//! Appendix B.1 (rendered as a table): combinatorial diversity of each
//! differentiation strategy — log10 of the number of potential combinations
//! per low-rank matrix pair, on the paper's LLaMA2-7B configuration and on
//! the tiny preset.
//!
//! Reproduction target: the strict ordering
//! pure (1) < subset C(Le,r) < dissociation C(Le,r)^2 < sharding C(Lle,rl)^2,
//! with sharding's *increment* much smaller than dissociation's — matching
//! the ablation result that -pd hurts more than -vs.
//!
//! Run: cargo bench --bench fig_diversity

use mos::adapter::mos::diversity::analyze;
use mos::bench::Table;

fn main() {
    let settings = [
        ("LLaMA2-7B, e=2, r=8, l=2", 32u64, 2u64, 8u64, 2u64),
        ("LLaMA2-7B, e=8, r=32, l=2", 32, 8, 32, 2),
        ("tiny preset, e=2, r=8, l=2", 4, 2, 8, 2),
        ("tiny preset, e=8, r=8, l=4", 4, 8, 8, 4),
    ];
    let mut table = Table::new(
        "Appendix B.1 — combinational diversity (log10 #combinations per pair)",
        &["setting", "pure", "subset", "+dissociation", "+sharding",
          "shard gain"],
    );
    for (name, blocks, e, r, l) in settings {
        let d = analyze(blocks, e, r, l);
        table.row(vec![
            name.to_string(),
            format!("{:.0}", d.pure_sharing),
            format!("{:.1}", d.subset_selection),
            format!("{:.1}", d.pair_dissociation),
            format!("{:.1}", d.vector_sharding),
            format!("{:+.1}", d.vector_sharding - d.pair_dissociation),
        ]);
    }
    table.print();
    println!(
        "\nordering check: dissociation doubles the exponent (big jump — \
         matches -pd being the most damaging ablation), sharding adds a \
         smaller increment (matches -vs being the mildest)."
    );
}
