//! Integration tests over the real AOT artifacts (PJRT round trips).
//! These exercise the full L1/L2/L3 composition:
//!   - Rust gather materialization == AOT pallas shard_gather kernel
//!   - fwd artifact with zero adapters == base model (for every method)
//!   - pallas-gather fwd artifact == fused fwd artifact (same logits)
//!   - train artifact reduces loss and only moves routed pool shards
//!
//! All tests skip gracefully when `make artifacts` hasn't been run.

use mos::adapter::mos::materialize::gather_rows;
use mos::adapter::mos::router::build_router;
use mos::config::MethodCfg;
use mos::runtime::{Manifest, Runtime};
use mos::util::bank::{read_bank, Bank, Tensor};
use mos::util::rng::Rng;

fn setup() -> Option<(Runtime, Manifest)> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some((Runtime::cpu().expect("pjrt"), Manifest::load(&dir).expect("manifest")))
}

#[test]
fn pallas_shard_gather_matches_rust_gather() {
    let Some((rt, manifest)) = setup() else { return };
    let exe = rt.load(&manifest, "materialize_tiny").expect("load");
    let art = &exe.art;
    let (r, l) = (art.method_cfg.r, art.method_cfg.l);
    let pool_spec = &art.inputs[0];
    let (n, s) = (pool_spec.shape[0], pool_spec.shape[1]);

    let mut rng = Rng::new(7, 0);
    let pool = Tensor::from_f32(&[n, s], rng.normal_vec(n * s, 1.0));
    let idx: Vec<i32> =
        (0..r * l).map(|_| rng.range(0, n) as i32).collect();

    let mut inputs = Bank::new();
    inputs.insert("pool".into(), pool.clone());
    inputs.insert("idx".into(), Tensor::from_i32(&[r, l], idx.clone()));
    let out = exe.execute_bank(&inputs).expect("execute");
    let dense_pjrt = out["dense"].f32s().unwrap();

    let dense_rust = gather_rows(&pool, &idx, r, l);
    assert_eq!(dense_pjrt.len(), dense_rust.len());
    for (a, b) in dense_pjrt.iter().zip(&dense_rust) {
        assert_eq!(a, b, "pallas gather and rust gather disagree");
    }
}

fn fwd_with_zero_params(
    rt: &Runtime,
    manifest: &Manifest,
    name: &str,
    mc: &MethodCfg,
    tokens: &[i32],
) -> Vec<f32> {
    let exe = rt.load(manifest, name).expect("load fwd");
    let bank = read_bank(&manifest.bank_path("tiny")).expect("bank");
    let cfg = manifest.presets["tiny"].clone();
    let router = if mc.method == mos::config::Method::MoS {
        build_router(&cfg, mc, 0).into_bank()
    } else {
        Bank::new()
    };
    let mut inputs = Bank::new();
    for spec in &exe.art.inputs {
        let t = match spec.role.as_str() {
            "base" => bank[&spec.name].clone(),
            "param" => match spec.dtype.as_str() {
                // zero adapters => base behaviour... except scale-vector
                // params whose zero also zeroes the (zero) B side; fine.
                _ => Tensor::zeros(&spec.shape),
            },
            "aux" => router
                .get(&spec.name)
                .or_else(|| bank.get(&spec.name))
                .cloned()
                .unwrap_or_else(|| Tensor::zeros(&spec.shape)),
            "data" => Tensor::from_i32(&spec.shape, tokens.to_vec()),
            r => panic!("role {r}"),
        };
        inputs.insert(spec.name.clone(), t);
    }
    let out = exe.execute_bank(&inputs).expect("exec");
    out["logits"].f32s().unwrap().to_vec()
}

#[test]
fn zero_adapters_make_all_methods_equal_base() {
    let Some((rt, manifest)) = setup() else { return };
    let cfg = manifest.presets["tiny"].clone();
    let n = cfg.batch * cfg.seq;
    let tokens: Vec<i32> = (0..n).map(|i| (i % cfg.vocab) as i32).collect();

    let lora = fwd_with_zero_params(
        &rt, &manifest, "fwd_lora_r2_tiny", &MethodCfg::lora(2), &tokens,
    );
    let mos_cfg = MethodCfg::mos(8, 2, 2, 1);
    let mos = fwd_with_zero_params(
        &rt, &manifest, "fwd_mos_r8_l2_e2_tiny", &mos_cfg, &tokens,
    );
    assert_eq!(lora.len(), mos.len());
    for (a, b) in lora.iter().zip(&mos) {
        assert!(
            (a - b).abs() < 1e-4,
            "zero-adapter logits differ between lora and mos: {a} vs {b}"
        );
    }
}

#[test]
fn pallas_fwd_matches_fused_fwd() {
    let Some((rt, manifest)) = setup() else { return };
    if !manifest.artifacts.contains_key("fwd_mos_r8_l2_e2_tiny_pallas") {
        eprintln!("skipping: pallas fwd artifact not built");
        return;
    }
    let cfg = manifest.presets["tiny"].clone();
    let mc = MethodCfg::mos(8, 2, 2, 1);
    let bank = read_bank(&manifest.bank_path("tiny")).unwrap();
    let params = read_bank(&manifest.init_path("tiny", "mos_r8_l2_e2")).unwrap();
    // randomize pool_b so adapters actually contribute
    let mut rng = Rng::new(3, 0);
    let mut params2 = params.clone();
    for t in mos::config::LAYER_TYPES {
        let key = format!("{t}.pool_b");
        let old = params2[&key].clone();
        params2.insert(
            key,
            Tensor::from_f32(old.shape(), rng.normal_vec(old.len(), 0.05)),
        );
    }
    let router = build_router(&cfg, &mc, 5).into_bank();
    let n = cfg.batch * cfg.seq;
    let tokens: Vec<i32> = (0..n).map(|i| ((i * 7) % cfg.vocab) as i32).collect();

    let run = |name: &str| -> Vec<f32> {
        let exe = rt.load(&manifest, name).unwrap();
        let mut inputs = Bank::new();
        for spec in &exe.art.inputs {
            let t = match spec.role.as_str() {
                "base" => bank[&spec.name].clone(),
                "param" => params2[&spec.name].clone(),
                "aux" => router[&spec.name].clone(),
                "data" => Tensor::from_i32(&spec.shape, tokens.clone()),
                r => panic!("role {r}"),
            };
            inputs.insert(spec.name.clone(), t);
        }
        exe.execute_bank(&inputs).unwrap()["logits"]
            .f32s()
            .unwrap()
            .to_vec()
    };
    let fused = run("fwd_mos_r8_l2_e2_tiny");
    let pallas = run("fwd_mos_r8_l2_e2_tiny_pallas");
    for (a, b) in fused.iter().zip(&pallas) {
        assert!(
            (a - b).abs() < 1e-3,
            "pallas-gather fwd disagrees with fused fwd: {a} vs {b}"
        );
    }
}

#[test]
fn train_artifact_moves_only_routed_shards() {
    let Some((rt, manifest)) = setup() else { return };
    let cfg = manifest.presets["tiny"].clone();
    // l=1, rank 4 of pool 8: half the pool stays unrouted per side
    let mc = MethodCfg::mos(4, 1, 2, 0);
    let mut be = mos::train::pjrt::PjrtBackend::load(&rt, &manifest, "tiny", &mc, 11)
        .expect("backend");
    // randomize pool_b so A-side gradients are live too
    let mut rng = Rng::new(1, 0);
    for t in mos::config::LAYER_TYPES {
        let key = format!("{t}.pool_b");
        let old = be.params[&key].clone();
        be.params.insert(
            key,
            Tensor::from_f32(old.shape(), rng.normal_vec(old.len(), 0.05)),
        );
    }
    // constrain the router: every block routes A to shards {0,1} and B to
    // shards {2,3} only, guaranteeing unrouted shards exist
    for t in mos::config::LAYER_TYPES {
        let shape = [cfg.blocks, mc.r, mc.l];
        let n = cfg.blocks * mc.r * mc.l;
        be.aux.insert(
            format!("{t}.idx_a"),
            Tensor::from_i32(&shape, (0..n).map(|i| (i % 2) as i32).collect()),
        );
        be.aux.insert(
            format!("{t}.idx_b"),
            Tensor::from_i32(&shape, (0..n).map(|i| 2 + (i % 2) as i32).collect()),
        );
    }
    let before = be.params.clone();
    let routed_a: std::collections::HashSet<i32> = be.aux["q.idx_a"]
        .i32s()
        .unwrap()
        .iter()
        .copied()
        .collect();
    assert!(routed_a.len() < 8, "test requires unrouted shards");

    let mut loader = mos::data::Loader::new(
        mos::data::tasks::Task::new(mos::data::tasks::TaskKind::Recall, 0),
        cfg.batch,
        cfg.seq,
    );
    use mos::train::Backend;
    let batch = loader.next_train();
    let loss0 = be.train_step(&batch, 1e-2).unwrap();
    assert!(loss0.is_finite() && loss0 > 0.0);

    let pa0 = before["q.pool_a"].f32s().unwrap();
    let pa1 = be.params["q.pool_a"].f32s().unwrap();
    let width = before["q.pool_a"].shape()[1];
    for shard in 0..8 {
        let moved = pa0[shard * width..(shard + 1) * width]
            != pa1[shard * width..(shard + 1) * width];
        let routed = routed_a.contains(&(shard as i32));
        assert_eq!(
            moved, routed,
            "shard {shard}: moved={moved} but routed={routed}"
        );
    }
}

#[test]
fn train_artifact_learns() {
    let Some((rt, manifest)) = setup() else { return };
    let mc = MethodCfg::mos(8, 2, 2, 1);
    let mut be =
        mos::train::pjrt::PjrtBackend::load(&rt, &manifest, "tiny", &mc, 0)
            .expect("backend");
    use mos::train::Backend;
    let (batch_sz, seq, _) = be.shape();
    let mut loader = mos::data::Loader::new(
        mos::data::tasks::Task::new(mos::data::tasks::TaskKind::Recall, 0),
        batch_sz,
        seq,
    );
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..30 {
        let b = loader.next_train();
        let loss = be.train_step(&b, 2e-2).unwrap();
        if step == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < first - 0.3,
        "pjrt training did not learn: {first:.3} -> {last:.3}"
    );
}
