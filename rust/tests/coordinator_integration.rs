//! Coordinator integration: train a real adapter, register it as a tenant
//! from a checkpoint spec, serve requests through the full
//! batcher/cache/server pipeline, and check the answers match direct
//! (non-served) evaluation.

use mos::adapter::mos::router::build_router;
use mos::config::{presets, MethodCfg};
use mos::coordinator::{
    GenOptions, HostEngine, Registry, Server, ServerCfg, TenantSpec,
};
use mos::data::tasks::{Task, TaskKind};
use mos::data::Tokenizer;
use mos::train::checkpoint::Checkpoint;
use mos::train::host::HostBackend;
use mos::train::run;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn trained_tenant_serves_correct_answers() {
    // keep it small: host training on a reduced-batch tiny preset
    let mut cfg = presets::tiny();
    cfg.batch = 8;
    let mc = MethodCfg::mos(8, 2, 2, 1);
    let seed = 0u64;

    let mut be = HostBackend::new(&cfg, &mc, seed);
    let result = run(
        &mut be,
        || Task::new(TaskKind::Recall, seed),
        60,
        2e-2,
        8,
        0,
    )
    .unwrap();
    // the training must at least be making progress; absolute quality is
    // covered by the benches (the core assertion here is served == direct)
    assert!(
        mos::train::final_loss(&result.losses, 5)
            < mos::train::final_loss(&result.losses[..5], 5),
        "training made no progress"
    );

    // register the trained adapter as a tenant (checkpoint spec — the same
    // path a deployment uses); serve the same eval prompts through the
    // coordinator and compare with direct generation.
    let base = be.model.base.clone();
    let params = be.model.params.clone();
    let aux = be.model.aux.clone();
    // verify router determinism: rebuilding with the stored seed matches
    assert_eq!(build_router(&cfg, &mc, seed).into_bank(), aux);

    let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
    let mut server = Server::new(
        Arc::clone(&registry),
        ServerCfg {
            max_batch: cfg.batch,
            max_wait: Duration::from_millis(5),
            cache_capacity: 4,
            ..ServerCfg::default()
        },
    );
    server
        .register(
            "user",
            TenantSpec::from_checkpoint(Checkpoint {
                preset: "tiny".into(),
                mc: mc.clone(),
                router_seed: seed,
                params,
                aux,
            }),
        )
        .unwrap();
    let base2 = base.clone();
    let cfg2 = cfg.clone();
    server.start(1, move |_| HostEngine {
        cfg: cfg2.clone(),
        base: base2.clone(),
    });

    let task = Task::new(TaskKind::Recall, seed);
    let tk = Tokenizer::new();
    let mut matched = 0;
    let n = 8;
    let mut handles = Vec::new();
    let mut examples = Vec::new();
    for i in 0..n {
        let ex = task.example("eval", i);
        handles.push(
            server
                .submit("user", &ex.prompt, GenOptions::greedy())
                .unwrap(),
        );
        examples.push(ex);
    }
    let mut served_scores = 0.0;
    for (h, ex) in handles.into_iter().zip(&examples) {
        let resp = h
            .wait_timeout(Duration::from_secs(120))
            .expect("timed out")
            .expect("request failed");
        served_scores += task.score(ex, &resp.text);
        // served output must equal direct greedy generation
        let mut fwd = |tokens: &[i32]| be.model.forward(tokens);
        let direct = mos::eval::decode(
            &mut fwd,
            &[tk.prompt_tokens(&ex.prompt)],
            &GenOptions::greedy(),
            cfg.seq,
            cfg.vocab,
        );
        if tk.decode(&direct[0]) == resp.text {
            matched += 1;
        }
    }
    assert_eq!(
        matched, n,
        "served generations diverge from direct generations"
    );
    let served = 100.0 * served_scores / n as f64;
    assert!(
        (served - result.report.score).abs() < 30.0,
        "served quality {served:.1} wildly differs from direct {:.1}",
        result.report.score
    );
    server.shutdown();
}

#[test]
fn memory_pressure_evicts_and_recovers() {
    let cfg = presets::tiny();
    let mc = MethodCfg::mos(8, 2, 2, 1);
    let one = mos::adapter::params::serving_bytes(&cfg, &mc, 4);
    let registry = Arc::new(Registry::new(cfg.clone(), one * 2 + 100));
    for i in 0..5 {
        registry
            .register_spec(&format!("t{i}"), TenantSpec::mos(8, 2, 2, 1).seed(i))
            .unwrap();
    }
    // only 2 fit
    assert_eq!(registry.len(), 2);
    // evicted tenants can re-register (recovery path)
    registry
        .register_spec("t0", TenantSpec::mos(8, 2, 2, 1).seed(0))
        .unwrap();
    assert!(registry.get("t0").is_some());
}

#[test]
fn serving_contract_under_churn() {
    // end-to-end lifecycle: register -> serve -> re-register (version
    // bump) -> serve fresh -> remove -> typed UnknownTenant at submit
    let mut cfg = presets::tiny();
    cfg.batch = 4;
    let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
    let mut server = Server::new(
        Arc::clone(&registry),
        ServerCfg {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            cache_capacity: 4,
            ..ServerCfg::default()
        },
    );
    let cfg2 = cfg.clone();
    server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));

    server
        .register("churn", TenantSpec::mos(4, 2, 2, 0).seed(1))
        .unwrap();
    let r1 = server
        .submit("churn", "q:a", GenOptions::greedy())
        .unwrap()
        .wait_timeout(Duration::from_secs(60))
        .unwrap()
        .unwrap();
    assert_eq!(r1.tenant, "churn");

    server
        .register("churn", TenantSpec::mos(4, 2, 2, 0).seed(2))
        .unwrap();
    assert_eq!(registry.get("churn").unwrap().version, 1);
    server
        .submit("churn", "q:a", GenOptions::greedy())
        .unwrap()
        .wait_timeout(Duration::from_secs(60))
        .unwrap()
        .unwrap();
    let (_, misses) = server.cache.stats();
    assert_eq!(misses, 2, "version bump must rebuild factors");

    assert!(server.remove("churn"));
    assert!(matches!(
        server.submit("churn", "q:a", GenOptions::greedy()),
        Err(mos::coordinator::ServeError::UnknownTenant(_))
    ));
    server.shutdown();
}
