//! Coordinator integration: train a real adapter, register it as a tenant,
//! serve requests through the full batcher/cache/server pipeline, and check
//! the answers match direct (non-served) evaluation.

use mos::adapter::mos::router::build_router;
use mos::config::{presets, MethodCfg};
use mos::coordinator::server::HostEngine;
use mos::coordinator::{Registry, Server, Tenant};
use mos::data::tasks::{Task, TaskKind};
use mos::data::Tokenizer;
use mos::train::host::HostBackend;
use mos::train::run;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn trained_tenant_serves_correct_answers() {
    // keep it small: host training on a reduced-batch tiny preset
    let mut cfg = presets::tiny();
    cfg.batch = 8;
    let mc = MethodCfg::mos(8, 2, 2, 1);
    let seed = 0u64;

    let mut be = HostBackend::new(&cfg, &mc, seed);
    let result = run(
        &mut be,
        || Task::new(TaskKind::Recall, seed),
        60,
        2e-2,
        8,
        0,
    )
    .unwrap();
    // the training must at least be making progress; absolute quality is
    // covered by the benches (the core assertion here is served == direct)
    assert!(
        mos::train::final_loss(&result.losses, 5)
            < mos::train::final_loss(&result.losses[..5], 5),
        "training made no progress"
    );

    // register the trained adapter as a tenant; serve the same eval
    // prompts through the coordinator and compare with direct generation.
    let base = be.model.base.clone();
    let params = be.model.params.clone();
    let aux = be.model.aux.clone();
    let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
    registry
        .register(Tenant {
            id: "user".into(),
            mc: mc.clone(),
            params,
            aux: aux.clone(),
            router_seed: seed,
        })
        .unwrap();
    // verify router determinism: rebuilding with the stored seed matches
    assert_eq!(build_router(&cfg, &mc, seed).into_bank(), aux);

    let mut server =
        Server::new(Arc::clone(&registry), cfg.batch, Duration::from_millis(5), 4);
    let base2 = base.clone();
    let cfg2 = cfg.clone();
    server.start(1, move |_| HostEngine {
        cfg: cfg2.clone(),
        base: base2.clone(),
    });

    let task = Task::new(TaskKind::Recall, seed);
    let tk = Tokenizer::new();
    let mut matched = 0;
    let n = 8;
    let mut rxs = Vec::new();
    let mut examples = Vec::new();
    for i in 0..n {
        let ex = task.example("eval", i);
        rxs.push(server.submit("user", &ex.prompt));
        examples.push(ex);
    }
    let mut served_scores = 0.0;
    for (rx, ex) in rxs.into_iter().zip(&examples) {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        served_scores += task.score(ex, &resp.text);
        // served output must equal direct greedy generation
        let mut fwd = |tokens: &[i32]| be.model.forward(tokens);
        let direct = mos::eval::greedy_decode(
            &mut fwd,
            &[tk.prompt_tokens(&ex.prompt)],
            cfg.seq,
            cfg.vocab,
        );
        if tk.decode(&direct[0]) == resp.text {
            matched += 1;
        }
    }
    assert_eq!(
        matched, n,
        "served generations diverge from direct generations"
    );
    let served = 100.0 * served_scores / n as f64;
    assert!(
        (served - result.report.score).abs() < 30.0,
        "served quality {served:.1} wildly differs from direct {:.1}",
        result.report.score
    );
    server.shutdown();
}

#[test]
fn memory_pressure_evicts_and_recovers() {
    let cfg = presets::tiny();
    let mc = MethodCfg::mos(8, 2, 2, 1);
    let one = mos::adapter::params::serving_bytes(&cfg, &mc, 4);
    let registry = Arc::new(Registry::new(cfg.clone(), one * 2 + 100));
    for i in 0..5 {
        let t = Tenant {
            id: format!("t{i}"),
            mc: mc.clone(),
            params: mos::adapter::init_params(&cfg, &mc, i),
            aux: build_router(&cfg, &mc, i).into_bank(),
            router_seed: i,
        };
        registry.register(t).unwrap();
    }
    // only 2 fit
    assert_eq!(registry.len(), 2);
    // evicted tenants can re-register (recovery path)
    let t = Tenant {
        id: "t0".into(),
        mc: mc.clone(),
        params: mos::adapter::init_params(&cfg, &mc, 0),
        aux: build_router(&cfg, &mc, 0).into_bank(),
        router_seed: 0,
    };
    registry.register(t).unwrap();
    assert!(registry.get("t0").is_some());
}
