//! Coordinator integration: train a real adapter, register it as a tenant
//! from a checkpoint spec, serve requests through the full
//! batcher/cache/server pipeline, and check the answers match direct
//! (non-served) evaluation.

use mos::adapter::mos::router::build_router;
use mos::config::{presets, MethodCfg};
use mos::coordinator::{
    EngineRun, GenOptions, HostEngine, Registry, ServeEngine, Server,
    ServerCfg, TenantSpec,
};
use mos::data::tasks::{Task, TaskKind};
use mos::data::Tokenizer;
use mos::train::checkpoint::Checkpoint;
use mos::train::host::HostBackend;
use mos::train::run;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A host engine whose decode steps are artificially slowed, so tests can
/// observe a generation mid-flight without racing the real decode speed.
struct SlowStepEngine {
    inner: HostEngine,
    step_delay: Duration,
}

impl ServeEngine for SlowStepEngine {
    fn forward(
        &mut self,
        tenant: &mos::coordinator::Tenant,
        adapter: &mos::adapter::ServingAdapter,
        tokens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        self.inner.forward(tenant, adapter, tokens)
    }
    fn shape(&self) -> (usize, usize, usize) {
        self.inner.shape()
    }
    fn supports_steps(&self) -> bool {
        true
    }
    fn prefill_rows(
        &mut self,
        runs: &[EngineRun],
        rows: &[usize],
        tokens: &[i32],
        last: &[usize],
    ) -> anyhow::Result<Vec<f32>> {
        self.inner.prefill_rows(runs, rows, tokens, last)
    }
    fn decode_rows(
        &mut self,
        runs: &[EngineRun],
        entries: &[(usize, usize, i32)],
    ) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.step_delay);
        self.inner.decode_rows(runs, entries)
    }
    fn kv_admit(
        &mut self,
        row: usize,
        tenant: &mos::coordinator::Tenant,
        prompt: &[i32],
    ) -> bool {
        self.inner.kv_admit(row, tenant, prompt)
    }
    fn kv_release(&mut self, row: usize) {
        self.inner.kv_release(row)
    }
    fn kv_tenant_bytes(&self, tenant: &mos::coordinator::Tenant) -> usize {
        self.inner.kv_tenant_bytes(tenant)
    }
    fn kv_resident_bytes(&self) -> usize {
        self.inner.kv_resident_bytes()
    }
}

#[test]
fn continuous_batching_admits_late_request_mid_decode() {
    // A request submitted while a long generation is mid-flight must be
    // admitted into the running batch between decode steps and start
    // streaming tokens before the long request completes.
    let mut cfg = presets::tiny();
    cfg.batch = 4;
    let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
    let mut server = Server::new(
        Arc::clone(&registry),
        ServerCfg {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            cache_capacity: 4,
            ..ServerCfg::default()
        },
    );
    server
        .register("tenant", TenantSpec::mos(4, 2, 2, 0).seed(1))
        .unwrap();
    let cfg2 = cfg.clone();
    server.start(1, move |_| SlowStepEngine {
        inner: HostEngine::new(cfg2.clone(), 0),
        step_delay: Duration::from_millis(5),
    });

    // ~40 decode steps at >= 5ms each: a wide admission window
    let long = server
        .submit(
            "tenant",
            "q:long",
            GenOptions::greedy().stop_tokens(Vec::new()),
        )
        .unwrap();
    long.recv_token_timeout(Duration::from_secs(30))
        .expect("long request never streamed");

    // the long generation is now mid-flight; submit a short request
    let late = server
        .submit(
            "tenant",
            "q:late",
            GenOptions::greedy().max_new_tokens(2).stop_tokens(Vec::new()),
        )
        .unwrap();
    late.recv_token_timeout(Duration::from_secs(30))
        .expect("late request never streamed");
    let late_first_at = Instant::now();

    // first-token timestamp check: the long request must still be
    // unresolved at the instant the late request's first token arrived
    assert!(
        long.try_wait().is_none(),
        "late first token at {late_first_at:?} but the long request \
         already resolved — continuous batching did not interleave"
    );
    let late_resp = late.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
    assert_eq!(late_resp.tokens, 2);
    let long_resp = long.wait_timeout(Duration::from_secs(60)).unwrap().unwrap();
    assert!(
        long_resp.tokens > late_resp.tokens,
        "long generation should outlast the late one"
    );
    assert!(
        server.metrics.refilled.load(Ordering::Relaxed) >= 1,
        "late request was not admitted through the refill path"
    );
    server.shutdown();
}

#[test]
fn streaming_tokens_arrive_incrementally_and_match_wait() {
    let mut cfg = presets::tiny();
    cfg.batch = 4;
    let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
    let mut server = Server::new(
        Arc::clone(&registry),
        ServerCfg {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            cache_capacity: 4,
            ..ServerCfg::default()
        },
    );
    server
        .register("tenant", TenantSpec::mos(4, 2, 2, 0).seed(2))
        .unwrap();
    let cfg2 = cfg.clone();
    server.start(1, move |_| SlowStepEngine {
        inner: HostEngine::new(cfg2.clone(), 0),
        step_delay: Duration::from_millis(5),
    });

    let h = server
        .submit(
            "tenant",
            "q:stream",
            GenOptions::greedy().max_new_tokens(10).stop_tokens(Vec::new()),
        )
        .unwrap();
    let mut streamed = Vec::new();
    let first = h
        .recv_token_timeout(Duration::from_secs(30))
        .expect("no first token");
    streamed.push(first);
    // incremental delivery: the request is still unresolved after the
    // first token arrives (more slow steps remain)
    assert!(
        h.try_wait().is_none(),
        "request resolved before the stream finished"
    );
    while let Some(tok) = h.recv_token_timeout(Duration::from_secs(30)) {
        streamed.push(tok);
    }
    let resp = h.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
    assert_eq!(resp.tokens, streamed.len());
    assert_eq!(
        resp.text,
        Tokenizer::new().decode(&streamed),
        "streamed tokens must decode to the one-shot wait text"
    );
    server.shutdown();
}

#[test]
fn trained_tenant_serves_correct_answers() {
    // keep it small: host training on a reduced-batch tiny preset
    let mut cfg = presets::tiny();
    cfg.batch = 8;
    let mc = MethodCfg::mos(8, 2, 2, 1);
    let seed = 0u64;

    let mut be = HostBackend::new(&cfg, &mc, seed);
    let result = run(
        &mut be,
        || Task::new(TaskKind::Recall, seed),
        60,
        2e-2,
        8,
        0,
    )
    .unwrap();
    // the training must at least be making progress; absolute quality is
    // covered by the benches (the core assertion here is served == direct)
    assert!(
        mos::train::final_loss(&result.losses, 5)
            < mos::train::final_loss(&result.losses[..5], 5),
        "training made no progress"
    );

    // register the trained adapter as a tenant (checkpoint spec — the same
    // path a deployment uses); serve the same eval prompts through the
    // coordinator and compare with direct generation.
    let base = be.model.base.clone();
    let params = be.model.params.clone();
    let aux = be.model.aux.clone();
    // verify router determinism: rebuilding with the stored seed matches
    assert_eq!(build_router(&cfg, &mc, seed).into_bank(), aux);

    let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
    let mut server = Server::new(
        Arc::clone(&registry),
        ServerCfg {
            max_batch: cfg.batch,
            max_wait: Duration::from_millis(5),
            cache_capacity: 4,
            ..ServerCfg::default()
        },
    );
    server
        .register(
            "user",
            TenantSpec::from_checkpoint(Checkpoint {
                preset: "tiny".into(),
                mc: mc.clone(),
                router_seed: seed,
                params,
                aux,
            }),
        )
        .unwrap();
    let base2 = base.clone();
    let cfg2 = cfg.clone();
    server.start(1, move |_| HostEngine::with_base(cfg2.clone(), base2.clone()));

    let task = Task::new(TaskKind::Recall, seed);
    let tk = Tokenizer::new();
    let mut matched = 0;
    let n = 8;
    let mut handles = Vec::new();
    let mut examples = Vec::new();
    for i in 0..n {
        let ex = task.example("eval", i);
        handles.push(
            server
                .submit("user", &ex.prompt, GenOptions::greedy())
                .unwrap(),
        );
        examples.push(ex);
    }
    let mut served_scores = 0.0;
    for (h, ex) in handles.into_iter().zip(&examples) {
        let resp = h
            .wait_timeout(Duration::from_secs(120))
            .expect("timed out")
            .expect("request failed");
        served_scores += task.score(ex, &resp.text);
        // served output must equal direct greedy generation
        let mut fwd = |tokens: &[i32]| be.model.forward(tokens);
        let direct = mos::eval::decode(
            &mut fwd,
            &[tk.prompt_tokens(&ex.prompt)],
            &GenOptions::greedy(),
            cfg.seq,
            cfg.vocab,
        );
        if tk.decode(&direct[0]) == resp.text {
            matched += 1;
        }
    }
    assert_eq!(
        matched, n,
        "served generations diverge from direct generations"
    );
    let served = 100.0 * served_scores / n as f64;
    assert!(
        (served - result.report.score).abs() < 30.0,
        "served quality {served:.1} wildly differs from direct {:.1}",
        result.report.score
    );
    server.shutdown();
}

#[test]
fn memory_pressure_evicts_and_recovers() {
    let cfg = presets::tiny();
    let mc = MethodCfg::mos(8, 2, 2, 1);
    let one = mos::adapter::params::serving_bytes(&cfg, &mc, 4);
    let registry = Arc::new(Registry::new(cfg.clone(), one * 2 + 100));
    for i in 0..5 {
        registry
            .register_spec(&format!("t{i}"), TenantSpec::mos(8, 2, 2, 1).seed(i))
            .unwrap();
    }
    // only 2 fit
    assert_eq!(registry.len(), 2);
    // evicted tenants can re-register (recovery path)
    registry
        .register_spec("t0", TenantSpec::mos(8, 2, 2, 1).seed(0))
        .unwrap();
    assert!(registry.get("t0").is_some());
}

#[test]
fn serving_contract_under_churn() {
    // end-to-end lifecycle: register -> serve -> re-register (version
    // bump) -> serve fresh -> remove -> typed UnknownTenant at submit
    let mut cfg = presets::tiny();
    cfg.batch = 4;
    let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
    let mut server = Server::new(
        Arc::clone(&registry),
        ServerCfg {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            cache_capacity: 4,
            ..ServerCfg::default()
        },
    );
    let cfg2 = cfg.clone();
    server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));

    server
        .register("churn", TenantSpec::mos(4, 2, 2, 0).seed(1))
        .unwrap();
    let r1 = server
        .submit("churn", "q:a", GenOptions::greedy())
        .unwrap()
        .wait_timeout(Duration::from_secs(60))
        .unwrap()
        .unwrap();
    assert_eq!(r1.tenant, "churn");

    server
        .register("churn", TenantSpec::mos(4, 2, 2, 0).seed(2))
        .unwrap();
    assert_eq!(registry.get("churn").unwrap().version, 1);
    server
        .submit("churn", "q:a", GenOptions::greedy())
        .unwrap()
        .wait_timeout(Duration::from_secs(60))
        .unwrap()
        .unwrap();
    let (_, misses) = server.cache.stats();
    assert_eq!(misses, 2, "version bump must rebuild factors");

    assert!(server.remove("churn"));
    assert!(matches!(
        server.submit("churn", "q:a", GenOptions::greedy()),
        Err(mos::coordinator::ServeError::UnknownTenant(_))
    ));
    server.shutdown();
}
