//! Front-door integration: the HTTP edge must be a transparent window
//! onto the coordinator — byte-identical token streams, and the same
//! cancel / deadline / shutdown semantics (with the same resource
//! accounting) as an in-process `ResponseHandle`.

use mos::config::presets;
use mos::coordinator::{
    EngineRun, GenOptions, HostEngine, KvStats, Registry, ServeEngine,
    Server, ServerCfg, TenantSpec,
};
use mos::frontend::{http, Frontend, FrontendCfg};
use mos::util::json::Json;
use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// A host engine whose decode steps are artificially slowed, so tests can
/// hang up / expire a generation mid-flight without racing the real
/// decode speed. `Duration::ZERO` leaves it at full speed.
struct SlowStepEngine {
    inner: HostEngine,
    step_delay: Duration,
}

impl ServeEngine for SlowStepEngine {
    fn forward(
        &mut self,
        tenant: &mos::coordinator::Tenant,
        adapter: &mos::adapter::ServingAdapter,
        tokens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        self.inner.forward(tenant, adapter, tokens)
    }
    fn shape(&self) -> (usize, usize, usize) {
        self.inner.shape()
    }
    fn supports_steps(&self) -> bool {
        true
    }
    fn prefill_rows(
        &mut self,
        runs: &[EngineRun],
        rows: &[usize],
        tokens: &[i32],
        last: &[usize],
    ) -> anyhow::Result<Vec<f32>> {
        self.inner.prefill_rows(runs, rows, tokens, last)
    }
    fn decode_rows(
        &mut self,
        runs: &[EngineRun],
        entries: &[(usize, usize, i32)],
    ) -> anyhow::Result<Vec<f32>> {
        if self.step_delay > Duration::ZERO {
            thread::sleep(self.step_delay);
        }
        self.inner.decode_rows(runs, entries)
    }
    fn kv_admit(
        &mut self,
        row: usize,
        tenant: &mos::coordinator::Tenant,
        prompt: &[i32],
    ) -> bool {
        self.inner.kv_admit(row, tenant, prompt)
    }
    fn kv_release(&mut self, row: usize) {
        self.inner.kv_release(row)
    }
    fn kv_tenant_bytes(&self, tenant: &mos::coordinator::Tenant) -> usize {
        self.inner.kv_tenant_bytes(tenant)
    }
    fn kv_resident_bytes(&self) -> usize {
        self.inner.kv_resident_bytes()
    }
}

/// Tiny server with one engine worker and "alice" registered, fronted by
/// the HTTP edge on an ephemeral loopback port. A `probe` also disables
/// prefix sharing so a cancel storm drains the KV pool to exactly zero.
fn serve_edge(
    step_delay: Duration,
    probe: Option<Arc<KvStats>>,
) -> (Arc<Server>, Frontend) {
    let cfg = presets::tiny();
    let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
    let mut server = Server::new(
        registry,
        ServerCfg { max_batch: 4, ..ServerCfg::default() },
    );
    server
        .register("alice", TenantSpec::mos(4, 2, 2, 1).seed(7))
        .unwrap();
    let cfg2 = cfg.clone();
    server.start(1, move |_| {
        let mut inner = HostEngine::new(cfg2.clone(), 0);
        if let Some(p) = &probe {
            inner = inner.no_prefix_share().kv_stats(Arc::clone(p));
        }
        SlowStepEngine { inner, step_delay }
    });
    let server = Arc::new(server);
    let fe = Frontend::start(
        Arc::clone(&server),
        "127.0.0.1:0",
        FrontendCfg {
            poll: Duration::from_millis(5),
            ..FrontendCfg::default()
        },
    )
    .unwrap();
    (server, fe)
}

/// Full `POST /v1/generate` round trip: returns the status, the streamed
/// token ids in arrival order, and the terminal `{"done":...}` line.
fn generate_http(
    addr: SocketAddr,
    tenant: &str,
    prompt: &str,
    max_new_tokens: usize,
    deadline_ms: Option<u64>,
) -> (u16, Vec<i32>, Option<Json>) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut fields = vec![
        ("tenant", Json::str(tenant)),
        ("prompt", Json::str(prompt)),
        ("max_new_tokens", Json::num(max_new_tokens as f64)),
    ];
    if let Some(ms) = deadline_ms {
        fields.push(("deadline_ms", Json::num(ms as f64)));
    }
    let body = Json::obj(fields).to_string();
    stream
        .write_all(
            format!(
                "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
    let (status, _headers) = http::read_response_head(&mut stream).unwrap();
    if status != 200 {
        return (status, Vec::new(), None);
    }
    let mut tokens = Vec::new();
    let mut done = None;
    while let Ok(Some(line)) = http::read_chunk(&mut stream) {
        let json = Json::parse(std::str::from_utf8(&line).unwrap().trim())
            .expect("stream line is not JSON");
        if let Some(t) = json.get("token").and_then(Json::as_f64) {
            tokens.push(t as i32);
        } else if json.get("done").is_some() {
            done = Some(json);
        }
    }
    (status, tokens, done)
}

#[test]
fn http_stream_matches_in_process_token_sequence() {
    let (server, fe) = serve_edge(Duration::ZERO, None);
    let addr = fe.local_addr();

    // in-process reference: same tenant, same prompt, same options
    let h = server
        .submit("alice", "q:42", GenOptions::greedy().max_new_tokens(12))
        .unwrap();
    let resp = h.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
    let reference: Vec<i32> = h.tokens().collect();
    assert!(!reference.is_empty());

    let (status, tokens, done) =
        generate_http(addr, "alice", "q:42", 12, None);
    assert_eq!(status, 200);
    assert_eq!(tokens, reference, "HTTP stream diverged from in-process");
    let done = done.expect("stream ended without a terminal line");
    assert!(done.get("error").is_none(), "{done:?}");
    assert_eq!(done.req_str("text").unwrap(), resp.text);
    assert_eq!(done.req_usize("tokens").unwrap(), resp.tokens);
    assert!(done.get("latency_ms").and_then(Json::as_f64).unwrap() > 0.0);
    drop(fe);
}

#[test]
fn connection_drop_cancels_and_frees_admission_and_kv() {
    let probe = Arc::new(KvStats::default());
    let (server, fe) =
        serve_edge(Duration::from_millis(3), Some(Arc::clone(&probe)));
    let addr = fe.local_addr();

    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let body =
        r#"{"tenant":"alice","prompt":"q:drop","max_new_tokens":200}"#;
    stream
        .write_all(
            format!(
                "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
    let (status, _) = http::read_response_head(&mut stream).unwrap();
    assert_eq!(status, 200);
    // first token line proves the decode is mid-flight
    assert!(http::read_chunk(&mut stream).unwrap().is_some());
    drop(stream); // hang up — over HTTP this IS the cancel

    let t0 = Instant::now();
    while server.metrics.cancelled.load(Ordering::Relaxed) == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(15),
            "connection drop never cancelled the request"
        );
        thread::sleep(Duration::from_millis(5));
    }
    // the cancel must return both the admission slot and the KV pages
    let t0 = Instant::now();
    while server.batcher.depth() != 0 || probe.resident_bytes() != 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(15),
            "leaked after drop: depth={} kv_bytes={}",
            server.batcher.depth(),
            probe.resident_bytes()
        );
        thread::sleep(Duration::from_millis(5));
    }
    // and the freed slot serves the next request
    let h = server
        .submit("alice", "q:next", GenOptions::greedy().max_new_tokens(4))
        .unwrap();
    assert!(h.wait_timeout(Duration::from_secs(30)).unwrap().is_ok());
    drop(fe);
}

#[test]
fn deadline_expires_cleanly_over_http() {
    let (server, fe) = serve_edge(Duration::from_millis(3), None);
    let addr = fe.local_addr();
    // 3ms/step against a 20ms budget: expires mid-decode, after the 200
    // status and a few token lines have already gone out
    let (status, tokens, done) =
        generate_http(addr, "alice", "q:tight", 200, Some(20));
    assert_eq!(
        status, 200,
        "mid-stream expiry ends in a terminal line, not an error status"
    );
    let done = done.expect("missing terminal line");
    assert_eq!(done.req_str("kind").unwrap(), "deadline", "{done:?}");
    assert!(tokens.len() < 200, "deadline never fired");
    assert_eq!(server.metrics.expired.load(Ordering::Relaxed), 1);
    assert_eq!(server.batcher.depth(), 0);
    drop(fe);
}

#[test]
fn frontend_shutdown_drains_in_flight_stream() {
    let (server, mut fe) = serve_edge(Duration::from_millis(2), None);
    let addr = fe.local_addr();
    let client = thread::spawn(move || {
        generate_http(addr, "alice", "q:drain", 24, None)
    });
    // let the stream get going, then shut the edge down under it
    thread::sleep(Duration::from_millis(30));
    fe.shutdown();
    let (status, tokens, done) =
        client.join().expect("client hung across frontend shutdown");
    assert_eq!(status, 200);
    let done =
        done.expect("shutdown severed the stream before its terminal line");
    assert!(done.get("error").is_none(), "{done:?}");
    assert_eq!(done.req_usize("tokens").unwrap(), tokens.len());
    // the coordinator outlives its edge: in-process serving still works
    let h = server
        .submit(
            "alice",
            "q:post-edge",
            GenOptions::greedy().max_new_tokens(4),
        )
        .unwrap();
    assert!(h.wait_timeout(Duration::from_secs(30)).unwrap().is_ok());
}
