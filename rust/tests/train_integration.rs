//! Cross-backend integration: the host oracle and the PJRT artifacts must
//! agree on the *semantics* of training (same init conventions, same
//! optimizer, comparable learning behaviour), and checkpoints must
//! round-trip into servable tenants.

use mos::config::{presets, MethodCfg};
use mos::data::tasks::{Task, TaskKind};
use mos::train::checkpoint::Checkpoint;
use mos::train::host::HostBackend;
use mos::train::{final_loss, run};

#[test]
fn all_methods_learn_on_host() {
    // every adapter family must be able to fit `recall` at tiny scale
    let mut cfg = presets::tiny();
    cfg.batch = 8;
    for mc in [
        MethodCfg::lora(2),
        MethodCfg::mos(8, 2, 2, 1),
        MethodCfg::vera(16),
        MethodCfg::tied(8),
        MethodCfg::prolora(8, 4),
    ] {
        let mut be = HostBackend::new(&cfg, &mc, 0);
        let r = run(&mut be, || Task::new(TaskKind::Recall, 0), 40, 2e-2, 0, 0)
            .unwrap();
        let first = final_loss(&r.losses[..5], 5);
        let last = final_loss(&r.losses, 5);
        assert!(
            last < first - 0.15,
            "{:?} failed to learn: {first:.3} -> {last:.3}",
            mc.method
        );
    }
}

#[test]
fn ablations_preserve_budget_and_learn() {
    let mut cfg = presets::tiny();
    cfg.batch = 8;
    use mos::adapter::params::trainable_params;
    let full = MethodCfg::mos(8, 2, 2, 1);
    let budget = trainable_params(&cfg, &full);
    for (name, mc) in [
        ("-sp", MethodCfg::mos(8, 2, 2, 0)),
        ("-vs", MethodCfg::mos(8, 1, 2, 1)),
        (
            "-pd",
            MethodCfg { pair_dissociation: false, ..MethodCfg::mos(8, 2, 2, 1) },
        ),
    ] {
        assert_eq!(
            trainable_params(&cfg, &mc),
            budget,
            "{name} changed the trainable budget"
        );
        let mut be = HostBackend::new(&cfg, &mc, 0);
        let r = run(&mut be, || Task::new(TaskKind::Recall, 0), 40, 2e-2, 0, 0)
            .unwrap();
        assert!(
            final_loss(&r.losses, 5) < final_loss(&r.losses[..5], 5) - 0.15,
            "{name} failed to learn"
        );
    }
}

#[test]
fn checkpoint_roundtrip_preserves_behaviour() {
    let mut cfg = presets::tiny();
    cfg.batch = 4;
    let mc = MethodCfg::mos(8, 2, 2, 1);
    let mut be = HostBackend::new(&cfg, &mc, 0);
    run(&mut be, || Task::new(TaskKind::Recall, 0), 20, 2e-2, 0, 0).unwrap();

    let ck = Checkpoint {
        preset: "tiny".into(),
        mc: mc.clone(),
        router_seed: 0,
        params: be.model.params.clone(),
        aux: be.model.aux.clone(),
    };
    let dir = std::env::temp_dir().join("mos_int_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    ck.save(&dir).unwrap();
    let loaded = Checkpoint::load(&dir).unwrap();

    // a model rebuilt from the checkpoint produces identical logits
    let tokens: Vec<i32> = (0..cfg.batch * cfg.seq)
        .map(|i| (i % cfg.vocab) as i32)
        .collect();
    let want = be.model.forward(&tokens);
    let mut rebuilt = mos::model::HostModel::new(
        cfg.clone(),
        loaded.mc,
        be.model.base.clone(),
        loaded.params,
        loaded.aux,
    );
    let got = rebuilt.forward(&tokens);
    assert_eq!(want.len(), got.len());
    for (a, b) in want.iter().zip(&got) {
        assert_eq!(a, b, "checkpoint did not preserve behaviour");
    }
}

#[test]
fn mos_beats_pure_sharing_at_equal_budget() {
    // the paper's core qualitative claim, as a smoke-level integration test
    // (full sweeps live in the benches): differentiated MoS should reach a
    // lower training loss than pure sharing on a mixed workload.
    let mut cfg = presets::tiny();
    cfg.batch = 8;
    let steps = 60;
    let task = || Task::new(TaskKind::Recall, 0);

    let mut pure = HostBackend::new(&cfg, &MethodCfg::pure_sharing(2, cfg.blocks), 0);
    let r_pure = run(&mut pure, task, steps, 2e-2, 0, 0).unwrap();
    let mut mos_be = HostBackend::new(&cfg, &MethodCfg::mos(8, 2, 2, 1), 0);
    let r_mos = run(&mut mos_be, task, steps, 2e-2, 0, 0).unwrap();

    let lp = final_loss(&r_pure.losses, 10);
    let lm = final_loss(&r_mos.losses, 10);
    // allow slack: single-seed, tiny model — require MoS not to be worse
    // by more than noise, and report values for the record.
    eprintln!("pure={lp:.4} mos={lm:.4}");
    assert!(
        lm < lp + 0.05,
        "MoS ({lm:.4}) should not lose to pure sharing ({lp:.4})"
    );
}
