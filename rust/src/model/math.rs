//! Dense math kernels for the host model (row-major f32).
//!
//! Loop orders are chosen for contiguous inner loops; the perf pass
//! (EXPERIMENTS.md §Perf) iterates on these.

/// Dot product with 4 independent accumulators (breaks the fp dependency
/// chain so the autovectorizer emits wide fma; EXPERIMENTS.md §Perf).
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

/// c (m,n) += a (m,k) @ b^T where b is (n,k). Contiguous dot products.
pub fn matmul_nt_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            crow[j] += dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// c (m,n) = a (m,k) @ b^T.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_nt_acc(a, b, &mut c, m, k, n);
    c
}

/// c (m,n) += a (m,k) @ b where b is (k,n). axpy inner loop.
pub fn matmul_nn_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_nn_acc(a, b, &mut c, m, k, n);
    c
}

/// c (m,n) += a^T @ b where a is (k,m), b is (k,n). axpy over k.
pub fn matmul_tn_acc(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_tn_acc(a, b, &mut c, k, m, n);
    c
}

/// In-place numerically-stable softmax over the last `n` of each row.
pub fn softmax_rows(x: &mut [f32], rows: usize, n: usize) {
    for i in 0..rows {
        let row = &mut x[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// SiLU (swish) activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// d silu / dx.
#[inline]
pub fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn naive_matmul(
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        at: bool,
        bt: bool,
    ) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    let av = if at { a[p * m + i] } else { a[i * k + p] };
                    let bv = if bt { b[j * k + p] } else { b[p * n + j] };
                    acc += av * bv;
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn matmul_variants_match_naive() {
        prop::check("matmul-variants", 25, |rng| {
            let m = rng.range(1, 9);
            let k = rng.range(1, 9);
            let n = rng.range(1, 9);
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let bt: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
            let bn: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let at: Vec<f32> = (0..k * m).map(|_| rng.normal()).collect();
            prop::assert_allclose(
                &matmul_nt(&a, &bt, m, k, n),
                &naive_matmul(&a, &bt, m, k, n, false, true),
                1e-4,
                1e-4,
            )?;
            prop::assert_allclose(
                &matmul_nn(&a, &bn, m, k, n),
                &naive_matmul(&a, &bn, m, k, n, false, false),
                1e-4,
                1e-4,
            )?;
            prop::assert_allclose(
                &matmul_tn(&at, &bn, k, m, n),
                &naive_matmul(&at, &bn, m, k, n, true, false),
                1e-4,
                1e-4,
            )
        });
    }

    #[test]
    fn softmax_rows_properties() {
        let mut rng = Rng::new(1, 0);
        let (rows, n) = (5, 9);
        let mut x: Vec<f32> = (0..rows * n).map(|_| rng.normal() * 4.0).collect();
        let orig = x.clone();
        softmax_rows(&mut x, rows, n);
        for i in 0..rows {
            let row = &x[i * n..(i + 1) * n];
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&v| v >= 0.0));
            // argmax preserved
            let am_in = (0..n)
                .max_by(|&a, &b| orig[i * n + a].total_cmp(&orig[i * n + b]))
                .unwrap();
            let am_out =
                (0..n).max_by(|&a, &b| row[a].total_cmp(&row[b])).unwrap();
            assert_eq!(am_in, am_out);
        }
    }

    #[test]
    fn softmax_extreme_values_stable() {
        let mut x = vec![1000.0, 1000.0, -1000.0];
        softmax_rows(&mut x, 1, 3);
        assert!((x[0] - 0.5).abs() < 1e-6);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn silu_grad_matches_fd() {
        for &x in &[-3.0f32, -0.5, 0.0, 0.7, 2.5] {
            let eps = 1e-3;
            let fd = (silu(x + eps) - silu(x - eps)) / (2.0 * eps);
            assert!((fd - silu_grad(x)).abs() < 1e-4, "x={x}");
        }
    }
}
