//! Dense math kernels for the host model (row-major f32): a blocked,
//! panel-packed, multithreaded GEMM engine plus softmax/activation helpers.
//!
//! ## Tiling scheme
//!
//! Every `matmul_*` entry funnels into one engine, [`gemm`] /
//! [`gemm_with`]: `C (m,n) += alpha * op(A) (m,k) @ op(B) (k,n)` where
//! `op` is identity or transpose ([`Trans`]), so all four storage
//! combinations (`nt`, `nn`, `tn`, `tt`) share a single optimized path.
//!
//! * **Microkernel** — a register-tiled `MR x NR` (4x8) block of C held in
//!   independent accumulators. The tile ships in explicit-SIMD flavors
//!   ([`Kernel`]): 256-bit AVX and 128-bit SSE2 `core::arch` kernels plus
//!   the portable scalar tile, selected once per process by runtime
//!   feature detection (override with `MOS_SIMD=0|auto|4|8`). Every SIMD
//!   tile performs the scalar tile's exact per-element mul/add sequence
//!   (separate mul and add — **no fma**), so all kernels are bitwise
//!   interchangeable and the canonical-order contracts below hold for any
//!   selection.
//! * **Packing** — B is packed once per call into `NR`-wide column panels
//!   (`KC`-deep blocks, k-major inside each panel) and A into `MR`-wide
//!   row panels per `(row-block, k-block)`, so the microkernel reads both
//!   operands contiguously regardless of the source layout/transpose.
//! * **Blocking** — k is split into `KC` blocks (packed-B block stays
//!   cache-resident), rows into `MC` blocks (packed-A fits L2).
//! * **Threading** — row-blocks of C are distributed over the process
//!   global [`pool`] (worker count from `MOS_THREADS`, default
//!   `available_parallelism`). Each C element is accumulated by exactly
//!   one worker in the same k-order regardless of the worker count, so
//!   results are **bitwise identical** for any `MOS_THREADS` (see the
//!   thread-invariance tests).
//! * **Small shapes** fall back to the scalar kernels (packing overhead
//!   dominates below ~64k flops); `m = 1` decode rows use a
//!   column-partitioned dot/axpy path instead of row tiles.
//!
//! Scratch buffers (packing panels, per-head attention temporaries, the
//! backward pass) come from a per-thread [`Arena`] via [`scratch_take`] /
//! [`scratch_put`] so steady-state training/serving does not allocate.

use crate::util::threadpool::{self, ThreadPool};
use std::cell::RefCell;
use std::sync::OnceLock;

// ---------------------------------------------------------------------------
// thread pool
// ---------------------------------------------------------------------------

/// Process-global worker pool for GEMM and factor precompute. Sized by
/// `MOS_THREADS` (default: `available_parallelism`). Built lazily on first
/// use so short CLI paths never spawn workers.
pub fn pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::env::var("MOS_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            });
        ThreadPool::new(n)
    })
}

/// Pool for an auto-parallel kernel call from the current thread: the
/// global pool, unless this thread *is* a pool worker (nested fan-out runs
/// serial — see `threadpool::in_worker`).
pub(crate) fn auto_pool() -> Option<&'static ThreadPool> {
    if threadpool::in_worker() {
        None
    } else {
        Some(pool())
    }
}

// ---------------------------------------------------------------------------
// scratch arena
// ---------------------------------------------------------------------------

/// A recycling pool of `Vec<f32>` scratch buffers: `take` hands out a
/// zero-filled buffer (reusing the allocation of a previously `put` one
/// when large enough), so hot loops stop allocating fresh vectors.
pub struct Arena {
    free: Vec<Vec<f32>>,
    /// Total capacity (floats) parked in `free`.
    free_floats: usize,
    /// Park limit, [`MAX_FREE_FLOATS`] outside tests.
    cap: usize,
}

impl Default for Arena {
    fn default() -> Arena {
        Arena { free: Vec::new(), free_floats: 0, cap: MAX_FREE_FLOATS }
    }
}

/// Cap on the floats a thread's free list may park ([`Arena::put`] past
/// it drops the buffer instead of keeping it). The steady-state working
/// sets (GEMM packing, inference buffers, the backward sweep) sit orders
/// of magnitude below this, so the cap never binds on the arena-balanced
/// hot paths — it exists to bound worker memory when callers recycle
/// buffers the arena never handed out (e.g. the serving loop putting an
/// engine's freshly allocated full-window logits every step: without a
/// cap the free list grows by one window-sized buffer per token).
const MAX_FREE_FLOATS: usize = 1 << 26; // 64 M floats = 256 MB

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    /// A zero-filled buffer of exactly `len` elements.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = match self.free.iter().position(|b| b.capacity() >= len) {
            Some(i) => self.free.swap_remove(i),
            None => self.free.pop().unwrap_or_default(),
        };
        self.free_floats -= v.capacity().min(self.free_floats);
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Return a buffer for reuse by a later `take` (dropped instead once
    /// the free list holds [`MAX_FREE_FLOATS`]).
    pub fn put(&mut self, v: Vec<f32>) {
        if v.capacity() == 0 || self.free_floats + v.capacity() > self.cap {
            return;
        }
        self.free_floats += v.capacity();
        self.free.push(v);
    }

    #[cfg(test)]
    fn with_cap(cap: usize) -> Arena {
        Arena { cap, ..Arena::default() }
    }
}

thread_local! {
    static SCRATCH: RefCell<Arena> = RefCell::new(Arena::new());
}

/// Take a zero-filled buffer from the current thread's scratch arena.
pub fn scratch_take(len: usize) -> Vec<f32> {
    SCRATCH.with(|a| a.borrow_mut().take(len))
}

/// Return a buffer to the current thread's scratch arena.
pub fn scratch_put(v: Vec<f32>) {
    SCRATCH.with(|a| a.borrow_mut().put(v))
}

// ---------------------------------------------------------------------------
// GEMM engine
// ---------------------------------------------------------------------------

/// Storage of an operand: `N` = stored as the logical matrix, `T` = stored
/// as its transpose (so logical `A (m,k)` with `Trans::T` is a `(k,m)`
/// row-major buffer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trans {
    N,
    T,
}

/// Microkernel tile height (C rows per register tile).
const MR: usize = 4;
/// Microkernel tile width (C cols per register tile).
pub(crate) const NR: usize = 8;
/// k-blocking: depth of one packed panel block.
pub(crate) const KC: usize = 256;
/// Row-blocking: A rows packed per inner block (multiple of MR).
const MC: usize = 64;
/// Column-blocking: packed-B columns walked per group (multiple of NR).
/// Bounds the packed-B working set of the inner loops to `KC * NC` floats
/// (~512 KB) — without it a row-block streams the *entire* packed B per
/// k-block, which falls out of cache at llama-scale n. Per-element k-order
/// is untouched (the group loop sits outside the k loop), so results stay
/// bitwise identical to the ungrouped walk.
const NC: usize = 512;
/// Below this many flops the scalar kernels win (packing overhead).
const SMALL_FLOPS: usize = 1 << 16;
/// Below this many flops a single core is faster than fan-out.
pub(crate) const PAR_FLOPS: usize = 1 << 21;

pub(crate) fn div_up(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

// ---------------------------------------------------------------------------
// microkernel selection (explicit SIMD)
// ---------------------------------------------------------------------------

/// Microkernel flavor for the blocked path's `MR x NR` register tile.
///
/// All flavors execute the *same* per-element IEEE-754 operation sequence
/// (independent accumulator per C element, ascending-k mul-then-add, no
/// fma), so they are bitwise interchangeable — the choice affects speed
/// only, and every canonical-order contract (thread invariance, decode
/// vs. prefill row batching) holds identically under each of them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar tile (the autovectorizer may still emit SIMD).
    Scalar,
    /// 128-bit SSE2 lanes (width 4): part of the x86_64 baseline, so it
    /// is always runnable on this arch.
    #[cfg(target_arch = "x86_64")]
    Sse4,
    /// 256-bit AVX lanes (width 8): runtime-detected, so a baseline
    /// `x86-64` build still uses 256-bit ops on hardware that has them.
    #[cfg(target_arch = "x86_64")]
    Avx8,
}

impl Kernel {
    /// Stable name used by `BENCH_gemm.json` and the bench gates.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Kernel::Sse4 => "sse4",
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx8 => "avx8",
        }
    }

    /// Lane width in f32 elements (1 for the scalar tile).
    pub fn width(self) -> usize {
        match self {
            Kernel::Scalar => 1,
            #[cfg(target_arch = "x86_64")]
            Kernel::Sse4 => 4,
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx8 => 8,
        }
    }

    /// Whether the current CPU can run this kernel.
    pub fn supported(self) -> bool {
        match self {
            Kernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Sse4 => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx8 => std::arch::is_x86_feature_detected!("avx"),
        }
    }
}

/// Every kernel compiled into this build, widest last. Not all are
/// necessarily runnable at runtime — filter with [`Kernel::supported`].
pub fn compiled_kernels() -> &'static [Kernel] {
    #[cfg(target_arch = "x86_64")]
    {
        &[Kernel::Scalar, Kernel::Sse4, Kernel::Avx8]
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        &[Kernel::Scalar]
    }
}

/// Widest supported kernel with lane width `<= max_width` — the
/// deterministic fallback chain 8 → 4 → scalar.
fn widest_supported(max_width: usize) -> Kernel {
    #[cfg(target_arch = "x86_64")]
    {
        if max_width >= 8 && Kernel::Avx8.supported() {
            return Kernel::Avx8;
        }
        if max_width >= 4 {
            return Kernel::Sse4;
        }
    }
    let _ = max_width;
    Kernel::Scalar
}

/// The process-wide microkernel, selected once from `MOS_SIMD`:
/// * `0` / `scalar` — pin the scalar tile;
/// * `auto` or unset — widest runtime-supported lane width;
/// * a width (`4`, `8`) — that lane width, falling back deterministically
///   (8 → 4 → scalar) when the CPU or build lacks it.
///
/// Selection never changes results (see [`Kernel`]); benches pin kernels
/// explicitly through [`gemm_with_kernel`] instead of re-reading the env.
pub fn selected_kernel() -> Kernel {
    static SEL: OnceLock<Kernel> = OnceLock::new();
    *SEL.get_or_init(|| match std::env::var("MOS_SIMD").ok().as_deref() {
        None => widest_supported(usize::MAX),
        Some(s) => match s.trim() {
            "auto" | "" => widest_supported(usize::MAX),
            "scalar" => Kernel::Scalar,
            w => match w.parse::<usize>() {
                Ok(w) => widest_supported(w),
                Err(_) => widest_supported(usize::MAX),
            },
        },
    })
}

/// `c (m,n) += alpha * op(a) @ op(b)` on the auto-selected pool (global
/// pool, or inline when already on a pool worker).
pub fn gemm(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    gemm_with(auto_pool(), m, n, k, alpha, a, ta, b, tb, c)
}

/// [`gemm`] with an explicit pool (`None` = single-threaded). Benches and
/// the thread-invariance tests pin pools through this entry.
#[allow(clippy::too_many_arguments)]
pub fn gemm_with(
    pool: Option<&ThreadPool>,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    gemm_dispatch(selected_kernel(), pool, m, n, k, alpha, a, ta, b, tb, c)
}

/// [`gemm_with`] with the blocked path's microkernel pinned explicitly
/// (the per-kernel bench arms and lane-width invariance tests; normal
/// callers go through the `MOS_SIMD` selection). Shapes below the tile /
/// flop thresholds take the same scalar fallbacks as [`gemm_with`] —
/// kernels are bitwise interchangeable, so the pin affects speed only.
#[allow(clippy::too_many_arguments)]
pub fn gemm_with_kernel(
    kernel: Kernel,
    pool: Option<&ThreadPool>,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    debug_assert!(kernel.supported());
    gemm_dispatch(kernel, pool, m, n, k, alpha, a, ta, b, tb, c)
}

/// The one shape dispatch behind [`gemm_with`] / [`gemm_with_kernel`].
#[allow(clippy::too_many_arguments)]
fn gemm_dispatch(
    kernel: Kernel,
    pool: Option<&ThreadPool>,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(n)
        .saturating_mul(k);
    if m == 1 {
        // decode row: no row tiles to pack; dot/axpy split across columns
        return gemm_row(pool.filter(|_| flops >= PAR_FLOPS), n, k, alpha, a, b, tb, c);
    }
    if m < MR {
        // too few rows for a register tile (e.g. low-rank dA: m = r); below
        // the parallel threshold use the scalar kernels, above it run each
        // row through the column-partitioned path (a low-rank backward GEMM
        // can be many MFLOP even with m = 2)
        if flops < PAR_FLOPS || pool.is_none() {
            return gemm_small(m, n, k, alpha, a, ta, b, tb, c);
        }
        let mut arow = scratch_take(k);
        for i in 0..m {
            match ta {
                Trans::N => arow.copy_from_slice(&a[i * k..(i + 1) * k]),
                Trans::T => {
                    for (p, v) in arow.iter_mut().enumerate() {
                        *v = a[p * m + i];
                    }
                }
            }
            gemm_row(pool, n, k, alpha, &arow, b, tb, &mut c[i * n..(i + 1) * n]);
        }
        scratch_put(arow);
        return;
    }
    if flops < SMALL_FLOPS {
        return gemm_small(m, n, k, alpha, a, ta, b, tb, c);
    }
    let pool = pool.filter(|_| flops >= PAR_FLOPS);
    gemm_blocked_k(kernel, pool, m, n, k, alpha, a, ta, b, tb, c)
}

/// Canonical-order GEMM: `c (m,n) += alpha * op(a) @ op(b)` with a
/// per-element operation sequence that does **not** depend on the shape.
///
/// Every C element is accumulated in ascending-k order (mul, then add),
/// with `alpha` applied once per `KC` block at writeback — exactly the
/// per-element order of the blocked/tiled path. Shapes that the tiled
/// path already serves (`m >= MR` and above the small-flops threshold)
/// are forwarded to it unchanged; everything else runs a scalar kernel
/// that replicates the same order instead of the multi-accumulator `dot`
/// used by the throughput-first small/`m = 1` paths.
///
/// Why it exists: the transformer's *inference* path must produce
/// bitwise-identical activations regardless of how many rows were
/// batched together. The KV-cached decode step computes one position
/// (`m = live rows`, as small as 1) and must bit-match the full-window
/// forward (`m = batch * seq`, always on the tiled path at preset
/// sizes), and continuous batching means a request's logits must not
/// depend on how many neighbours shared its decode step. The backward
/// pass has no such contract and stays on the faster [`gemm`] dispatch.
pub fn gemm_canon(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    gemm_canon_dispatch(true, m, n, k, alpha, a, ta, b, tb, c)
}

/// Single-threaded [`gemm_canon`] (`parallel = false` pins the pool off):
/// bitwise identical — the blocked path's per-element order does not
/// depend on the worker count. [`gemm_canon_batch`] runs its sub-problems
/// through this so a sub-GEMM inside a pool worker never nests fan-out.
#[allow(clippy::too_many_arguments)]
fn gemm_canon_serial(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    gemm_canon_dispatch(false, m, n, k, alpha, a, ta, b, tb, c)
}

/// The one canonical-order shape dispatch [`gemm_canon`] and
/// [`gemm_canon_serial`] share — a single copy so the bitwise contract
/// cannot drift between the pooled and serial entries.
#[allow(clippy::too_many_arguments)]
fn gemm_canon_dispatch(
    parallel: bool,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(n)
        .saturating_mul(k);
    if m >= MR && flops >= SMALL_FLOPS {
        let pool = if parallel {
            auto_pool().filter(|_| flops >= PAR_FLOPS)
        } else {
            None
        };
        return gemm_blocked(pool, m, n, k, alpha, a, ta, b, tb, c);
    }
    gemm_canon_small(m, n, k, alpha, a, ta, b, tb, c)
}

/// `nb` independent canonical-order GEMMs in one call:
/// `c_i (m,n) += alpha * op(a_i) @ op(b_i)` for `i in 0..nb`, with the
/// operands packed contiguously (`a` is `nb * m * k`, `b` is `nb * k * n`,
/// `c` is `nb * m * n`).
///
/// This exists for per-head attention: a single head's score/context GEMM
/// is far below [`PAR_FLOPS`], so dispatching heads one by one leaves the
/// pool idle. Batching every `(batch, head)` sub-problem into one call
/// lets the *batch* dimension feed the pool whole sub-GEMMs, while each
/// sub-problem still runs the exact [`gemm_canon`] per-element order —
/// results are bitwise identical to `nb` individual [`gemm_canon`] calls,
/// for any worker count (each `c_i` is written by exactly one worker).
#[allow(clippy::too_many_arguments)]
pub fn gemm_canon_batch(
    nb: usize,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), nb * m * k);
    debug_assert_eq!(b.len(), nb * k * n);
    debug_assert_eq!(c.len(), nb * m * n);
    if nb == 0 || m == 0 || n == 0 || k == 0 {
        return;
    }
    let (asz, bsz, csz) = (m * k, k * n, m * n);
    let sub = |i: usize, ci: &mut [f32]| {
        gemm_canon_serial(
            m,
            n,
            k,
            alpha,
            &a[i * asz..(i + 1) * asz],
            ta,
            &b[i * bsz..(i + 1) * bsz],
            tb,
            ci,
        )
    };
    let total_flops = 2usize
        .saturating_mul(nb)
        .saturating_mul(m)
        .saturating_mul(n)
        .saturating_mul(k);
    // don't even build the pool below the parallel threshold
    let pool = if nb > 1 && total_flops >= PAR_FLOPS {
        auto_pool()
    } else {
        None
    };
    let nth = pool.map(|p| p.workers()).unwrap_or(1);
    if nth <= 1 {
        for (i, ci) in c.chunks_exact_mut(csz).enumerate() {
            sub(i, ci);
        }
        return;
    }
    let per = div_up(nb, nth);
    let mut tasks: Vec<(usize, &mut [f32])> = Vec::new();
    let mut rest: &mut [f32] = c;
    let mut i0 = 0usize;
    while i0 < nb {
        let take = per.min(nb - i0);
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(take * csz);
        tasks.push((i0, head));
        rest = tail;
        i0 += take;
    }
    pool.unwrap().scoped_map(tasks, |(i0, chunk)| {
        for (j, ci) in chunk.chunks_exact_mut(csz).enumerate() {
            sub(i0 + j, ci);
        }
    });
}

// ---------------------------------------------------------------------------
// shard-gather GEMM (pooled serving path)
// ---------------------------------------------------------------------------

/// Gather `idx` shard slices out of a shard pool into a dense row-major
/// matrix, replicating `adapter/mos/materialize.rs::gather_rows` order
/// exactly: gathered row `row` is the concatenation of the `l` shards
/// `idx[row*l..row*l+l]`, each `shard_w` floats wide, and an optional
/// per-row scale is folded in afterwards with the same `s != 1.0` guard
/// as the materialized path (so `1.0`-scaled rows stay bit-untouched).
fn gather_pooled(
    g: &mut [f32],
    pool: &[f32],
    shard_w: usize,
    idx: &[i32],
    l: usize,
    row_scale: Option<&[f32]>,
) {
    let g_rows = idx.len() / l;
    let width = l * shard_w;
    debug_assert_eq!(idx.len(), g_rows * l);
    debug_assert_eq!(g.len(), g_rows * width);
    for row in 0..g_rows {
        for j in 0..l {
            let shard = idx[row * l + j] as usize;
            g[row * width + j * shard_w..row * width + (j + 1) * shard_w]
                .copy_from_slice(&pool[shard * shard_w..(shard + 1) * shard_w]);
        }
    }
    if let Some(scale) = row_scale {
        debug_assert_eq!(scale.len(), g_rows);
        for row in 0..g_rows {
            let s = scale[row];
            if s != 1.0 {
                for v in &mut g[row * width..(row + 1) * width] {
                    *v *= s;
                }
            }
        }
    }
}

/// Canonical-order GEMM against a *gathered* operand: computes
/// `c (m,n) += alpha * a @ op(G)` where `G` is the dense matrix the
/// materialized path would build from `(pool, idx, row_scale)` — without
/// the caller ever holding a per-tenant dense copy.
///
/// `G` has `idx.len() / l` rows of `l * shard_w` floats (gathered row
/// `row` = shards `idx[row*l..(row+1)*l]`, scaled by `row_scale[row]`).
/// `tg` gives `G`'s storage role exactly like [`gemm_canon`]'s `tb`:
/// * `Trans::T` — `G` is `(n, k)`; the A-factor apply `x @ A_g^T`
///   (`n = r`, `k = l * shard_w`).
/// * `Trans::N` — `G` is `(k, n)`; the B-factor apply `t @ B_g`
///   (`k = r`, `n = l * shard_w`). The dense oracle stores `B` as
///   `(out, r)` and reads it through `Trans::T`; reading the ungathered
///   `(r, out)` layout through `Trans::N` addresses the very same values,
///   so the per-element mul/add sequence is unchanged.
///
/// The gather itself writes into per-thread scratch ([`scratch_take`]),
/// then runs the ordinary [`gemm_canon`]: pooled results are **bitwise
/// identical** to materializing first, for any thread count, because the
/// kernel that touches the floats is literally the same one. Per-tenant
/// residency stays O(pool); the gather's O(rows · l · shard_w) copy is
/// the price, measured against dense apply in `bench_materialize`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_gather_canon(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    pool: &[f32],
    shard_w: usize,
    idx: &[i32],
    l: usize,
    row_scale: Option<&[f32]>,
    tg: Trans,
    c: &mut [f32],
) {
    let g_rows = idx.len() / l;
    let width = l * shard_w;
    match tg {
        Trans::T => debug_assert_eq!((n, k), (g_rows, width)),
        Trans::N => debug_assert_eq!((k, n), (g_rows, width)),
    }
    let mut g = scratch_take(g_rows * width);
    gather_pooled(&mut g, pool, shard_w, idx, l, row_scale);
    gemm_canon(m, n, k, alpha, a, Trans::N, &g, tg, c);
    scratch_put(g);
}

/// `nb` independent [`gemm_gather_canon`] problems in one call, sharing a
/// single shard pool: sub-problem `i` gathers `idx[i*gsz..(i+1)*gsz]`
/// (and `row_scale[i*g_rows..]` when given) and accumulates into
/// `c[i*m*n..]` from `a[i*m*k..]`. This is the per-run projection batch
/// for mixed-tenant serving — whole sub-GEMMs fan out over the pool
/// ([`gemm_canon_batch`] discipline), each gathering into its own
/// worker-local scratch, so results are bitwise identical to `nb`
/// individual calls for any worker count.
#[allow(clippy::too_many_arguments)]
pub fn gemm_gather_canon_batch(
    nb: usize,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    pool: &[f32],
    shard_w: usize,
    idx: &[i32],
    l: usize,
    row_scale: Option<&[f32]>,
    tg: Trans,
    c: &mut [f32],
) {
    if nb == 0 || m == 0 || n == 0 || k == 0 {
        return;
    }
    let g_rows = idx.len() / (nb * l);
    let gsz = g_rows * l;
    let width = l * shard_w;
    debug_assert_eq!(idx.len(), nb * gsz);
    debug_assert_eq!(a.len(), nb * m * k);
    debug_assert_eq!(c.len(), nb * m * n);
    match tg {
        Trans::T => debug_assert_eq!((n, k), (g_rows, width)),
        Trans::N => debug_assert_eq!((k, n), (g_rows, width)),
    }
    let (asz, csz) = (m * k, m * n);
    let sub = |i: usize, ci: &mut [f32]| {
        let mut g = scratch_take(g_rows * width);
        gather_pooled(
            &mut g,
            pool,
            shard_w,
            &idx[i * gsz..(i + 1) * gsz],
            l,
            row_scale.map(|s| &s[i * g_rows..(i + 1) * g_rows]),
        );
        gemm_canon_serial(m, n, k, alpha, &a[i * asz..(i + 1) * asz], Trans::N, &g, tg, ci);
        scratch_put(g);
    };
    let total_flops = 2usize
        .saturating_mul(nb)
        .saturating_mul(m)
        .saturating_mul(n)
        .saturating_mul(k);
    let pool_ref = if nb > 1 && total_flops >= PAR_FLOPS {
        auto_pool()
    } else {
        None
    };
    let nth = pool_ref.map(|p| p.workers()).unwrap_or(1);
    if nth <= 1 {
        for (i, ci) in c.chunks_exact_mut(csz).enumerate() {
            sub(i, ci);
        }
        return;
    }
    let per = div_up(nb, nth);
    let mut tasks: Vec<(usize, &mut [f32])> = Vec::new();
    let mut rest: &mut [f32] = c;
    let mut i0 = 0usize;
    while i0 < nb {
        let take = per.min(nb - i0);
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(take * csz);
        tasks.push((i0, head));
        rest = tail;
        i0 += take;
    }
    pool_ref.unwrap().scoped_map(tasks, |(i0, chunk)| {
        for (j, ci) in chunk.chunks_exact_mut(csz).enumerate() {
            sub(i0 + j, ci);
        }
    });
}

/// Scalar kernel replicating the tiled path's per-element order: for each
/// KC block, accumulate `sum_p a[i,p] * b[p,j]` sequentially from zero,
/// then write back `c += partial` (or `c += alpha * partial`) — the same
/// mul/add sequence `run_chunk` + `micro_tile` perform per element.
#[allow(clippy::too_many_arguments)]
fn gemm_canon_small(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    let at = |i: usize, p: usize| match ta {
        Trans::N => a[i * k + p],
        Trans::T => a[p * m + i],
    };
    let bt = |p: usize, j: usize| match tb {
        Trans::N => b[p * n + j],
        Trans::T => b[j * k + p],
    };
    let mut pc = 0;
    while pc < k {
        let kc = KC.min(k - pc);
        for i in 0..m {
            let crow = &mut c[i * n..(i + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for p in pc..pc + kc {
                    acc += at(i, p) * bt(p, j);
                }
                if alpha == 1.0 {
                    *cv += acc;
                } else {
                    *cv += alpha * acc;
                }
            }
        }
        pc += kc;
    }
}

/// Scalar fallback for small problems — the seed's loop-ordered kernels,
/// kept as the low-overhead path (and mirrored by the naive test oracle).
#[allow(clippy::too_many_arguments)]
fn gemm_small(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    match (ta, tb) {
        (Trans::N, Trans::T) => {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += alpha * dot(arow, &b[j * k..(j + 1) * k]);
                }
            }
        }
        (Trans::N, Trans::N) => {
            for i in 0..m {
                let crow = &mut c[i * n..(i + 1) * n];
                for p in 0..k {
                    let av = a[i * k + p];
                    if av == 0.0 {
                        continue;
                    }
                    let av = av * alpha;
                    let brow = &b[p * n..(p + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
        (Trans::T, Trans::N) => {
            for p in 0..k {
                let arow = &a[p * m..(p + 1) * m];
                let brow = &b[p * n..(p + 1) * n];
                for i in 0..m {
                    let av = arow[i];
                    if av == 0.0 {
                        continue;
                    }
                    let av = av * alpha;
                    let crow = &mut c[i * n..(i + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
        (Trans::T, Trans::T) => {
            for i in 0..m {
                let crow = &mut c[i * n..(i + 1) * n];
                for j in 0..n {
                    let brow = &b[j * k..(j + 1) * k];
                    let mut acc = 0.0f32;
                    for p in 0..k {
                        acc += a[p * m + i] * brow[p];
                    }
                    crow[j] += alpha * acc;
                }
            }
        }
    }
}

/// `m == 1` path: one C row, partitioned across columns when a pool is
/// given. With a single row, `a` has identical layout under `N` and `T`
/// (a length-k strip), so only `tb` matters.
fn gemm_row(
    pool: Option<&ThreadPool>,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    let row_range = |j0: usize, cchunk: &mut [f32]| match tb {
        Trans::T => {
            for (jj, cv) in cchunk.iter_mut().enumerate() {
                let j = j0 + jj;
                *cv += alpha * dot(a, &b[j * k..(j + 1) * k]);
            }
        }
        Trans::N => {
            for (p, &av) in a.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let av = av * alpha;
                let brow = &b[p * n + j0..p * n + j0 + cchunk.len()];
                for (cv, bv) in cchunk.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    };
    let nth = pool.map(|p| p.workers()).unwrap_or(1);
    if nth <= 1 || n < 2 * NR {
        return row_range(0, c);
    }
    let chunk = div_up(n, nth).max(NR);
    let mut tasks: Vec<(usize, &mut [f32])> = Vec::new();
    let mut rest: &mut [f32] = c;
    let mut j0 = 0usize;
    while !rest.is_empty() {
        let w = chunk.min(rest.len());
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(w);
        tasks.push((j0, head));
        rest = tail;
        j0 += w;
    }
    pool.unwrap().scoped_map(tasks, |(j0, cchunk)| row_range(j0, cchunk));
}

/// Blocked path: pack B once, then fan row-blocks of C out over the pool,
/// with the process-selected microkernel.
#[allow(clippy::too_many_arguments)]
fn gemm_blocked(
    pool: Option<&ThreadPool>,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    gemm_blocked_k(selected_kernel(), pool, m, n, k, alpha, a, ta, b, tb, c)
}

/// [`gemm_blocked`] with an explicit microkernel (threaded into every
/// worker's [`run_chunk`], so one call uses one kernel throughout).
#[allow(clippy::too_many_arguments)]
fn gemm_blocked_k(
    kernel: Kernel,
    pool: Option<&ThreadPool>,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    ta: Trans,
    b: &[f32],
    tb: Trans,
    c: &mut [f32],
) {
    let n_round = div_up(n, NR) * NR;
    let mut bp = scratch_take(k * n_round);
    pack_b(&mut bp, b, tb, k, n, n_round);

    let nth = pool.map(|p| p.workers()).unwrap_or(1);
    let max_chunks = div_up(m, MR);
    if nth <= 1 || max_chunks < 2 {
        run_chunk(kernel, a, ta, m, k, n, n_round, alpha, &bp, 0, m, c);
    } else {
        let nchunks = nth.min(max_chunks);
        let chunk_rows = div_up(div_up(m, nchunks), MR) * MR;
        let mut tasks: Vec<(usize, usize, &mut [f32])> = Vec::new();
        let mut rest: &mut [f32] = c;
        let mut i0 = 0usize;
        while i0 < m {
            let rows = chunk_rows.min(m - i0);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(rows * n);
            tasks.push((i0, rows, head));
            rest = tail;
            i0 += rows;
        }
        let bp_ref: &[f32] = &bp;
        pool.unwrap().scoped_map(tasks, |(i0, rows, cchunk)| {
            run_chunk(kernel, a, ta, m, k, n, n_round, alpha, bp_ref, i0, rows, cchunk)
        });
    }
    scratch_put(bp);
}

/// Pack all of B into NR-wide column panels, KC-deep blocks: the block for
/// k-range `[pc, pc+kc)` starts at `pc * n_round`; inside it, panel `jp`
/// (columns `[jp*NR, jp*NR+NR)`) is `kc * NR` contiguous floats, k-major.
/// Padded columns (n..n_round) stay zero (the scratch buffer is zeroed).
fn pack_b(bp: &mut [f32], b: &[f32], tb: Trans, k: usize, n: usize, n_round: usize) {
    let npanels = n_round / NR;
    let mut pc = 0;
    while pc < k {
        let kc = KC.min(k - pc);
        let block = &mut bp[pc * n_round..pc * n_round + kc * n_round];
        for jp in 0..npanels {
            let j0 = jp * NR;
            let w = NR.min(n - j0);
            let panel = &mut block[jp * kc * NR..(jp + 1) * kc * NR];
            match tb {
                Trans::N => {
                    for p in 0..kc {
                        let src = (pc + p) * n + j0;
                        panel[p * NR..p * NR + w]
                            .copy_from_slice(&b[src..src + w]);
                    }
                }
                Trans::T => {
                    for jj in 0..w {
                        let col = &b[(j0 + jj) * k + pc..(j0 + jj) * k + pc + kc];
                        for (p, &v) in col.iter().enumerate() {
                            panel[p * NR + jj] = v;
                        }
                    }
                }
            }
        }
        pc += kc;
    }
}

/// Pack A rows `[i0, i0+mc)`, k-range `[pc, pc+kc)` into MR-wide row
/// panels, k-major inside each panel. Lanes past the last real row hold
/// stale values; their accumulators are discarded at writeback.
#[allow(clippy::too_many_arguments)]
fn pack_a(
    ap: &mut [f32],
    a: &[f32],
    ta: Trans,
    m: usize,
    k: usize,
    i0: usize,
    mc: usize,
    pc: usize,
    kc: usize,
) {
    let rpanels = div_up(mc, MR);
    for rp in 0..rpanels {
        let r0 = i0 + rp * MR;
        let h = MR.min(i0 + mc - r0);
        let panel = &mut ap[rp * kc * MR..(rp + 1) * kc * MR];
        match ta {
            Trans::N => {
                for r in 0..h {
                    let row = &a[(r0 + r) * k + pc..(r0 + r) * k + pc + kc];
                    for (p, &v) in row.iter().enumerate() {
                        panel[p * MR + r] = v;
                    }
                }
            }
            Trans::T => {
                // a is (k, m): logical A[i, p] = a[p*m + i]
                for p in 0..kc {
                    let src = (pc + p) * m + r0;
                    panel[p * MR..p * MR + h].copy_from_slice(&a[src..src + h]);
                }
            }
        }
    }
}

/// Register-tiled MR x NR microkernel over packed panels: dispatch to the
/// selected flavor. All flavors perform the identical per-element
/// sequence — for each `p` ascending, each C element does one mul and one
/// add (`acc[r][j] += ap[p,r] * bp[p,j]`, **never** fused) — so outputs
/// are bitwise equal across kernels; only the register width differs.
#[inline(always)]
fn micro_tile(kernel: Kernel, kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    match kernel {
        Kernel::Scalar => micro_tile_scalar(kc, ap, bp, acc),
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse4 => micro_tile_sse4(kc, ap, bp, acc),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx8 is only selectable/pinnable when
        // `Kernel::supported()` saw the `avx` cpuid bit (selected_kernel's
        // fallback chain and gemm_with_kernel's debug_assert enforce it).
        Kernel::Avx8 => unsafe { micro_tile_avx8(kc, ap, bp, acc) },
    }
}

/// Scalar tile: independent accumulators per C element break the fp
/// dependency chain; the autovectorizer may widen the NR lane dimension,
/// which preserves the per-element mul/add sequence exactly like the
/// hand-written tiles below.
#[inline(always)]
fn micro_tile_scalar(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert!(ap.len() >= kc * MR);
    debug_assert!(bp.len() >= kc * NR);
    for p in 0..kc {
        let ar = &ap[p * MR..p * MR + MR];
        let br = &bp[p * NR..p * NR + NR];
        for r in 0..MR {
            let av = ar[r];
            let accr = &mut acc[r];
            for j in 0..NR {
                accr[j] += av * br[j];
            }
        }
    }
}

// The hand-written tiles are unrolled for exactly the 4x8 geometry.
#[cfg(target_arch = "x86_64")]
const _: () = assert!(MR == 4 && NR == 8, "SIMD tiles assume a 4x8 tile");

/// SSE2 tile (lane width 4): two 128-bit accumulators per C row. SSE2 is
/// part of the x86_64 baseline, so this flavor is always runnable here.
/// `_mm_add_ps(_, _mm_mul_ps(..))` keeps mul and add as separate IEEE
/// roundings per lane — the scalar tile's sequence, four lanes at a time.
#[cfg(target_arch = "x86_64")]
#[inline]
fn micro_tile_sse4(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    use std::arch::x86_64::*;
    debug_assert!(ap.len() >= kc * MR);
    debug_assert!(bp.len() >= kc * NR);
    // SAFETY: all pointers stay inside `ap`/`bp`/`acc` (lengths asserted
    // above; acc rows are NR = 8 floats); loads/stores are unaligned.
    unsafe {
        let mut lo = [
            _mm_loadu_ps(acc[0].as_ptr()),
            _mm_loadu_ps(acc[1].as_ptr()),
            _mm_loadu_ps(acc[2].as_ptr()),
            _mm_loadu_ps(acc[3].as_ptr()),
        ];
        let mut hi = [
            _mm_loadu_ps(acc[0].as_ptr().add(4)),
            _mm_loadu_ps(acc[1].as_ptr().add(4)),
            _mm_loadu_ps(acc[2].as_ptr().add(4)),
            _mm_loadu_ps(acc[3].as_ptr().add(4)),
        ];
        let (a, b) = (ap.as_ptr(), bp.as_ptr());
        for p in 0..kc {
            let blo = _mm_loadu_ps(b.add(p * NR));
            let bhi = _mm_loadu_ps(b.add(p * NR + 4));
            for r in 0..MR {
                let av = _mm_set1_ps(*a.add(p * MR + r));
                lo[r] = _mm_add_ps(lo[r], _mm_mul_ps(av, blo));
                hi[r] = _mm_add_ps(hi[r], _mm_mul_ps(av, bhi));
            }
        }
        for r in 0..MR {
            _mm_storeu_ps(acc[r].as_mut_ptr(), lo[r]);
            _mm_storeu_ps(acc[r].as_mut_ptr().add(4), hi[r]);
        }
    }
}

/// AVX tile (lane width 8): one 256-bit accumulator per C row, compiled
/// with the `avx` target feature so a baseline `x86-64` build still emits
/// 256-bit ops — the caller must have verified runtime support.
/// `_mm256_add_ps(_, _mm256_mul_ps(..))` — separate mul and add, never
/// fma, so each lane reproduces the scalar tile's roundings bit-for-bit.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn micro_tile_avx8(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    use std::arch::x86_64::*;
    debug_assert!(ap.len() >= kc * MR);
    debug_assert!(bp.len() >= kc * NR);
    let mut v = [
        _mm256_loadu_ps(acc[0].as_ptr()),
        _mm256_loadu_ps(acc[1].as_ptr()),
        _mm256_loadu_ps(acc[2].as_ptr()),
        _mm256_loadu_ps(acc[3].as_ptr()),
    ];
    let (a, b) = (ap.as_ptr(), bp.as_ptr());
    for p in 0..kc {
        let br = _mm256_loadu_ps(b.add(p * NR));
        for r in 0..MR {
            let av = _mm256_set1_ps(*a.add(p * MR + r));
            v[r] = _mm256_add_ps(v[r], _mm256_mul_ps(av, br));
        }
    }
    for r in 0..MR {
        _mm256_storeu_ps(acc[r].as_mut_ptr(), v[r]);
    }
}

/// One worker's share: C rows `[i0, i0+rows)` (given as the matching
/// `cchunk` slice), all k-blocks, all column panels. Column panels are
/// walked in `NC`-wide groups (outermost loop) so the packed-B working
/// set of the k/row loops stays `KC * NC`-bounded instead of streaming
/// the full packed B per row-block; A is re-packed per group, which
/// amortizes against the `m * k * NC` flops each group performs.
/// k-blocks accumulate in ascending order per element (the group loop is
/// outside the k loop and never revisits a column), so the result is
/// bitwise independent of both the worker count and the grouping.
#[allow(clippy::too_many_arguments)]
fn run_chunk(
    kernel: Kernel,
    a: &[f32],
    ta: Trans,
    m: usize,
    k: usize,
    n: usize,
    n_round: usize,
    alpha: f32,
    bp: &[f32],
    i0: usize,
    rows: usize,
    cchunk: &mut [f32],
) {
    debug_assert_eq!(cchunk.len(), rows * n);
    let npanels = n_round / NR;
    let gpanels = NC / NR; // panels per column group
    let mut ap = scratch_take(MC * KC);
    let mut jc = 0;
    while jc < npanels {
        let jend = (jc + gpanels).min(npanels);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            let bblock = &bp[pc * n_round..pc * n_round + kc * n_round];
            let mut ic = 0;
            while ic < rows {
                let mc = MC.min(rows - ic);
                pack_a(&mut ap, a, ta, m, k, i0 + ic, mc, pc, kc);
                let rpanels = div_up(mc, MR);
                for rp in 0..rpanels {
                    let appanel = &ap[rp * kc * MR..(rp + 1) * kc * MR];
                    let r0 = ic + rp * MR; // chunk-local row of this tile
                    let h = MR.min(mc - rp * MR);
                    for jp in jc..jend {
                        let bpanel = &bblock[jp * kc * NR..(jp + 1) * kc * NR];
                        let mut acc = [[0.0f32; NR]; MR];
                        micro_tile(kernel, kc, appanel, bpanel, &mut acc);
                        let j0 = jp * NR;
                        let w = NR.min(n - j0);
                        for r in 0..h {
                            let coff = (r0 + r) * n + j0;
                            let crow = &mut cchunk[coff..coff + w];
                            let accr = &acc[r];
                            if alpha == 1.0 {
                                for (cv, av) in crow.iter_mut().zip(accr) {
                                    *cv += av;
                                }
                            } else {
                                for (cv, av) in crow.iter_mut().zip(accr) {
                                    *cv += alpha * av;
                                }
                            }
                        }
                    }
                }
                ic += mc;
            }
            pc += kc;
        }
        jc = jend;
    }
    scratch_put(ap);
}

// ---------------------------------------------------------------------------
// public wrappers (seed-compatible signatures)
// ---------------------------------------------------------------------------

/// Dot product with 4 independent accumulators (breaks the fp dependency
/// chain so the autovectorizer emits wide fma; EXPERIMENTS.md §Perf).
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

/// c (m,n) += a (m,k) @ b^T where b is (n,k).
pub fn matmul_nt_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm(m, n, k, 1.0, a, Trans::N, b, Trans::T, c)
}

/// c (m,n) = a (m,k) @ b^T.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_nt_acc(a, b, &mut c, m, k, n);
    c
}

/// c (m,n) += a (m,k) @ b where b is (k,n).
pub fn matmul_nn_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm(m, n, k, 1.0, a, Trans::N, b, Trans::N, c)
}

pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_nn_acc(a, b, &mut c, m, k, n);
    c
}

/// c (m,n) += a^T @ b where a is (k,m), b is (k,n).
pub fn matmul_tn_acc(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    gemm(m, n, k, 1.0, a, Trans::T, b, Trans::N, c)
}

pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_tn_acc(a, b, &mut c, k, m, n);
    c
}

/// Cache-blocked transpose of a row-major (rows, cols) matrix into
/// (cols, rows): 32x32 tiles keep both the reads and the strided writes
/// inside one cache-line working set.
pub fn transpose(m: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(m.len(), rows * cols);
    const TB: usize = 32;
    let mut out = vec![0.0f32; m.len()];
    let mut r0 = 0;
    while r0 < rows {
        let rend = (r0 + TB).min(rows);
        let mut c0 = 0;
        while c0 < cols {
            let cend = (c0 + TB).min(cols);
            for r in r0..rend {
                for c in c0..cend {
                    out[c * rows + r] = m[r * cols + c];
                }
            }
            c0 = cend;
        }
        r0 = rend;
    }
    out
}

// ---------------------------------------------------------------------------
// softmax / activations
// ---------------------------------------------------------------------------

/// In-place numerically-stable softmax over the last `n` of each row.
pub fn softmax_rows(x: &mut [f32], rows: usize, n: usize) {
    for i in 0..rows {
        let row = &mut x[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// SiLU (swish) activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// d silu / dx.
#[inline]
pub fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn naive_matmul(
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        at: bool,
        bt: bool,
    ) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    let av = if at { a[p * m + i] } else { a[i * k + p] };
                    let bv = if bt { b[j * k + p] } else { b[p * n + j] };
                    acc += av * bv;
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn matmul_variants_match_naive() {
        prop::check("matmul-variants", 25, |rng| {
            let m = rng.range(1, 9);
            let k = rng.range(1, 9);
            let n = rng.range(1, 9);
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let bt: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
            let bn: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let at: Vec<f32> = (0..k * m).map(|_| rng.normal()).collect();
            prop::assert_allclose(
                &matmul_nt(&a, &bt, m, k, n),
                &naive_matmul(&a, &bt, m, k, n, false, true),
                1e-4,
                1e-4,
            )?;
            prop::assert_allclose(
                &matmul_nn(&a, &bn, m, k, n),
                &naive_matmul(&a, &bn, m, k, n, false, false),
                1e-4,
                1e-4,
            )?;
            prop::assert_allclose(
                &matmul_tn(&at, &bn, k, m, n),
                &naive_matmul(&at, &bn, m, k, n, true, false),
                1e-4,
                1e-4,
            )
        });
    }

    /// Shapes chosen to cross every tile/panel boundary: not multiples of
    /// MR/NR/KC, m=1 decode rows, k=2 low-rank, plus the seed's smalls.
    fn awkward_dims(rng: &mut Rng) -> (usize, usize, usize) {
        const DIMS: [usize; 12] = [1, 2, 3, 4, 5, 7, 8, 9, 17, 33, 65, 130];
        (
            DIMS[rng.range(0, DIMS.len())],
            DIMS[rng.range(0, DIMS.len())],
            DIMS[rng.range(0, DIMS.len())],
        )
    }

    #[test]
    fn blocked_engine_matches_naive_all_layouts() {
        prop::check("blocked-vs-naive", 40, |rng| {
            let (m, k, n) = awkward_dims(rng);
            let an: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let at: Vec<f32> = (0..k * m).map(|_| rng.normal()).collect();
            let bn: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let bt: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
            for (a, ta, b, tb, at_flag, bt_flag) in [
                (&an, Trans::N, &bt, Trans::T, false, true),
                (&an, Trans::N, &bn, Trans::N, false, false),
                (&at, Trans::T, &bn, Trans::N, true, false),
                (&at, Trans::T, &bt, Trans::T, true, true),
            ] {
                // force the blocked path regardless of flop thresholds
                let mut c = vec![0.0f32; m * n];
                gemm_blocked(None, m, n, k, 1.0, a, ta, b, tb, &mut c);
                let want = naive_matmul(a, b, m, k, n, at_flag, bt_flag);
                prop::assert_allclose(&c, &want, 1e-3, 1e-3)?;
            }
            Ok(())
        });
    }

    #[test]
    fn public_wrappers_match_naive_medium_shapes() {
        prop::check("wrappers-medium", 15, |rng| {
            let m = rng.range(1, 70);
            let k = rng.range(1, 70);
            let n = rng.range(1, 70);
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let bt: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
            let bn: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let at: Vec<f32> = (0..k * m).map(|_| rng.normal()).collect();
            prop::assert_allclose(
                &matmul_nt(&a, &bt, m, k, n),
                &naive_matmul(&a, &bt, m, k, n, false, true),
                1e-3,
                1e-3,
            )?;
            prop::assert_allclose(
                &matmul_nn(&a, &bn, m, k, n),
                &naive_matmul(&a, &bn, m, k, n, false, false),
                1e-3,
                1e-3,
            )?;
            prop::assert_allclose(
                &matmul_tn(&at, &bn, k, m, n),
                &naive_matmul(&at, &bn, m, k, n, true, false),
                1e-3,
                1e-3,
            )
        });
    }

    #[test]
    fn alpha_scales_accumulation() {
        let mut rng = Rng::new(7, 0);
        let (m, k, n) = (13, 21, 17);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let mut c = vec![1.0f32; m * n];
        gemm(m, n, k, 0.5, &a, Trans::N, &b, Trans::N, &mut c);
        let full = naive_matmul(&a, &b, m, k, n, false, false);
        let want: Vec<f32> = full.iter().map(|v| 1.0 + 0.5 * v).collect();
        prop::assert_allclose(&c, &want, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn thread_count_never_changes_results() {
        // bitwise identity between serial, 1-thread, and 4-thread runs,
        // on shapes that exercise the row-chunked and m=1 column paths
        let pool1 = ThreadPool::new(1);
        let pool4 = ThreadPool::new(4);
        let mut rng = Rng::new(11, 3);
        for (m, k, n) in [(65, 47, 33), (128, 96, 64), (1, 512, 301), (37, 2, 129)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
            let run = |pool: Option<&ThreadPool>| -> Vec<u32> {
                let mut c = vec![0.0f32; m * n];
                if m == 1 {
                    gemm_row(pool, n, k, 1.0, &a, &b, Trans::T, &mut c);
                } else {
                    gemm_blocked(pool, m, n, k, 1.0, &a, Trans::N, &b, Trans::T, &mut c);
                }
                c.iter().map(|v| v.to_bits()).collect()
            };
            let serial = run(None);
            assert_eq!(serial, run(Some(&pool1)), "({m},{k},{n}) 1 thread");
            assert_eq!(serial, run(Some(&pool4)), "({m},{k},{n}) 4 threads");
        }
    }

    #[test]
    fn public_entry_thread_invariant_above_parallel_threshold() {
        // flops > PAR_FLOPS: gemm_with engages the pool; covers the row-
        // chunked blocked path (m=160) and the low-rank m < MR row-split
        // path (m=2, the backward dA shape)
        let pool1 = ThreadPool::new(1);
        let pool4 = ThreadPool::new(4);
        for (m, k, n, ta) in [
            (160, 128, 96, Trans::N),
            (2, 1024, 600, Trans::T),
            (3, 700, 512, Trans::N),
        ] {
            let mut rng = Rng::new(13, 1);
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let run = |pool: &ThreadPool| -> Vec<u32> {
                let mut c = vec![0.0f32; m * n];
                gemm_with(Some(pool), m, n, k, 1.0, &a, ta, &b, Trans::N, &mut c);
                c.iter().map(|v| v.to_bits()).collect()
            };
            assert_eq!(run(&pool1), run(&pool4), "({m},{k},{n})");
            // and the parallel path agrees with the serial oracle
            let mut c = vec![0.0f32; m * n];
            gemm_with(Some(&pool4), m, n, k, 1.0, &a, ta, &b, Trans::N, &mut c);
            let want = naive_matmul(&a, &b, m, k, n, ta == Trans::T, false);
            prop::assert_allclose(&c, &want, 1e-3, 1e-3).unwrap();
        }
    }

    #[test]
    fn canon_matches_naive_all_layouts() {
        prop::check("canon-vs-naive", 40, |rng| {
            let (m, k, n) = awkward_dims(rng);
            let an: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let at: Vec<f32> = (0..k * m).map(|_| rng.normal()).collect();
            let bn: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let bt: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
            for (a, ta, b, tb, at_flag, bt_flag) in [
                (&an, Trans::N, &bt, Trans::T, false, true),
                (&an, Trans::N, &bn, Trans::N, false, false),
                (&at, Trans::T, &bn, Trans::N, true, false),
                (&at, Trans::T, &bt, Trans::T, true, true),
            ] {
                let mut c = vec![0.0f32; m * n];
                gemm_canon(m, n, k, 1.0, a, ta, b, tb, &mut c);
                let want = naive_matmul(a, b, m, k, n, at_flag, bt_flag);
                prop::assert_allclose(&c, &want, 1e-3, 1e-3)?;
            }
            Ok(())
        });
    }

    #[test]
    fn canon_rows_bitwise_independent_of_batching() {
        // THE decode-path contract: computing a row alone (m = 1, scalar
        // canonical kernel) must bit-match the same row computed inside a
        // larger batch (m >= MR, blocked/tiled kernel). Shapes cross the
        // SMALL_FLOPS boundary and k > KC exercises per-block alpha.
        let mut rng = Rng::new(23, 5);
        for (m, k, n, alpha, tb) in [
            (6, 300, 40, 1.0f32, Trans::T), // multi KC block, blocked path
            (6, 300, 40, 1.7, Trans::T),    // alpha != 1 per-block writeback
            (8, 64, 64, 1.0, Trans::T),     // the projection shape family
            (5, 48, 16, 1.0, Trans::N),     // attention ctx shape family
            (4, 64, 8, 0.25, Trans::T),     // low-rank adapter apply
        ] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let mut c = c0.clone();
            gemm_canon(m, n, k, alpha, &a, Trans::N, &b, tb, &mut c);
            for i in 0..m {
                let mut crow = c0[i * n..(i + 1) * n].to_vec();
                gemm_canon(
                    1,
                    n,
                    k,
                    alpha,
                    &a[i * k..(i + 1) * k],
                    Trans::N,
                    &b,
                    tb,
                    &mut crow,
                );
                let batched: Vec<u32> =
                    c[i * n..(i + 1) * n].iter().map(|v| v.to_bits()).collect();
                let alone: Vec<u32> = crow.iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    batched, alone,
                    "row {i} of ({m},{k},{n}) alpha={alpha} depends on batching"
                );
            }
        }
    }

    #[test]
    fn canon_batch_matches_individual_calls_bitwise() {
        // the batched-head attention contract: one gemm_canon_batch call
        // must be bit-identical to nb individual gemm_canon calls, for
        // shapes covering the decode (m=1) and prefill (T x T) attention
        // sub-problems, nb large enough to engage the pool, and alpha != 1
        let mut rng = Rng::new(31, 7);
        for (nb, m, n, k, alpha, tb) in [
            (8usize, 48, 48, 16, 1.0f32, Trans::T), // prefill scores family
            (8, 48, 16, 48, 1.0, Trans::N),         // prefill ctx family
            (12, 1, 33, 16, 1.0, Trans::T),         // decode scores family
            (12, 1, 16, 33, 1.0, Trans::N),         // decode ctx family
            (5, 7, 9, 11, 0.5, Trans::T),           // awkward + alpha
            (1, 20, 20, 20, 1.0, Trans::N),         // nb = 1 degenerate
            (64, 48, 48, 64, 1.0, Trans::T),        // above PAR_FLOPS: pooled
        ] {
            let a: Vec<f32> = (0..nb * m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..nb * k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..nb * m * n).map(|_| rng.normal()).collect();
            let mut batched = c0.clone();
            gemm_canon_batch(nb, m, n, k, alpha, &a, Trans::N, &b, tb, &mut batched);
            let mut alone = c0.clone();
            for i in 0..nb {
                gemm_canon(
                    m,
                    n,
                    k,
                    alpha,
                    &a[i * m * k..(i + 1) * m * k],
                    Trans::N,
                    &b[i * k * n..(i + 1) * k * n],
                    tb,
                    &mut alone[i * m * n..(i + 1) * m * n],
                );
            }
            let bb: Vec<u32> = batched.iter().map(|v| v.to_bits()).collect();
            let ab: Vec<u32> = alone.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bb, ab, "batch ({nb},{m},{n},{k}) alpha={alpha} diverges");
        }
    }

    /// Materialize the gathered matrix the way `gather_pooled` defines it
    /// — the dense oracle the pooled kernels must bit-match.
    fn materialize_gather(
        pool: &[f32],
        shard_w: usize,
        idx: &[i32],
        l: usize,
        scale: Option<&[f32]>,
    ) -> Vec<f32> {
        let g_rows = idx.len() / l;
        let mut g = vec![0.0f32; g_rows * l * shard_w];
        gather_pooled(&mut g, pool, shard_w, idx, l, scale);
        g
    }

    #[test]
    fn gather_gemm_matches_dense_materialized_bitwise() {
        // both operand roles (A-side Trans::T, B-side Trans::N), scale
        // folding with values != 1, and shapes on either side of the
        // SMALL_FLOPS boundary — the pooled path must bit-match running
        // gemm_canon against the pre-materialized gathered matrix
        let mut rng = Rng::new(41, 9);
        for (m, g_rows, l, shard_w, alpha, tg, scaled) in [
            (6usize, 8usize, 2usize, 32usize, 1.0f32, Trans::T, true),
            (6, 8, 2, 32, 0.25, Trans::N, true),
            (1, 4, 3, 8, 1.0, Trans::T, false), // decode row, small kernel
            (48, 16, 2, 64, 1.0, Trans::T, true), // above SMALL_FLOPS: tiled
            (48, 16, 2, 64, 0.25, Trans::N, true),
            (5, 6, 1, 16, 1.0, Trans::T, true), // l = 1 ablation shape
        ] {
            let n_shards = 24usize;
            let pool: Vec<f32> =
                (0..n_shards * shard_w).map(|_| rng.normal()).collect();
            let idx: Vec<i32> = (0..g_rows * l)
                .map(|_| rng.range(0, n_shards) as i32)
                .collect();
            let scale: Option<Vec<f32>> = scaled.then(|| {
                (0..g_rows)
                    .map(|i| if i % 3 == 0 { 1.0 } else { rng.normal().abs() + 0.5 })
                    .collect()
            });
            let width = l * shard_w;
            let (n, k) = match tg {
                Trans::T => (g_rows, width),
                Trans::N => (width, g_rows),
            };
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let g = materialize_gather(&pool, shard_w, &idx, l, scale.as_deref());
            let mut dense = c0.clone();
            gemm_canon(m, n, k, alpha, &a, Trans::N, &g, tg, &mut dense);
            let mut pooled = c0.clone();
            gemm_gather_canon(
                m, n, k, alpha, &a, &pool, shard_w, &idx, l,
                scale.as_deref(), tg, &mut pooled,
            );
            let db: Vec<u32> = dense.iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u32> = pooled.iter().map(|v| v.to_bits()).collect();
            assert_eq!(db, pb, "({m},{g_rows},{l},{shard_w}) tg={tg:?} diverges");
        }
    }

    #[test]
    fn gather_gemm_batch_matches_individual_calls_bitwise() {
        // the mixed-tenant projection batch: one gemm_gather_canon_batch
        // call must bit-match nb individual calls, including nb large
        // enough to engage the pool and per-sub idx/scale slices
        let mut rng = Rng::new(43, 2);
        for (nb, m, g_rows, l, shard_w, alpha, tg) in [
            (4usize, 6usize, 8usize, 2usize, 16usize, 1.0f32, Trans::T),
            (4, 6, 8, 2, 16, 0.25, Trans::N),
            (1, 3, 4, 2, 8, 1.0, Trans::T), // nb = 1 degenerate
            (32, 16, 8, 2, 64, 1.0, Trans::T), // above PAR_FLOPS: pooled
        ] {
            let n_shards = 24usize;
            let pool: Vec<f32> =
                (0..n_shards * shard_w).map(|_| rng.normal()).collect();
            let idx: Vec<i32> = (0..nb * g_rows * l)
                .map(|_| rng.range(0, n_shards) as i32)
                .collect();
            let scale: Vec<f32> = (0..nb * g_rows)
                .map(|i| if i % 4 == 0 { 1.0 } else { rng.normal().abs() + 0.5 })
                .collect();
            let width = l * shard_w;
            let (n, k) = match tg {
                Trans::T => (g_rows, width),
                Trans::N => (width, g_rows),
            };
            let a: Vec<f32> = (0..nb * m * k).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..nb * m * n).map(|_| rng.normal()).collect();
            let mut batched = c0.clone();
            gemm_gather_canon_batch(
                nb, m, n, k, alpha, &a, &pool, shard_w, &idx, l,
                Some(&scale), tg, &mut batched,
            );
            let mut alone = c0.clone();
            for i in 0..nb {
                gemm_gather_canon(
                    m, n, k, alpha,
                    &a[i * m * k..(i + 1) * m * k],
                    &pool, shard_w,
                    &idx[i * g_rows * l..(i + 1) * g_rows * l], l,
                    Some(&scale[i * g_rows..(i + 1) * g_rows]), tg,
                    &mut alone[i * m * n..(i + 1) * m * n],
                );
            }
            let bb: Vec<u32> = batched.iter().map(|v| v.to_bits()).collect();
            let ab: Vec<u32> = alone.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bb, ab, "batch ({nb},{m},{g_rows},{l}) diverges");
        }
    }

    #[test]
    fn nc_grouped_walk_matches_naive_and_ungrouped_order() {
        // n > NC crosses the column-group boundary; the grouped walk must
        // agree with the naive oracle and stay bitwise thread-invariant
        let pool4 = ThreadPool::new(4);
        let mut rng = Rng::new(37, 4);
        for (m, k, n) in [(9, 40, NC + 130), (33, 300, 2 * NC + 7)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
            let mut serial = vec![0.0f32; m * n];
            gemm_blocked(None, m, n, k, 1.0, &a, Trans::N, &b, Trans::T, &mut serial);
            let want = naive_matmul(&a, &b, m, k, n, false, true);
            prop::assert_allclose(&serial, &want, 1e-3, 1e-3).unwrap();
            let mut par = vec![0.0f32; m * n];
            gemm_blocked(
                Some(&pool4), m, n, k, 1.0, &a, Trans::N, &b, Trans::T, &mut par,
            );
            let sb: Vec<u32> = serial.iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u32> = par.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sb, pb, "({m},{k},{n}) grouped walk thread-variant");
            // canonical row-batching independence must also hold across
            // the NC boundary (the inference-path contract)
            for i in [0usize, m - 1] {
                let mut crow = vec![0.0f32; n];
                gemm_canon(
                    1, n, k, 1.0, &a[i * k..(i + 1) * k], Trans::N, &b,
                    Trans::T, &mut crow,
                );
                let alone: Vec<u32> = crow.iter().map(|v| v.to_bits()).collect();
                let batched: Vec<u32> = serial[i * n..(i + 1) * n]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                assert_eq!(alone, batched, "row {i} of ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn canon_agrees_with_engine_on_tiled_shapes() {
        // above the small-flops threshold with m >= MR, gemm_canon forwards
        // to the very same blocked path as gemm — bitwise equal
        let mut rng = Rng::new(29, 2);
        let (m, k, n) = (48, 64, 64);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        gemm(m, n, k, 1.0, &a, Trans::N, &b, Trans::T, &mut c1);
        gemm_canon(m, n, k, 1.0, &a, Trans::N, &b, Trans::T, &mut c2);
        let b1: Vec<u32> = c1.iter().map(|v| v.to_bits()).collect();
        let b2: Vec<u32> = c2.iter().map(|v| v.to_bits()).collect();
        assert_eq!(b1, b2);
    }

    #[test]
    fn simd_kernels_bitwise_match_scalar_tile() {
        // THE lane-width contract: every supported kernel must produce the
        // exact bits of the scalar tile on the blocked path, serially and
        // under any worker count — this is what makes MOS_SIMD a pure
        // performance knob, and what carries the canonical-order contracts
        // (decode vs. prefill row batching) over to the SIMD tiles
        // unchanged. Shapes cross the MR/NR/KC/NC boundaries and use
        // alpha != 1 for the per-KC-block writeback.
        let pool1 = ThreadPool::new(1);
        let pool4 = ThreadPool::new(4);
        let mut rng = Rng::new(57, 3);
        for (m, k, n, alpha) in [
            (65usize, 47usize, 33usize, 1.0f32),
            (128, KC + 44, 96, 1.7),
            (48, 64, NC + 9, 1.0),
            (12, 300, 40, 0.25),
        ] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let mut want = c0.clone();
            gemm_blocked_k(
                Kernel::Scalar, None, m, n, k, alpha, &a, Trans::N, &b, Trans::T, &mut want,
            );
            // the scalar tile itself must agree with the naive oracle
            let naive = naive_matmul(&a, &b, m, k, n, false, true);
            let want_delta: Vec<f32> = want
                .iter()
                .zip(&c0)
                .map(|(w, c)| (w - c) / alpha)
                .collect();
            prop::assert_allclose(&want_delta, &naive, 1e-3, 1e-3).unwrap();
            let wbits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            for &kern in compiled_kernels() {
                if !kern.supported() {
                    continue;
                }
                for pool in [None, Some(&pool1), Some(&pool4)] {
                    let mut c = c0.clone();
                    gemm_blocked_k(
                        kern, pool, m, n, k, alpha, &a, Trans::N, &b, Trans::T, &mut c,
                    );
                    let bits: Vec<u32> = c.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(
                        wbits,
                        bits,
                        "kernel {} (width {}) pool={:?} diverges from scalar on ({m},{k},{n}) alpha={alpha}",
                        kern.name(),
                        kern.width(),
                        pool.map(|p| p.workers()),
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_with_kernel_full_dispatch_matches_default() {
        // the pinned public entry must route small/m=1/low-rank shapes
        // through the same fallbacks as gemm_with — bit-equal end to end
        let mut rng = Rng::new(59, 1);
        for (m, k, n) in [(1usize, 96usize, 64usize), (3, 40, 24), (48, 64, 64), (200, 128, 96)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
            let mut base = vec![0.0f32; m * n];
            gemm_with(None, m, n, k, 1.0, &a, Trans::N, &b, Trans::T, &mut base);
            for &kern in compiled_kernels() {
                if !kern.supported() {
                    continue;
                }
                let mut c = vec![0.0f32; m * n];
                gemm_with_kernel(kern, None, m, n, k, 1.0, &a, Trans::N, &b, Trans::T, &mut c);
                let b1: Vec<u32> = base.iter().map(|v| v.to_bits()).collect();
                let b2: Vec<u32> = c.iter().map(|v| v.to_bits()).collect();
                assert_eq!(b1, b2, "kernel {} ({m},{k},{n})", kern.name());
            }
        }
    }

    #[test]
    fn kernel_selection_is_supported_and_deterministic() {
        // whatever MOS_SIMD said, the selected kernel must be runnable
        // here and stable across calls; names/widths are the bench keys
        let sel = selected_kernel();
        assert!(sel.supported());
        assert_eq!(sel, selected_kernel());
        assert!(compiled_kernels().contains(&sel));
        for &k in compiled_kernels() {
            assert!(["scalar", "sse4", "avx8"].contains(&k.name()));
            assert!(k.width() == 1 || k.width() == 4 || k.width() == 8);
        }
        // the fallback chain is deterministic and never widens past the cap
        assert_eq!(widest_supported(0), Kernel::Scalar);
        assert!(widest_supported(4).width() <= 4);
        assert!(widest_supported(8).width() <= 8);
        assert!(widest_supported(usize::MAX).supported());
    }

    #[test]
    fn arena_reuses_and_rezeroes() {
        let mut ar = Arena::new();
        let mut v = ar.take(128);
        assert!(v.iter().all(|&x| x == 0.0));
        for x in v.iter_mut() {
            *x = 7.0;
        }
        let cap = v.capacity();
        ar.put(v);
        let v2 = ar.take(64);
        assert!(v2.capacity() >= cap.min(128), "allocation not reused");
        assert_eq!(v2.len(), 64);
        assert!(v2.iter().all(|&x| x == 0.0), "stale values leaked");
        ar.put(v2);
        // larger request than any freed buffer still works
        let v3 = ar.take(4096);
        assert_eq!(v3.len(), 4096);
        assert!(v3.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn arena_free_list_is_bounded() {
        // regression for the serving-fallback growth: recycling buffers
        // the arena never handed out (engine logits) must not grow the
        // free list without bound — puts past the cap drop the buffer
        let mut ar = Arena::with_cap(1000);
        for _ in 0..10 {
            ar.put(vec![0.0f32; 400]);
        }
        let parked: usize = ar.free.iter().map(|b| b.capacity()).sum();
        assert!(parked <= 1000, "free list exceeded its cap: {parked}");
        assert_eq!(ar.free.len(), 2);
        // takes still work, and the accounting frees room for new puts
        let v = ar.take(400);
        assert_eq!(v.len(), 400);
        ar.put(v);
        assert_eq!(ar.free.len(), 2);
    }

    #[test]
    fn transpose_matches_naive() {
        prop::check("transpose-blocked", 20, |rng| {
            let r = rng.range(1, 80);
            let c = rng.range(1, 80);
            let m: Vec<f32> = (0..r * c).map(|_| rng.normal()).collect();
            let t = transpose(&m, r, c);
            for i in 0..r {
                for j in 0..c {
                    if t[j * r + i] != m[i * c + j] {
                        return Err(format!("mismatch at ({i},{j})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn softmax_rows_properties() {
        let mut rng = Rng::new(1, 0);
        let (rows, n) = (5, 9);
        let mut x: Vec<f32> = (0..rows * n).map(|_| rng.normal() * 4.0).collect();
        let orig = x.clone();
        softmax_rows(&mut x, rows, n);
        for i in 0..rows {
            let row = &x[i * n..(i + 1) * n];
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&v| v >= 0.0));
            // argmax preserved
            let am_in = (0..n)
                .max_by(|&a, &b| orig[i * n + a].total_cmp(&orig[i * n + b]))
                .unwrap();
            let am_out =
                (0..n).max_by(|&a, &b| row[a].total_cmp(&row[b])).unwrap();
            assert_eq!(am_in, am_out);
        }
    }

    #[test]
    fn softmax_extreme_values_stable() {
        let mut x = vec![1000.0, 1000.0, -1000.0];
        softmax_rows(&mut x, 1, 3);
        assert!((x[0] - 0.5).abs() < 1e-6);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn silu_grad_matches_fd() {
        for &x in &[-3.0f32, -0.5, 0.0, 0.7, 2.5] {
            let eps = 1e-3;
            let fd = (silu(x + eps) - silu(x - eps)) / (2.0 * eps);
            assert!((fd - silu_grad(x)).abs() < 1e-4, "x={x}");
        }
    }
}
