//! AdamW optimizer over parameter banks — host twin of the optimizer baked
//! into the AOT train-step artifact (same hyperparameters, python
//! `model.py::train_step`).

use crate::util::bank::{Bank, Tensor};

pub const B1: f32 = 0.9;
pub const B2: f32 = 0.999;
pub const EPS: f32 = 1e-8;
pub const WEIGHT_DECAY: f32 = 0.0;

/// Optimizer state (first/second moments), shaped like the params bank.
pub struct AdamW {
    pub m: Bank,
    pub v: Bank,
    pub step: u64,
}

impl AdamW {
    pub fn new(params: &Bank) -> AdamW {
        let zeros = |b: &Bank| -> Bank {
            b.iter()
                .map(|(k, t)| (k.clone(), Tensor::zeros(t.shape())))
                .collect()
        };
        AdamW { m: zeros(params), v: zeros(params), step: 0 }
    }

    /// One update step; mutates `params` in place. Large tensors are
    /// chunked across the shared math pool (the update is elementwise, so
    /// results are identical for any worker count).
    pub fn update(&mut self, params: &mut Bank, grads: &Bank, lr: f32) {
        self.step += 1;
        let bc1 = 1.0 - B1.powi(self.step as i32);
        let bc2 = 1.0 - B2.powi(self.step as i32);
        for (key, g) in grads {
            let g = g.f32s().expect("grad must be f32");
            let pt = params.get_mut(key).expect("param/grad mismatch");
            let p = match pt {
                Tensor::F32 { data, .. } => data,
                _ => panic!("params must be f32"),
            };
            let m = match self.m.get_mut(key).unwrap() {
                Tensor::F32 { data, .. } => data,
                _ => unreachable!(),
            };
            let v = match self.v.get_mut(key).unwrap() {
                Tensor::F32 { data, .. } => data,
                _ => unreachable!(),
            };
            debug_assert_eq!(p.len(), g.len());
            const PAR_MIN: usize = 1 << 16;
            if g.len() < PAR_MIN {
                step_chunk(p, m, v, g, lr, bc1, bc2);
            } else {
                let pool = crate::model::math::pool();
                let chunk = g.len().div_euclid(pool.workers()).max(1 << 12);
                let items: Vec<_> = p
                    .chunks_mut(chunk)
                    .zip(m.chunks_mut(chunk))
                    .zip(v.chunks_mut(chunk))
                    .zip(g.chunks(chunk))
                    .collect();
                pool.scoped_map(items, |(((pc, mc), vc), gc)| {
                    step_chunk(pc, mc, vc, gc, lr, bc1, bc2)
                });
            }
        }
    }
}

/// Elementwise AdamW update over one contiguous chunk.
fn step_chunk(
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
    bc1: f32,
    bc2: f32,
) {
    for i in 0..g.len() {
        m[i] = B1 * m[i] + (1.0 - B1) * g[i];
        v[i] = B2 * v[i] + (1.0 - B2) * g[i] * g[i];
        let upd = (m[i] / bc1) / ((v[i] / bc2).sqrt() + EPS);
        p[i] -= lr * (upd + WEIGHT_DECAY * p[i]);
    }
}

/// Linear warmup then linear decay to zero (paper Appendix A.2).
pub fn lr_schedule(step: usize, total: usize, peak: f64, warmup_frac: f64) -> f64 {
    let warmup = ((total as f64 * warmup_frac).ceil() as usize).max(1);
    if step < warmup {
        peak * (step + 1) as f64 / warmup as f64
    } else {
        let rem = (total - step) as f64 / (total - warmup).max(1) as f64;
        peak * rem.max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        // minimize (p - 3)^2 elementwise
        let mut params = Bank::new();
        params.insert("p".into(), Tensor::from_f32(&[4], vec![0.0; 4]));
        let mut opt = AdamW::new(&params);
        for _ in 0..800 {
            let p = params["p"].f32s().unwrap();
            let g: Vec<f32> = p.iter().map(|x| 2.0 * (x - 3.0)).collect();
            let mut grads = Bank::new();
            grads.insert("p".into(), Tensor::from_f32(&[4], g));
            opt.update(&mut params, &grads, 0.05);
        }
        for &x in params["p"].f32s().unwrap() {
            assert!((x - 3.0).abs() < 1e-2, "x={x}");
        }
    }

    #[test]
    fn first_step_is_lr_sized() {
        // bias correction makes the first Adam step ~= lr * sign(g)
        let mut params = Bank::new();
        params.insert("p".into(), Tensor::from_f32(&[1], vec![1.0]));
        let mut opt = AdamW::new(&params);
        let mut grads = Bank::new();
        grads.insert("p".into(), Tensor::from_f32(&[1], vec![0.5]));
        opt.update(&mut params, &grads, 0.01);
        let p = params["p"].f32s().unwrap()[0];
        assert!((p - (1.0 - 0.01)).abs() < 1e-4, "p={p}");
    }

    #[test]
    fn schedule_warmup_and_decay() {
        let peak = 1e-3;
        let s0 = lr_schedule(0, 100, peak, 0.1);
        let s9 = lr_schedule(9, 100, peak, 0.1);
        let s55 = lr_schedule(55, 100, peak, 0.1);
        let s99 = lr_schedule(99, 100, peak, 0.1);
        assert!(s0 < s9);
        assert!((s9 - peak).abs() < 1e-9);
        assert!(s55 < peak && s55 > s99);
        assert!(s99 > 0.0 && s99 < 0.02 * peak + 1e-9);
    }
}
