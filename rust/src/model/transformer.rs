//! Decoder-only transformer forward/backward on the host, mirroring
//! `python/compile/model.py::forward` op-for-op (RMSNorm -> causal MHA ->
//! RMSNorm -> SwiGLU, sinusoidal positions, tied embedding/lm-head).
//!
//! Gradients flow only into the dense adapter factors (base is frozen),
//! matching the AOT train-step semantics.
//!
//! The inference path ([`forward`], [`infer_prefill`], [`decode_step`])
//! runs every matmul in canonical GEMM order ([`gemm_canon`] /
//! [`gemm_canon_batch`]): per-element results are bitwise independent of
//! how many rows share a call, which makes (a) full forwards batch-size
//! invariant and (b) the KV-cached [`infer_prefill`] + [`decode_step`]
//! bit-identical to the full-forward oracle. The backward pass keeps the
//! throughput-first [`gemm`] dispatch (no such contract).
//!
//! Training and inference forwards are split: [`forward`] materializes
//! the [`ForwardCache`] the backward pass consumes; [`infer_prefill`]
//! writes K/V straight into a [`KvCache`], keeps every intermediate in
//! the scratch arena (zero steady-state heap allocations, like
//! [`decode_step`]), and projects logits only at each row's last prompt
//! position — serving never pays for backward-only state or the
//! full-window vocab projection.

use super::math::*;
use super::paged::PagedKvCache;
use super::quant::{self, QuantBase, QuantMatrix};
use crate::adapter::{Factors, PooledAdapter, QuantPooledAdapter};
use crate::config::{MethodCfg, ModelCfg, LAYER_TYPES};
use crate::util::bank::{Bank, Tensor};
use crate::util::rng::Rng;
use std::collections::BTreeMap;

const EPS: f32 = 1e-6;

/// Host-side random frozen base (twin of python `init_base`, independent
/// RNG — host and artifact runs use their own banks).
pub fn init_base(cfg: &ModelCfg, seed: u64) -> Bank {
    let mut rng = Rng::new(seed, 41);
    let mut bank = Bank::new();
    bank.insert(
        "embed".into(),
        Tensor::from_f32(
            &[cfg.vocab, cfg.hidden],
            // std 0.1, matching python init_base (see the positional-
            // encoding scale note in forward)
            rng.normal_vec(cfg.vocab * cfg.hidden, 0.1),
        ),
    );
    for t in LAYER_TYPES {
        let (o, i) = cfg.dims(t);
        bank.insert(
            format!("w.{t}"),
            Tensor::from_f32(
                &[cfg.blocks, o, i],
                rng.normal_vec(cfg.blocks * o * i, (i as f32).powf(-0.5)),
            ),
        );
    }
    bank.insert(
        "norm_attn".into(),
        Tensor::from_f32(&[cfg.blocks, cfg.hidden], vec![1.0; cfg.blocks * cfg.hidden]),
    );
    bank.insert(
        "norm_mlp".into(),
        Tensor::from_f32(&[cfg.blocks, cfg.hidden], vec![1.0; cfg.blocks * cfg.hidden]),
    );
    bank.insert(
        "norm_final".into(),
        Tensor::from_f32(&[cfg.hidden], vec![1.0; cfg.hidden]),
    );
    bank
}

/// Sinusoidal positional encoding, matching python `_sinusoid`.
pub fn sinusoid(t_len: usize, h: usize) -> Vec<f32> {
    let mut enc = vec![0.0f32; t_len * h];
    for pos in 0..t_len {
        for d in 0..h {
            let angle = pos as f64
                / (10000f64).powf((2 * (d / 2)) as f64 / h as f64);
            enc[pos * h + d] =
                if d % 2 == 0 { angle.sin() } else { angle.cos() } as f32;
        }
    }
    enc
}

/// Per-block activation cache for backward.
pub struct BlockCache {
    pub x_in: Vec<f32>,  // (BT, C)
    pub rstd1: Vec<f32>, // (BT,)
    pub hn1: Vec<f32>,   // (BT, C)
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,     // (BT, C) each
    pub probs: Vec<f32>, // (B, H, T, T)
    pub ctx: Vec<f32>,   // (BT, C)
    pub x_mid: Vec<f32>, // (BT, C) after attention residual
    pub rstd2: Vec<f32>,
    pub hn2: Vec<f32>,
    pub g_pre: Vec<f32>, // (BT, F) gate pre-activation
    pub u_val: Vec<f32>, // (BT, F)
    pub f_val: Vec<f32>, // (BT, F)
    pub ta: BTreeMap<String, Vec<f32>>, // adapter mid products t = x@A^T (BT,r)
}

pub struct ForwardCache {
    pub blocks: Vec<BlockCache>,
    pub x_final_in: Vec<f32>, // input to final norm
    pub rstd_f: Vec<f32>,
    pub xf: Vec<f32>, // after final norm
    pub logits: Vec<f32>,
}

fn rmsnorm_fwd(x: &[f32], g: &[f32], c: usize) -> (Vec<f32>, Vec<f32>) {
    let rows = x.len() / c;
    let mut y = vec![0.0f32; x.len()];
    let mut rstd = vec![0.0f32; rows];
    for i in 0..rows {
        let xr = &x[i * c..(i + 1) * c];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / c as f32;
        let s = 1.0 / (ms + EPS).sqrt();
        rstd[i] = s;
        for j in 0..c {
            y[i * c + j] = g[j] * xr[j] * s;
        }
    }
    (y, rstd)
}

/// RMSNorm into a caller buffer, no rstd retention — the inference-path
/// twin of [`rmsnorm_fwd`] with per-row arithmetic kept op-for-op
/// identical (the bitwise oracle tests depend on it). `y` is fully
/// overwritten.
fn rmsnorm_rows_into(x: &[f32], g: &[f32], c: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let rows = x.len() / c;
    for i in 0..rows {
        let xr = &x[i * c..(i + 1) * c];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / c as f32;
        let s = 1.0 / (ms + EPS).sqrt();
        let yr = &mut y[i * c..(i + 1) * c];
        for j in 0..c {
            yr[j] = g[j] * xr[j] * s;
        }
    }
}

fn rmsnorm_bwd(
    x: &[f32],
    g: &[f32],
    rstd: &[f32],
    dy: &[f32],
    c: usize,
    dx: &mut [f32],
) {
    let rows = x.len() / c;
    for i in 0..rows {
        let xr = &x[i * c..(i + 1) * c];
        let dyr = &dy[i * c..(i + 1) * c];
        let s = rstd[i];
        let mut dot = 0.0f32;
        for j in 0..c {
            dot += dyr[j] * g[j] * xr[j];
        }
        let coef = s * s * s * dot / c as f32;
        let dxr = &mut dx[i * c..(i + 1) * c];
        for j in 0..c {
            dxr[j] += s * g[j] * dyr[j] - coef * xr[j];
        }
    }
}

/// Adapted linear forward: y = x@W^T + scale * (x@A^T)@B^T.
/// Returns (y, t) where t = x@A^T is cached for backward.
///
/// Runs in canonical GEMM order ([`gemm_canon`]) so the result for a row
/// does not depend on how many rows were batched with it — the contract
/// the KV-cached [`decode_step`] relies on to bit-match full forwards.
fn adapted_fwd(
    x: &[f32],
    w: &[f32],
    f: &Factors,
    block: usize,
    scale: f32,
    rows: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; rows * f.out_dim];
    let mut t = vec![0.0f32; rows * f.r];
    adapted_fwd_into(x, WeightsRef::F32(w), f, block, scale, rows, &mut y, &mut t);
    (y, t)
}

/// One frozen base weight as the inference paths consume it: the f32 bank
/// slice, or the int8 codes + per-output-row scales of a [`QuantBase`].
/// Both describe the same `(out, in)` row-major operand; [`base_gemm`]
/// dispatches on the representation.
#[derive(Clone, Copy)]
enum WeightsRef<'a> {
    F32(&'a [f32]),
    Int8 { q: &'a [i8], scale: &'a [f32] },
}

/// The frozen-base projection `y = x @ W^T` (`y` fully overwritten) for
/// either representation. The int8 arm accumulates in f32 in the same
/// canonical per-element order ([`quant::gemm_canon_q8`]), so it shares
/// the f32 path's row-batch/thread invariance — only the weight values
/// themselves are quantized.
fn base_gemm(rows: usize, o: usize, i: usize, x: &[f32], w: WeightsRef, y: &mut [f32]) {
    y.fill(0.0);
    match w {
        WeightsRef::F32(w) => {
            gemm_canon(rows, o, i, 1.0, x, Trans::N, w, Trans::T, y)
        }
        WeightsRef::Int8 { q, scale } => {
            quant::gemm_canon_q8(rows, o, i, 1.0, x, q, scale, y)
        }
    }
}

/// [`adapted_fwd`] into caller buffers (`y` `(rows, out)`, `t` `(rows, r)`
/// — both fully overwritten): the allocation-free form the lean inference
/// paths route every projection through, same canonical GEMM sequence.
#[allow(clippy::too_many_arguments)]
fn adapted_fwd_into(
    x: &[f32],
    w: WeightsRef,
    f: &Factors,
    block: usize,
    scale: f32,
    rows: usize,
    y: &mut [f32],
    t: &mut [f32],
) {
    let (i, o, r) = (f.in_dim, f.out_dim, f.r);
    debug_assert_eq!(y.len(), rows * o);
    debug_assert_eq!(t.len(), rows * r);
    base_gemm(rows, o, i, x, w, y);
    t.fill(0.0);
    gemm_canon(rows, r, i, 1.0, x, Trans::N, &f.a[block], Trans::T, t);
    // y += scale * t @ B^T  (B is (o,r)); scale folds into the GEMM
    gemm_canon(rows, o, r, scale, t, Trans::N, &f.b[block], Trans::T, y);
}

/// One tenant's adapter state as the inference paths consume it: the
/// legacy dense per-block factors, or the pooled shard representation the
/// shard-gather GEMMs read directly (no per-tenant dense copy).
#[derive(Clone, Copy)]
pub enum AdapterRef<'a> {
    Dense(&'a BTreeMap<String, Factors>),
    Pooled(&'a PooledAdapter),
    /// The int8 serving tier: shard pools stay resident as codes+scales,
    /// the gather GEMM dequantizes only the gathered slices per call.
    PooledInt8(&'a QuantPooledAdapter),
}

/// A contiguous run of batch rows served by one tenant: `rows` request
/// rows ([`infer_prefill_runs`]) or decode entries ([`decode_step_runs`])
/// share this adapter. A batch is a slice of bindings whose `rows` sum to
/// the batch size — one binding per tenant, rows grouped by tenant, so
/// every adapter sub-GEMM covers a whole run. Canonical GEMM order makes
/// each row's result bitwise independent of the grouping.
#[derive(Clone, Copy)]
pub struct AdapterBinding<'a> {
    pub rows: usize,
    pub mc: &'a MethodCfg,
    pub adapter: AdapterRef<'a>,
}

impl<'a> AdapterBinding<'a> {
    pub fn new(rows: usize, mc: &'a MethodCfg, adapter: AdapterRef<'a>) -> Self {
        AdapterBinding { rows, mc, adapter }
    }
}

/// [`adapted_fwd_into`] for one binding: dispatches on the representation.
/// The pooled arm gathers shard slices straight into the canonical GEMM
/// ([`gemm_gather_canon`]) — bitwise identical to materializing the dense
/// factors first, because the kernel consuming the floats is the same one
/// (A-side reads the gathered `(r, in)` through `Trans::T` exactly like
/// the dense path; B-side reads the *ungathered* `(r, out)` layout through
/// `Trans::N`, which addresses the very same values the dense path reads
/// from its transposed `(out, r)` copy through `Trans::T`).
#[allow(clippy::too_many_arguments)]
fn adapted_fwd_binding(
    x: &[f32],
    w: WeightsRef,
    b: &AdapterBinding,
    ti: usize,
    kb: usize,
    rows: usize,
    y: &mut [f32],
    t: &mut [f32],
) {
    let scale = (b.mc.alpha / b.mc.r as f64) as f32;
    match b.adapter {
        AdapterRef::Dense(f) => {
            adapted_fwd_into(x, w, &f[LAYER_TYPES[ti]], kb, scale, rows, y, t)
        }
        AdapterRef::Pooled(p) => {
            let v = p.view(LAYER_TYPES[ti]);
            let (r, l) = (b.mc.r, b.mc.l);
            let (i, o) = (l * v.shard_w_a, l * v.shard_w_b);
            debug_assert_eq!(y.len(), rows * o);
            debug_assert_eq!(t.len(), rows * r);
            base_gemm(rows, o, i, x, w, y);
            t.fill(0.0);
            let per = r * l;
            gemm_gather_canon(
                rows, r, i, 1.0, x, v.pool_a, v.shard_w_a,
                &v.idx_a[kb * per..(kb + 1) * per], l,
                Some(&v.rank_scale[kb * r..(kb + 1) * r]), Trans::T, t,
            );
            gemm_gather_canon(
                rows, o, r, scale, t, v.pool_b, v.shard_w_b,
                &v.idx_b[kb * per..(kb + 1) * per], l, None, Trans::N, y,
            );
        }
        AdapterRef::PooledInt8(p) => {
            // same shard-gather shape as the f32 pooled arm; the pools
            // stay int8-resident and dequantize per gathered slice —
            // bit-identical to gathering from a pre-dequantized pool
            // (see `quant::gemm_gather_canon_q8`)
            let v = p.view(LAYER_TYPES[ti]);
            let (r, l) = (b.mc.r, b.mc.l);
            let (i, o) = (l * v.pool_a.shard_w, l * v.pool_b.shard_w);
            debug_assert_eq!(y.len(), rows * o);
            debug_assert_eq!(t.len(), rows * r);
            base_gemm(rows, o, i, x, w, y);
            t.fill(0.0);
            let per = r * l;
            quant::gemm_gather_canon_q8(
                rows, r, i, 1.0, x, v.pool_a,
                &v.idx_a[kb * per..(kb + 1) * per], l,
                Some(&v.rank_scale[kb * r..(kb + 1) * r]), Trans::T, t,
            );
            quant::gemm_gather_canon_q8(
                rows, o, r, scale, t, v.pool_b,
                &v.idx_b[kb * per..(kb + 1) * per], l, None, Trans::N, y,
            );
        }
    }
}

/// One projection over a whole mixed-tenant batch: walk the bindings in
/// order, applying each run's adapter to its contiguous row range. `unit`
/// is batch rows per binding row (`seq` for prefill windows, 1 for decode
/// entries); `x`/`y` are the full `(batch_rows * unit, dim)` buffers.
#[allow(clippy::too_many_arguments)]
fn adapted_fwd_bindings(
    runs: &[AdapterBinding],
    ti: usize,
    kb: usize,
    w: WeightsRef,
    unit: usize,
    i_dim: usize,
    o_dim: usize,
    x: &[f32],
    y: &mut [f32],
    t_buf: &mut [f32],
) {
    let mut r0 = 0usize;
    for b in runs {
        let rows = b.rows * unit;
        adapted_fwd_binding(
            &x[r0 * i_dim..(r0 + rows) * i_dim],
            w,
            b,
            ti,
            kb,
            rows,
            &mut y[r0 * o_dim..(r0 + rows) * o_dim],
            &mut t_buf[..rows * b.mc.r],
        );
        r0 += rows;
    }
    debug_assert_eq!(r0 * i_dim, x.len());
}

/// Adapted linear backward. Accumulates dx, dA, dB.
#[allow(clippy::too_many_arguments)]
fn adapted_bwd(
    x: &[f32],
    w: &[f32],
    f: &Factors,
    t: &[f32],
    block: usize,
    scale: f32,
    rows: usize,
    dy: &[f32],
    dx: &mut [f32],
    df: &mut Factors,
) {
    let (i, o, r) = (f.in_dim, f.out_dim, f.r);
    // dx += dy @ W  (W is (o,i))
    matmul_nn_acc(dy, w, dx, rows, o, i);
    // dt = scale * dy @ B  (B (o,r))
    let mut dt = scratch_take(rows * r);
    gemm(rows, r, o, scale, dy, Trans::N, &f.b[block], Trans::N, &mut dt);
    // dB += scale * dy^T @ t  (o,r)
    gemm(o, r, rows, scale, dy, Trans::T, t, Trans::N, &mut df.b[block]);
    // dA += dt^T @ x  (r,i)
    matmul_tn_acc(&dt, x, &mut df.a[block], rows, r, i);
    // dx += dt @ A  (A (r,i))
    matmul_nn_acc(&dt, &f.a[block], dx, rows, r, i);
    scratch_put(dt);
}

/// Full forward. `tokens` is (B*T,) i32. Returns the cache (logits inside).
pub fn forward(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    base: &Bank,
    factors: &BTreeMap<String, Factors>,
    tokens: &[i32],
) -> (ForwardCache, f32) {
    let (bsz, t_len, c) = (tokens.len() / cfg.seq, cfg.seq, cfg.hidden);
    let (heads, hd, ff) = (cfg.heads, cfg.head_dim(), cfg.ff);
    let rows = bsz * t_len;
    let scale = (mc.alpha / mc.r as f64) as f32;
    let embed = base["embed"].f32s().unwrap();
    let pos = sinusoid(t_len, c);

    let mut x = vec![0.0f32; rows * c];
    for (row, &tok) in tokens.iter().enumerate() {
        let e = &embed[tok as usize * c..(tok as usize + 1) * c];
        let p = &pos[(row % t_len) * c..(row % t_len + 1) * c];
        for j in 0..c {
            // 0.1-scaled positions, matching python forward
            x[row * c + j] = e[j] + 0.1 * p[j];
        }
    }

    let att_scale = (hd as f32).powf(-0.5);
    // per-head gather/score scratch, reused across every (block, batch,
    // head) iteration instead of reallocating bsz*heads*blocks times
    let mut qh = scratch_take(t_len * hd);
    let mut kh = scratch_take(t_len * hd);
    let mut vh = scratch_take(t_len * hd);
    let mut ch = scratch_take(t_len * hd);
    let mut att = scratch_take(t_len * t_len);
    let mut blocks = Vec::with_capacity(cfg.blocks);
    for kb in 0..cfg.blocks {
        let na = &base["norm_attn"].f32s().unwrap()[kb * c..(kb + 1) * c];
        let nm = &base["norm_mlp"].f32s().unwrap()[kb * c..(kb + 1) * c];
        let w = |t: &str| {
            let (o, i) = cfg.dims(t);
            &base[&format!("w.{t}")].f32s().unwrap()[kb * o * i..(kb + 1) * o * i]
        };

        let x_in = x.clone();
        let (hn1, rstd1) = rmsnorm_fwd(&x, na, c);
        let mut ta = BTreeMap::new();
        let (q, tq) = adapted_fwd(&hn1, w("q"), &factors["q"], kb, scale, rows);
        let (k, tk) = adapted_fwd(&hn1, w("k"), &factors["k"], kb, scale, rows);
        let (v, tv) = adapted_fwd(&hn1, w("v"), &factors["v"], kb, scale, rows);
        ta.insert("q".into(), tq);
        ta.insert("k".into(), tk);
        ta.insert("v".into(), tv);

        // attention per (batch, head)
        let mut probs = vec![0.0f32; bsz * heads * t_len * t_len];
        let mut ctx = vec![0.0f32; rows * c];
        for b in 0..bsz {
            for h in 0..heads {
                // gather head slices: q_h (T, hd)
                for tt in 0..t_len {
                    let row = b * t_len + tt;
                    qh[tt * hd..(tt + 1) * hd]
                        .copy_from_slice(&q[row * c + h * hd..row * c + (h + 1) * hd]);
                    kh[tt * hd..(tt + 1) * hd]
                        .copy_from_slice(&k[row * c + h * hd..row * c + (h + 1) * hd]);
                    vh[tt * hd..(tt + 1) * hd]
                        .copy_from_slice(&v[row * c + h * hd..row * c + (h + 1) * hd]);
                }
                att.fill(0.0);
                gemm_canon(
                    t_len, t_len, hd, 1.0, &qh, Trans::N, &kh, Trans::T,
                    &mut att,
                );
                for i in 0..t_len {
                    for j in 0..t_len {
                        att[i * t_len + j] = if j <= i {
                            att[i * t_len + j] * att_scale
                        } else {
                            -1e9
                        };
                    }
                }
                softmax_rows(&mut att, t_len, t_len);
                ch.fill(0.0);
                gemm_canon(
                    t_len, hd, t_len, 1.0, &att, Trans::N, &vh, Trans::N,
                    &mut ch,
                );
                let off = (b * heads + h) * t_len * t_len;
                probs[off..off + t_len * t_len].copy_from_slice(&att);
                for tt in 0..t_len {
                    let row = b * t_len + tt;
                    ctx[row * c + h * hd..row * c + (h + 1) * hd]
                        .copy_from_slice(&ch[tt * hd..(tt + 1) * hd]);
                }
            }
        }

        let (attn_out, to) =
            adapted_fwd(&ctx, w("o"), &factors["o"], kb, scale, rows);
        ta.insert("o".into(), to);
        for (xv, av) in x.iter_mut().zip(&attn_out) {
            *xv += av;
        }
        let x_mid = x.clone();

        let (hn2, rstd2) = rmsnorm_fwd(&x, nm, c);
        let (g_pre, tg) =
            adapted_fwd(&hn2, w("gate"), &factors["gate"], kb, scale, rows);
        let (u_val, tu) =
            adapted_fwd(&hn2, w("up"), &factors["up"], kb, scale, rows);
        ta.insert("gate".into(), tg);
        ta.insert("up".into(), tu);
        let mut f_val = vec![0.0f32; rows * ff];
        for idx in 0..rows * ff {
            f_val[idx] = silu(g_pre[idx]) * u_val[idx];
        }
        let (down_out, td) =
            adapted_fwd(&f_val, w("down"), &factors["down"], kb, scale, rows);
        ta.insert("down".into(), td);
        for (xv, dv) in x.iter_mut().zip(&down_out) {
            *xv += dv;
        }

        blocks.push(BlockCache {
            x_in,
            rstd1,
            hn1,
            q,
            k,
            v,
            probs,
            ctx,
            x_mid,
            rstd2,
            hn2,
            g_pre,
            u_val,
            f_val,
            ta,
        });
    }

    scratch_put(qh);
    scratch_put(kh);
    scratch_put(vh);
    scratch_put(ch);
    scratch_put(att);

    let nf = base["norm_final"].f32s().unwrap();
    let x_final_in = x.clone();
    let (xf, rstd_f) = rmsnorm_fwd(&x, nf, c);
    let mut logits = vec![0.0f32; rows * cfg.vocab];
    gemm_canon(
        rows, cfg.vocab, c, 1.0, &xf, Trans::N, embed, Trans::T, &mut logits,
    );

    (
        ForwardCache { blocks, x_final_in, rstd_f, xf, logits },
        0.0,
    )
}

/// Per-layer K/V buffers for incremental (KV-cached) decoding.
///
/// Row `r`'s position `p` lives at offset `(r * seq + p) * dim` of each
/// block's buffer. [`prefill`] fills a row's full window (positions past
/// the prompt hold pad garbage), and [`decode_step`] overwrites position
/// `p` *before* attending over `0..=p`, so stale tails are never read.
pub struct KvCache {
    pub bsz: usize,
    pub seq: usize,
    /// Hidden width of the cached projections. The host model runs MHA
    /// (`kv_heads == heads`), so K/V rows are (hidden,) like Q.
    pub dim: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Sinusoidal position table (seq, hidden), computed once — the same
    /// values [`forward`] derives per call.
    pos: Vec<f32>,
}

impl KvCache {
    pub fn new(cfg: &ModelCfg, bsz: usize) -> KvCache {
        assert_eq!(
            cfg.kv_heads, cfg.heads,
            "host KV cache assumes MHA (kv_heads == heads)"
        );
        // the pooled batched-head layout treats a (rows, hidden) projection
        // as (rows * heads, head_dim) — heads must tile hidden exactly
        assert_eq!(
            cfg.heads * cfg.head_dim(),
            cfg.hidden,
            "host KV-cached inference assumes heads * head_dim == hidden"
        );
        let sz = bsz * cfg.seq * cfg.hidden;
        KvCache {
            bsz,
            seq: cfg.seq,
            dim: cfg.hidden,
            k: vec![vec![0.0; sz]; cfg.blocks],
            v: vec![vec![0.0; sz]; cfg.blocks],
            pos: sinusoid(cfg.seq, cfg.hidden),
        }
    }

    /// Copy a training forward's per-block K/V activations into cache
    /// rows `rows[i]` — the legacy (pre-PR-5) prefill capture, kept for
    /// the full-forward comparison arm in `HostEngine`/`bench_serving`.
    pub fn copy_from_forward(&mut self, fc: &ForwardCache, rows: &[usize]) {
        let stride = self.seq * self.dim;
        for (kb, bc) in fc.blocks.iter().enumerate() {
            for (i, &r) in rows.iter().enumerate() {
                debug_assert!(r < self.bsz);
                self.k[kb][r * stride..(r + 1) * stride]
                    .copy_from_slice(&bc.k[i * stride..(i + 1) * stride]);
                self.v[kb][r * stride..(r + 1) * stride]
                    .copy_from_slice(&bc.v[i * stride..(i + 1) * stride]);
            }
        }
    }
}

/// Layer-type indices into [`InferRefs`] arrays ([`LAYER_TYPES`] order).
const WQ: usize = 0;
const WK: usize = 1;
const WV: usize = 2;
const WO: usize = 3;
const WGATE: usize = 4;
const WUP: usize = 5;
const WDOWN: usize = 6;

/// Quantize a frozen base [`Bank`] once per model: the seven projection
/// stacks (`rows = blocks * out`, one scale per output row) and the tied
/// embedding `(vocab, hidden)`. Norm weights stay f32 in the bank — they
/// are `O(hidden)` bytes and multiplicative, so quantizing them buys
/// nothing (see [`QuantBase`]).
pub fn quantize_base(cfg: &ModelCfg, base: &Bank) -> QuantBase {
    let w = LAYER_TYPES
        .iter()
        .map(|t| {
            let (o, i) = cfg.dims(t);
            QuantMatrix::quantize(
                cfg.blocks * o,
                i,
                base[&format!("w.{t}")].f32s().unwrap(),
            )
        })
        .collect();
    let embed =
        QuantMatrix::quantize(cfg.vocab, cfg.hidden, base["embed"].f32s().unwrap());
    QuantBase { w, embed }
}

/// One model's frozen base as the inference paths consume it: the f32
/// [`Bank`] (norms always read from here), plus optionally the int8
/// [`QuantBase`] the `MOS_SERVE_INT8=1` serving tier substitutes for the
/// projection weights and the tied embedding. The `*_runs` entry points
/// take their `&Bank` as [`BaseRef::f32`]; `HostEngine` hands the
/// `*_runs_base` variants an int8 ref when serving quantized.
#[derive(Clone, Copy)]
pub struct BaseRef<'a> {
    pub bank: &'a Bank,
    pub quant: Option<&'a QuantBase>,
}

impl<'a> BaseRef<'a> {
    /// The plain f32 base (what every pre-int8 call site means).
    pub fn f32(bank: &'a Bank) -> BaseRef<'a> {
        BaseRef { bank, quant: None }
    }

    /// Int8 projection weights + embedding; norms still from `bank`.
    pub fn int8(bank: &'a Bank, quant: &'a QuantBase) -> BaseRef<'a> {
        BaseRef { bank, quant: Some(quant) }
    }
}

/// The tied embedding in either representation (also the LM head).
#[derive(Clone, Copy)]
enum EmbedRef<'a> {
    F32(&'a [f32]),
    Int8(&'a QuantMatrix),
}

/// The seven projection stacks in either representation.
#[derive(Clone, Copy)]
enum WBase<'a> {
    F32([&'a [f32]; 7]),
    Int8(&'a [QuantMatrix]),
}

/// Hoisted per-call views of the frozen base for the lean inference
/// paths: one Bank probe per tensor per call. (The old per-block closure
/// formatted a fresh `"w.{t}"` key string — a heap allocation — for every
/// (block, projection) lookup.) Adapter state travels separately as
/// [`AdapterBinding`]s since PR 6 (one batch can mix tenants and
/// representations); since PR 10 the base itself can be int8
/// ([`BaseRef`]), with `w`/`embed` dispatching per representation.
struct InferRefs<'a> {
    embed: EmbedRef<'a>,
    norm_attn: &'a [f32],
    norm_mlp: &'a [f32],
    norm_final: &'a [f32],
    w: WBase<'a>,
    wsz: [usize; 7],
    /// per-block output rows per layer type (scale-slice stride)
    wout: [usize; 7],
}

impl<'a> InferRefs<'a> {
    fn new(cfg: &ModelCfg, base: BaseRef<'a>) -> InferRefs<'a> {
        let bank = base.bank;
        let (w, embed) = match base.quant {
            None => (
                WBase::F32([
                    bank["w.q"].f32s().unwrap(),
                    bank["w.k"].f32s().unwrap(),
                    bank["w.v"].f32s().unwrap(),
                    bank["w.o"].f32s().unwrap(),
                    bank["w.gate"].f32s().unwrap(),
                    bank["w.up"].f32s().unwrap(),
                    bank["w.down"].f32s().unwrap(),
                ]),
                EmbedRef::F32(bank["embed"].f32s().unwrap()),
            ),
            Some(q) => {
                debug_assert_eq!(q.w.len(), 7);
                (WBase::Int8(&q.w), EmbedRef::Int8(&q.embed))
            }
        };
        let mut wsz = [0usize; 7];
        let mut wout = [0usize; 7];
        for (ti, &t) in LAYER_TYPES.iter().enumerate() {
            let (o, i) = cfg.dims(t);
            wsz[ti] = o * i;
            wout[ti] = o;
        }
        InferRefs {
            embed,
            norm_attn: bank["norm_attn"].f32s().unwrap(),
            norm_mlp: bank["norm_mlp"].f32s().unwrap(),
            norm_final: bank["norm_final"].f32s().unwrap(),
            w,
            wsz,
            wout,
        }
    }

    /// Block `kb`'s weight for layer type `t` (a `W*` index) — an f32
    /// slice or the matching int8 code rows + per-row scales.
    fn w(&self, t: usize, kb: usize) -> WeightsRef<'a> {
        match self.w {
            WBase::F32(ws) => {
                WeightsRef::F32(&ws[t][kb * self.wsz[t]..(kb + 1) * self.wsz[t]])
            }
            WBase::Int8(qs) => {
                let o = self.wout[t];
                let (q, scale) = qs[t].rows_slice(kb * o, o);
                WeightsRef::Int8 { q, scale }
            }
        }
    }

    /// Token `tok`'s embedding row: a borrow of the f32 table, or one row
    /// dequantized into `buf` (`c` floats — trivial per token next to the
    /// projections it feeds).
    fn embed_row<'b>(&self, tok: usize, c: usize, buf: &'b mut [f32]) -> &'b [f32]
    where
        'a: 'b,
    {
        match self.embed {
            EmbedRef::F32(e) => &e[tok * c..(tok + 1) * c],
            EmbedRef::Int8(q) => {
                q.row_into(tok, &mut buf[..c]);
                &buf[..c]
            }
        }
    }

    /// Project `m` final-norm rows against the tied embedding (LM head).
    fn project_logits(
        &self,
        m: usize,
        vocab: usize,
        c: usize,
        xf: &[f32],
        logits: &mut [f32],
    ) {
        match self.embed {
            EmbedRef::F32(e) => {
                gemm_canon(m, vocab, c, 1.0, xf, Trans::N, e, Trans::T, logits)
            }
            EmbedRef::Int8(q) => {
                quant::gemm_canon_q8(m, vocab, c, 1.0, xf, &q.q, &q.scale, logits)
            }
        }
    }
}

/// Inference-only prefill: one lean full-window forward for `rows.len()`
/// requests that writes every block's K/V **directly into `cache` rows**
/// (no [`ForwardCache`], no per-block activation clones, no probs
/// retention, no copy-out loop), keeps every intermediate in the
/// per-thread scratch arena — steady-state prefill performs zero
/// per-token heap allocations (asserted by test below the pool
/// threshold; past the pool threshold (`math::PAR_FLOPS`) only the pool's O(workers) dispatch
/// bookkeeping allocates) — and projects logits **only at each row's
/// last prompt position**: `last[i]` names that window position, and the return is
/// `(rows.len() * vocab)` next-token logit rows — a ~seq-fold smaller
/// vocab GEMM than the full-window projection the training [`forward`]
/// runs. The returned buffer is `scratch_take`-backed; hand it back with
/// [`scratch_put`] when done to keep the serving loop allocation-free.
///
/// Attention runs as pooled batched-head GEMMs ([`gemm_canon_batch`]):
/// all `(row, head)` score/context sub-problems ship in one call each, so
/// the thread pool sees whole sub-GEMMs instead of per-head fragments
/// below its parallel threshold.
///
/// Bitwise contract: every matmul is canonical-order, so these logits are
/// bit-identical to the rows a full [`forward`] produces at the same
/// positions, and the cached K/V bit-match the training path's (enforced
/// by the oracle tests).
#[allow(clippy::too_many_arguments)]
pub fn infer_prefill(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    base: &Bank,
    factors: &BTreeMap<String, Factors>,
    tokens: &[i32],
    last: &[usize],
    cache: &mut KvCache,
    rows: &[usize],
) -> Vec<f32> {
    let runs = [AdapterBinding::new(rows.len(), mc, AdapterRef::Dense(factors))];
    infer_prefill_runs(cfg, base, &runs, tokens, last, cache, rows)
}

/// [`infer_prefill`] over a mixed-tenant batch: `runs` holds one
/// [`AdapterBinding`] per tenant, covering `rows`/`tokens`/`last` in
/// order (`runs[i].rows` request rows each, summing to `rows.len()`).
/// Each adapter sub-GEMM spans a whole run; the pooled representation is
/// consumed in place by the shard-gather GEMMs. Canonical order keeps a
/// row's logits bitwise independent of which tenants share the batch.
#[allow(clippy::too_many_arguments)]
pub fn infer_prefill_runs(
    cfg: &ModelCfg,
    base: &Bank,
    runs: &[AdapterBinding],
    tokens: &[i32],
    last: &[usize],
    cache: &mut KvCache,
    rows: &[usize],
) -> Vec<f32> {
    infer_prefill_runs_base(cfg, BaseRef::f32(base), runs, tokens, last, cache, rows)
}

/// [`infer_prefill_runs`] against a [`BaseRef`]: the int8 serving tier
/// enters here with quantized projection weights + embedding. All bitwise
/// contracts hold *per representation* — the int8 path is itself
/// batch/thread invariant, it just computes against quantized weights
/// (accuracy gated by the tiny-preset logit-error budget).
#[allow(clippy::too_many_arguments)]
pub fn infer_prefill_runs_base(
    cfg: &ModelCfg,
    base: BaseRef,
    runs: &[AdapterBinding],
    tokens: &[i32],
    last: &[usize],
    cache: &mut KvCache,
    rows: &[usize],
) -> Vec<f32> {
    let nr = rows.len();
    debug_assert_eq!(tokens.len(), nr * cfg.seq);
    debug_assert_eq!(last.len(), nr);
    debug_assert_eq!(runs.iter().map(|b| b.rows).sum::<usize>(), nr);
    if nr == 0 {
        return Vec::new();
    }
    let (t_len, c) = (cfg.seq, cfg.hidden);
    let (heads, hd, ff) = (cfg.heads, cfg.head_dim(), cfg.ff);
    let nrows = nr * t_len;
    let r_max = runs.iter().map(|b| b.mc.r).max().unwrap();
    let att_scale = (hd as f32).powf(-0.5);
    let stride = t_len * c;
    let rf = InferRefs::new(cfg, base);

    let mut x = scratch_take(nrows * c);
    let mut e_buf = scratch_take(c);
    for (row, &tok) in tokens.iter().enumerate() {
        let e = rf.embed_row(tok as usize, c, &mut e_buf);
        // cache.pos holds the same sinusoid table forward derives per call
        let p = &cache.pos[(row % t_len) * c..(row % t_len + 1) * c];
        for j in 0..c {
            // 0.1-scaled positions, the same expression forward evaluates
            x[row * c + j] = e[j] + 0.1 * p[j];
        }
    }

    let mut hn = scratch_take(nrows * c);
    let mut q_buf = scratch_take(nrows * c);
    let mut proj = scratch_take(nrows * c); // o/down projection outputs
    let mut ctx = scratch_take(nrows * c);
    let mut g_pre = scratch_take(nrows * ff);
    let mut u_val = scratch_take(nrows * ff);
    let mut f_val = scratch_take(nrows * ff);
    let mut t_buf = scratch_take(nrows * r_max);
    let mut t_kv = scratch_take(t_len * r_max);
    // pooled head-major attention buffers: (nr * heads, t_len, ·)
    let mut qh = scratch_take(nr * heads * t_len * hd);
    let mut kh = scratch_take(nr * heads * t_len * hd);
    let mut vh = scratch_take(nr * heads * t_len * hd);
    let mut ch = scratch_take(nr * heads * t_len * hd);
    let mut att = scratch_take(nr * heads * t_len * t_len);

    for kb in 0..cfg.blocks {
        let na = &rf.norm_attn[kb * c..(kb + 1) * c];
        let nm = &rf.norm_mlp[kb * c..(kb + 1) * c];

        rmsnorm_rows_into(&x, na, c, &mut hn);
        adapted_fwd_bindings(
            runs, WQ, kb, rf.w(WQ, kb), t_len, c, c, &hn, &mut q_buf,
            &mut t_buf,
        );
        // K/V: projected straight into this block's cache rows, one
        // canonical GEMM triple per request row — row-batch independence
        // makes each bit-identical to the full-batch projection forward
        // runs, so no staging buffer or copy-out loop is needed. Requests
        // walk in run order so each row uses its own tenant's adapter.
        // Contiguous-rows fast path: when a run's cache rows are
        // consecutive, its destination slices tile one contiguous cache
        // range, so the whole run projects in a single GEMM per side —
        // bit-identical to the per-request split because canonical order
        // is row-batch invariant (enforced by test). t_buf is free here:
        // its contents are dead between adapted_fwd_bindings calls.
        let mut req0 = 0usize;
        for b in runs {
            let contiguous = b.rows > 0
                && (1..b.rows).all(|j| rows[req0 + j] == rows[req0 + j - 1] + 1);
            if contiguous {
                let r0 = rows[req0];
                debug_assert!(r0 + b.rows <= cache.bsz);
                let hn_run = &hn[req0 * stride..(req0 + b.rows) * stride];
                adapted_fwd_binding(
                    hn_run, rf.w(WK, kb), b, WK, kb, b.rows * t_len,
                    &mut cache.k[kb][r0 * stride..(r0 + b.rows) * stride],
                    &mut t_buf[..b.rows * t_len * b.mc.r],
                );
                adapted_fwd_binding(
                    hn_run, rf.w(WV, kb), b, WV, kb, b.rows * t_len,
                    &mut cache.v[kb][r0 * stride..(r0 + b.rows) * stride],
                    &mut t_buf[..b.rows * t_len * b.mc.r],
                );
            } else {
                for i in req0..req0 + b.rows {
                    let r = rows[i];
                    debug_assert!(r < cache.bsz);
                    let hn_row = &hn[i * stride..(i + 1) * stride];
                    adapted_fwd_binding(
                        hn_row, rf.w(WK, kb), b, WK, kb, t_len,
                        &mut cache.k[kb][r * stride..(r + 1) * stride],
                        &mut t_kv[..t_len * b.mc.r],
                    );
                    adapted_fwd_binding(
                        hn_row, rf.w(WV, kb), b, WV, kb, t_len,
                        &mut cache.v[kb][r * stride..(r + 1) * stride],
                        &mut t_kv[..t_len * b.mc.r],
                    );
                }
            }
            req0 += b.rows;
        }

        // batched-head attention: gather Q from the projection and K/V
        // from the rows just written, head-major
        for (i, &r) in rows.iter().enumerate() {
            for h in 0..heads {
                let b0 = (i * heads + h) * t_len * hd;
                for tt in 0..t_len {
                    let qs = (i * t_len + tt) * c + h * hd;
                    qh[b0 + tt * hd..b0 + (tt + 1) * hd]
                        .copy_from_slice(&q_buf[qs..qs + hd]);
                    let ks = (r * t_len + tt) * c + h * hd;
                    kh[b0 + tt * hd..b0 + (tt + 1) * hd]
                        .copy_from_slice(&cache.k[kb][ks..ks + hd]);
                    vh[b0 + tt * hd..b0 + (tt + 1) * hd]
                        .copy_from_slice(&cache.v[kb][ks..ks + hd]);
                }
            }
        }
        att.fill(0.0);
        gemm_canon_batch(
            nr * heads, t_len, t_len, hd, 1.0, &qh, Trans::N, &kh, Trans::T,
            &mut att,
        );
        // causal mask + scale, then softmax — op-for-op what forward runs
        for bh in 0..nr * heads {
            let a0 = bh * t_len * t_len;
            for i in 0..t_len {
                for j in 0..t_len {
                    let idx = a0 + i * t_len + j;
                    att[idx] = if j <= i { att[idx] * att_scale } else { -1e9 };
                }
            }
        }
        softmax_rows(&mut att, nr * heads * t_len, t_len);
        ch.fill(0.0);
        gemm_canon_batch(
            nr * heads, t_len, hd, t_len, 1.0, &att, Trans::N, &vh, Trans::N,
            &mut ch,
        );
        ctx.fill(0.0);
        for i in 0..nr {
            for h in 0..heads {
                let b0 = (i * heads + h) * t_len * hd;
                for tt in 0..t_len {
                    let dst = (i * t_len + tt) * c + h * hd;
                    ctx[dst..dst + hd]
                        .copy_from_slice(&ch[b0 + tt * hd..b0 + (tt + 1) * hd]);
                }
            }
        }

        adapted_fwd_bindings(
            runs, WO, kb, rf.w(WO, kb), t_len, c, c, &ctx, &mut proj,
            &mut t_buf,
        );
        for (xv, av) in x.iter_mut().zip(&proj) {
            *xv += av;
        }

        rmsnorm_rows_into(&x, nm, c, &mut hn);
        adapted_fwd_bindings(
            runs, WGATE, kb, rf.w(WGATE, kb), t_len, c, ff, &hn, &mut g_pre,
            &mut t_buf,
        );
        adapted_fwd_bindings(
            runs, WUP, kb, rf.w(WUP, kb), t_len, c, ff, &hn, &mut u_val,
            &mut t_buf,
        );
        for idx in 0..nrows * ff {
            f_val[idx] = silu(g_pre[idx]) * u_val[idx];
        }
        adapted_fwd_bindings(
            runs, WDOWN, kb, rf.w(WDOWN, kb), t_len, ff, c, &f_val, &mut proj,
            &mut t_buf,
        );
        for (xv, dv) in x.iter_mut().zip(&proj) {
            *xv += dv;
        }
    }

    // last-position-only logits: gather the lean (nr, hidden) tail, norm,
    // and project against the tied embedding
    let mut xl = scratch_take(nr * c);
    for (i, &p) in last.iter().enumerate() {
        debug_assert!(p < t_len);
        xl[i * c..(i + 1) * c]
            .copy_from_slice(&x[(i * t_len + p) * c..(i * t_len + p + 1) * c]);
    }
    let mut xf = scratch_take(nr * c);
    rmsnorm_rows_into(&xl, rf.norm_final, c, &mut xf);
    let mut logits = scratch_take(nr * cfg.vocab);
    rf.project_logits(nr, cfg.vocab, c, &xf, &mut logits);
    for buf in [
        x, e_buf, hn, q_buf, proj, ctx, g_pre, u_val, f_val, t_buf, t_kv, qh,
        kh, vh, ch, att, xl, xf,
    ] {
        scratch_put(buf);
    }
    logits
}

/// Legacy name for [`infer_prefill`], kept so the PR-4 entry point still
/// resolves by name — the signature moved with it (new `last` argument;
/// the return shrank from full-window `(rows·seq·vocab)` logits to
/// **last-position-only** `(rows·vocab)`, and no [`ForwardCache`] is
/// constructed — see DESIGN.md §Serving API migration table). New code
/// should call [`infer_prefill`] directly.
#[allow(clippy::too_many_arguments)]
pub fn prefill(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    base: &Bank,
    factors: &BTreeMap<String, Factors>,
    tokens: &[i32],
    last: &[usize],
    cache: &mut KvCache,
    rows: &[usize],
) -> Vec<f32> {
    infer_prefill(cfg, mc, base, factors, tokens, last, cache, rows)
}

/// One KV-cached decode position per entry `(cache row, position, token)`:
/// embeds the token at `position`, runs every block at that single
/// position attending over the cached `0..=position`, appends the new K/V,
/// and returns next-token logits `(entries.len() * vocab)` — a
/// `scratch_take`-backed buffer; hand it back with [`scratch_put`] when
/// done to keep the serving loop allocation-free. Every intermediate is
/// arena-backed: steady-state decode performs zero per-token heap
/// allocations (asserted by test below the pool threshold; once a GEMM
/// crosses the pool threshold (`math::PAR_FLOPS`) the only remaining allocations are the pool's
/// O(workers) dispatch bookkeeping per pooled call).
///
/// Attention is batched across every `(entry, head)` sub-problem via
/// [`gemm_canon_batch`] over a shared padded span (the longest live
/// prefix this step): a sub-problem's positions past its own span hold
/// zeroed K/V and zeroed probs, contributing exactly nothing — the same
/// neutrality the full-window oracle's masked tail already relies on.
///
/// Every matmul runs in canonical order, so these logits are bitwise
/// identical to a full-window [`forward`] over the same prefix — and
/// independent of which other rows shared the step (the
/// continuous-batching determinism contract).
pub fn decode_step(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    base: &Bank,
    factors: &BTreeMap<String, Factors>,
    cache: &mut KvCache,
    entries: &[(usize, usize, i32)],
) -> Vec<f32> {
    let runs = [AdapterBinding::new(entries.len(), mc, AdapterRef::Dense(factors))];
    decode_step_runs(cfg, base, &runs, cache, entries)
}

/// [`decode_step`] over a mixed-tenant batch: `runs` holds one
/// [`AdapterBinding`] per tenant covering `entries` in order
/// (`runs[i].rows` decode entries each, summing to `entries.len()`).
/// Adapter sub-GEMMs span whole runs; pooled tenants decode straight off
/// their shard pools. Canonical order keeps each entry's logits bitwise
/// independent of which tenants share the step.
pub fn decode_step_runs(
    cfg: &ModelCfg,
    base: &Bank,
    runs: &[AdapterBinding],
    cache: &mut KvCache,
    entries: &[(usize, usize, i32)],
) -> Vec<f32> {
    decode_step_runs_base(cfg, BaseRef::f32(base), runs, cache, entries)
}

/// [`decode_step_runs`] against a [`BaseRef`] (int8 serving tier entry —
/// see [`infer_prefill_runs_base`] for the representation contract).
pub fn decode_step_runs_base(
    cfg: &ModelCfg,
    base: BaseRef,
    runs: &[AdapterBinding],
    cache: &mut KvCache,
    entries: &[(usize, usize, i32)],
) -> Vec<f32> {
    let m = entries.len();
    debug_assert_eq!(runs.iter().map(|b| b.rows).sum::<usize>(), m);
    if m == 0 {
        return Vec::new();
    }
    let (t_len, c) = (cfg.seq, cfg.hidden);
    let (heads, hd, ff) = (cfg.heads, cfg.head_dim(), cfg.ff);
    let r_max = runs.iter().map(|b| b.mc.r).max().unwrap();
    let att_scale = (hd as f32).powf(-0.5);
    let rf = InferRefs::new(cfg, base);
    // shared padded attention span for the pooled batch
    let t_pad = entries.iter().map(|&(_, pos, _)| pos + 1).max().unwrap();

    let mut x = scratch_take(m * c);
    let mut e_buf = scratch_take(c);
    for (i, &(row, pos, tok)) in entries.iter().enumerate() {
        debug_assert!(row < cache.bsz && pos < t_len);
        let e = rf.embed_row(tok as usize, c, &mut e_buf);
        let p = &cache.pos[pos * c..(pos + 1) * c];
        for j in 0..c {
            // 0.1-scaled positions, the same expression forward evaluates
            x[i * c + j] = e[j] + 0.1 * p[j];
        }
    }

    let mut hn = scratch_take(m * c);
    let mut q_buf = scratch_take(m * c);
    let mut k_new = scratch_take(m * c);
    let mut v_new = scratch_take(m * c);
    let mut proj = scratch_take(m * c);
    let mut ctx = scratch_take(m * c);
    let mut g_pre = scratch_take(m * ff);
    let mut u_val = scratch_take(m * ff);
    let mut f_val = scratch_take(m * ff);
    let mut t_buf = scratch_take(m * r_max);
    // pooled head-major K/V over the padded span; positions past a
    // sub-problem's own span stay zero from the arena's zero-fill
    let mut kh = scratch_take(m * heads * t_pad * hd);
    let mut vh = scratch_take(m * heads * t_pad * hd);
    let mut att = scratch_take(m * heads * t_pad);

    for kb in 0..cfg.blocks {
        let na = &rf.norm_attn[kb * c..(kb + 1) * c];
        let nm = &rf.norm_mlp[kb * c..(kb + 1) * c];

        rmsnorm_rows_into(&x, na, c, &mut hn);
        adapted_fwd_bindings(
            runs, WQ, kb, rf.w(WQ, kb), 1, c, c, &hn, &mut q_buf, &mut t_buf,
        );
        adapted_fwd_bindings(
            runs, WK, kb, rf.w(WK, kb), 1, c, c, &hn, &mut k_new, &mut t_buf,
        );
        adapted_fwd_bindings(
            runs, WV, kb, rf.w(WV, kb), 1, c, c, &hn, &mut v_new, &mut t_buf,
        );
        for (i, &(row, pos, _)) in entries.iter().enumerate() {
            let dst = (row * t_len + pos) * c;
            cache.k[kb][dst..dst + c]
                .copy_from_slice(&k_new[i * c..(i + 1) * c]);
            cache.v[kb][dst..dst + c]
                .copy_from_slice(&v_new[i * c..(i + 1) * c]);
        }

        // batched-head attention over cached 0..=pos: gather K/V
        // head-major (tails past each span stay zero)
        for (i, &(row, pos, _)) in entries.iter().enumerate() {
            let span = pos + 1;
            for h in 0..heads {
                let b0 = (i * heads + h) * t_pad * hd;
                for tt in 0..span {
                    let src = (row * t_len + tt) * c + h * hd;
                    kh[b0 + tt * hd..b0 + (tt + 1) * hd]
                        .copy_from_slice(&cache.k[kb][src..src + hd]);
                    vh[b0 + tt * hd..b0 + (tt + 1) * hd]
                        .copy_from_slice(&cache.v[kb][src..src + hd]);
                }
            }
        }
        att.fill(0.0);
        // q_buf's (m, heads*hd) layout *is* the pooled (m*heads, 1, hd) A
        gemm_canon_batch(
            m * heads, 1, t_pad, hd, 1.0, &q_buf, Trans::N, &kh, Trans::T,
            &mut att,
        );
        for (i, &(_, pos, _)) in entries.iter().enumerate() {
            let span = pos + 1;
            for h in 0..heads {
                let a0 = (i * heads + h) * t_pad;
                for a in att[a0..a0 + span].iter_mut() {
                    *a *= att_scale;
                }
                softmax_rows(&mut att[a0..a0 + span], 1, span);
                // padded columns hold q·0 scores (±0): zero them exactly
                // so the ctx GEMM's tail terms are the oracle's 0-prob adds
                att[a0 + span..a0 + t_pad].fill(0.0);
            }
        }
        // context lands straight in the (m, heads*hd) projection layout
        ctx.fill(0.0);
        gemm_canon_batch(
            m * heads, 1, hd, t_pad, 1.0, &att, Trans::N, &vh, Trans::N,
            &mut ctx,
        );

        adapted_fwd_bindings(
            runs, WO, kb, rf.w(WO, kb), 1, c, c, &ctx, &mut proj, &mut t_buf,
        );
        for (xv, av) in x.iter_mut().zip(&proj) {
            *xv += av;
        }

        rmsnorm_rows_into(&x, nm, c, &mut hn);
        adapted_fwd_bindings(
            runs, WGATE, kb, rf.w(WGATE, kb), 1, c, ff, &hn, &mut g_pre,
            &mut t_buf,
        );
        adapted_fwd_bindings(
            runs, WUP, kb, rf.w(WUP, kb), 1, c, ff, &hn, &mut u_val,
            &mut t_buf,
        );
        for idx in 0..m * ff {
            f_val[idx] = silu(g_pre[idx]) * u_val[idx];
        }
        adapted_fwd_bindings(
            runs, WDOWN, kb, rf.w(WDOWN, kb), 1, ff, c, &f_val, &mut proj,
            &mut t_buf,
        );
        for (xv, dv) in x.iter_mut().zip(&proj) {
            *xv += dv;
        }
    }

    let mut xf = scratch_take(m * c);
    rmsnorm_rows_into(&x, rf.norm_final, c, &mut xf);
    let mut logits = scratch_take(m * cfg.vocab);
    rf.project_logits(m, cfg.vocab, c, &xf, &mut logits);
    for buf in [
        x, e_buf, hn, q_buf, k_new, v_new, proj, ctx, g_pre, u_val, f_val,
        t_buf, kh, vh, att, xf,
    ] {
        scratch_put(buf);
    }
    logits
}

/// End of the entry segment starting at `e0`: paged entries are grouped
/// by cache row (one segment per request), ascending positions within.
fn seg_end(entries: &[(usize, usize, i32)], e0: usize) -> usize {
    let row = entries[e0].0;
    let mut e1 = e0 + 1;
    while e1 < entries.len() && entries[e1].0 == row {
        debug_assert!(entries[e1].1 == entries[e1 - 1].1 + 1);
        e1 += 1;
    }
    e1
}

/// The unified paged-KV inference step: every K/V read and write goes
/// through a [`PagedKvCache`] page table instead of a fixed-window
/// buffer. One call covers both serving phases:
///
/// * **decode** — one entry `(row, pos, tok)` per live row, `lean =
///   None` (logits for every entry): the paged twin of
///   [`decode_step_runs`].
/// * **prefill** — consecutive entries per row spanning exactly the
///   positions prefill must compute (`start..=last`, where `start > 0`
///   when a shared prefix already holds `0..start` — the warm-prefix
///   case computes *only the unshared tail*), `lean` selecting each
///   row's last entry: the paged twin of [`infer_prefill_runs`], which
///   also never touches pad positions past a prompt's end.
///
/// Entries must be grouped by row with ascending positions; rows must
/// have been admitted ([`PagedKvCache::admit_row`]). Page acquisition
/// and copy-on-write forks happen up front via
/// [`PagedKvCache::prepare_write`], drawing on the admission
/// reservation — this function cannot run out of pool.
///
/// Bitwise contract (the tentpole invariant, enforced by the oracle
/// tests below): logits are bit-identical to the fixed-window
/// [`KvCache`] path at any `MOS_THREADS` and across adapter ablations.
/// It holds because (a) every matmul is canonical-order, so per-element
/// results are independent of row count and batch composition — K/V
/// projected per entry (`unit = 1`) bit-match the fixed path's
/// whole-window projections row-for-row, and GEMM outputs don't depend
/// on the destination buffer, so staging-then-scatter into pages equals
/// the fixed path's direct cache writes; (b) attention gathers K/V
/// position-by-position into the same head-major scratch layout both
/// fixed paths use — only the *source* of each `head_dim` slice changes
/// (page table vs. contiguous row), the GEMM inputs are byte-identical;
/// (c) the truncated-span softmax with zeroed padded columns is the
/// established decode-step recipe, bitwise equal to the full-window
/// masked softmax (`exp(-1e9 - max)` underflows to exactly `0.0`, and
/// zero-probability tail terms add exactly nothing); and (d) skipping
/// shared prefix positions cannot change the tail's bits — embeddings
/// and sinusoid positions are absolute, and the tail's attention reads
/// the shared pages' K/V, which the sharer computed from identical
/// inputs through the same canonical ops.
///
/// Attention batches all `(row, head)` sub-problems in two
/// [`gemm_canon_batch`] calls over a shared `(nt_max, t_pad)` padded
/// shape; padded query rows keep zero Q (zero scores, never softmaxed,
/// zero probs), padded key columns keep zero K/V — both contribute
/// exactly nothing, the same neutrality [`decode_step_runs`] relies on.
///
/// Steady-state allocation-free like both fixed paths: every
/// intermediate is scratch-arena-backed, page acquisition is a
/// free-list pop, and the returned logits are `scratch_take`-backed —
/// hand them back with [`scratch_put`].
pub fn paged_infer_runs(
    cfg: &ModelCfg,
    base: &Bank,
    runs: &[AdapterBinding],
    cache: &mut PagedKvCache,
    entries: &[(usize, usize, i32)],
    lean: Option<&[usize]>,
) -> Vec<f32> {
    paged_infer_runs_base(cfg, BaseRef::f32(base), runs, cache, entries, lean)
}

/// [`paged_infer_runs`] against a [`BaseRef`] (int8 serving tier entry —
/// see [`infer_prefill_runs_base`] for the representation contract).
pub fn paged_infer_runs_base(
    cfg: &ModelCfg,
    base: BaseRef,
    runs: &[AdapterBinding],
    cache: &mut PagedKvCache,
    entries: &[(usize, usize, i32)],
    lean: Option<&[usize]>,
) -> Vec<f32> {
    let m = entries.len();
    debug_assert_eq!(runs.iter().map(|b| b.rows).sum::<usize>(), m);
    if m == 0 {
        return Vec::new();
    }
    let (t_len, c) = (cfg.seq, cfg.hidden);
    let (heads, hd, ff) = (cfg.heads, cfg.head_dim(), cfg.ff);
    let r_max = runs.iter().map(|b| b.mc.r).max().unwrap();
    let att_scale = (hd as f32).powf(-0.5);
    let rf = InferRefs::new(cfg, base);
    cache.note_computed(m);

    // page acquisition + COW forks once per entry, before any K/V write
    for &(row, pos, _) in entries {
        debug_assert!(row < cache.bsz && pos < t_len);
        cache.prepare_write(row, pos);
    }

    // segment scan: one (rows, span) sub-problem per request row
    let (mut nr_seg, mut nt_max, mut t_pad) = (0usize, 0usize, 0usize);
    let mut e0 = 0;
    while e0 < m {
        let e1 = seg_end(entries, e0);
        nr_seg += 1;
        nt_max = nt_max.max(e1 - e0);
        t_pad = t_pad.max(entries[e1 - 1].1 + 1);
        e0 = e1;
    }

    let mut x = scratch_take(m * c);
    let mut e_buf = scratch_take(c);
    for (i, &(_, pos, tok)) in entries.iter().enumerate() {
        let e = rf.embed_row(tok as usize, c, &mut e_buf);
        let p = cache.pos_row(pos);
        for j in 0..c {
            // 0.1-scaled positions, the same expression forward evaluates
            x[i * c + j] = e[j] + 0.1 * p[j];
        }
    }

    let mut hn = scratch_take(m * c);
    let mut q_buf = scratch_take(m * c);
    let mut k_new = scratch_take(m * c);
    let mut v_new = scratch_take(m * c);
    let mut proj = scratch_take(m * c);
    let mut ctx = scratch_take(m * c);
    let mut g_pre = scratch_take(m * ff);
    let mut u_val = scratch_take(m * ff);
    let mut f_val = scratch_take(m * ff);
    let mut t_buf = scratch_take(m * r_max);
    // pooled head-major buffers over the padded (nt_max, t_pad) shape;
    // positions past a sub-problem's own rows/span stay zero from the
    // arena's zero-fill
    let mut qh = scratch_take(nr_seg * heads * nt_max * hd);
    let mut kh = scratch_take(nr_seg * heads * t_pad * hd);
    let mut vh = scratch_take(nr_seg * heads * t_pad * hd);
    let mut ch = scratch_take(nr_seg * heads * nt_max * hd);
    let mut att = scratch_take(nr_seg * heads * nt_max * t_pad);

    for kb in 0..cfg.blocks {
        let na = &rf.norm_attn[kb * c..(kb + 1) * c];
        let nm = &rf.norm_mlp[kb * c..(kb + 1) * c];

        rmsnorm_rows_into(&x, na, c, &mut hn);
        adapted_fwd_bindings(
            runs, WQ, kb, rf.w(WQ, kb), 1, c, c, &hn, &mut q_buf, &mut t_buf,
        );
        adapted_fwd_bindings(
            runs, WK, kb, rf.w(WK, kb), 1, c, c, &hn, &mut k_new, &mut t_buf,
        );
        adapted_fwd_bindings(
            runs, WV, kb, rf.w(WV, kb), 1, c, c, &hn, &mut v_new, &mut t_buf,
        );
        // scatter the staged projections into the page tables — GEMM
        // output bits don't depend on the destination, so this equals
        // the fixed path's direct in-cache projection
        for (i, &(row, pos, _)) in entries.iter().enumerate() {
            cache.write_kv(
                row,
                kb,
                pos,
                &k_new[i * c..(i + 1) * c],
                &v_new[i * c..(i + 1) * c],
            );
        }

        // batched-head attention: gather Q per entry and K/V per cached
        // position through the page table, head-major. Within a block,
        // every entry's K/V lands before any gather, so an entry at
        // position p sees its same-row predecessors at 0..p.
        let (mut si, mut e0) = (0usize, 0usize);
        while e0 < m {
            let e1 = seg_end(entries, e0);
            let row = entries[e0].0;
            let span = entries[e1 - 1].1 + 1;
            for h in 0..heads {
                let qb = (si * heads + h) * nt_max * hd;
                for j in 0..e1 - e0 {
                    let qs = (e0 + j) * c + h * hd;
                    qh[qb + j * hd..qb + (j + 1) * hd]
                        .copy_from_slice(&q_buf[qs..qs + hd]);
                }
                let b0 = (si * heads + h) * t_pad * hd;
                for tt in 0..span {
                    kh[b0 + tt * hd..b0 + (tt + 1) * hd]
                        .copy_from_slice(&cache.k_at(row, kb, tt)[h * hd..(h + 1) * hd]);
                    vh[b0 + tt * hd..b0 + (tt + 1) * hd]
                        .copy_from_slice(&cache.v_at(row, kb, tt)[h * hd..(h + 1) * hd]);
                }
            }
            si += 1;
            e0 = e1;
        }
        att.fill(0.0);
        gemm_canon_batch(
            nr_seg * heads, nt_max, t_pad, hd, 1.0, &qh, Trans::N, &kh,
            Trans::T, &mut att,
        );
        // causal scale + truncated-span softmax per live query row, then
        // exact zeros on the padded columns (decode_step's recipe);
        // padded query rows keep their ±0 scores un-softmaxed -> zero ctx
        let (mut si, mut e0) = (0usize, 0usize);
        while e0 < m {
            let e1 = seg_end(entries, e0);
            for h in 0..heads {
                let a0 = (si * heads + h) * nt_max * t_pad;
                for j in 0..e1 - e0 {
                    let span = entries[e0 + j].1 + 1;
                    let r0 = a0 + j * t_pad;
                    for a in att[r0..r0 + span].iter_mut() {
                        *a *= att_scale;
                    }
                    softmax_rows(&mut att[r0..r0 + span], 1, span);
                    att[r0 + span..r0 + t_pad].fill(0.0);
                }
            }
            si += 1;
            e0 = e1;
        }
        ch.fill(0.0);
        gemm_canon_batch(
            nr_seg * heads, nt_max, hd, t_pad, 1.0, &att, Trans::N, &vh,
            Trans::N, &mut ch,
        );
        // scatter context back to the (m, heads*hd) projection layout
        ctx.fill(0.0);
        let (mut si, mut e0) = (0usize, 0usize);
        while e0 < m {
            let e1 = seg_end(entries, e0);
            for h in 0..heads {
                let b0 = (si * heads + h) * nt_max * hd;
                for j in 0..e1 - e0 {
                    let dst = (e0 + j) * c + h * hd;
                    ctx[dst..dst + hd]
                        .copy_from_slice(&ch[b0 + j * hd..b0 + (j + 1) * hd]);
                }
            }
            si += 1;
            e0 = e1;
        }

        adapted_fwd_bindings(
            runs, WO, kb, rf.w(WO, kb), 1, c, c, &ctx, &mut proj, &mut t_buf,
        );
        for (xv, av) in x.iter_mut().zip(&proj) {
            *xv += av;
        }

        rmsnorm_rows_into(&x, nm, c, &mut hn);
        adapted_fwd_bindings(
            runs, WGATE, kb, rf.w(WGATE, kb), 1, c, ff, &hn, &mut g_pre,
            &mut t_buf,
        );
        adapted_fwd_bindings(
            runs, WUP, kb, rf.w(WUP, kb), 1, c, ff, &hn, &mut u_val,
            &mut t_buf,
        );
        for idx in 0..m * ff {
            f_val[idx] = silu(g_pre[idx]) * u_val[idx];
        }
        adapted_fwd_bindings(
            runs, WDOWN, kb, rf.w(WDOWN, kb), 1, ff, c, &f_val, &mut proj,
            &mut t_buf,
        );
        for (xv, dv) in x.iter_mut().zip(&proj) {
            *xv += dv;
        }
    }

    // logits only at the selected entries (each prefill row's last
    // position; decode takes all)
    let nl = lean.map_or(m, <[usize]>::len);
    let mut xl = scratch_take(nl * c);
    match lean {
        None => xl.copy_from_slice(&x),
        Some(sel) => {
            for (i, &e) in sel.iter().enumerate() {
                debug_assert!(e < m);
                xl[i * c..(i + 1) * c].copy_from_slice(&x[e * c..(e + 1) * c]);
            }
        }
    }
    let mut xf = scratch_take(nl * c);
    rmsnorm_rows_into(&xl, rf.norm_final, c, &mut xf);
    let mut logits = scratch_take(nl * cfg.vocab);
    rf.project_logits(nl, cfg.vocab, c, &xf, &mut logits);
    for buf in [
        x, e_buf, hn, q_buf, k_new, v_new, proj, ctx, g_pre, u_val, f_val,
        t_buf, qh, kh, vh, ch, att, xl, xf,
    ] {
        scratch_put(buf);
    }
    logits
}

/// Masked next-token cross-entropy loss over cached logits.
pub fn loss(
    cache: &ForwardCache,
    targets: &[i32],
    weight: &[f32],
    vocab: usize,
) -> f32 {
    let rows = targets.len();
    let denom: f32 = weight.iter().sum::<f32>().max(1.0);
    let mut total = 0.0f32;
    for row in 0..rows {
        if weight[row] == 0.0 {
            continue;
        }
        let lr = &cache.logits[row * vocab..(row + 1) * vocab];
        let mx = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = mx + lr.iter().map(|v| (v - mx).exp()).sum::<f32>().ln();
        total += weight[row] * (lse - lr[targets[row] as usize]);
    }
    total / denom
}

/// Full backward: returns (loss, per-type dense factor gradients).
#[allow(clippy::too_many_arguments)]
pub fn backward(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    base: &Bank,
    factors: &BTreeMap<String, Factors>,
    cache: &ForwardCache,
    tokens: &[i32],
    targets: &[i32],
    weight: &[f32],
) -> (f32, BTreeMap<String, Factors>) {
    let (t_len, c, vocab) = (cfg.seq, cfg.hidden, cfg.vocab);
    let bsz = tokens.len() / t_len;
    let rows = bsz * t_len;
    let (heads, hd, ff) = (cfg.heads, cfg.head_dim(), cfg.ff);
    let scale = (mc.alpha / mc.r as f64) as f32;
    let embed = base["embed"].f32s().unwrap();
    let att_scale = (hd as f32).powf(-0.5);

    let loss_val = loss(cache, targets, weight, vocab);

    // zero-initialized factor grads
    let mut dfactors: BTreeMap<String, Factors> = BTreeMap::new();
    for t in LAYER_TYPES {
        let f = &factors[t];
        dfactors.insert(
            t.to_string(),
            Factors {
                r: f.r,
                in_dim: f.in_dim,
                out_dim: f.out_dim,
                a: vec![vec![0.0; f.r * f.in_dim]; cfg.blocks],
                b: vec![vec![0.0; f.out_dim * f.r]; cfg.blocks],
            },
        );
    }

    // dlogits = (softmax - onehot) * weight / denom
    let denom: f32 = weight.iter().sum::<f32>().max(1.0);
    let mut dlogits = scratch_take(rows * vocab);
    for row in 0..rows {
        if weight[row] == 0.0 {
            continue;
        }
        let lr = &cache.logits[row * vocab..(row + 1) * vocab];
        let mx = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        let dr = &mut dlogits[row * vocab..(row + 1) * vocab];
        for (d, &l) in dr.iter_mut().zip(lr) {
            *d = (l - mx).exp();
            sum += *d;
        }
        let wrow = weight[row] / denom;
        for d in dr.iter_mut() {
            *d = *d / sum * wrow;
        }
        dr[targets[row] as usize] -= wrow;
    }

    // dxf = dlogits @ E (V,c)
    let mut dxf = scratch_take(rows * c);
    matmul_nn_acc(&dlogits, embed, &mut dxf, rows, vocab, c);
    scratch_put(dlogits);
    // final rmsnorm backward
    let nf = base["norm_final"].f32s().unwrap();
    let mut dx = scratch_take(rows * c);
    rmsnorm_bwd(&cache.x_final_in, nf, &cache.rstd_f, &dxf, c, &mut dx);
    scratch_put(dxf);

    // per-block / per-head backward scratch, reused across the whole sweep
    let mut d_out = scratch_take(rows * c); // residual-branch dy (down / o)
    let mut d_f = scratch_take(rows * ff);
    let mut d_g = scratch_take(rows * ff);
    let mut d_u = scratch_take(rows * ff);
    let mut d_hn2 = scratch_take(rows * c);
    let mut d_ctx = scratch_take(rows * c);
    let mut d_q = scratch_take(rows * c);
    let mut d_k = scratch_take(rows * c);
    let mut d_v = scratch_take(rows * c);
    let mut d_hn1 = scratch_take(rows * c);
    let mut qh = scratch_take(t_len * hd);
    let mut kh = scratch_take(t_len * hd);
    let mut vh = scratch_take(t_len * hd);
    let mut dch = scratch_take(t_len * hd);
    let mut dprobs = scratch_take(t_len * t_len);
    let mut dvh = scratch_take(t_len * hd);
    let mut dscores = scratch_take(t_len * t_len);
    let mut dqh = scratch_take(t_len * hd);
    let mut dkh = scratch_take(t_len * hd);

    for kb in (0..cfg.blocks).rev() {
        let bc = &cache.blocks[kb];
        let na = &base["norm_attn"].f32s().unwrap()[kb * c..(kb + 1) * c];
        let nm = &base["norm_mlp"].f32s().unwrap()[kb * c..(kb + 1) * c];
        let w = |t: &str| {
            let (o, i) = cfg.dims(t);
            &base[&format!("w.{t}")].f32s().unwrap()[kb * o * i..(kb + 1) * o * i]
        };

        // ---- MLP residual: x = x_mid + down(f)
        d_out.copy_from_slice(&dx); // gradient wrt down output
        d_f.fill(0.0);
        adapted_bwd(
            &bc.f_val,
            w("down"),
            &factors["down"],
            &bc.ta["down"],
            kb,
            scale,
            rows,
            &d_out,
            &mut d_f,
            dfactors.get_mut("down").unwrap(),
        );
        // f = silu(g_pre) * u_val  (d_g/d_u fully overwritten)
        for idx in 0..rows * ff {
            d_g[idx] = d_f[idx] * bc.u_val[idx] * silu_grad(bc.g_pre[idx]);
            d_u[idx] = d_f[idx] * silu(bc.g_pre[idx]);
        }
        d_hn2.fill(0.0);
        adapted_bwd(
            &bc.hn2,
            w("gate"),
            &factors["gate"],
            &bc.ta["gate"],
            kb,
            scale,
            rows,
            &d_g,
            &mut d_hn2,
            dfactors.get_mut("gate").unwrap(),
        );
        adapted_bwd(
            &bc.hn2,
            w("up"),
            &factors["up"],
            &bc.ta["up"],
            kb,
            scale,
            rows,
            &d_u,
            &mut d_hn2,
            dfactors.get_mut("up").unwrap(),
        );
        // rmsnorm2 backward adds into dx (residual path already in dx)
        rmsnorm_bwd(&bc.x_mid, nm, &bc.rstd2, &d_hn2, c, &mut dx);

        // ---- attention residual: x_mid = x_in + o(ctx)
        d_out.copy_from_slice(&dx);
        d_ctx.fill(0.0);
        adapted_bwd(
            &bc.ctx,
            w("o"),
            &factors["o"],
            &bc.ta["o"],
            kb,
            scale,
            rows,
            &d_out,
            &mut d_ctx,
            dfactors.get_mut("o").unwrap(),
        );

        // attention backward per (b, h); the per-head scatters only cover
        // heads*head_dim columns, which can be < hidden — re-zero so no
        // stale gradient survives from the previous block
        d_q.fill(0.0);
        d_k.fill(0.0);
        d_v.fill(0.0);
        for b in 0..bsz {
            for h in 0..heads {
                for tt in 0..t_len {
                    let row = b * t_len + tt;
                    qh[tt * hd..(tt + 1) * hd]
                        .copy_from_slice(&bc.q[row * c + h * hd..row * c + (h + 1) * hd]);
                    kh[tt * hd..(tt + 1) * hd]
                        .copy_from_slice(&bc.k[row * c + h * hd..row * c + (h + 1) * hd]);
                    vh[tt * hd..(tt + 1) * hd]
                        .copy_from_slice(&bc.v[row * c + h * hd..row * c + (h + 1) * hd]);
                    dch[tt * hd..(tt + 1) * hd].copy_from_slice(
                        &d_ctx[row * c + h * hd..row * c + (h + 1) * hd],
                    );
                }
                let off = (b * heads + h) * t_len * t_len;
                let probs = &bc.probs[off..off + t_len * t_len];
                // dprobs = dch @ vh^T
                dprobs.fill(0.0);
                matmul_nt_acc(&dch, &vh, &mut dprobs, t_len, hd, t_len);
                // dvh = probs^T @ dch
                dvh.fill(0.0);
                matmul_tn_acc(probs, &dch, &mut dvh, t_len, t_len, hd);
                // softmax backward: ds = p * (dp - sum(dp * p));
                // only the lower triangle is written, so re-zero first
                dscores.fill(0.0);
                for i in 0..t_len {
                    let pr = &probs[i * t_len..(i + 1) * t_len];
                    let dpr = &dprobs[i * t_len..(i + 1) * t_len];
                    let dot: f32 =
                        pr.iter().zip(dpr).map(|(p, d)| p * d).sum();
                    for j in 0..=i {
                        dscores[i * t_len + j] =
                            pr[j] * (dpr[j] - dot) * att_scale;
                    }
                }
                // dqh = dscores @ kh ; dkh = dscores^T @ qh
                dqh.fill(0.0);
                matmul_nn_acc(&dscores, &kh, &mut dqh, t_len, t_len, hd);
                dkh.fill(0.0);
                matmul_tn_acc(&dscores, &qh, &mut dkh, t_len, t_len, hd);
                for tt in 0..t_len {
                    let row = b * t_len + tt;
                    d_q[row * c + h * hd..row * c + (h + 1) * hd]
                        .copy_from_slice(&dqh[tt * hd..(tt + 1) * hd]);
                    d_k[row * c + h * hd..row * c + (h + 1) * hd]
                        .copy_from_slice(&dkh[tt * hd..(tt + 1) * hd]);
                    d_v[row * c + h * hd..row * c + (h + 1) * hd]
                        .copy_from_slice(&dvh[tt * hd..(tt + 1) * hd]);
                }
            }
        }

        d_hn1.fill(0.0);
        adapted_bwd(
            &bc.hn1,
            w("q"),
            &factors["q"],
            &bc.ta["q"],
            kb,
            scale,
            rows,
            &d_q,
            &mut d_hn1,
            dfactors.get_mut("q").unwrap(),
        );
        adapted_bwd(
            &bc.hn1,
            w("k"),
            &factors["k"],
            &bc.ta["k"],
            kb,
            scale,
            rows,
            &d_k,
            &mut d_hn1,
            dfactors.get_mut("k").unwrap(),
        );
        adapted_bwd(
            &bc.hn1,
            w("v"),
            &factors["v"],
            &bc.ta["v"],
            kb,
            scale,
            rows,
            &d_v,
            &mut d_hn1,
            dfactors.get_mut("v").unwrap(),
        );
        rmsnorm_bwd(&bc.x_in, na, &bc.rstd1, &d_hn1, c, &mut dx);
    }

    for buf in [
        dx, d_out, d_f, d_g, d_u, d_hn2, d_ctx, d_q, d_k, d_v, d_hn1, qh, kh,
        vh, dch, dprobs, dvh, dscores, dqh, dkh,
    ] {
        scratch_put(buf);
    }

    (loss_val, dfactors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter;
    use crate::config::presets;

    fn micro() -> ModelCfg {
        ModelCfg {
            name: "micro".into(),
            vocab: 11,
            hidden: 8,
            blocks: 2,
            heads: 2,
            kv_heads: 2,
            ff: 12,
            seq: 5,
            batch: 2,
        }
    }

    fn setup(cfg: &ModelCfg, mc: &MethodCfg, seed: u64) -> (Bank, BTreeMap<String, Factors>) {
        let base = init_base(cfg, seed);
        let mut rng = Rng::new(seed + 9, 0);
        let mut params = adapter::init_params(cfg, mc, seed);
        // randomize everything so deltas are active
        let keys: Vec<String> = params.keys().cloned().collect();
        for kname in keys {
            let t = params[&kname].clone();
            params.insert(
                kname,
                Tensor::from_f32(t.shape(), rng.normal_vec(t.len(), 0.05)),
            );
        }
        let aux = match mc.method {
            crate::config::Method::MoS => {
                adapter::mos::router::build_router(cfg, mc, seed).into_bank()
            }
            crate::config::Method::VeRA => {
                adapter::vera::frozen_matrices(cfg, mc, seed)
            }
            _ => Bank::new(),
        };
        let mut f = BTreeMap::new();
        for t in LAYER_TYPES {
            f.insert(
                t.to_string(),
                adapter::materialize(cfg, mc, &params, &aux, t),
            );
        }
        (base, f)
    }

    /// Like [`setup`] but MoS-only, also returning the zero-copy pooled
    /// representation built from the *same* params/aux the dense factors
    /// were materialized from — so dense and pooled describe one adapter.
    fn setup_pooled(
        cfg: &ModelCfg,
        mc: &MethodCfg,
        seed: u64,
    ) -> (Bank, BTreeMap<String, Factors>, PooledAdapter) {
        let base = init_base(cfg, seed);
        let mut rng = Rng::new(seed + 9, 0);
        let mut params = adapter::init_params(cfg, mc, seed);
        let keys: Vec<String> = params.keys().cloned().collect();
        for kname in keys {
            let t = params[&kname].clone();
            params.insert(
                kname,
                Tensor::from_f32(t.shape(), rng.normal_vec(t.len(), 0.05)),
            );
        }
        let aux = adapter::mos::router::build_router(cfg, mc, seed).into_bank();
        let mut f = BTreeMap::new();
        for t in LAYER_TYPES {
            f.insert(
                t.to_string(),
                adapter::materialize(cfg, mc, &params, &aux, t),
            );
        }
        let pooled = PooledAdapter::new(
            mc.clone(),
            std::sync::Arc::new(params),
            std::sync::Arc::new(aux),
        )
        .unwrap();
        (base, f, pooled)
    }

    #[test]
    fn sinusoid_matches_python_formula() {
        let enc = sinusoid(3, 4);
        // pos 0: sin(0)=0, cos(0)=1 alternating
        assert_eq!(&enc[0..4], &[0.0, 1.0, 0.0, 1.0]);
        // pos 1 dim 0: sin(1)
        assert!((enc[4] - 1f64.sin() as f32).abs() < 1e-6);
        // pos 2 dim 2: sin(2 / 10000^(2/4))
        let want = (2.0f64 / 10000f64.powf(0.5)).sin() as f32;
        assert!((enc[2 * 4 + 2] - want).abs() < 1e-6);
    }

    #[test]
    fn causality_on_host() {
        let cfg = micro();
        let mc = MethodCfg::mos(3, 2, 2, 0);
        let (base, f) = setup(&cfg, &mc, 1);
        let n = cfg.batch * cfg.seq;
        let tokens: Vec<i32> = (0..n).map(|i| (i % cfg.vocab) as i32).collect();
        let (c1, _) = forward(&cfg, &mc, &base, &f, &tokens);
        let mut tokens2 = tokens.clone();
        // change last token of each sequence
        for b in 0..cfg.batch {
            let idx = b * cfg.seq + cfg.seq - 1;
            tokens2[idx] = (tokens2[idx] + 1) % cfg.vocab as i32;
        }
        let (c2, _) = forward(&cfg, &mc, &base, &f, &tokens2);
        let v = cfg.vocab;
        for b in 0..cfg.batch {
            for tt in 0..cfg.seq - 1 {
                let row = b * cfg.seq + tt;
                for j in 0..v {
                    assert!(
                        (c1.logits[row * v + j] - c2.logits[row * v + j]).abs()
                            < 1e-5,
                        "future token leaked into position {tt}"
                    );
                }
            }
        }
    }

    #[test]
    fn loss_masked_rows_do_not_contribute() {
        let cfg = micro();
        let mc = MethodCfg::lora(2);
        let (base, f) = setup(&cfg, &mc, 2);
        let n = cfg.batch * cfg.seq;
        let tokens: Vec<i32> = (0..n).map(|i| (i % cfg.vocab) as i32).collect();
        let targets = tokens.clone();
        let (cache, _) = forward(&cfg, &mc, &base, &f, &tokens);
        let w_all = vec![1.0f32; n];
        let mut w_half = vec![0.0f32; n];
        for (i, w) in w_half.iter_mut().enumerate() {
            if i % 2 == 0 {
                *w = 1.0;
            }
        }
        let l_all = loss(&cache, &targets, &w_all, cfg.vocab);
        let l_half = loss(&cache, &targets, &w_half, cfg.vocab);
        assert!(l_all > 0.0 && l_half > 0.0);
        assert_ne!(l_all, l_half);
        let l_none = loss(&cache, &targets, &vec![0.0; n], cfg.vocab);
        assert_eq!(l_none, 0.0);
    }

    /// Greedy argmax over one logit row.
    fn argmax(lrow: &[f32]) -> i32 {
        (0..lrow.len())
            .max_by(|&a, &b| lrow[a].total_cmp(&lrow[b]))
            .unwrap() as i32
    }

    #[test]
    fn kv_decode_bitwise_matches_full_forward_oracle() {
        // The acceptance contract: prefill + decode_step greedy generations
        // (and the logits behind them) must be bit-identical to re-running
        // a full forward over the growing window every step.
        let mut cfg = presets::tiny();
        cfg.batch = 2;
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let (base, f) = setup(&cfg, &mc, 3);
        let (t_len, vocab) = (cfg.seq, cfg.vocab);
        let prompts: Vec<Vec<i32>> = vec![vec![1, 9, 4, 2], vec![1, 5, 6, 7, 8, 2]];
        let lens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
        let steps = 8;

        let window_of = |gens: &[Vec<i32>]| {
            let mut w = vec![0i32; 2 * t_len];
            for r in 0..2 {
                w[r * t_len..r * t_len + lens[r]].copy_from_slice(&prompts[r]);
                w[r * t_len + lens[r]..r * t_len + lens[r] + gens[r].len()]
                    .copy_from_slice(&gens[r]);
            }
            w
        };

        // KV path: lean prefill once, then one decode_step per token
        let mut cache = KvCache::new(&cfg, 2);
        let last: Vec<usize> = lens.iter().map(|&l| l - 1).collect();
        let pre_logits = infer_prefill(
            &cfg, &mc, &base, &f,
            &window_of(&[Vec::new(), Vec::new()]),
            &last, &mut cache, &[0, 1],
        );
        let mut kv_logits: Vec<Vec<f32>> = Vec::new(); // per step, rows concat
        let mut kv_tokens: Vec<Vec<i32>> = vec![Vec::new(); 2];
        let mut next: Vec<i32> = (0..2)
            .map(|r| argmax(&pre_logits[r * vocab..(r + 1) * vocab]))
            .collect();
        for _ in 0..steps {
            let entries: Vec<(usize, usize, i32)> = (0..2)
                .map(|r| (r, lens[r] + kv_tokens[r].len(), next[r]))
                .collect();
            for (r, &(_, _, tok)) in entries.iter().enumerate() {
                kv_tokens[r].push(tok);
            }
            let logits = decode_step(&cfg, &mc, &base, &f, &mut cache, &entries);
            next = (0..2).map(|r| argmax(&logits[r * vocab..(r + 1) * vocab])).collect();
            kv_logits.push(logits);
        }

        // oracle: a fresh full forward over the growing window every step
        let mut oracle_tokens: Vec<Vec<i32>> = vec![Vec::new(); 2];
        for step in 0..=steps {
            let (fc, _) =
                forward(&cfg, &mc, &base, &f, &window_of(&oracle_tokens));
            for r in 0..2 {
                let read = lens[r] + oracle_tokens[r].len() - 1;
                let lrow = &fc.logits
                    [(r * t_len + read) * vocab..(r * t_len + read + 1) * vocab];
                // the decode-step logits for this position must be
                // bit-identical to the full forward's
                if step > 0 {
                    let kv = &kv_logits[step - 1][r * vocab..(r + 1) * vocab];
                    let kvb: Vec<u32> = kv.iter().map(|v| v.to_bits()).collect();
                    let orb: Vec<u32> = lrow.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(kvb, orb, "row {r} step {step}: logits diverge");
                }
                if step < steps {
                    oracle_tokens[r].push(argmax(lrow));
                }
            }
        }
        assert_eq!(kv_tokens, oracle_tokens, "greedy generations diverge");
    }

    #[test]
    fn decode_step_independent_of_cobatched_rows() {
        // continuous-batching contract: a row's decode logits don't depend
        // on which other rows shared the step
        let mut cfg = presets::tiny();
        cfg.batch = 2;
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let (base, f) = setup(&cfg, &mc, 5);
        let t_len = cfg.seq;
        let prompts: Vec<Vec<i32>> = vec![vec![1, 7, 3, 2], vec![1, 2]];
        let mut window = vec![0i32; 2 * t_len];
        for (r, p) in prompts.iter().enumerate() {
            window[r * t_len..r * t_len + p.len()].copy_from_slice(p);
        }
        let mut cache = KvCache::new(&cfg, 2);
        infer_prefill(
            &cfg, &mc, &base, &f, &window, &[3, 1], &mut cache, &[0, 1],
        );
        // step row 0 together with row 1 (mixed spans also exercise the
        // shared padded-span batched attention)...
        let both = decode_step(
            &cfg, &mc, &base, &f, &mut cache,
            &[(0, 4, 9), (1, 2, 5)],
        );
        // ...and alone, on a fresh prefill of the same prompt
        let mut cache2 = KvCache::new(&cfg, 2);
        infer_prefill(
            &cfg, &mc, &base, &f, &window[..t_len], &[3], &mut cache2, &[0],
        );
        let alone = decode_step(&cfg, &mc, &base, &f, &mut cache2, &[(0, 4, 9)]);
        let a: Vec<u32> = both[..cfg.vocab].iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = alone.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "row 0 logits depend on co-batched rows");
    }

    #[test]
    fn infer_prefill_bitwise_matches_forward_oracle() {
        // the lean inference forward must reproduce the training forward's
        // logits (at each row's last prompt position) and its K/V caches
        // bit-for-bit, on the awkward shapes: a single row, a full-window
        // prompt, mixed lengths in one batch
        let mut cfg = presets::tiny();
        cfg.batch = 3;
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let (base, f) = setup(&cfg, &mc, 11);
        let (t_len, c, vocab) = (cfg.seq, cfg.hidden, cfg.vocab);

        let full: Vec<i32> =
            (0..t_len).map(|i| (i % (vocab - 1) + 1) as i32).collect();
        let prompts: Vec<Vec<i32>> =
            vec![vec![1, 9, 4, 2], full, vec![1, 5]];
        let mut window = vec![0i32; 3 * t_len];
        for (r, p) in prompts.iter().enumerate() {
            window[r * t_len..r * t_len + p.len()].copy_from_slice(p);
        }
        let last: Vec<usize> = prompts.iter().map(|p| p.len() - 1).collect();

        let mut cache = KvCache::new(&cfg, 3);
        let lean = infer_prefill(
            &cfg, &mc, &base, &f, &window, &last, &mut cache, &[0, 1, 2],
        );
        assert_eq!(lean.len(), 3 * vocab);

        let (fc, _) = forward(&cfg, &mc, &base, &f, &window);
        for r in 0..3 {
            let off = (r * t_len + last[r]) * vocab;
            let ob: Vec<u32> = fc.logits[off..off + vocab]
                .iter()
                .map(|v| v.to_bits())
                .collect();
            let lb: Vec<u32> = lean[r * vocab..(r + 1) * vocab]
                .iter()
                .map(|v| v.to_bits())
                .collect();
            assert_eq!(lb, ob, "row {r} logits diverge from the oracle");
        }
        // the K/V written straight into the cache must bit-match the
        // training path's activations (decode continuity depends on it)
        let stride = t_len * c;
        for (kb, bc) in fc.blocks.iter().enumerate() {
            for r in 0..3 {
                let ck: Vec<u32> = cache.k[kb][r * stride..(r + 1) * stride]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                let ok: Vec<u32> = bc.k[r * stride..(r + 1) * stride]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                assert_eq!(ck, ok, "block {kb} row {r} K diverges");
                let cv: Vec<u32> = cache.v[kb][r * stride..(r + 1) * stride]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                let ov: Vec<u32> = bc.v[r * stride..(r + 1) * stride]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                assert_eq!(cv, ov, "block {kb} row {r} V diverges");
            }
        }

        // one row alone must reproduce its batched logits exactly
        let mut cache1 = KvCache::new(&cfg, 1);
        let solo = infer_prefill(
            &cfg, &mc, &base, &f, &window[..t_len], &last[..1], &mut cache1,
            &[0],
        );
        let sb: Vec<u32> = solo.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> =
            lean[..vocab].iter().map(|v| v.to_bits()).collect();
        assert_eq!(sb, bb, "single-row prefill depends on co-batched rows");
    }

    #[test]
    fn steady_state_prefill_and_decode_allocate_nothing() {
        // acceptance criterion: once the scratch arena is warm, the lean
        // prefill + decode step never touch the heap. Counted by the
        // test-binary global allocator (util::alloc) thread-locally, so
        // concurrently running tests cannot bleed in; the micro config
        // stays below every pool threshold, so the whole path runs on
        // this thread.
        let cfg = micro();
        let mc = MethodCfg::mos(3, 2, 2, 0);
        let (base, f) = setup(&cfg, &mc, 7);
        let mut cache = KvCache::new(&cfg, 2);
        let prompts: [&[i32]; 2] = [&[1, 4, 2], &[1, 5, 6, 2]];
        let mut window = vec![0i32; 2 * cfg.seq];
        for (r, p) in prompts.iter().enumerate() {
            window[r * cfg.seq..r * cfg.seq + p.len()].copy_from_slice(p);
        }
        let last = [2usize, 3];
        let entries = [(0usize, 3usize, 5i32), (1usize, 4usize, 6i32)];
        let run = |cache: &mut KvCache| {
            let l1 = infer_prefill(
                &cfg, &mc, &base, &f, &window, &last, cache, &[0, 1],
            );
            scratch_put(l1);
            let l2 = decode_step(&cfg, &mc, &base, &f, cache, &entries);
            scratch_put(l2);
        };
        // the probe itself must be live (otherwise this test passes
        // vacuously)
        let t0 = crate::util::alloc::thread_allocs();
        let v = vec![0u8; 4096];
        std::hint::black_box(&v);
        drop(v);
        assert!(
            crate::util::alloc::thread_allocs() > t0,
            "allocation probe inactive"
        );
        // warm the arena to its fixed point: capacities only grow, so the
        // take/put cycle stops allocating after finitely many iterations
        let mut warmups = 0;
        loop {
            let b = crate::util::alloc::thread_allocs();
            run(&mut cache);
            if crate::util::alloc::thread_allocs() == b {
                break;
            }
            warmups += 1;
            assert!(
                warmups < 64,
                "scratch arena never reached a zero-alloc fixed point"
            );
        }
        let before = crate::util::alloc::thread_allocs();
        for _ in 0..4 {
            run(&mut cache);
        }
        let allocs = crate::util::alloc::thread_allocs() - before;
        assert_eq!(
            allocs, 0,
            "steady-state prefill/decode hit the heap {allocs} times"
        );
    }

    #[test]
    fn pooled_path_bitwise_matches_dense_oracle_across_ablations() {
        // acceptance criterion: serving straight off the shard pool must be
        // bit-identical to the materialized dense oracle — prefill logits,
        // the K/V written into the cache, and the following decode step —
        // across the MoS ablation space (paper default with a private rank
        // slot, l=1 whole-matrix shards, deeper private segment, pair
        // dissociation off).
        let mut cfg = presets::tiny();
        cfg.batch = 2;
        let mut no_pd = MethodCfg::mos(8, 2, 2, 0);
        no_pd.pair_dissociation = false;
        let variants = [
            MethodCfg::mos(8, 2, 2, 1),
            MethodCfg::mos(8, 1, 2, 0),
            MethodCfg::mos(8, 2, 2, 3),
            no_pd,
        ];
        let (t_len, c, vocab) = (cfg.seq, cfg.hidden, cfg.vocab);
        let prompts: Vec<Vec<i32>> = vec![vec![1, 9, 4, 2], vec![1, 5, 6]];
        let mut window = vec![0i32; 2 * t_len];
        for (r, p) in prompts.iter().enumerate() {
            window[r * t_len..r * t_len + p.len()].copy_from_slice(p);
        }
        let last: Vec<usize> = prompts.iter().map(|p| p.len() - 1).collect();
        for (vi, mc) in variants.iter().enumerate() {
            mc.validate(&cfg).unwrap();
            let (base, f, pooled) = setup_pooled(&cfg, mc, 21 + vi as u64);
            let runs =
                [AdapterBinding::new(2, mc, AdapterRef::Pooled(&pooled))];

            let mut cd = KvCache::new(&cfg, 2);
            let dense = infer_prefill(
                &cfg, mc, &base, &f, &window, &last, &mut cd, &[0, 1],
            );
            let mut cp = KvCache::new(&cfg, 2);
            let pool = infer_prefill_runs(
                &cfg, &base, &runs, &window, &last, &mut cp, &[0, 1],
            );
            let db: Vec<u32> = dense.iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u32> = pool.iter().map(|v| v.to_bits()).collect();
            assert_eq!(pb, db, "variant {vi}: prefill logits diverge");
            let stride = t_len * c;
            for kb in 0..cfg.blocks {
                let dk: Vec<u32> = cd.k[kb][..2 * stride]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                let pk: Vec<u32> = cp.k[kb][..2 * stride]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                assert_eq!(pk, dk, "variant {vi} block {kb}: K diverges");
                let dv: Vec<u32> = cd.v[kb][..2 * stride]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                let pv: Vec<u32> = cp.v[kb][..2 * stride]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                assert_eq!(pv, dv, "variant {vi} block {kb}: V diverges");
            }

            let entries = [(0usize, 4usize, 9i32), (1usize, 3usize, 5i32)];
            let d_dec = decode_step(&cfg, mc, &base, &f, &mut cd, &entries);
            let p_dec = decode_step_runs(&cfg, &base, &runs, &mut cp, &entries);
            let db: Vec<u32> = d_dec.iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u32> = p_dec.iter().map(|v| v.to_bits()).collect();
            assert_eq!(pb, db, "variant {vi}: decode logits diverge");
            assert_eq!(d_dec.len(), 2 * vocab);
        }
    }

    #[test]
    fn mixed_tenant_batch_rows_bitwise_independent() {
        // pooled serving contract: a row's logits depend only on its own
        // tenant's adapter, bit-for-bit — never on which other tenants
        // share the batch (prefill and decode, even with different ranks
        // per tenant in one step)
        let mut cfg = presets::tiny();
        cfg.batch = 3;
        let mc_a = MethodCfg::mos(8, 2, 2, 1);
        let mc_b = MethodCfg::mos(4, 2, 2, 0);
        let (base, _fa, pa) = setup_pooled(&cfg, &mc_a, 31);
        // tenant B serves from the same base with its own adapter
        let (_unused, _fb, pb) = setup_pooled(&cfg, &mc_b, 77);
        let (t_len, vocab) = (cfg.seq, cfg.vocab);
        let prompts: Vec<Vec<i32>> =
            vec![vec![1, 9, 4, 2], vec![1, 5, 6], vec![1, 7, 3, 2, 8]];
        let mut window = vec![0i32; 3 * t_len];
        for (r, p) in prompts.iter().enumerate() {
            window[r * t_len..r * t_len + p.len()].copy_from_slice(p);
        }
        let last: Vec<usize> = prompts.iter().map(|p| p.len() - 1).collect();

        // mixed batch: row 0 is tenant A, rows 1-2 are tenant B
        let runs = [
            AdapterBinding::new(1, &mc_a, AdapterRef::Pooled(&pa)),
            AdapterBinding::new(2, &mc_b, AdapterRef::Pooled(&pb)),
        ];
        let mut cache = KvCache::new(&cfg, 3);
        let mixed = infer_prefill_runs(
            &cfg, &base, &runs, &window, &last, &mut cache, &[0, 1, 2],
        );

        // tenant A's row prefilled alone
        let runs_a = [AdapterBinding::new(1, &mc_a, AdapterRef::Pooled(&pa))];
        let mut cache_a = KvCache::new(&cfg, 1);
        let solo_a = infer_prefill_runs(
            &cfg, &base, &runs_a, &window[..t_len], &last[..1], &mut cache_a,
            &[0],
        );
        let ma: Vec<u32> =
            mixed[..vocab].iter().map(|v| v.to_bits()).collect();
        let sa: Vec<u32> = solo_a.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ma, sa, "tenant A prefill depends on co-batched tenant B");

        // tenant B's rows prefilled without tenant A in the batch
        let runs_b = [AdapterBinding::new(2, &mc_b, AdapterRef::Pooled(&pb))];
        let mut cache_b = KvCache::new(&cfg, 2);
        let solo_b = infer_prefill_runs(
            &cfg, &base, &runs_b, &window[t_len..], &last[1..], &mut cache_b,
            &[0, 1],
        );
        let mb: Vec<u32> =
            mixed[vocab..].iter().map(|v| v.to_bits()).collect();
        let sb: Vec<u32> = solo_b.iter().map(|v| v.to_bits()).collect();
        assert_eq!(mb, sb, "tenant B prefill depends on co-batched tenant A");

        // one mixed decode step vs each tenant stepping alone
        let entries =
            [(0usize, 4usize, 9i32), (1usize, 3usize, 5i32), (2usize, 5usize, 2i32)];
        let mixed_dec =
            decode_step_runs(&cfg, &base, &runs, &mut cache, &entries);
        let solo_a_dec = decode_step_runs(
            &cfg, &base, &runs_a, &mut cache_a, &entries[..1],
        );
        let solo_b_dec = decode_step_runs(
            &cfg, &base, &runs_b, &mut cache_b,
            &[(0, 3, 5), (1, 5, 2)],
        );
        let ma: Vec<u32> =
            mixed_dec[..vocab].iter().map(|v| v.to_bits()).collect();
        let sa: Vec<u32> = solo_a_dec.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ma, sa, "tenant A decode depends on co-batched tenant B");
        let mb: Vec<u32> =
            mixed_dec[vocab..].iter().map(|v| v.to_bits()).collect();
        let sb: Vec<u32> = solo_b_dec.iter().map(|v| v.to_bits()).collect();
        assert_eq!(mb, sb, "tenant B decode depends on co-batched tenant A");
    }

    #[test]
    fn prefill_contiguous_rows_fast_path_bitwise_matches_split() {
        // cache rows [0,1] take the contiguous K/V fast path (one
        // run-wide projection straight into the cache); rows [0,2] fall
        // back to the per-request loop. Same requests either way, so the
        // logits and every written cache row must match bit-for-bit.
        let mut cfg = presets::tiny();
        cfg.batch = 3;
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let (base, _f, pooled) = setup_pooled(&cfg, &mc, 53);
        let (t_len, c, vocab) = (cfg.seq, cfg.hidden, cfg.vocab);
        let prompts: Vec<Vec<i32>> = vec![vec![1, 9, 4, 2], vec![1, 5, 6]];
        let mut window = vec![0i32; 2 * t_len];
        for (r, p) in prompts.iter().enumerate() {
            window[r * t_len..r * t_len + p.len()].copy_from_slice(p);
        }
        let last: Vec<usize> = prompts.iter().map(|p| p.len() - 1).collect();
        let runs = [AdapterBinding::new(2, &mc, AdapterRef::Pooled(&pooled))];

        let mut c_fast = KvCache::new(&cfg, 3);
        let l_fast = infer_prefill_runs(
            &cfg, &base, &runs, &window, &last, &mut c_fast, &[0, 1],
        );
        let mut c_split = KvCache::new(&cfg, 3);
        let l_split = infer_prefill_runs(
            &cfg, &base, &runs, &window, &last, &mut c_split, &[0, 2],
        );

        let fb: Vec<u32> = l_fast.iter().map(|v| v.to_bits()).collect();
        let sb: Vec<u32> = l_split.iter().map(|v| v.to_bits()).collect();
        assert_eq!(fb, sb, "logits diverge between row layouts");
        assert_eq!(l_fast.len(), 2 * vocab);
        let stride = t_len * c;
        for kb in 0..cfg.blocks {
            for (rf, rs) in [(0usize, 0usize), (1, 2)] {
                let fk: Vec<u32> = c_fast.k[kb][rf * stride..(rf + 1) * stride]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                let sk: Vec<u32> = c_split.k[kb]
                    [rs * stride..(rs + 1) * stride]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                assert_eq!(fk, sk, "block {kb} row {rf}: K diverges");
                let fv: Vec<u32> = c_fast.v[kb][rf * stride..(rf + 1) * stride]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                let sv: Vec<u32> = c_split.v[kb]
                    [rs * stride..(rs + 1) * stride]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                assert_eq!(fv, sv, "block {kb} row {rf}: V diverges");
            }
        }
    }

    #[test]
    fn int8_serving_within_logit_error_budget() {
        // the MOS_SERVE_INT8 accuracy gate at the model layer: prefill
        // plus several decode steps through the fully quantized path
        // (int8 base + int8 shard pool) stay within the logit budget of
        // the f32 pooled oracle on the same token stream
        let mut cfg = presets::tiny();
        cfg.batch = 2;
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let (base, _f, pooled) = setup_pooled(&cfg, &mc, 71);
        let qbase = quantize_base(&cfg, &base);
        let qpool = QuantPooledAdapter::quantize(&pooled);
        let (t_len, vocab) = (cfg.seq, cfg.vocab);
        let prompts: Vec<Vec<i32>> = vec![vec![1, 9, 4, 2], vec![1, 5, 6]];
        let mut window = vec![0i32; 2 * t_len];
        for (r, p) in prompts.iter().enumerate() {
            window[r * t_len..r * t_len + p.len()].copy_from_slice(p);
        }
        let last: Vec<usize> = prompts.iter().map(|p| p.len() - 1).collect();
        let runs_f = [AdapterBinding::new(2, &mc, AdapterRef::Pooled(&pooled))];
        let runs_q =
            [AdapterBinding::new(2, &mc, AdapterRef::PooledInt8(&qpool))];

        let mut cache_f = KvCache::new(&cfg, 2);
        let mut reference = infer_prefill_runs(
            &cfg, &base, &runs_f, &window, &last, &mut cache_f, &[0, 1],
        );
        let mut cache_q = KvCache::new(&cfg, 2);
        let mut candidate = infer_prefill_runs_base(
            &cfg,
            BaseRef::int8(&base, &qbase),
            &runs_q,
            &window,
            &last,
            &mut cache_q,
            &[0, 1],
        );
        // both paths decode the same fixed token stream so the error is
        // purely representational, never a diverging-trajectory artifact
        let toks = [(9i32, 5i32), (2, 7), (4, 1), (8, 3)];
        for (j, (ta, tb)) in toks.iter().enumerate() {
            let entries = [(0usize, 4 + j, *ta), (1usize, 3 + j, *tb)];
            reference.extend(decode_step_runs(
                &cfg, &base, &runs_f, &mut cache_f, &entries,
            ));
            candidate.extend(decode_step_runs_base(
                &cfg,
                BaseRef::int8(&base, &qbase),
                &runs_q,
                &mut cache_q,
                &entries,
            ));
        }
        let err = quant::logit_error(&reference, &candidate, vocab);
        assert!(
            err.max_abs <= quant::LOGIT_BUDGET_MAX_ABS,
            "int8 max |dlogit| {} over budget {}",
            err.max_abs,
            quant::LOGIT_BUDGET_MAX_ABS
        );
        assert!(
            err.top1_agree >= quant::LOGIT_BUDGET_TOP1,
            "int8 top-1 agreement {} under budget {}",
            err.top1_agree,
            quant::LOGIT_BUDGET_TOP1
        );
        // and the int8 path honors the same row-batch discipline: the
        // quantized results must be deterministic across repeat calls
        let mut cache_q2 = KvCache::new(&cfg, 2);
        let again = infer_prefill_runs_base(
            &cfg,
            BaseRef::int8(&base, &qbase),
            &runs_q,
            &window,
            &last,
            &mut cache_q2,
            &[0, 1],
        );
        let a: Vec<u32> = again.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> =
            candidate[..2 * vocab].iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "int8 prefill not deterministic");
    }

    #[test]
    fn steady_state_pooled_prefill_and_decode_allocate_nothing() {
        // the pooled path must hold the same zero-alloc discipline the
        // dense path proves above: once the arena is warm, serving straight
        // off the shard pool never touches the heap
        let cfg = micro();
        let mc = MethodCfg::mos(3, 2, 2, 0);
        let (base, _f, pooled) = setup_pooled(&cfg, &mc, 7);
        let mut cache = KvCache::new(&cfg, 2);
        let prompts: [&[i32]; 2] = [&[1, 4, 2], &[1, 5, 6, 2]];
        let mut window = vec![0i32; 2 * cfg.seq];
        for (r, p) in prompts.iter().enumerate() {
            window[r * cfg.seq..r * cfg.seq + p.len()].copy_from_slice(p);
        }
        let last = [2usize, 3];
        let entries = [(0usize, 3usize, 5i32), (1usize, 4usize, 6i32)];
        let run = |cache: &mut KvCache| {
            let runs =
                [AdapterBinding::new(2, &mc, AdapterRef::Pooled(&pooled))];
            let l1 = infer_prefill_runs(
                &cfg, &base, &runs, &window, &last, cache, &[0, 1],
            );
            scratch_put(l1);
            let l2 = decode_step_runs(&cfg, &base, &runs, cache, &entries);
            scratch_put(l2);
        };
        let t0 = crate::util::alloc::thread_allocs();
        let v = vec![0u8; 4096];
        std::hint::black_box(&v);
        drop(v);
        assert!(
            crate::util::alloc::thread_allocs() > t0,
            "allocation probe inactive"
        );
        let mut warmups = 0;
        loop {
            let b = crate::util::alloc::thread_allocs();
            run(&mut cache);
            if crate::util::alloc::thread_allocs() == b {
                break;
            }
            warmups += 1;
            assert!(
                warmups < 64,
                "scratch arena never reached a zero-alloc fixed point"
            );
        }
        let before = crate::util::alloc::thread_allocs();
        for _ in 0..4 {
            run(&mut cache);
        }
        let allocs = crate::util::alloc::thread_allocs() - before;
        assert_eq!(
            allocs, 0,
            "steady-state pooled prefill/decode hit the heap {allocs} times"
        );
    }

    #[test]
    fn paged_path_bitwise_matches_fixed_oracle_across_ablations() {
        // tentpole acceptance: the block-paged cache must be bitwise
        // identical to the fixed-window oracle — prefill logits, the K/V
        // actually cached, and a full decode trajectory — across MoS
        // ablations and a LoRA tenant. Both sides run canonical-order
        // matmuls only, so this holds at any MOS_THREADS.
        let mut cfg = presets::tiny();
        cfg.batch = 2;
        let mut no_pd = MethodCfg::mos(8, 2, 2, 0);
        no_pd.pair_dissociation = false;
        let variants = [
            MethodCfg::mos(8, 2, 2, 1),
            MethodCfg::mos(8, 1, 2, 0),
            MethodCfg::mos(8, 2, 2, 3),
            no_pd,
            MethodCfg::lora(2),
        ];
        let (t_len, c, vocab) = (cfg.seq, cfg.hidden, cfg.vocab);
        let prompts: Vec<Vec<i32>> = vec![vec![1, 9, 4, 2], vec![1, 5, 6]];
        let mut window = vec![0i32; 2 * t_len];
        for (r, p) in prompts.iter().enumerate() {
            window[r * t_len..r * t_len + p.len()].copy_from_slice(p);
        }
        let last: Vec<usize> = prompts.iter().map(|p| p.len() - 1).collect();
        for (vi, mc) in variants.iter().enumerate() {
            mc.validate(&cfg).unwrap();
            let (base, f) = setup(&cfg, mc, 31 + vi as u64);
            let runs_of =
                |n: usize| [AdapterBinding::new(n, mc, AdapterRef::Dense(&f))];

            let mut fixed = KvCache::new(&cfg, 2);
            let lf = infer_prefill_runs(
                &cfg, &base, &runs_of(2), &window, &last, &mut fixed, &[0, 1],
            );

            // page (4 tokens) far smaller than the window: prompts span
            // page boundaries and decode crosses several acquisitions
            let mut paged =
                PagedKvCache::new(&cfg, 2, 4, 2 * t_len.div_ceil(4));
            let mut entries = Vec::new();
            let mut lean = Vec::new();
            for (r, p) in prompts.iter().enumerate() {
                assert_eq!(paged.admit_row(r, p, 0), Some(0));
                for (pos, &tok) in p.iter().enumerate() {
                    entries.push((r, pos, tok));
                }
                lean.push(entries.len() - 1);
            }
            let lp = paged_infer_runs(
                &cfg,
                &base,
                &runs_of(entries.len()),
                &mut paged,
                &entries,
                Some(&lean),
            );
            let fb: Vec<u32> = lf.iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u32> = lp.iter().map(|v| v.to_bits()).collect();
            assert_eq!(pb, fb, "variant {vi}: prefill logits diverge");
            // the cached K/V themselves must match at every real position
            for kb in 0..cfg.blocks {
                for (r, p) in prompts.iter().enumerate() {
                    for pos in 0..p.len() {
                        let f0 = (r * t_len + pos) * c;
                        let fkk: Vec<u32> = fixed.k[kb][f0..f0 + c]
                            .iter()
                            .map(|v| v.to_bits())
                            .collect();
                        let pkk: Vec<u32> = paged
                            .k_at(r, kb, pos)
                            .iter()
                            .map(|v| v.to_bits())
                            .collect();
                        assert_eq!(
                            pkk, fkk,
                            "variant {vi} block {kb} row {r} pos {pos}: K"
                        );
                        let fvv: Vec<u32> = fixed.v[kb][f0..f0 + c]
                            .iter()
                            .map(|v| v.to_bits())
                            .collect();
                        let pvv: Vec<u32> = paged
                            .v_at(r, kb, pos)
                            .iter()
                            .map(|v| v.to_bits())
                            .collect();
                        assert_eq!(
                            pvv, fvv,
                            "variant {vi} block {kb} row {r} pos {pos}: V"
                        );
                    }
                }
            }

            // greedy decode trajectory through both caches
            let mut toks =
                [argmax(&lp[..vocab]), argmax(&lp[vocab..2 * vocab])];
            for step in 0..8 {
                let steps: Vec<(usize, usize, i32)> = (0..2)
                    .map(|r| (r, prompts[r].len() + step, toks[r]))
                    .collect();
                let df =
                    decode_step_runs(&cfg, &base, &runs_of(2), &mut fixed, &steps);
                let dp = paged_infer_runs(
                    &cfg, &base, &runs_of(2), &mut paged, &steps, None,
                );
                let fb: Vec<u32> = df.iter().map(|v| v.to_bits()).collect();
                let pb: Vec<u32> = dp.iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    pb, fb,
                    "variant {vi} step {step}: decode logits diverge"
                );
                toks = [argmax(&dp[..vocab]), argmax(&dp[vocab..2 * vocab])];
            }
        }
    }

    #[test]
    fn warm_prefix_prefill_bitwise_matches_cold_while_skipping_positions() {
        // tentpole acceptance: prefilling on top of a shared prefix must
        // produce bitwise-identical logits to a cold prefill of the same
        // prompt while *provably* computing only the unshared tail —
        // asserted via the computed-positions counter, not timing.
        let mut cfg = presets::tiny();
        cfg.batch = 3;
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let (base, f) = setup(&cfg, &mc, 41);
        let runs_of =
            |n: usize| [AdapterBinding::new(n, &mc, AdapterRef::Dense(&f))];
        let prefill = |cache: &mut PagedKvCache,
                       row: usize,
                       prompt: &[i32],
                       start: usize|
         -> Vec<f32> {
            let entries: Vec<(usize, usize, i32)> = (start..prompt.len())
                .map(|pos| (row, pos, prompt[pos]))
                .collect();
            let lean = [entries.len() - 1];
            paged_infer_runs(
                &cfg,
                &base,
                &runs_of(entries.len()),
                cache,
                &entries,
                Some(&lean),
            )
        };

        // a 12-token "system prompt" (3 full pages at P=4) and a sibling
        // prompt extending it by a private tail
        let sys: Vec<i32> = vec![2, 7, 1, 8, 2, 8, 1, 8, 2, 8, 4, 5];
        let mut ext = sys.clone();
        ext.extend_from_slice(&[9, 3, 3]);

        let mut paged = PagedKvCache::new(&cfg, 3, 4, 3 * cfg.seq.div_ceil(4));
        let stats = paged.stats();

        // cold prefill of the system prompt, then publish its pages
        assert_eq!(paged.admit_row(0, &sys, 0), Some(0));
        let l_cold = prefill(&mut paged, 0, &sys, 0);
        paged.register_prefix(0, &sys);
        assert_eq!(stats.computed_positions(), sys.len() as u64);

        // identical prompt admitted warm: everything but the last
        // position is shared, and the one computed position lands in a
        // shared page -> COW fork
        let start = paged.admit_row(1, &sys, 0).unwrap();
        assert_eq!(start, sys.len() - 1);
        let l_warm = prefill(&mut paged, 1, &sys, start);
        let cb: Vec<u32> = l_cold.iter().map(|v| v.to_bits()).collect();
        let wb: Vec<u32> = l_warm.iter().map(|v| v.to_bits()).collect();
        assert_eq!(wb, cb, "warm prefill diverges from cold");
        assert_eq!(
            stats.computed_positions(),
            (sys.len() + 1) as u64,
            "warm prefill recomputed shared positions"
        );
        assert_eq!(stats.shared_positions(), (sys.len() - 1) as u64);
        assert_eq!(stats.cow_forks(), 1);

        // extending prompt admitted warm: shares all three system pages,
        // computes only its private tail; bitwise equal to a fully cold
        // prefill of the same prompt in a fresh cache
        let start = paged.admit_row(2, &ext, 0).unwrap();
        assert_eq!(start, sys.len());
        let l_ext_warm = prefill(&mut paged, 2, &ext, start);
        let mut cold_cache =
            PagedKvCache::new(&cfg, 1, 4, cfg.seq.div_ceil(4));
        assert_eq!(cold_cache.admit_row(0, &ext, 0), Some(0));
        let l_ext_cold = prefill(&mut cold_cache, 0, &ext, 0);
        let wb: Vec<u32> = l_ext_warm.iter().map(|v| v.to_bits()).collect();
        let cb: Vec<u32> = l_ext_cold.iter().map(|v| v.to_bits()).collect();
        assert_eq!(wb, cb, "extended warm prefill diverges from cold");

        // and the cold paged result itself bit-matches the fixed-window
        // oracle, closing the loop warm == cold == fixed
        let mut window = vec![0i32; cfg.seq];
        window[..ext.len()].copy_from_slice(&ext);
        let mut fixed = KvCache::new(&cfg, 1);
        let l_fixed = infer_prefill_runs(
            &cfg,
            &base,
            &runs_of(1),
            &window,
            &[ext.len() - 1],
            &mut fixed,
            &[0],
        );
        let fb: Vec<u32> = l_fixed.iter().map(|v| v.to_bits()).collect();
        assert_eq!(cb, fb, "cold paged prefill diverges from fixed oracle");
    }

    #[test]
    fn steady_state_paged_prefill_and_decode_allocate_nothing() {
        // acceptance criterion: the paged serving cycle — admit (prefix
        // lookup + reservation), warm prefill, COW fork, decode step,
        // release — never touches the heap once the arena and prefix
        // index are warm. Page acquisition is amortized through the
        // pool's free list.
        let cfg = micro();
        let mc = MethodCfg::mos(3, 2, 2, 0);
        let (base, f) = setup(&cfg, &mc, 7);
        let mut cache = PagedKvCache::new(&cfg, 2, 2, 8);
        let prompts: [&[i32]; 2] = [&[1, 4, 2], &[1, 5, 6, 2]];
        let mut entries: Vec<(usize, usize, i32)> = Vec::with_capacity(8);
        let mut lean: Vec<usize> = Vec::with_capacity(2);
        let mut run = |cache: &mut PagedKvCache| {
            entries.clear();
            lean.clear();
            for (r, p) in prompts.iter().enumerate() {
                let start = cache.admit_row(r, p, 0).unwrap();
                for pos in start..p.len() {
                    entries.push((r, pos, p[pos]));
                }
                lean.push(entries.len() - 1);
            }
            let runs =
                [AdapterBinding::new(entries.len(), &mc, AdapterRef::Dense(&f))];
            let l1 =
                paged_infer_runs(&cfg, &base, &runs, cache, &entries, Some(&lean));
            scratch_put(l1);
            for (r, p) in prompts.iter().enumerate() {
                cache.register_prefix(r, p);
            }
            // one decode step per row (row 1's write forks a shared page
            // every iteration — the fork itself must be allocation-free)
            let steps = [(0usize, 3usize, 5i32), (1usize, 4usize, 6i32)];
            let runs = [AdapterBinding::new(2, &mc, AdapterRef::Dense(&f))];
            let l2 = paged_infer_runs(&cfg, &base, &runs, cache, &steps, None);
            scratch_put(l2);
            for r in 0..2 {
                cache.release_row(r);
            }
        };
        // the probe itself must be live (otherwise this passes vacuously)
        let t0 = crate::util::alloc::thread_allocs();
        let v = vec![0u8; 4096];
        std::hint::black_box(&v);
        drop(v);
        assert!(
            crate::util::alloc::thread_allocs() > t0,
            "allocation probe inactive"
        );
        // warm to the fixed point: arena capacities and the prefix index
        // only grow, so the cycle stops allocating after finitely many
        // iterations
        let mut warmups = 0;
        loop {
            let b = crate::util::alloc::thread_allocs();
            run(&mut cache);
            if crate::util::alloc::thread_allocs() == b {
                break;
            }
            warmups += 1;
            assert!(
                warmups < 64,
                "paged serving cycle never reached a zero-alloc fixed point"
            );
        }
        let before = crate::util::alloc::thread_allocs();
        for _ in 0..4 {
            run(&mut cache);
        }
        let allocs = crate::util::alloc::thread_allocs() - before;
        assert_eq!(
            allocs, 0,
            "steady-state paged prefill/decode hit the heap {allocs} times"
        );
    }

    #[test]
    fn tiny_preset_forward_shape() {
        let cfg = presets::tiny();
        let mc = MethodCfg::lora(2);
        let (base, f) = setup(&cfg, &mc, 0);
        let n = cfg.batch * cfg.seq;
        let tokens: Vec<i32> = (0..n).map(|i| (i % cfg.vocab) as i32).collect();
        let (cache, _) = forward(&cfg, &mc, &base, &f, &tokens);
        assert_eq!(cache.logits.len(), n * cfg.vocab);
        assert!(cache.logits.iter().all(|x| x.is_finite()));
    }
}
