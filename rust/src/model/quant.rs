//! Int8 weight-only quantization for the serving path.
//!
//! MoS's serving economy — one shared shard pool behind every tenant —
//! means a *single* quantization of the pool (and of the frozen base
//! weights, once per model) amortizes across all adapters. This module
//! holds the quantized representations and the canonical-order kernels
//! that consume them; the wiring (`MOS_SERVE_INT8=1`) lives in
//! `coordinator::*` and `model::transformer`.
//!
//! ## Scheme
//!
//! Symmetric per-row quantization, weights only:
//!
//! * scale `s_j = max_abs(row j) / 127` (`1.0` for an all-zero row);
//! * `q = round(x / s_j)` clamped to `[-127, 127]` (the `-128` code is
//!   unused, keeping the grid symmetric);
//! * activations stay f32; accumulation is f32 throughout.
//!
//! "Row" is an output row for a base weight matrix ([`QuantMatrix`],
//! `(out, in)` row-major) and a shard for the shared pool
//! ([`QuantPool`], `(shards, shard_w)`), so each scale covers exactly the
//! weights one output coordinate (or one shard) streams.
//!
//! ## Canonical order
//!
//! [`gemm_canon_q8`] fixes a per-element operation sequence that depends
//! on neither the batch size nor the worker count: for each C element,
//! `KC` blocks ascending, a single f32 accumulator over
//! `a[i,p] * (q[j,p] as f32)` in ascending `p`, then
//! `c += alpha * (s_j * acc)` at block writeback. Row-batching
//! independence (a decode row bit-matches the same row inside a prefill
//! batch) and `MOS_THREADS` invariance therefore hold exactly as they do
//! for the f32 `gemm_canon` — int8 results differ from f32 results (that
//! is the quantization error, gated by the logit-error budget), but they
//! never differ from *themselves* across batching or threads.
//!
//! The gather path ([`gemm_gather_canon_q8`]) keeps residency int8: only
//! the `rank x (l * shard_w)` gathered operand is dequantized, into
//! per-thread scratch, then the ordinary f32 `gemm_canon` runs — so the
//! pooled bitwise contracts carry over unchanged.

use super::math::{self, auto_pool, div_up, scratch_put, scratch_take, Trans, KC, NR};

/// Serving accuracy budget: max tolerated `|logit_f32 - logit_int8|`
/// on the tiny preset. Gross quantization breakage (wrong scales, code
/// overflow, mis-sliced blocks) lands orders of magnitude above this.
pub const LOGIT_BUDGET_MAX_ABS: f32 = 0.5;
/// Serving accuracy budget: minimum fraction of positions whose argmax
/// logit agrees between the f32 and int8 paths.
pub const LOGIT_BUDGET_TOP1: f32 = 0.70;

/// A quantized row-major matrix `(rows, cols)` with one scale per row.
#[derive(Debug, Clone)]
pub struct QuantMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Row-major codes, `rows * cols` entries in `[-127, 127]`.
    pub q: Vec<i8>,
    /// Per-row dequantization scales, `rows` entries.
    pub scale: Vec<f32>,
}

/// Quantize one row into codes, returning its scale.
fn quantize_row(row: &[f32], q: &mut [i8]) -> f32 {
    let max = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let s = if max == 0.0 { 1.0 } else { max / 127.0 };
    let inv = 1.0 / s;
    for (d, &v) in q.iter_mut().zip(row) {
        *d = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    s
}

impl QuantMatrix {
    /// Quantize a dense `(rows, cols)` row-major matrix.
    pub fn quantize(rows: usize, cols: usize, w: &[f32]) -> QuantMatrix {
        assert_eq!(w.len(), rows * cols, "quantize: shape mismatch");
        let mut q = vec![0i8; rows * cols];
        let mut scale = vec![0.0f32; rows];
        for r in 0..rows {
            scale[r] = quantize_row(
                &w[r * cols..(r + 1) * cols],
                &mut q[r * cols..(r + 1) * cols],
            );
        }
        QuantMatrix { rows, cols, q, scale }
    }

    /// Dequantize the whole matrix (tests and small fallbacks only — the
    /// serving path never materializes this).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            self.row_into(r, &mut out[r * self.cols..(r + 1) * self.cols]);
        }
        out
    }

    /// Dequantize row `r` into `out` (`cols` floats): `q * s_r`.
    pub fn row_into(&self, r: usize, out: &mut [f32]) {
        let s = self.scale[r];
        let row = &self.q[r * self.cols..(r + 1) * self.cols];
        for (d, &v) in out.iter_mut().zip(row) {
            *d = v as f32 * s;
        }
    }

    /// Codes + scales for the row range `[r0, r0 + rn)` — e.g. one
    /// transformer block out of a `(blocks * out, in)` stack.
    pub fn rows_slice(&self, r0: usize, rn: usize) -> (&[i8], &[f32]) {
        (
            &self.q[r0 * self.cols..(r0 + rn) * self.cols],
            &self.scale[r0..r0 + rn],
        )
    }

    /// Resident bytes of the quantized representation (codes + scales).
    pub fn nbytes(&self) -> usize {
        self.q.len() + 4 * self.scale.len()
    }
}

/// A quantized shard pool `(shards, shard_w)` with one scale per shard —
/// the int8 twin of the f32 `{t}.pool_a` / `{t}.pool_b` tensors.
#[derive(Debug, Clone)]
pub struct QuantPool {
    pub shards: usize,
    pub shard_w: usize,
    pub q: Vec<i8>,
    pub scale: Vec<f32>,
}

impl QuantPool {
    /// Quantize a shard pool (one scale per shard row).
    pub fn quantize(shard_w: usize, pool: &[f32]) -> QuantPool {
        assert!(shard_w > 0 && pool.len() % shard_w == 0);
        let shards = pool.len() / shard_w;
        let m = QuantMatrix::quantize(shards, shard_w, pool);
        QuantPool { shards, shard_w, q: m.q, scale: m.scale }
    }

    /// Dequantize the whole pool (tests only).
    pub fn dequantize(&self) -> Vec<f32> {
        QuantMatrix {
            rows: self.shards,
            cols: self.shard_w,
            q: self.q.clone(),
            scale: self.scale.clone(),
        }
        .dequantize()
    }

    /// Resident bytes (codes + scales).
    pub fn nbytes(&self) -> usize {
        self.q.len() + 4 * self.scale.len()
    }
}

/// The frozen base weights of one model, quantized once at engine
/// construction: the seven projection weights (transformer weight-id
/// order, all blocks concatenated, so `rows = blocks * out`) plus the
/// tied embedding (which is also the LM head — the largest base tensor).
/// Norm weights stay f32: they are `O(hidden)` bytes and multiplicative,
/// so quantizing them buys nothing.
#[derive(Debug, Clone)]
pub struct QuantBase {
    pub w: Vec<QuantMatrix>,
    pub embed: QuantMatrix,
}

impl QuantBase {
    /// Resident bytes of the quantized base (codes + scales).
    pub fn nbytes(&self) -> usize {
        self.w.iter().map(|m| m.nbytes()).sum::<usize>() + self.embed.nbytes()
    }
}

// ---------------------------------------------------------------------------
// canonical-order int8 kernels
// ---------------------------------------------------------------------------

/// One C row range `[j0, j0 + cchunk.len())` of the canonical int8 GEMM:
/// `KC` blocks ascending, single f32 accumulator per element over
/// `a[p] * (q[j,p] as f32)` in ascending `p`, scale (and `alpha`) folded
/// at block writeback. This fixed sequence is what every entry below
/// funnels into, so batching and threading can never reorder it.
fn q8_row_range(
    j0: usize,
    k: usize,
    alpha: f32,
    arow: &[f32],
    q: &[i8],
    scale: &[f32],
    cchunk: &mut [f32],
) {
    let mut pc = 0;
    while pc < k {
        let kc = KC.min(k - pc);
        for (jj, cv) in cchunk.iter_mut().enumerate() {
            let j = j0 + jj;
            let qrow = &q[j * k + pc..j * k + pc + kc];
            let ar = &arow[pc..pc + kc];
            let mut acc = 0.0f32;
            for (av, qv) in ar.iter().zip(qrow) {
                acc += *av * (*qv as f32);
            }
            let s = scale[j];
            if alpha == 1.0 {
                *cv += s * acc;
            } else {
                *cv += alpha * (s * acc);
            }
        }
        pc += kc;
    }
}

/// Canonical-order int8 GEMM: `c (m,n) += alpha * a @ deq(W)^T` where `W`
/// is `(n, k)` int8 codes with per-row scales (the base-weight serving
/// orientation — f32 activations against `W^T`, like
/// `gemm_canon(.., w, Trans::T, ..)`).
///
/// Accumulation is f32; the per-element order is fixed (see
/// [`q8_row_range`]), so results are bitwise independent of row batching
/// and of `MOS_THREADS` — rows of C fan out whole per worker (columns for
/// `m = 1` decode rows), never splitting an element's k loop.
#[allow(clippy::too_many_arguments)]
pub fn gemm_canon_q8(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    q: &[i8],
    scale: &[f32],
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(q.len(), n * k);
    debug_assert_eq!(scale.len(), n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(n)
        .saturating_mul(k);
    let pool = if flops >= math::PAR_FLOPS { auto_pool() } else { None };
    let nth = pool.map(|p| p.workers()).unwrap_or(1);
    if m == 1 {
        // decode row: partition columns; each c_j is computed whole by
        // one worker in the canonical order
        if nth <= 1 || n < 2 * NR {
            return q8_row_range(0, k, alpha, a, q, scale, c);
        }
        let chunk = div_up(n, nth).max(NR);
        let mut tasks: Vec<(usize, &mut [f32])> = Vec::new();
        let mut rest: &mut [f32] = c;
        let mut j0 = 0usize;
        while !rest.is_empty() {
            let w = chunk.min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(w);
            tasks.push((j0, head));
            rest = tail;
            j0 += w;
        }
        pool.unwrap()
            .scoped_map(tasks, |(j0, cchunk)| q8_row_range(j0, k, alpha, a, q, scale, cchunk));
        return;
    }
    let serial = |i0: usize, crows: &mut [f32]| {
        for (i, crow) in crows.chunks_exact_mut(n).enumerate() {
            let arow = &a[(i0 + i) * k..(i0 + i + 1) * k];
            q8_row_range(0, k, alpha, arow, q, scale, crow);
        }
    };
    if nth <= 1 {
        return serial(0, c);
    }
    let per = div_up(m, nth);
    let mut tasks: Vec<(usize, &mut [f32])> = Vec::new();
    let mut rest: &mut [f32] = c;
    let mut i0 = 0usize;
    while i0 < m {
        let take = per.min(m - i0);
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(take * n);
        tasks.push((i0, head));
        rest = tail;
        i0 += take;
    }
    pool.unwrap().scoped_map(tasks, |(i0, crows)| serial(i0, crows));
}

/// [`gemm_canon_q8`] against a [`QuantMatrix`] (shape-checked sugar).
pub fn gemm_canon_q8m(m: usize, alpha: f32, a: &[f32], w: &QuantMatrix, c: &mut [f32]) {
    gemm_canon_q8(m, w.rows, w.cols, alpha, a, &w.q, &w.scale, c)
}

/// Gather `idx` shard rows out of a *quantized* pool into a dense f32
/// matrix: each shard dequantizes as `q * s_shard` while copying, then
/// the optional per-row rank scale folds in afterwards with the same
/// `s != 1.0` guard as the f32 `gather_pooled` — so the result is
/// bit-identical to gathering from a pre-dequantized f32 pool.
fn gather_pooled_q8(
    g: &mut [f32],
    pool: &QuantPool,
    idx: &[i32],
    l: usize,
    row_scale: Option<&[f32]>,
) {
    let shard_w = pool.shard_w;
    let g_rows = idx.len() / l;
    let width = l * shard_w;
    debug_assert_eq!(idx.len(), g_rows * l);
    debug_assert_eq!(g.len(), g_rows * width);
    for row in 0..g_rows {
        for j in 0..l {
            let shard = idx[row * l + j] as usize;
            let s = pool.scale[shard];
            let src = &pool.q[shard * shard_w..(shard + 1) * shard_w];
            let dst = &mut g[row * width + j * shard_w..row * width + (j + 1) * shard_w];
            for (d, &v) in dst.iter_mut().zip(src) {
                *d = v as f32 * s;
            }
        }
    }
    if let Some(scale) = row_scale {
        debug_assert_eq!(scale.len(), g_rows);
        for row in 0..g_rows {
            let s = scale[row];
            if s != 1.0 {
                for v in &mut g[row * width..(row + 1) * width] {
                    *v *= s;
                }
            }
        }
    }
}

/// Int8 variant of `gemm_gather_canon`: the shard pool stays resident in
/// int8; only the gathered `g_rows x (l * shard_w)` operand is
/// dequantized, into per-thread scratch, and the ordinary f32
/// `gemm_canon` runs against it. `tg` has the same two roles as the f32
/// entry (`Trans::T` = A-factor apply, `Trans::N` = B-factor apply).
/// Bitwise identical to dequantizing the whole pool up front and calling
/// `gemm_gather_canon` — for any thread count — because the floats and
/// the kernel that touches them are literally the same.
#[allow(clippy::too_many_arguments)]
pub fn gemm_gather_canon_q8(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    pool: &QuantPool,
    idx: &[i32],
    l: usize,
    row_scale: Option<&[f32]>,
    tg: Trans,
    c: &mut [f32],
) {
    let g_rows = idx.len() / l;
    let width = l * pool.shard_w;
    match tg {
        Trans::T => debug_assert_eq!((n, k), (g_rows, width)),
        Trans::N => debug_assert_eq!((k, n), (g_rows, width)),
    }
    let mut g = scratch_take(g_rows * width);
    gather_pooled_q8(&mut g, pool, idx, l, row_scale);
    math::gemm_canon(m, n, k, alpha, a, Trans::N, &g, tg, c);
    scratch_put(g);
}

// ---------------------------------------------------------------------------
// logit-error budget
// ---------------------------------------------------------------------------

/// Accuracy of an int8 run against its f32 reference, over per-position
/// logit rows: the two budget metrics the tests and `bench_serving` gate.
#[derive(Debug, Clone, Copy)]
pub struct LogitError {
    /// `max |logit_int8 - logit_f32|` over every position and vocab slot.
    pub max_abs: f32,
    /// Fraction of positions whose argmax logit agrees, in `[0, 1]`.
    pub top1_agree: f32,
}

/// Compare candidate logits against a reference, `vocab` slots per row.
pub fn logit_error(reference: &[f32], candidate: &[f32], vocab: usize) -> LogitError {
    assert_eq!(reference.len(), candidate.len());
    assert!(vocab > 0 && reference.len() % vocab == 0);
    let rows = reference.len() / vocab;
    let mut max_abs = 0.0f32;
    let mut agree = 0usize;
    for r in 0..rows {
        let rf = &reference[r * vocab..(r + 1) * vocab];
        let cf = &candidate[r * vocab..(r + 1) * vocab];
        for (x, y) in rf.iter().zip(cf) {
            max_abs = max_abs.max((x - y).abs());
        }
        let am = |row: &[f32]| {
            (0..vocab)
                .max_by(|&a, &b| row[a].total_cmp(&row[b]))
                .unwrap()
        };
        if am(rf) == am(cf) {
            agree += 1;
        }
    }
    LogitError {
        max_abs,
        top1_agree: if rows == 0 { 1.0 } else { agree as f32 / rows as f32 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::threadpool::ThreadPool;

    #[test]
    fn quantize_dequantize_round_trip_within_half_step() {
        // symmetric per-row quant: every weight reconstructs within half a
        // quantization step of its row, extreme rows hit the ±127 codes,
        // and an all-zero row round-trips exactly
        let mut rng = Rng::new(71, 0);
        let (rows, cols) = (9, 40);
        let mut w: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * 0.1).collect();
        for v in &mut w[0..cols] {
            *v = 0.0; // all-zero row
        }
        let qm = QuantMatrix::quantize(rows, cols, &w);
        let deq = qm.dequantize();
        for r in 0..rows {
            let s = qm.scale[r];
            assert!(s > 0.0);
            for c in 0..cols {
                let err = (deq[r * cols + c] - w[r * cols + c]).abs();
                assert!(
                    err <= 0.5001 * s,
                    "row {r} col {c}: err {err} > half step {s}"
                );
            }
            let max_code = qm.q[r * cols..(r + 1) * cols]
                .iter()
                .map(|v| v.unsigned_abs())
                .max()
                .unwrap();
            if r == 0 {
                assert_eq!(max_code, 0, "zero row must quantize to zero codes");
                assert_eq!(s, 1.0);
            } else {
                assert_eq!(max_code, 127, "row max must land on the top code");
            }
        }
        assert_eq!(qm.nbytes(), rows * cols + 4 * rows);
    }

    #[test]
    fn q8_gemm_matches_dequantized_oracle_and_is_batch_invariant() {
        // gemm_canon_q8 vs a plain f32 GEMM on the dequantized matrix:
        // close numerically (the scale folds per KC block, not per
        // element, so not bitwise), and bitwise independent of row
        // batching — computing a row alone must bit-match the same row
        // inside a batch (the decode contract carried to int8)
        let mut rng = Rng::new(73, 1);
        for (m, n, k, alpha) in [
            (6usize, 24usize, 40usize, 1.0f32),
            (6, 24, 300, 1.7), // k > KC: per-block scale writeback
            (1, 33, 64, 1.0),  // decode row
            (16, 64, 128, 0.25),
        ] {
            let w: Vec<f32> = (0..n * k).map(|_| rng.normal() * 0.05).collect();
            let qm = QuantMatrix::quantize(n, k, &w);
            let deq = qm.dequantize();
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let mut got = c0.clone();
            gemm_canon_q8(m, n, k, alpha, &a, &qm.q, &qm.scale, &mut got);
            let mut want = c0.clone();
            math::gemm_canon(m, n, k, alpha, &a, Trans::N, &deq, Trans::T, &mut want);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() <= 1e-4 + 1e-4 * w.abs().max(1.0) * (k as f32).sqrt(),
                    "({m},{n},{k}) alpha={alpha}: {g} vs {w}"
                );
            }
            // row-batching independence, bitwise
            for i in 0..m {
                let mut crow = c0[i * n..(i + 1) * n].to_vec();
                gemm_canon_q8(
                    1, n, k, alpha, &a[i * k..(i + 1) * k], &qm.q, &qm.scale, &mut crow,
                );
                let alone: Vec<u32> = crow.iter().map(|v| v.to_bits()).collect();
                let batched: Vec<u32> =
                    got[i * n..(i + 1) * n].iter().map(|v| v.to_bits()).collect();
                assert_eq!(alone, batched, "row {i} of ({m},{n},{k}) alpha={alpha}");
            }
        }
    }

    #[test]
    fn q8_gemm_thread_invariant_bitwise() {
        // MOS_THREADS must never change int8 serving results: the pooled
        // fan-out partitions whole C rows (columns for m = 1), so outputs
        // are bit-identical across worker counts. Shapes exceed PAR_FLOPS
        // via the public entry's auto pool as well as pinned pools.
        let pool1 = ThreadPool::new(1);
        let pool4 = ThreadPool::new(4);
        let mut rng = Rng::new(79, 2);
        for (m, n, k) in [(48usize, 256usize, 128usize), (1, 2048, 512)] {
            let w: Vec<f32> = (0..n * k).map(|_| rng.normal() * 0.05).collect();
            let qm = QuantMatrix::quantize(n, k, &w);
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            // pinned pools through the worker path: emulate by running the
            // canonical entry inside the pool's own workers via scoped_map
            let run_auto = || -> Vec<u32> {
                let mut c = vec![0.0f32; m * n];
                gemm_canon_q8(m, n, k, 1.0, &a, &qm.q, &qm.scale, &mut c);
                c.iter().map(|v| v.to_bits()).collect()
            };
            let base = run_auto();
            assert_eq!(base, run_auto(), "({m},{n},{k}) not deterministic");
            // serial oracle: same entry with the pool suppressed by
            // running inside a single-worker pool task
            let serial: Vec<u32> = {
                let mut out = vec![Vec::new()];
                pool1.scoped_map(
                    out.iter_mut().map(|o| (0usize, o)).collect::<Vec<_>>(),
                    |(_, o)| {
                        let mut c = vec![0.0f32; m * n];
                        gemm_canon_q8(m, n, k, 1.0, &a, &qm.q, &qm.scale, &mut c);
                        *o = c.iter().map(|v| v.to_bits()).collect();
                    },
                );
                out.remove(0)
            };
            assert_eq!(base, serial, "({m},{n},{k}) thread-variant");
            // and a different worker count agrees too
            let par4: Vec<u32> = {
                let mut out = vec![Vec::new()];
                pool4.scoped_map(
                    out.iter_mut().map(|o| (0usize, o)).collect::<Vec<_>>(),
                    |(_, o)| {
                        let mut c = vec![0.0f32; m * n];
                        gemm_canon_q8(m, n, k, 1.0, &a, &qm.q, &qm.scale, &mut c);
                        *o = c.iter().map(|v| v.to_bits()).collect();
                    },
                );
                out.remove(0)
            };
            assert_eq!(base, par4, "({m},{n},{k}) 4-worker nest diverges");
        }
    }

    #[test]
    fn q8_gather_bitwise_matches_dequantized_pool_gather() {
        // the pooled serving contract in int8: gathering from the
        // quantized pool must bit-match dequantizing the whole pool first
        // and running the f32 gather GEMM — both operand roles, with and
        // without the rank scale
        let mut rng = Rng::new(83, 3);
        for (m, g_rows, l, shard_w, alpha, tg, scaled) in [
            (6usize, 8usize, 2usize, 32usize, 1.0f32, Trans::T, true),
            (6, 8, 2, 32, 0.25, Trans::N, true),
            (1, 4, 3, 8, 1.0, Trans::T, false),
            (48, 16, 2, 64, 1.0, Trans::N, true),
        ] {
            let n_shards = 24usize;
            let poolf: Vec<f32> =
                (0..n_shards * shard_w).map(|_| rng.normal() * 0.05).collect();
            let qp = QuantPool::quantize(shard_w, &poolf);
            let deq_pool = qp.dequantize();
            let idx: Vec<i32> = (0..g_rows * l)
                .map(|_| rng.range(0, n_shards) as i32)
                .collect();
            let scale: Option<Vec<f32>> = scaled.then(|| {
                (0..g_rows)
                    .map(|i| if i % 3 == 0 { 1.0 } else { rng.normal().abs() + 0.5 })
                    .collect()
            });
            let width = l * shard_w;
            let (n, k) = match tg {
                Trans::T => (g_rows, width),
                Trans::N => (width, g_rows),
            };
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let mut want = c0.clone();
            math::gemm_gather_canon(
                m, n, k, alpha, &a, &deq_pool, shard_w, &idx, l,
                scale.as_deref(), tg, &mut want,
            );
            let mut got = c0.clone();
            gemm_gather_canon_q8(
                m, n, k, alpha, &a, &qp, &idx, l, scale.as_deref(), tg, &mut got,
            );
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "({m},{g_rows},{l},{shard_w}) tg={tg:?} diverges");
        }
    }

    #[test]
    fn logit_error_metrics() {
        let reference = vec![1.0f32, 2.0, 0.0, /* row 2 */ 0.5, 0.1, 0.4];
        let mut cand = reference.clone();
        cand[0] = 1.1; // perturb but keep argmax
        let e = logit_error(&reference, &cand, 3);
        assert!((e.max_abs - 0.1).abs() < 1e-6);
        assert_eq!(e.top1_agree, 1.0);
        cand[3] = 0.0;
        cand[5] = 0.9; // flip row 2's argmax
        let e = logit_error(&reference, &cand, 3);
        assert_eq!(e.top1_agree, 0.5);
        assert!((e.max_abs - 0.5).abs() < 1e-6);
    }
}
