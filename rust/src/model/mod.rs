//! Pure-Rust host model substrate.
//!
//! A complete fwd/bwd implementation of the same decoder-only transformer
//! that `python/compile/model.py` defines (RMSNorm + causal MHA + SwiGLU,
//! sinusoidal positions, tied embedding), with gradients flowing into any
//! of the five adapter parameterizations.
//!
//! Why it exists (DESIGN.md §1): it is (a) the numerics oracle the PJRT
//! artifacts are cross-checked against, (b) the fast backend for the table
//! benches (no per-config XLA compile on 1 CPU core), and (c) a
//! grad-checked reference for the adapter backward rules.

pub mod adamw;
pub mod math;
pub mod paged;
pub mod quant;
pub mod transformer;

use crate::adapter::{self, Factors};
use crate::config::{Method, MethodCfg, ModelCfg, LAYER_TYPES};
use crate::util::bank::{Bank, Tensor};

/// Host model: frozen base + one adapter.
pub struct HostModel {
    pub cfg: ModelCfg,
    pub mc: MethodCfg,
    pub base: Bank,
    pub params: Bank,
    pub aux: Bank,
    /// cached dense factors (recomputed when params change)
    factors: Option<std::collections::BTreeMap<String, Factors>>,
}

impl HostModel {
    pub fn new(cfg: ModelCfg, mc: MethodCfg, base: Bank, params: Bank, aux: Bank) -> Self {
        HostModel { cfg, mc, base, params, aux, factors: None }
    }

    /// Generate a fresh model with host-side init (no artifacts needed).
    pub fn init(cfg: &ModelCfg, mc: &MethodCfg, seed: u64) -> Self {
        let base = transformer::init_base(cfg, seed);
        let params = adapter::init_params(cfg, mc, seed.wrapping_add(1));
        let aux = match mc.method {
            Method::MoS => {
                adapter::mos::router::build_router(cfg, mc, seed).into_bank()
            }
            Method::VeRA => adapter::vera::frozen_matrices(cfg, mc, seed),
            _ => Bank::new(),
        };
        HostModel::new(cfg.clone(), mc.clone(), base, params, aux)
    }

    /// Dense factors for every layer type (materialized on demand).
    pub fn factors(&mut self) -> &std::collections::BTreeMap<String, Factors> {
        if self.factors.is_none() {
            let mut m = std::collections::BTreeMap::new();
            for t in LAYER_TYPES {
                m.insert(
                    t.to_string(),
                    adapter::materialize(&self.cfg, &self.mc, &self.params, &self.aux, t),
                );
            }
            self.factors = Some(m);
        }
        self.factors.as_ref().unwrap()
    }

    pub fn invalidate_factors(&mut self) {
        self.factors = None;
    }

    /// Forward pass: logits (B*T*V).
    pub fn forward(&mut self, tokens: &[i32]) -> Vec<f32> {
        let cfg = self.cfg.clone();
        let mc = self.mc.clone();
        let base = self.base.clone();
        let f = self.factors().clone();
        let (cache, _) = transformer::forward(&cfg, &mc, &base, &f, tokens);
        cache.logits
    }

    /// Loss + gradient step state: see [`train::host::HostTrainer`].
    pub fn loss_and_grads(
        &mut self,
        tokens: &[i32],
        targets: &[i32],
        weight: &[f32],
    ) -> (f32, Bank) {
        let cfg = self.cfg.clone();
        let mc = self.mc.clone();
        let base = self.base.clone();
        let f = self.factors().clone();
        let (cache, _) = transformer::forward(&cfg, &mc, &base, &f, tokens);
        let (loss, dfactors) =
            transformer::backward(&cfg, &mc, &base, &f, &cache, tokens, targets, weight);
        let grads = backward_params(&cfg, &mc, &self.params, &self.aux, &dfactors);
        (loss, grads)
    }
}

/// Map dense-factor gradients back onto the trainable parameters of each
/// method (the host twin of jax autodiff through `materialize`).
pub fn backward_params(
    cfg: &ModelCfg,
    mc: &MethodCfg,
    params: &Bank,
    aux: &Bank,
    dfactors: &std::collections::BTreeMap<String, Factors>,
) -> Bank {
    let mut grads = Bank::new();
    for t in LAYER_TYPES {
        let (o, i) = cfg.dims(t);
        let df = &dfactors[t];
        let (r, l) = (mc.r, mc.l);
        match mc.method {
            Method::LoRA => {
                let mut ga = Vec::with_capacity(cfg.blocks * r * i);
                let mut gb = Vec::with_capacity(cfg.blocks * o * r);
                for k in 0..cfg.blocks {
                    ga.extend_from_slice(&df.a[k]);
                    gb.extend_from_slice(&df.b[k]);
                }
                grads.insert(format!("{t}.a"), Tensor::from_f32(&[cfg.blocks, r, i], ga));
                grads.insert(format!("{t}.b"), Tensor::from_f32(&[cfg.blocks, o, r], gb));
            }
            Method::MoS => {
                // scatter-add through the gather, with the rank scale folded
                // into the A side (matching materialize)
                let idx_a = aux[&format!("{t}.idx_a")].i32s().unwrap();
                let idx_b = aux[&format!("{t}.idx_b")].i32s().unwrap();
                let scale = aux[&format!("{t}.rank_scale")].f32s().unwrap();
                let n = mc.pool_shards(cfg.blocks);
                let (sa, sb) = (i / l, o / l);
                let mut gpa = vec![0.0f32; n * sa];
                let mut gpb = vec![0.0f32; n * sb];
                for k in 0..cfg.blocks {
                    let da = &df.a[k]; // (r, i)
                    for row in 0..r {
                        let s = scale[k * r + row];
                        for j in 0..l {
                            let shard = idx_a[(k * r + row) * l + j] as usize;
                            let src = &da[row * i + j * sa..row * i + (j + 1) * sa];
                            let dst = &mut gpa[shard * sa..(shard + 1) * sa];
                            for (d, v) in dst.iter_mut().zip(src) {
                                *d += s * v;
                            }
                        }
                    }
                    let db = &df.b[k]; // (o, r) — shard rows live in column slices
                    for row in 0..r {
                        for j in 0..l {
                            let shard = idx_b[(k * r + row) * l + j] as usize;
                            let dst = &mut gpb[shard * sb..(shard + 1) * sb];
                            for (p, d) in dst.iter_mut().enumerate() {
                                // B[j*sb + p, row] gathered from pool_b[shard, p]
                                *d += db[(j * sb + p) * r + row];
                            }
                        }
                    }
                }
                grads.insert(format!("{t}.pool_a"), Tensor::from_f32(&[n, sa], gpa));
                grads.insert(format!("{t}.pool_b"), Tensor::from_f32(&[n, sb], gpb));
            }
            Method::VeRA => {
                let fa = aux[&format!("{t}.frozen_a")].f32s().unwrap();
                let fb = aux[&format!("{t}.frozen_b")].f32s().unwrap();
                let mut gd = vec![0.0f32; cfg.blocks * r];
                let mut gbv = vec![0.0f32; cfg.blocks * o];
                for k in 0..cfg.blocks {
                    for rr in 0..r {
                        let mut acc = 0.0;
                        for c in 0..i {
                            acc += df.a[k][rr * i + c] * fa[rr * i + c];
                        }
                        gd[k * r + rr] = acc;
                    }
                    for oo in 0..o {
                        let mut acc = 0.0;
                        for rr in 0..r {
                            acc += df.b[k][oo * r + rr] * fb[oo * r + rr];
                        }
                        gbv[k * o + oo] = acc;
                    }
                }
                grads.insert(format!("{t}.d"), Tensor::from_f32(&[cfg.blocks, r], gd));
                grads.insert(format!("{t}.bvec"), Tensor::from_f32(&[cfg.blocks, o], gbv));
            }
            Method::Tied => {
                let sa = params[&format!("{t}.a")].f32s().unwrap();
                let sb = params[&format!("{t}.b")].f32s().unwrap();
                let u = params[&format!("{t}.u")].f32s().unwrap();
                let v = params[&format!("{t}.v")].f32s().unwrap();
                let mut ga = vec![0.0f32; r * i];
                let mut gb = vec![0.0f32; o * r];
                let mut gu = vec![0.0f32; cfg.blocks * r];
                let mut gv = vec![0.0f32; cfg.blocks * o];
                for k in 0..cfg.blocks {
                    for rr in 0..r {
                        let uk = u[k * r + rr];
                        let mut du = 0.0;
                        for c in 0..i {
                            let d = df.a[k][rr * i + c];
                            ga[rr * i + c] += uk * d;
                            du += d * sa[rr * i + c];
                        }
                        gu[k * r + rr] = du;
                    }
                    for oo in 0..o {
                        let vk = v[k * o + oo];
                        let mut dv = 0.0;
                        for rr in 0..r {
                            let d = df.b[k][oo * r + rr];
                            gb[oo * r + rr] += vk * d;
                            dv += d * sb[oo * r + rr];
                        }
                        gv[k * o + oo] = dv;
                    }
                }
                grads.insert(format!("{t}.a"), Tensor::from_f32(&[r, i], ga));
                grads.insert(format!("{t}.b"), Tensor::from_f32(&[o, r], gb));
                grads.insert(format!("{t}.u"), Tensor::from_f32(&[cfg.blocks, r], gu));
                grads.insert(format!("{t}.v"), Tensor::from_f32(&[cfg.blocks, o], gv));
            }
            Method::PRoLoRA => {
                let m = mc.m;
                let (ic, oc) = (i / m, o / m);
                let mut ga0 = vec![0.0f32; cfg.blocks * r * ic];
                let mut gb0 = vec![0.0f32; cfg.blocks * oc * r];
                for k in 0..cfg.blocks {
                    for j in 0..m {
                        for rr in 0..r {
                            let src_row = (rr + r - (j % r)) % r; // fwd: dst rr <- src row
                            for c in 0..ic {
                                ga0[(k * r + src_row) * ic + c] +=
                                    df.a[k][rr * i + j * ic + c];
                            }
                        }
                        for row in 0..oc {
                            for rr in 0..r {
                                let src_col = (rr + r - (j % r)) % r;
                                gb0[(k * oc + row) * r + src_col] +=
                                    df.b[k][(j * oc + row) * r + rr];
                            }
                        }
                    }
                }
                grads.insert(
                    format!("{t}.a0"),
                    Tensor::from_f32(&[cfg.blocks, r, ic], ga0),
                );
                grads.insert(
                    format!("{t}.b0"),
                    Tensor::from_f32(&[cfg.blocks, oc, r], gb0),
                );
            }
        }
    }
    grads
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::rng::Rng;

    fn micro_cfg() -> ModelCfg {
        ModelCfg {
            name: "micro".into(),
            vocab: 13,
            hidden: 8,
            blocks: 2,
            heads: 2,
            kv_heads: 2,
            ff: 12,
            seq: 6,
            batch: 2,
        }
    }

    /// Finite-difference gradient check of the *whole* pipeline (transformer
    /// backward + method backward) for every method. This is the strongest
    /// correctness signal in the host substrate.
    #[test]
    fn grad_check_all_methods() {
        let cfg = micro_cfg();
        for mc in [
            MethodCfg::lora(2),
            MethodCfg::mos(3, 2, 2, 1),
            MethodCfg::vera(2),
            MethodCfg::tied(2),
            MethodCfg::prolora(2, 2),
        ] {
            grad_check(&cfg, &mc);
        }
    }

    fn grad_check(cfg: &ModelCfg, mc: &MethodCfg) {
        let mut model = HostModel::init(cfg, mc, 3);
        // nonzero params everywhere so gradients are informative
        let mut rng = Rng::new(5, 0);
        let keys: Vec<String> = model.params.keys().cloned().collect();
        for kname in &keys {
            let t = model.params[kname].clone();
            model.params.insert(
                kname.clone(),
                Tensor::from_f32(t.shape(), rng.normal_vec(t.len(), 0.05)),
            );
        }
        let n_tok = cfg.batch * cfg.seq;
        let tokens: Vec<i32> =
            (0..n_tok).map(|_| rng.range(0, cfg.vocab) as i32).collect();
        let targets: Vec<i32> =
            (0..n_tok).map(|_| rng.range(0, cfg.vocab) as i32).collect();
        let weight = vec![1.0f32; n_tok];

        model.invalidate_factors();
        let (_, grads) = model.loss_and_grads(&tokens, &targets, &weight);

        // check a few random coordinates of every tensor by central diff
        for kname in &keys {
            let g = grads[kname].f32s().unwrap().to_vec();
            let n = g.len();
            for _ in 0..3.min(n) {
                let c = rng.range(0, n);
                let eps = 1e-3f32;
                let orig = model.params[kname].f32s().unwrap()[c];
                let lp = perturbed_loss(&mut model, kname, c, orig + eps,
                                        &tokens, &targets, &weight);
                let lm = perturbed_loss(&mut model, kname, c, orig - eps,
                                        &tokens, &targets, &weight);
                set_param(&mut model, kname, c, orig);
                let fd = (lp - lm) / (2.0 * eps);
                let ad = g[c];
                let tol = 2e-2f32.max(0.15 * fd.abs());
                assert!(
                    (fd - ad).abs() < tol,
                    "{:?} {kname}[{c}]: fd={fd:.5} ad={ad:.5}",
                    mc.method
                );
            }
        }
    }

    fn set_param(m: &mut HostModel, key: &str, c: usize, v: f32) {
        let t = m.params[key].clone();
        let mut data = t.f32s().unwrap().to_vec();
        data[c] = v;
        m.params.insert(key.to_string(), Tensor::from_f32(t.shape(), data));
        m.invalidate_factors();
    }

    fn perturbed_loss(
        m: &mut HostModel,
        key: &str,
        c: usize,
        v: f32,
        tokens: &[i32],
        targets: &[i32],
        weight: &[f32],
    ) -> f32 {
        set_param(m, key, c, v);
        let (loss, _) = m.loss_and_grads(tokens, targets, weight);
        loss
    }

    #[test]
    fn forward_deterministic_and_finite() {
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let mut m = HostModel::init(&cfg, &mc, 0);
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq)
            .map(|i| (i % cfg.vocab) as i32)
            .collect();
        let l1 = m.forward(&tokens);
        let l2 = m.forward(&tokens);
        assert_eq!(l1, l2);
        assert!(l1.iter().all(|x| x.is_finite()));
        assert_eq!(l1.len(), cfg.batch * cfg.seq * cfg.vocab);
    }
}
