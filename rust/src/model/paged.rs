//! Block-paged KV cache with copy-on-write shared-prefix reuse.
//!
//! MoS's core move — one global pool of fixed-size shards with per-tenant
//! index tables selecting into it — applied to KV memory instead of
//! adapter weights: a [`PagePool`] of refcounted fixed-size K/V pages
//! (the shards) plus a per-row page table per live request (the index
//! table), so resident KV bytes track live tokens instead of the fixed
//! `slots × window` buffer [`KvCache`](super::transformer::KvCache)
//! allocates up front.
//!
//! On top of paging sits prefix sharing: at admission the prompt's full
//! pages are chain-hashed ([`chain_hash`], FNV-1a over the token bytes so
//! page `i`'s key commits to the *entire* prefix `0..(i+1)*P`) and looked
//! up in a per-owner [`PrefixIndex`]. A hit — confirmed by a **full token
//! compare**, the hash alone is never trusted — maps the already-filled
//! pages into the new row's table (refcount bump, no copy, no compute)
//! and prefill only runs the unshared tail. A row that writes into a
//! page whose refcount is above one first forks a private copy
//! (copy-on-write), so sharers never observe each other's writes.
//!
//! Admission is reservation-based: [`PagedKvCache::admit_row`] reserves
//! the row's worst-case page count (window pages minus fully-shared
//! pages) up front and fails — *before* the row holds any state — when
//! the pool can't cover it. Decode-time page acquisition draws from the
//! reservation and therefore cannot fail mid-decode: a full pool degrades
//! to queueing at admission, never to OOM or a mid-generation error.
//! Stale prefix retentions are evicted LRU-first when a reservation
//! would otherwise not fit.
//!
//! Everything on the steady-state path — lookup, compare, page
//! acquire/release, COW fork — is allocation-free: the pool's slab,
//! refcounts and free list are preallocated, page tables are sized to
//! the window at construction, and forks copy within the slab.

use crate::config::ModelCfg;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// FNV-1a offset basis: the seed for the first page's [`chain_hash`].
pub const PREFIX_HASH_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Extend an FNV-1a chain hash over one page worth of tokens. Seeding
/// each page's hash with the previous page's makes the key for page `i`
/// commit to the whole prefix `0..(i+1)*page_tokens`, so two prompts
/// can only collide per-level, and a single token compare at the
/// matched level verifies the entire prefix.
pub fn chain_hash(mut h: u64, tokens: &[i32]) -> u64 {
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Shared observability for the paged KV subsystem: resident/peak pool
/// bytes, COW forks, and the shared-vs-computed position counters the
/// warm-prefill skip proof and `bench_serving`'s `kv_mb` column read.
/// Cloned (`Arc`) into the pool, the serving engine, tests, and benches.
#[derive(Debug, Default)]
pub struct KvStats {
    resident_bytes: AtomicUsize,
    peak_resident_bytes: AtomicUsize,
    cow_forks: AtomicU64,
    shared_positions: AtomicU64,
    computed_positions: AtomicU64,
}

impl KvStats {
    /// Bytes of pool slab currently backing at least one reference.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes.load(Ordering::SeqCst)
    }

    /// High-water mark of [`Self::resident_bytes`].
    pub fn peak_resident_bytes(&self) -> usize {
        self.peak_resident_bytes.load(Ordering::SeqCst)
    }

    /// Copy-on-write forks performed (a sharer wrote a shared page).
    pub fn cow_forks(&self) -> u64 {
        self.cow_forks.load(Ordering::SeqCst)
    }

    /// Prompt positions admitted *without* compute via prefix sharing.
    pub fn shared_positions(&self) -> u64 {
        self.shared_positions.load(Ordering::SeqCst)
    }

    /// Positions actually run through the paged transformer path
    /// (prefill tail entries + decode steps) — the warm-prefill tests
    /// assert this counter, not timing, to prove positions were skipped.
    pub fn computed_positions(&self) -> u64 {
        self.computed_positions.load(Ordering::SeqCst)
    }

    /// Record `m` computed positions (called by the paged model path).
    pub(crate) fn note_computed(&self, m: usize) {
        self.computed_positions.fetch_add(m as u64, Ordering::SeqCst);
    }

    fn note_resident(&self, bytes: usize) {
        self.resident_bytes.store(bytes, Ordering::SeqCst);
        self.peak_resident_bytes.fetch_max(bytes, Ordering::SeqCst);
    }
}

/// The global pool of fixed-size K/V pages — the KV-side analogue of
/// MoS's shard pool. One contiguous `f32` slab holds every page; a page
/// spans **all blocks** (one refcount covers the whole token range,
/// because prefix sharing is by token position, which is identical
/// across layers — per-layer tables would multiply bookkeeping for no
/// extra sharing). Page layout: `[block][k|v][slot][dim]`.
pub struct PagePool {
    blocks: usize,
    dim: usize,
    page_tokens: usize,
    /// Floats per page: `blocks * 2 * page_tokens * dim`.
    page_floats: usize,
    data: Vec<f32>,
    refcnt: Vec<u32>,
    /// Owner tag per resident page (an engine-assigned tenant tag);
    /// sharing never crosses owners, so per-owner page counts partition
    /// the pool exactly — the ledger-vs-pool assertion relies on this.
    owner: Vec<u32>,
    /// Free list: acquisition and release are a push/pop, no allocation.
    free: Vec<u32>,
    stats: Arc<KvStats>,
}

impl PagePool {
    pub fn new(
        blocks: usize,
        dim: usize,
        page_tokens: usize,
        capacity_pages: usize,
        stats: Arc<KvStats>,
    ) -> PagePool {
        assert!(page_tokens > 0 && capacity_pages > 0);
        let page_floats = blocks * 2 * page_tokens * dim;
        PagePool {
            blocks,
            dim,
            page_tokens,
            page_floats,
            data: vec![0.0; capacity_pages * page_floats],
            refcnt: vec![0; capacity_pages],
            owner: vec![0; capacity_pages],
            // pop() hands out low page ids first
            free: (0..capacity_pages as u32).rev().collect(),
            stats: Arc::clone(&stats),
        }
    }

    pub fn capacity_pages(&self) -> usize {
        self.refcnt.len()
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Bytes one page keeps resident.
    pub fn page_bytes(&self) -> usize {
        self.page_floats * std::mem::size_of::<f32>()
    }

    /// Bytes of slab currently backing at least one reference.
    pub fn resident_bytes(&self) -> usize {
        (self.capacity_pages() - self.free_pages()) * self.page_bytes()
    }

    /// Resident pages carrying `owner`'s tag.
    pub fn owned_pages(&self, owner: u32) -> usize {
        self.refcnt
            .iter()
            .zip(&self.owner)
            .filter(|&(&rc, &o)| rc > 0 && o == owner)
            .count()
    }

    /// Take a free page (refcount 1) tagged with `owner`.
    fn acquire(&mut self, owner: u32) -> Option<u32> {
        let pg = self.free.pop()?;
        debug_assert_eq!(self.refcnt[pg as usize], 0);
        self.refcnt[pg as usize] = 1;
        self.owner[pg as usize] = owner;
        self.stats.note_resident(self.resident_bytes());
        Some(pg)
    }

    /// Add a reference to a resident page (prefix share / index retain).
    fn retain(&mut self, pg: u32) {
        debug_assert!(self.refcnt[pg as usize] > 0);
        self.refcnt[pg as usize] += 1;
    }

    /// Drop a reference; the page returns to the free list when the
    /// last reference goes. No zeroing: writes always precede reads
    /// (decode overwrites position `p` before attending over `0..=p`),
    /// and gathers copy only live spans.
    fn release(&mut self, pg: u32) {
        let rc = &mut self.refcnt[pg as usize];
        debug_assert!(*rc > 0);
        *rc -= 1;
        if *rc == 0 {
            self.free.push(pg);
            self.stats.note_resident(self.resident_bytes());
        }
    }

    #[inline]
    fn offset(&self, pg: u32, kb: usize, kv: usize, slot: usize) -> usize {
        debug_assert!(kb < self.blocks && kv < 2 && slot < self.page_tokens);
        pg as usize * self.page_floats
            + ((kb * 2 + kv) * self.page_tokens + slot) * self.dim
    }

    /// Fork `src` into `dst`: copy the whole page (every block, K and
    /// V) within the slab — allocation-free.
    fn copy_page(&mut self, src: u32, dst: u32) {
        let (s, d) = (
            src as usize * self.page_floats,
            dst as usize * self.page_floats,
        );
        self.data.copy_within(s..s + self.page_floats, d);
    }
}

/// One live request row's view into the pool.
#[derive(Default)]
struct RowTable {
    /// Page ids covering positions `[i*P, (i+1)*P)`; capacity is fixed
    /// at `ceil(seq / P)` from construction so pushes never allocate.
    pages: Vec<u32>,
    /// Filled positions (high-water mark).
    len: usize,
    /// Pages reserved at admission but not yet acquired; decode-time
    /// acquisition draws these down and is therefore infallible.
    reserved: usize,
    owner: u32,
    admitted: bool,
}

/// Per-owner chain-hash index from full prompt pages to pool pages.
/// Each entry retains its page (one index reference), stores the
/// **entire prefix token string** for the mandatory compare-on-hit, and
/// carries an LRU stamp for eviction when a reservation needs room.
#[derive(Default)]
struct PrefixIndex {
    map: HashMap<(u32, u64), PrefixEntry>,
    clock: u64,
}

struct PrefixEntry {
    tokens: Vec<i32>,
    page: u32,
    stamp: u64,
}

impl PrefixIndex {
    /// Hash hit + full token compare; a hit refreshes the LRU stamp.
    fn lookup(&mut self, owner: u32, hash: u64, prefix: &[i32]) -> Option<u32> {
        let e = self.map.get_mut(&(owner, hash))?;
        if e.tokens.as_slice() != prefix {
            return None; // hash collision: never share on hash alone
        }
        self.clock += 1;
        e.stamp = self.clock;
        Some(e.page)
    }

    fn contains(&self, owner: u32, hash: u64) -> bool {
        self.map.contains_key(&(owner, hash))
    }

    fn insert(&mut self, owner: u32, hash: u64, tokens: Vec<i32>, page: u32) {
        self.clock += 1;
        let stamp = self.clock;
        self.map.insert((owner, hash), PrefixEntry { tokens, page, stamp });
    }

    /// Remove the least-recently-used entry, returning its page.
    fn evict_lru(&mut self) -> Option<u32> {
        let key = *self
            .map
            .iter()
            .min_by_key(|(_, e)| e.stamp)
            .map(|(k, _)| k)?;
        self.map.remove(&key).map(|e| e.page)
    }
}

/// Paged replacement for the fixed-window
/// [`KvCache`](super::transformer::KvCache): a [`PagePool`] plus one
/// page table per batch row. The transformer's paged path reads and
/// writes K/V through [`Self::k_at`]/[`Self::write_kv`]; the serving
/// layer drives the row lifecycle through
/// [`Self::admit_row`]/[`Self::register_prefix`]/[`Self::release_row`].
pub struct PagedKvCache {
    pub bsz: usize,
    pub seq: usize,
    /// Hidden width of the cached projections (MHA: K/V rows == Q rows).
    pub dim: usize,
    page_tokens: usize,
    pool: PagePool,
    rows: Vec<RowTable>,
    /// Total pages promised to admitted rows but not yet acquired.
    reserved_unacquired: usize,
    /// Prefix sharing enabled (the cold bench arm turns it off).
    share: bool,
    prefix: PrefixIndex,
    stats: Arc<KvStats>,
    /// Sinusoidal position table (seq, hidden) — same values the
    /// fixed-window cache and the training forward derive.
    pos: Vec<f32>,
}

impl PagedKvCache {
    /// Worst-case pages one row can touch: `ceil(seq / page_tokens)`.
    pub fn pages_per_row(cfg: &ModelCfg, page_tokens: usize) -> usize {
        cfg.seq.div_ceil(page_tokens)
    }

    pub fn new(
        cfg: &ModelCfg,
        bsz: usize,
        page_tokens: usize,
        capacity_pages: usize,
    ) -> PagedKvCache {
        assert_eq!(
            cfg.kv_heads, cfg.heads,
            "host KV cache assumes MHA (kv_heads == heads)"
        );
        assert_eq!(
            cfg.heads * cfg.head_dim(),
            cfg.hidden,
            "host KV-cached inference assumes heads * head_dim == hidden"
        );
        let page_tokens = page_tokens.clamp(1, cfg.seq);
        let stats = Arc::new(KvStats::default());
        let per_row = cfg.seq.div_ceil(page_tokens);
        let rows = (0..bsz)
            .map(|_| RowTable {
                pages: Vec::with_capacity(per_row),
                ..RowTable::default()
            })
            .collect();
        PagedKvCache {
            bsz,
            seq: cfg.seq,
            dim: cfg.hidden,
            page_tokens,
            pool: PagePool::new(
                cfg.blocks,
                cfg.hidden,
                page_tokens,
                capacity_pages,
                Arc::clone(&stats),
            ),
            rows,
            reserved_unacquired: 0,
            share: true,
            prefix: PrefixIndex::default(),
            stats,
            pos: super::transformer::sinusoid(cfg.seq, cfg.hidden),
        }
    }

    /// Disable prefix sharing (admission never maps existing pages and
    /// prefill never registers them) — the cold comparison arm.
    pub fn without_sharing(mut self) -> PagedKvCache {
        self.share = false;
        self
    }

    /// Report into an externally-owned stats probe instead of the
    /// internal one (lets servers and benches observe the pool from
    /// outside the engine's worker thread). Call before any admission.
    pub fn with_stats(mut self, stats: Arc<KvStats>) -> PagedKvCache {
        debug_assert_eq!(self.pool.resident_bytes(), 0);
        self.pool.stats = Arc::clone(&stats);
        self.stats = stats;
        self
    }

    /// The shared stats handle (clone to observe from outside).
    pub fn stats(&self) -> Arc<KvStats> {
        Arc::clone(&self.stats)
    }

    /// Count `m` positions as computed (the paged model path calls this;
    /// the warm-prefill tests read it to prove shared positions were
    /// skipped, not recomputed).
    pub fn note_computed(&self, m: usize) {
        self.stats.note_computed(m);
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn resident_bytes(&self) -> usize {
        self.pool.resident_bytes()
    }

    pub fn free_pages(&self) -> usize {
        self.pool.free_pages()
    }

    pub fn capacity_pages(&self) -> usize {
        self.pool.capacity_pages()
    }

    /// Resident bytes carrying `owner`'s tag (per-tenant ledger charge).
    pub fn owner_bytes(&self, owner: u32) -> usize {
        self.pool.owned_pages(owner) * self.pool.page_bytes()
    }

    /// Position-embedding row `p` (the sinusoid table slice).
    pub fn pos_row(&self, p: usize) -> &[f32] {
        &self.pos[p * self.dim..(p + 1) * self.dim]
    }

    /// Filled positions of `row`.
    pub fn row_len(&self, row: usize) -> usize {
        self.rows[row].len
    }

    /// Free pages not yet promised to an admitted row.
    fn avail(&self) -> usize {
        self.pool.free_pages() - self.reserved_unacquired
    }

    /// Admit `row` with `prompt`, reserving its worst-case page count
    /// and mapping any shared prefix pages. Returns the first position
    /// prefill must compute (`0` = cold, `s` = positions `0..s` are
    /// already cached via sharing), or `None` when the pool cannot
    /// cover the reservation even after evicting stale prefix
    /// retentions — the caller keeps the request queued and retries;
    /// nothing is held on failure.
    ///
    /// The shared length is capped at `prompt.len() - 1` so at least
    /// the last prompt position is always computed (its logits seed
    /// decoding).
    pub fn admit_row(
        &mut self,
        row: usize,
        prompt: &[i32],
        owner: u32,
    ) -> Option<usize> {
        let p = self.page_tokens;
        let rt = &mut self.rows[row];
        assert!(
            !rt.admitted && rt.pages.is_empty(),
            "row {row} admitted twice without release"
        );
        debug_assert!(!prompt.is_empty() && prompt.len() <= self.seq);

        // 1. walk the chain hash over the prompt's full pages, collecting
        //    matched pages (token-compared, not just hash-matched)
        let mut matched = 0usize;
        if self.share {
            let mut h = PREFIX_HASH_SEED;
            for i in 0..prompt.len() / p {
                h = chain_hash(h, &prompt[i * p..(i + 1) * p]);
                match self.prefix.lookup(owner, h, &prompt[..(i + 1) * p]) {
                    Some(pg) => {
                        rt.pages.push(pg);
                        matched = i + 1;
                    }
                    None => break,
                }
            }
        }
        let shared = if matched == 0 {
            0
        } else {
            (matched * p).min(prompt.len() - 1)
        };
        // pages actually mapped: those covering positions 0..shared
        rt.pages.truncate(shared.div_ceil(p));
        // map = retain NOW, before any prefix eviction below could free
        // a page we are counting on
        for i in 0..rt.pages.len() {
            self.pool.retain(rt.pages[i]);
        }

        // 2. reserve the worst case: every window page except the shared
        //    pages this row will never write (a partially-shared boundary
        //    page still counts — writing it costs a COW fork page)
        let needed = self.seq.div_ceil(p) - shared / p;
        while self.avail() < needed {
            let Some(pg) = self.prefix.evict_lru() else { break };
            self.pool.release(pg);
        }
        if self.avail() < needed {
            // roll back: drop the mapped shares, hold nothing
            let rt = &mut self.rows[row];
            while let Some(pg) = rt.pages.pop() {
                self.pool.release(pg);
            }
            return None;
        }

        self.reserved_unacquired += needed;
        let rt = &mut self.rows[row];
        rt.reserved = needed;
        rt.len = shared;
        rt.owner = owner;
        rt.admitted = true;
        self.stats
            .shared_positions
            .fetch_add(shared as u64, Ordering::SeqCst);
        Some(shared)
    }

    /// Publish `row`'s freshly prefilled full prompt pages into the
    /// prefix index so later admissions of the same prefix can share
    /// them. Re-registering an identical prompt is a no-op (hash hit +
    /// equal tokens), keeping the steady state allocation-free. Pages
    /// already shared *from* the index (or COW forks of them) hash-hit
    /// their existing entries and are skipped too.
    pub fn register_prefix(&mut self, row: usize, prompt: &[i32]) {
        if !self.share {
            return;
        }
        let p = self.page_tokens;
        let rt = &self.rows[row];
        debug_assert!(rt.admitted && rt.len >= prompt.len());
        let owner = rt.owner;
        let mut h = PREFIX_HASH_SEED;
        for i in 0..prompt.len() / p {
            h = chain_hash(h, &prompt[i * p..(i + 1) * p]);
            if self.prefix.contains(owner, h) {
                // identical prefix already published (or a collision —
                // first writer wins; lookups compare tokens anyway, and
                // deeper levels of a broken chain could never be walked)
                continue;
            }
            let pg = self.rows[row].pages[i];
            self.pool.retain(pg);
            self.prefix.insert(owner, h, prompt[..(i + 1) * p].to_vec(), pg);
        }
    }

    /// Release every page reference `row` holds and return its unused
    /// reservation; the row can be admitted again afterwards. Idempotent
    /// (releasing a never-admitted row is a no-op), so cancel/deadline
    /// sweeps can call it unconditionally. Pages also retained by the
    /// prefix index or other rows stay resident; the rest return to the
    /// free list — after a cancel storm the pool is back at its
    /// prefix-retention baseline.
    pub fn release_row(&mut self, row: usize) {
        let rt = &mut self.rows[row];
        if !rt.admitted {
            return;
        }
        self.reserved_unacquired -= rt.reserved;
        rt.reserved = 0;
        rt.len = 0;
        rt.admitted = false;
        while let Some(pg) = rt.pages.pop() {
            self.pool.release(pg);
        }
    }

    /// Make position `pos` of `row` writable: acquire the next page at
    /// a page boundary, or fork a shared page before the first write
    /// into it (copy-on-write). Draws on the admission reservation, so
    /// it cannot fail mid-decode.
    pub fn prepare_write(&mut self, row: usize, pos: usize) {
        let p = self.page_tokens;
        let pi = pos / p;
        let rt = &mut self.rows[row];
        debug_assert!(rt.admitted && pos < self.seq);
        debug_assert!(pi <= rt.pages.len(), "non-contiguous page write");
        if pi == rt.pages.len() {
            debug_assert!(rt.reserved > 0, "write past the admission reservation");
            let owner = rt.owner;
            let pg = self
                .pool
                .acquire(owner)
                .expect("reservation guarantees a free page");
            let rt = &mut self.rows[row];
            rt.pages.push(pg);
            rt.reserved -= 1;
            self.reserved_unacquired -= 1;
        } else if self.pool.refcnt[rt.pages[pi] as usize] > 1 {
            // first write into a partially-shared page: fork a private
            // copy so sharers keep seeing the original bits
            debug_assert!(rt.reserved > 0, "write past the admission reservation");
            let (owner, old) = (rt.owner, rt.pages[pi]);
            let fresh = self
                .pool
                .acquire(owner)
                .expect("reservation guarantees a free page");
            self.pool.copy_page(old, fresh);
            self.pool.release(old);
            let rt = &mut self.rows[row];
            rt.pages[pi] = fresh;
            rt.reserved -= 1;
            self.reserved_unacquired -= 1;
            self.stats.cow_forks.fetch_add(1, Ordering::SeqCst);
        }
        let rt = &mut self.rows[row];
        rt.len = rt.len.max(pos + 1);
    }

    /// Block `kb`'s cached K at `(row, pos)` — one `dim`-wide slice read
    /// through the page table.
    #[inline]
    pub fn k_at(&self, row: usize, kb: usize, pos: usize) -> &[f32] {
        let rt = &self.rows[row];
        debug_assert!(pos < rt.len, "read of an unwritten position");
        let off = self.pool.offset(
            rt.pages[pos / self.page_tokens],
            kb,
            0,
            pos % self.page_tokens,
        );
        &self.pool.data[off..off + self.dim]
    }

    /// Block `kb`'s cached V at `(row, pos)`.
    #[inline]
    pub fn v_at(&self, row: usize, kb: usize, pos: usize) -> &[f32] {
        let rt = &self.rows[row];
        debug_assert!(pos < rt.len, "read of an unwritten position");
        let off = self.pool.offset(
            rt.pages[pos / self.page_tokens],
            kb,
            1,
            pos % self.page_tokens,
        );
        &self.pool.data[off..off + self.dim]
    }

    /// Write block `kb`'s K and V rows at `(row, pos)`. The page must
    /// have been made writable by [`Self::prepare_write`] first.
    pub fn write_kv(&mut self, row: usize, kb: usize, pos: usize, k: &[f32], v: &[f32]) {
        let rt = &self.rows[row];
        debug_assert!(pos < rt.len);
        let pg = rt.pages[pos / self.page_tokens];
        debug_assert!(
            self.pool.refcnt[pg as usize] == 1,
            "write into a still-shared page (prepare_write not called?)"
        );
        let slot = pos % self.page_tokens;
        let ko = self.pool.offset(pg, kb, 0, slot);
        self.pool.data[ko..ko + self.dim].copy_from_slice(k);
        let vo = self.pool.offset(pg, kb, 1, slot);
        self.pool.data[vo..vo + self.dim].copy_from_slice(v);
    }

    /// Test hook: plant a prefix-index entry under an arbitrary hash
    /// (backed by a real acquired page) to force a hash collision.
    #[cfg(test)]
    pub(crate) fn insert_prefix_raw(&mut self, owner: u32, hash: u64, tokens: Vec<i32>) {
        let pg = self.pool.acquire(owner).expect("pool full in test");
        self.prefix.insert(owner, hash, tokens, pg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn micro() -> ModelCfg {
        let mut cfg = presets::tiny();
        cfg.blocks = 2;
        cfg.hidden = 8;
        cfg.heads = 2;
        cfg.kv_heads = 2;
        cfg.seq = 8;
        cfg
    }

    /// Fill positions `0..n` of `row` with a per-position marker.
    fn fill(cache: &mut PagedKvCache, row: usize, n: usize, tag: f32) {
        let d = cache.dim;
        for pos in 0..n {
            cache.prepare_write(row, pos);
            for kb in 0..2 {
                let val = tag + pos as f32 + kb as f32 * 0.25;
                cache.write_kv(row, kb, pos, &vec![val; d], &vec![-val; d]);
            }
        }
    }

    #[test]
    fn pool_acquire_release_roundtrip_tracks_resident_bytes() {
        let stats = Arc::new(KvStats::default());
        let mut pool = PagePool::new(2, 8, 4, 3, Arc::clone(&stats));
        assert_eq!(pool.free_pages(), 3);
        assert_eq!(pool.resident_bytes(), 0);
        let a = pool.acquire(7).unwrap();
        let b = pool.acquire(7).unwrap();
        assert_eq!(pool.free_pages(), 1);
        assert_eq!(pool.resident_bytes(), 2 * pool.page_bytes());
        assert_eq!(stats.resident_bytes(), 2 * pool.page_bytes());
        assert_eq!(pool.owned_pages(7), 2);
        pool.retain(a);
        pool.release(a); // still referenced
        assert_eq!(pool.free_pages(), 1);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.free_pages(), 3);
        assert_eq!(pool.resident_bytes(), 0);
        assert_eq!(stats.resident_bytes(), 0);
        assert_eq!(stats.peak_resident_bytes(), 2 * pool.page_bytes());
        let c = pool.acquire(1).unwrap();
        assert!((c as usize) < 3);
    }

    #[test]
    fn admission_reserves_worst_case_and_declines_when_full() {
        let cfg = micro(); // seq 8
        // 2 pages per row, pool of 3: one full row + one page of slack
        let mut cache = PagedKvCache::new(&cfg, 2, 4, 3);
        assert_eq!(cache.admit_row(0, &[1, 2, 3], 0), Some(0));
        // row 0 acquired nothing yet, but its 2-page reservation stands:
        // a second 2-page admission cannot be covered by the 1 free page
        assert_eq!(cache.admit_row(1, &[4, 5], 0), None);
        let r1 = &cache.rows[1];
        assert!(!r1.admitted && r1.pages.is_empty() && r1.reserved == 0);
        // a release returns the reservation and admission succeeds
        cache.release_row(0);
        assert_eq!(cache.admit_row(1, &[4, 5], 0), Some(0));
    }

    #[test]
    fn cancel_storm_returns_pool_to_baseline() {
        let cfg = micro();
        let mut cache = PagedKvCache::new(&cfg, 4, 4, 8).without_sharing();
        for storm in 0..10 {
            for row in 0..4 {
                assert_eq!(cache.admit_row(row, &[1, 2, 3, 4, 5], 0), Some(0));
                // partial fill: mid-decode cancellation leaves pages
                // acquired and reservation partly drawn
                fill(&mut cache, row, 3 + row, storm as f32);
            }
            assert!(cache.resident_bytes() > 0);
            for row in 0..4 {
                cache.release_row(row);
            }
            assert_eq!(cache.resident_bytes(), 0, "leaked pages after storm");
            assert_eq!(cache.free_pages(), cache.capacity_pages());
            assert_eq!(cache.reserved_unacquired, 0);
        }
    }

    #[test]
    fn prefix_sharing_maps_pages_and_caps_at_last_position() {
        let cfg = micro();
        let mut cache = PagedKvCache::new(&cfg, 3, 2, 12);
        let prompt = [10, 11, 12, 13, 14]; // 2 full pages + 1 slot
        assert_eq!(cache.admit_row(0, &prompt, 0), Some(0));
        fill(&mut cache, 0, 5, 100.0);
        cache.register_prefix(0, &prompt);
        let baseline = cache.resident_bytes();

        // same prompt, longer tail: shares both full pages
        let longer = [10, 11, 12, 13, 14, 15, 16];
        let shared = cache.admit_row(1, &longer, 0).unwrap();
        assert_eq!(shared, 4);
        assert_eq!(cache.rows[1].pages.len(), 2);
        assert_eq!(cache.rows[1].pages[..2], cache.rows[0].pages[..2]);
        // mapping bumped refcounts, not pages: nothing new resident
        assert_eq!(cache.resident_bytes(), baseline);
        // shared reads see row 0's bits
        assert_eq!(cache.k_at(1, 0, 2), cache.k_at(0, 0, 2));

        // identical prompt: shared capped at prompt_len - 1 so the last
        // position is still computed
        let shared = cache.admit_row(2, &prompt, 0).unwrap();
        assert_eq!(shared, 4);

        // a different owner never shares
        cache.release_row(2);
        assert_eq!(cache.admit_row(2, &prompt, 9), Some(0));
        assert_eq!(cache.stats().shared_positions(), 8);
    }

    #[test]
    fn cow_fork_on_write_into_partially_shared_page() {
        let cfg = micro();
        let mut cache = PagedKvCache::new(&cfg, 2, 2, 10);
        let prompt = [20, 21, 22, 23]; // exactly 2 full pages
        cache.admit_row(0, &prompt, 0).unwrap();
        fill(&mut cache, 0, 4, 0.0);
        cache.register_prefix(0, &prompt);

        // identical prompt: shared = 3, boundary page (positions 2..4)
        // is mapped shared and will be written at position 3
        let shared = cache.admit_row(1, &prompt, 0).unwrap();
        assert_eq!(shared, 3);
        let shared_page = cache.rows[1].pages[1];
        assert_eq!(shared_page, cache.rows[0].pages[1]);

        cache.prepare_write(1, 3);
        assert_eq!(cache.stats().cow_forks(), 1);
        let forked = cache.rows[1].pages[1];
        assert_ne!(forked, shared_page, "write went into the shared page");
        // the fork carried the shared bits at the untouched position 2
        assert_eq!(cache.k_at(1, 0, 2), cache.k_at(0, 0, 2).to_vec());
        // a divergent write is invisible to the original row
        let d = cache.dim;
        cache.write_kv(1, 0, 3, &vec![77.0; d], &vec![-77.0; d]);
        assert_eq!(cache.k_at(0, 0, 3), vec![3.0_f32; d]);
        assert_eq!(cache.k_at(1, 0, 3), vec![77.0; d]);
        // page 0 (fully shared, never written) is still shared
        assert_eq!(cache.rows[1].pages[0], cache.rows[0].pages[0]);
    }

    #[test]
    fn prefix_hash_collision_rejected_by_token_compare() {
        let cfg = micro();
        let mut cache = PagedKvCache::new(&cfg, 1, 2, 8);
        let prompt = [30, 31, 32];
        // plant an entry under the exact chain hash of prompt's first
        // page but with different tokens — a forced collision
        let h = chain_hash(PREFIX_HASH_SEED, &prompt[..2]);
        cache.insert_prefix_raw(0, h, vec![99, 98]);
        // admission must refuse to share: token compare fails
        assert_eq!(cache.admit_row(0, &prompt, 0), Some(0));
        assert_eq!(cache.stats().shared_positions(), 0);
    }

    #[test]
    fn stale_prefix_retentions_evicted_to_cover_reservation() {
        let cfg = micro(); // seq 8, P=4 -> 2 pages per row
        let mut cache = PagedKvCache::new(&cfg, 2, 4, 4);
        let prompt = [1, 2, 3, 4, 5, 6, 7];
        cache.admit_row(0, &prompt, 0).unwrap();
        fill(&mut cache, 0, 7, 0.0);
        cache.register_prefix(0, &prompt);
        cache.release_row(0);
        // the index retains 1 full page; 2 rows of cold admissions need
        // all 4 pages -> the retention must be evicted, not block
        assert_eq!(cache.resident_bytes(), cache.pool.page_bytes());
        let a = cache.admit_row(0, &[9, 9, 9, 9, 9, 9], 1).unwrap();
        let b = cache.admit_row(1, &[8, 8, 8, 8, 8, 8], 1).unwrap();
        assert_eq!((a, b), (0, 0));
        fill(&mut cache, 0, 6, 1.0);
        fill(&mut cache, 1, 6, 2.0);
        assert_eq!(cache.free_pages(), 0);
    }

    #[test]
    fn owner_bytes_partition_the_pool() {
        let cfg = micro();
        let mut cache = PagedKvCache::new(&cfg, 4, 4, 8);
        cache.admit_row(0, &[1, 2, 3, 4, 5], 3).unwrap();
        fill(&mut cache, 0, 5, 0.0);
        cache.admit_row(1, &[6, 7], 4).unwrap();
        fill(&mut cache, 1, 2, 0.0);
        assert_eq!(
            cache.owner_bytes(3) + cache.owner_bytes(4),
            cache.resident_bytes()
        );
    }
}
