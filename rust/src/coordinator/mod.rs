//! Multi-tenant adapter-serving coordinator — the deployable system around
//! the paper's contribution (the intro scenario: thousands of customized
//! models served concurrently, where LoRA state alone would occupy TBs and
//! MoS shrinks it ~8×).
//!
//! Pipeline: requests enter through [`Server::submit`] with per-request
//! [`GenOptions`], pass admission control into the [`batcher`] keyed by
//! tenant; worker threads pull batches round-robin — stepping engines mix
//! tenants up to capacity (`pop_batch(mix)`, PR 7), grouping the batch
//! into per-tenant [`EngineRun`]s — fetch each request's serving adapter
//! through the version-keyed two-tier [`cache`] (pooled zero-copy shard
//! views by default; dense materialized factors behind
//! `MOS_SERVE_DENSE=1` — index-based routing makes even that a
//! *precompute*, paper Limitations §C), and run a continuously batched,
//! KV-cached decode loop: one single-position step per generated token,
//! newly queued requests admitted into freed slots between steps
//! ([`Batcher::try_fill_any`]), each token streamed through the request's
//! [`server::ResponseHandle`] before it resolves with a typed `Result`.
//! KV residency runs on the paged pool
//! ([`crate::model::paged::PagedKvCache`]): refcounted pages with
//! copy-on-write prefix sharing, reservation-based admission that
//! degrades to queueing when the pool is full, and measured per-tenant
//! bytes synced into the ledger's KV side-table. The [`registry`] owns
//! versioned tenant state built from [`TenantSpec`]s, the [`memory`]
//! ledger enforces an accelerator-memory budget with LRU eviction
//! charging the bytes each serve mode actually keeps resident (eviction
//! invalidates the adapter cache through [`Registry::set_evict_hook`]),
//! and [`metrics`] records latency/TTFT/throughput/rejections.
//!
//! See DESIGN.md §Serving API for the request lifecycle and the migration
//! notes from the pre-redesign `submit(&str, &str) -> Receiver` surface.

pub mod batcher;
pub mod cache;
pub mod memory;
pub mod metrics;
pub mod registry;
pub mod server;

pub use batcher::{
    Admission, Batcher, Request, RequestId, Response, ServeError, ServeResult,
};
pub use cache::{AdapterCache, TenantFactors};
pub use memory::MemoryLedger;
pub use metrics::{Metrics, TenantCounters};
pub use registry::{QosSpec, Registry, Tenant, TenantSpec};
pub use server::{
    EngineRun, FullWindowEngine, HostEngine, ResponseHandle, ServeEngine,
    Server, ServerCfg,
};

// the serving KV-residency probe lives with the paged cache; re-export it
// so servers/benches observing pool bytes import from one place
pub use crate::model::paged::KvStats;

// the per-request options live next to the decoder; re-export them here so
// serving callers import everything from one place
pub use crate::eval::GenOptions;
