//! Multi-tenant adapter-serving coordinator — the deployable system around
//! the paper's contribution (the intro scenario: thousands of customized
//! models served concurrently, where LoRA state alone would occupy TBs and
//! MoS shrinks it ~8×).
//!
//! Pipeline: requests enter the [`batcher`] keyed by tenant; worker threads
//! pull per-tenant batches, materialize the tenant's low-rank factors
//! through the [`cache`] (index-based routing makes this a *precompute*,
//! paper Limitations §C), run batched greedy decoding, and respond.
//! The [`registry`] owns tenant state and the [`memory`] ledger enforces
//! an accelerator-memory budget with LRU eviction; [`metrics`] records
//! latency/throughput.

pub mod batcher;
pub mod cache;
pub mod memory;
pub mod metrics;
pub mod registry;
pub mod server;

pub use batcher::{Batcher, Request, Response};
pub use memory::MemoryLedger;
pub use metrics::Metrics;
pub use registry::{Registry, Tenant};
pub use server::Server;
