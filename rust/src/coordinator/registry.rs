//! Tenant registry: per-tenant adapter state (method config, trainable
//! params, router state) plus memory accounting via the ledger.
//!
//! Low-cost switching (paper Sec. 3.6): swapping tenants swaps only the
//! adapter tensors — the frozen base is shared by everyone.
//!
//! Tenants are built from a [`TenantSpec`] (fresh synthetic adapter or a
//! trained checkpoint) and carry a registry-assigned `version` that bumps
//! on every re-register, so downstream caches can key on `(id, version)`
//! and never serve stale factors.

use super::memory::MemoryLedger;
use crate::adapter;
use crate::config::{MethodCfg, ModelCfg};
use crate::train::checkpoint::Checkpoint;
use crate::util::bank::Bank;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

/// One customized model. Params and aux live behind `Arc`s so the pooled
/// serving representation ([`crate::adapter::PooledAdapter`]) can alias the
/// registry's tensors zero-copy instead of materializing its own.
#[derive(Debug, Clone)]
pub struct Tenant {
    pub id: String,
    pub mc: MethodCfg,
    pub params: Arc<Bank>,
    pub aux: Arc<Bank>,
    pub router_seed: u64,
    /// Assigned by [`Registry::register`]; bumps on re-register. Factor
    /// caches key on `(id, version)`.
    pub version: u64,
}

impl Tenant {
    /// Actual bytes of this tenant's serving state (f32 host copy).
    pub fn actual_bytes(&self) -> usize {
        self.params.values().map(|t| t.nbytes()).sum::<usize>()
            + self.aux.values().map(|t| t.nbytes()).sum::<usize>()
    }
}

/// Per-tenant scheduling contract: a deficit-weighted-round-robin weight
/// plus an optional token-bucket rate limit. Carried on the [`TenantSpec`]
/// and plumbed to the batcher at registration (`Server::register`), so the
/// registry stays purely about adapter state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QosSpec {
    /// Relative share of scheduled tokens under contention (DWRR credit
    /// per scheduling round). Must be ≥ 1; default 1 = the old equal
    /// round-robin share.
    pub weight: u32,
    /// Token-bucket refill rate in scheduled tokens per second; `None`
    /// disables rate limiting (the default).
    pub rate_tok_per_s: Option<f64>,
    /// Bucket capacity in tokens — the largest burst the tenant can spend
    /// at once. Only meaningful with a rate; clamped up to cover at least
    /// one typical request so a limited tenant can always make progress.
    pub burst: f64,
}

impl Default for QosSpec {
    fn default() -> QosSpec {
        QosSpec { weight: 1, rate_tok_per_s: None, burst: 0.0 }
    }
}

/// Declarative tenant recipe — replaces the hand-assembled `Bank` + router
/// ritual every call site used to repeat. Build with one of the method
/// constructors (or from a checkpoint), then register through
/// [`super::Server::register`] or [`TenantSpec::build`].
///
/// ```ignore
/// server.register("alice", TenantSpec::mos(8, 2, 2, 1).seed(42))?;
/// server.register("bob", TenantSpec::lora(8).weight(4))?;
/// server.register("carol", TenantSpec::from_checkpoint(ckpt)
///     .rate_limit(500.0, 64.0))?;
/// ```
#[derive(Debug, Clone)]
pub struct TenantSpec {
    source: SpecSource,
    qos: QosSpec,
}

#[derive(Debug, Clone)]
enum SpecSource {
    /// Freshly initialized adapter of the given geometry and init seed.
    Fresh { mc: MethodCfg, seed: u64 },
    /// Trained adapter state loaded from a checkpoint.
    Checkpoint(Box<Checkpoint>),
}

impl TenantSpec {
    /// MoS adapter: rank `r`, `l` shards/vector, `e` budget factor,
    /// `private_rank` privatized rank slots.
    pub fn mos(r: usize, l: usize, e: usize, private_rank: usize) -> TenantSpec {
        TenantSpec::method(MethodCfg::mos(r, l, e, private_rank))
    }

    /// Plain LoRA adapter of rank `r` (the capacity baseline).
    pub fn lora(r: usize) -> TenantSpec {
        TenantSpec::method(MethodCfg::lora(r))
    }

    /// Any other adapter geometry.
    pub fn method(mc: MethodCfg) -> TenantSpec {
        TenantSpec {
            source: SpecSource::Fresh { mc, seed: 0 },
            qos: QosSpec::default(),
        }
    }

    /// A trained adapter (params + router state) from a checkpoint.
    pub fn from_checkpoint(ck: Checkpoint) -> TenantSpec {
        TenantSpec {
            source: SpecSource::Checkpoint(Box::new(ck)),
            qos: QosSpec::default(),
        }
    }

    /// Init seed for a fresh adapter (ignored for checkpoints, which carry
    /// their own router seed).
    pub fn seed(mut self, seed: u64) -> TenantSpec {
        if let SpecSource::Fresh { seed: s, .. } = &mut self.source {
            *s = seed;
        }
        self
    }

    /// DWRR weight (≥ 1): this tenant's relative share of scheduled
    /// tokens when the queue is contended.
    pub fn weight(mut self, weight: u32) -> TenantSpec {
        assert!(weight >= 1, "QoS weight must be >= 1");
        self.qos.weight = weight;
        self
    }

    /// Token-bucket rate limit: `tok_per_s` sustained scheduled tokens
    /// per second with up to `burst` tokens of headroom. A limited tenant
    /// is *deferred* in rotation while its bucket is dry, never errored.
    pub fn rate_limit(mut self, tok_per_s: f64, burst: f64) -> TenantSpec {
        assert!(tok_per_s > 0.0, "rate must be positive");
        self.qos.rate_tok_per_s = Some(tok_per_s);
        self.qos.burst = burst.max(1.0);
        self
    }

    /// The scheduling contract this spec will hand the batcher.
    pub fn qos(&self) -> QosSpec {
        self.qos
    }

    /// The adapter geometry this spec will register.
    pub fn method_cfg(&self) -> &MethodCfg {
        match &self.source {
            SpecSource::Fresh { mc, .. } => mc,
            SpecSource::Checkpoint(ck) => &ck.mc,
        }
    }

    /// Materialize the tenant state for `id` on the given base geometry.
    /// Version starts at 0; the registry assigns the real one.
    pub fn build(self, cfg: &ModelCfg, id: &str) -> Result<Tenant> {
        match self.source {
            SpecSource::Fresh { mc, seed } => {
                mc.validate(cfg)?;
                Ok(Tenant {
                    id: id.to_string(),
                    params: Arc::new(adapter::init_params(cfg, &mc, seed)),
                    aux: Arc::new(
                        adapter::mos::router::build_router(cfg, &mc, seed)
                            .into_bank(),
                    ),
                    mc,
                    router_seed: seed,
                    version: 0,
                })
            }
            SpecSource::Checkpoint(ck) => {
                ck.mc.validate(cfg)?;
                Ok(Tenant {
                    id: id.to_string(),
                    mc: ck.mc,
                    params: Arc::new(ck.params),
                    aux: Arc::new(ck.aux),
                    router_seed: ck.router_seed,
                    version: 0,
                })
            }
        }
    }
}

/// Thread-safe tenant registry with a memory budget.
pub struct Registry {
    pub cfg: ModelCfg,
    tenants: RwLock<HashMap<String, Arc<Tenant>>>,
    pub ledger: Mutex<MemoryLedger>,
    /// Persistent per-id version counters (survive remove/evict, so a
    /// re-registered tenant can never alias a stale cache entry).
    versions: Mutex<HashMap<String, u64>>,
    /// `true` = serve dense materialized factors (legacy path, forced by
    /// `MOS_SERVE_DENSE=1`); the ledger then charges materialized size.
    serve_dense: bool,
    /// `true` = quantize pooled MoS entries to int8 (`MOS_SERVE_INT8=1`);
    /// the ledger then charges codes + per-shard scales instead of f32
    /// pools. Ignored when `serve_dense` (dense stays the f32 oracle).
    serve_int8: bool,
    /// Called with each ledger-evicted tenant id while it is being dropped
    /// — the server wires this to `AdapterCache::invalidate` so "evicted"
    /// tenants cannot keep serving from the cache.
    evict_hook: Mutex<Option<Box<dyn Fn(&str) + Send + Sync>>>,
}

impl Registry {
    pub fn new(cfg: ModelCfg, capacity_bytes: usize) -> Registry {
        let dense = std::env::var("MOS_SERVE_DENSE")
            .map(|v| v == "1")
            .unwrap_or(false);
        let int8 = std::env::var("MOS_SERVE_INT8")
            .map(|v| v == "1")
            .unwrap_or(false);
        Registry::with_serve_mode(cfg, capacity_bytes, dense).with_int8(int8)
    }

    /// Like [`Registry::new`] with the serving representation pinned
    /// explicitly (tests/benches; `new` reads `MOS_SERVE_DENSE`).
    pub fn with_serve_mode(
        cfg: ModelCfg,
        capacity_bytes: usize,
        serve_dense: bool,
    ) -> Registry {
        Registry {
            cfg,
            tenants: RwLock::new(HashMap::new()),
            ledger: Mutex::new(MemoryLedger::new(capacity_bytes)),
            versions: Mutex::new(HashMap::new()),
            serve_dense,
            serve_int8: false,
            evict_hook: Mutex::new(None),
        }
    }

    /// Pin the int8 pooled tier explicitly (tests/benches; [`Registry::new`]
    /// reads `MOS_SERVE_INT8`). Must be applied before tenants register —
    /// the ledger charge is computed at admission.
    pub fn with_int8(mut self, int8: bool) -> Registry {
        self.serve_int8 = int8;
        self
    }

    /// Should tenants be served from dense materialized factors instead of
    /// the pooled zero-copy representation?
    pub fn serve_dense(&self) -> bool {
        self.serve_dense
    }

    /// Should pooled MoS tenants be served from int8-quantized shard pools?
    pub fn serve_int8(&self) -> bool {
        self.serve_int8
    }

    /// Install the eviction callback (replacing any previous one).
    pub fn set_evict_hook(&self, hook: impl Fn(&str) + Send + Sync + 'static) {
        *self.evict_hook.lock().unwrap() = Some(Box::new(hook));
    }

    /// Bytes the serving stack will actually keep resident for `tenant`
    /// under the current serve mode: the tenant's own tensors (pools +
    /// index tables — equal to `serving_bytes` for MoS) on the pooled
    /// path, the dense materialized factors when `serve_dense`.
    pub fn resident_bytes_for(&self, tenant: &Tenant) -> usize {
        use crate::config::LAYER_TYPES;
        use crate::config::Method;
        if self.serve_dense || tenant.mc.method != Method::MoS {
            // dense per-block factors: r x (i + o) f32 per block per type.
            // For non-MoS methods this equals the tenant's own tensors
            // except VeRA/Tied, whose dense expansion is what serving
            // holds — charge what will actually sit in memory.
            LAYER_TYPES
                .iter()
                .map(|t| {
                    let (o, i) = self.cfg.dims(t);
                    self.cfg.blocks * tenant.mc.r * (i + o) * 4
                })
                .sum()
        } else if self.serve_int8 {
            // int8 pooled tier: 1 byte per pool element + one f32 scale
            // per shard (shards = leading dim of each params tensor); aux
            // index/scale tables stay f32 and aliased. This is exactly
            // `QuantPooledAdapter::resident_bytes` — asserted in tests.
            tenant
                .params
                .values()
                .map(|t| t.len() + 4 * t.shape()[0])
                .sum::<usize>()
                + tenant.aux.values().map(|t| t.nbytes()).sum::<usize>()
        } else {
            tenant.actual_bytes()
        }
    }

    /// Register (or replace) a tenant; may evict LRU tenants to fit.
    /// Assigns the tenant's version (previous version + 1 on re-register,
    /// even across an intervening remove/evict). Returns the evicted
    /// tenant ids.
    pub fn register(&self, mut tenant: Tenant) -> Result<Vec<String>> {
        tenant.mc.validate(&self.cfg)?;
        // measured, not analytic: what this serve mode keeps resident
        let bytes = self.resident_bytes_for(&tenant);
        let mut ledger = self.ledger.lock().unwrap();
        let Some(evicted) = ledger.admit(&tenant.id, bytes) else {
            bail!(
                "tenant '{}' needs {bytes} B > capacity {} B",
                tenant.id,
                ledger.capacity
            );
        };
        drop(ledger);
        let mut map = self.tenants.write().unwrap();
        for id in &evicted {
            map.remove(id);
        }
        if !evicted.is_empty() {
            let hook = self.evict_hook.lock().unwrap();
            if let Some(hook) = hook.as_ref() {
                for id in &evicted {
                    hook(id);
                }
            }
        }
        // assign the version under the same write lock as the insert, so
        // concurrent re-registers of one id commit versions in map order
        // (lock order is always tenants -> versions; no other path nests)
        {
            let mut versions = self.versions.lock().unwrap();
            let v = versions
                .entry(tenant.id.clone())
                .and_modify(|v| *v += 1)
                .or_insert(0);
            tenant.version = *v;
        }
        map.insert(tenant.id.clone(), Arc::new(tenant));
        Ok(evicted)
    }

    /// Build a tenant from a spec against this registry's geometry, then
    /// register it.
    pub fn register_spec(&self, id: &str, spec: TenantSpec) -> Result<Vec<String>> {
        self.register(spec.build(&self.cfg, id)?)
    }

    pub fn get(&self, id: &str) -> Option<Arc<Tenant>> {
        let t = self.tenants.read().unwrap().get(id).cloned();
        if t.is_some() {
            self.ledger.lock().unwrap().touch(id);
        }
        t
    }

    pub fn remove(&self, id: &str) -> bool {
        let removed = self.tenants.write().unwrap().remove(id).is_some();
        if removed {
            self.ledger.lock().unwrap().release(id);
        }
        removed
    }

    pub fn len(&self) -> usize {
        self.tenants.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn ids(&self) -> Vec<String> {
        self.tenants.read().unwrap().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::params::serving_bytes;
    use crate::config::presets;

    fn mk_tenant(cfg: &ModelCfg, id: &str, seed: u64) -> Tenant {
        TenantSpec::mos(8, 2, 2, 1)
            .seed(seed)
            .build(cfg, id)
            .unwrap()
    }

    #[test]
    fn register_and_get() {
        let cfg = presets::tiny();
        let reg = Registry::new(cfg.clone(), 1 << 30);
        let t = mk_tenant(&cfg, "alice", 1);
        assert!(reg.register(t).unwrap().is_empty());
        assert!(reg.get("alice").is_some());
        assert!(reg.get("bob").is_none());
        assert_eq!(reg.len(), 1);
        assert!(reg.remove("alice"));
        assert!(reg.is_empty());
    }

    #[test]
    fn versions_bump_on_reregister() {
        let cfg = presets::tiny();
        let reg = Registry::new(cfg.clone(), 1 << 30);
        reg.register(mk_tenant(&cfg, "a", 1)).unwrap();
        assert_eq!(reg.get("a").unwrap().version, 0);
        reg.register(mk_tenant(&cfg, "a", 2)).unwrap();
        assert_eq!(reg.get("a").unwrap().version, 1);
        // version survives removal: a third registration must not reuse 0
        reg.remove("a");
        reg.register(mk_tenant(&cfg, "a", 3)).unwrap();
        assert_eq!(reg.get("a").unwrap().version, 2);
    }

    #[test]
    fn spec_builders_cover_methods() {
        let cfg = presets::tiny();
        let reg = Registry::new(cfg.clone(), 1 << 30);
        reg.register_spec("m", TenantSpec::mos(4, 2, 2, 0).seed(7))
            .unwrap();
        reg.register_spec("l", TenantSpec::lora(4)).unwrap();
        reg.register_spec("v", TenantSpec::method(MethodCfg::vera(4)))
            .unwrap();
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.get("m").unwrap().router_seed, 7);
        // fresh-spec determinism: same seed rebuilds identical router state
        let again = TenantSpec::mos(4, 2, 2, 0).seed(7).build(&cfg, "m").unwrap();
        assert_eq!(again.aux, reg.get("m").unwrap().aux);
    }

    #[test]
    fn capacity_evicts_lru() {
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let one = serving_bytes(&cfg, &mc, 4);
        let reg = Registry::new(cfg.clone(), 2 * one + one / 2);
        reg.register(mk_tenant(&cfg, "a", 1)).unwrap();
        reg.register(mk_tenant(&cfg, "b", 2)).unwrap();
        let _ = reg.get("a"); // touch a; b is LRU
        let evicted = reg.register(mk_tenant(&cfg, "c", 3)).unwrap();
        assert_eq!(evicted, vec!["b".to_string()]);
        assert!(reg.get("b").is_none());
        assert!(reg.get("a").is_some() && reg.get("c").is_some());
    }

    #[test]
    fn mos_budget_fits_8x_more_than_lora_r16() {
        // capacity sized for exactly 10 LoRA-r16 tenants fits ~80 MoS ones
        let cfg = presets::tiny();
        let lora = serving_bytes(&cfg, &MethodCfg::lora(16), 4);
        let reg = Registry::new(cfg.clone(), 10 * lora);
        let mut admitted = 0;
        for i in 0..200 {
            let t = mk_tenant(&cfg, &format!("t{i}"), i as u64);
            let evicted = reg.register(t).unwrap();
            if evicted.is_empty() {
                admitted += 1;
            } else {
                break;
            }
        }
        assert!(admitted >= 60, "only {admitted} MoS tenants fit");
    }

    #[test]
    fn ledger_charges_measured_resident_bytes() {
        // acceptance criterion: on the pooled path each tenant is charged
        // exactly the bytes its tensors keep resident (pools + index
        // tables), which for MoS equals the analytic `serving_bytes` —
        // the ledger's "8x more tenants" claim is measured, not asserted
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let reg = Registry::with_serve_mode(cfg.clone(), 1 << 30, false);
        reg.register(mk_tenant(&cfg, "a", 1)).unwrap();
        let t = reg.get("a").unwrap();
        assert_eq!(reg.ledger.lock().unwrap().used(), t.actual_bytes());
        assert_eq!(t.actual_bytes(), serving_bytes(&cfg, &mc, 4));

        // dense mode charges the materialized factors instead — ~8x more
        let dense = Registry::with_serve_mode(cfg.clone(), 1 << 30, true);
        assert!(dense.serve_dense());
        dense.register(mk_tenant(&cfg, "a", 1)).unwrap();
        let db = dense.ledger.lock().unwrap().used();
        let ratio = db as f64 / t.actual_bytes() as f64;
        assert!(ratio > 3.0, "dense/pooled byte ratio only {ratio:.1}");
    }

    #[test]
    fn int8_ledger_charge_matches_measured_quantized_bytes() {
        // the analytic int8 admission charge must equal what the cache's
        // quantized entry actually keeps resident — the ledger stays
        // measured under MOS_SERVE_INT8 exactly as it is for f32 pooled
        use crate::adapter::{PooledAdapter, QuantPooledAdapter};
        let cfg = presets::tiny();
        let reg =
            Registry::with_serve_mode(cfg.clone(), 1 << 30, false).with_int8(true);
        assert!(reg.serve_int8());
        reg.register(mk_tenant(&cfg, "a", 1)).unwrap();
        let t = reg.get("a").unwrap();
        let pooled = PooledAdapter::new(
            t.mc.clone(),
            Arc::clone(&t.params),
            Arc::clone(&t.aux),
        )
        .unwrap();
        let q = QuantPooledAdapter::quantize(&pooled);
        let charged = reg.ledger.lock().unwrap().used();
        assert_eq!(charged, q.resident_bytes());
        assert_eq!(charged, reg.resident_bytes_for(&t));
        // and the int8 charge sits well under the f32 pooled charge
        assert!(
            charged < t.actual_bytes(),
            "int8 charge {charged} B not below f32 {} B",
            t.actual_bytes()
        );
    }

    #[test]
    fn evict_hook_fires_for_each_victim() {
        // ledger eviction must reach downstream caches; the hook is the
        // wire (see Server::new)
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let one = serving_bytes(&cfg, &mc, 4);
        let reg = Registry::with_serve_mode(cfg.clone(), 2 * one + one / 2, false);
        let seen = std::sync::Arc::new(Mutex::new(Vec::<String>::new()));
        let seen2 = std::sync::Arc::clone(&seen);
        reg.set_evict_hook(move |id| seen2.lock().unwrap().push(id.to_string()));
        reg.register(mk_tenant(&cfg, "a", 1)).unwrap();
        reg.register(mk_tenant(&cfg, "b", 2)).unwrap();
        let _ = reg.get("a"); // touch a; b is LRU
        let evicted = reg.register(mk_tenant(&cfg, "c", 3)).unwrap();
        assert_eq!(evicted, vec!["b".to_string()]);
        assert_eq!(*seen.lock().unwrap(), vec!["b".to_string()]);
    }

    #[test]
    fn qos_builders_compose_with_method_builders() {
        let spec = TenantSpec::mos(4, 2, 2, 0)
            .seed(9)
            .weight(4)
            .rate_limit(100.0, 16.0);
        assert_eq!(spec.qos().weight, 4);
        assert_eq!(spec.qos().rate_tok_per_s, Some(100.0));
        assert_eq!(spec.qos().burst, 16.0);
        // defaults: weight 1, unlimited — the pre-QoS behavior
        assert_eq!(TenantSpec::lora(4).qos(), QosSpec::default());
        // qos does not disturb the built adapter state
        let cfg = presets::tiny();
        let a = TenantSpec::mos(4, 2, 2, 0).seed(9).build(&cfg, "t").unwrap();
        let b = spec.build(&cfg, "t").unwrap();
        assert_eq!(a.aux, b.aux);
    }

    #[test]
    fn rejects_invalid_method_for_geometry() {
        let cfg = presets::tiny();
        let reg = Registry::new(cfg.clone(), 1 << 30);
        let mut mc = MethodCfg::mos(8, 2, 2, 1);
        mc.l = 7; // doesn't divide dims
        assert!(reg.register_spec("bad", TenantSpec::method(mc)).is_err());
    }
}
