//! Tenant registry: per-tenant adapter state (method config, trainable
//! params, router state) plus memory accounting via the ledger.
//!
//! Low-cost switching (paper Sec. 3.6): swapping tenants swaps only the
//! adapter tensors — the frozen base is shared by everyone.

use super::memory::MemoryLedger;
use crate::adapter::params::serving_bytes;
use crate::config::{MethodCfg, ModelCfg};
use crate::train::checkpoint::Checkpoint;
use crate::util::bank::Bank;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

/// One customized model.
#[derive(Debug, Clone)]
pub struct Tenant {
    pub id: String,
    pub mc: MethodCfg,
    pub params: Bank,
    pub aux: Bank,
    pub router_seed: u64,
}

impl Tenant {
    pub fn from_checkpoint(id: &str, ck: Checkpoint) -> Tenant {
        Tenant {
            id: id.to_string(),
            mc: ck.mc,
            params: ck.params,
            aux: ck.aux,
            router_seed: ck.router_seed,
        }
    }

    /// Actual bytes of this tenant's serving state (f32 host copy).
    pub fn actual_bytes(&self) -> usize {
        self.params.values().map(|t| t.nbytes()).sum::<usize>()
            + self.aux.values().map(|t| t.nbytes()).sum::<usize>()
    }
}

/// Thread-safe tenant registry with a memory budget.
pub struct Registry {
    pub cfg: ModelCfg,
    tenants: RwLock<HashMap<String, Arc<Tenant>>>,
    pub ledger: Mutex<MemoryLedger>,
}

impl Registry {
    pub fn new(cfg: ModelCfg, capacity_bytes: usize) -> Registry {
        Registry {
            cfg,
            tenants: RwLock::new(HashMap::new()),
            ledger: Mutex::new(MemoryLedger::new(capacity_bytes)),
        }
    }

    /// Register (or replace) a tenant; may evict LRU tenants to fit.
    /// Returns the evicted tenant ids.
    pub fn register(&self, tenant: Tenant) -> Result<Vec<String>> {
        tenant.mc.validate(&self.cfg)?;
        // the analytic model (what a GPU deployment would allocate, fp32)
        let bytes = serving_bytes(&self.cfg, &tenant.mc, 4);
        let mut ledger = self.ledger.lock().unwrap();
        let Some(evicted) = ledger.admit(&tenant.id, bytes) else {
            bail!(
                "tenant '{}' needs {bytes} B > capacity {} B",
                tenant.id,
                ledger.capacity
            );
        };
        drop(ledger);
        let mut map = self.tenants.write().unwrap();
        for id in &evicted {
            map.remove(id);
        }
        map.insert(tenant.id.clone(), Arc::new(tenant));
        Ok(evicted)
    }

    pub fn get(&self, id: &str) -> Option<Arc<Tenant>> {
        let t = self.tenants.read().unwrap().get(id).cloned();
        if t.is_some() {
            self.ledger.lock().unwrap().touch(id);
        }
        t
    }

    pub fn remove(&self, id: &str) -> bool {
        let removed = self.tenants.write().unwrap().remove(id).is_some();
        if removed {
            self.ledger.lock().unwrap().release(id);
        }
        removed
    }

    pub fn len(&self) -> usize {
        self.tenants.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn ids(&self) -> Vec<String> {
        self.tenants.read().unwrap().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter;
    use crate::config::presets;

    fn mk_tenant(cfg: &ModelCfg, id: &str, seed: u64) -> Tenant {
        let mc = MethodCfg::mos(8, 2, 2, 1);
        Tenant {
            id: id.into(),
            mc: mc.clone(),
            params: adapter::init_params(cfg, &mc, seed),
            aux: adapter::mos::router::build_router(cfg, &mc, seed).into_bank(),
            router_seed: seed,
        }
    }

    #[test]
    fn register_and_get() {
        let cfg = presets::tiny();
        let reg = Registry::new(cfg.clone(), 1 << 30);
        let t = mk_tenant(&cfg, "alice", 1);
        assert!(reg.register(t).unwrap().is_empty());
        assert!(reg.get("alice").is_some());
        assert!(reg.get("bob").is_none());
        assert_eq!(reg.len(), 1);
        assert!(reg.remove("alice"));
        assert!(reg.is_empty());
    }

    #[test]
    fn capacity_evicts_lru() {
        let cfg = presets::tiny();
        let mc = MethodCfg::mos(8, 2, 2, 1);
        let one = serving_bytes(&cfg, &mc, 4);
        let reg = Registry::new(cfg.clone(), 2 * one + one / 2);
        reg.register(mk_tenant(&cfg, "a", 1)).unwrap();
        reg.register(mk_tenant(&cfg, "b", 2)).unwrap();
        let _ = reg.get("a"); // touch a; b is LRU
        let evicted = reg.register(mk_tenant(&cfg, "c", 3)).unwrap();
        assert_eq!(evicted, vec!["b".to_string()]);
        assert!(reg.get("b").is_none());
        assert!(reg.get("a").is_some() && reg.get("c").is_some());
    }

    #[test]
    fn mos_budget_fits_8x_more_than_lora_r16() {
        // capacity sized for exactly 10 LoRA-r16 tenants fits ~80 MoS ones
        let cfg = presets::tiny();
        let lora = serving_bytes(&cfg, &MethodCfg::lora(16), 4);
        let reg = Registry::new(cfg.clone(), 10 * lora);
        let mut admitted = 0;
        for i in 0..200 {
            let t = mk_tenant(&cfg, &format!("t{i}"), i as u64);
            let evicted = reg.register(t).unwrap();
            if evicted.is_empty() {
                admitted += 1;
            } else {
                break;
            }
        }
        assert!(admitted >= 60, "only {admitted} MoS tenants fit");
    }

    #[test]
    fn rejects_invalid_method_for_geometry() {
        let cfg = presets::tiny();
        let reg = Registry::new(cfg.clone(), 1 << 30);
        let mut t = mk_tenant(&cfg, "bad", 0);
        t.mc.l = 7; // doesn't divide dims
        assert!(reg.register(t).is_err());
    }
}
