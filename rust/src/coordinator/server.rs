//! The serving event loop: worker threads pull per-tenant batches from the
//! batcher, materialize factors through the cache, run batched decoding
//! with each request's [`GenOptions`], and deliver typed responses.
//! Engines are worker-owned (one PJRT executable or host model per
//! worker), so no engine needs to be `Sync`.
//!
//! Request lifecycle (see DESIGN.md §Serving API):
//! `submit(tenant, prompt, opts) -> Result<ResponseHandle, ServeError>`;
//! the handle resolves exactly once to `Result<Response, ServeError>` via
//! `wait` / `wait_timeout` / `try_wait`, and `cancel` drops the request
//! from the queue before it reaches an engine.

use super::batcher::{
    Admission, Batcher, Request, RequestId, Response, ServeError, ServeResult,
};
use super::cache::{MaterializeCache, TenantFactors};
use super::metrics::Metrics;
use super::registry::{Registry, Tenant, TenantSpec};
use crate::data::tokenizer::Tokenizer;
use crate::eval::{decode, GenOptions};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

/// A per-worker inference engine.
pub trait ServeEngine {
    /// Batched forward for one tenant: padded tokens (batch*seq) -> logits
    /// (batch*seq*vocab).
    fn forward(
        &mut self,
        tenant: &Tenant,
        factors: &TenantFactors,
        tokens: &[i32],
    ) -> Result<Vec<f32>>;
    /// (batch, seq, vocab)
    fn shape(&self) -> (usize, usize, usize);
}

/// Host-model serving engine: shared frozen base + cached tenant factors.
pub struct HostEngine {
    pub cfg: crate::config::ModelCfg,
    pub base: crate::util::bank::Bank,
}

impl HostEngine {
    pub fn new(cfg: crate::config::ModelCfg, seed: u64) -> HostEngine {
        let base = crate::model::transformer::init_base(&cfg, seed);
        HostEngine { cfg, base }
    }
}

impl ServeEngine for HostEngine {
    fn forward(
        &mut self,
        tenant: &Tenant,
        factors: &TenantFactors,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let (cache, _) = crate::model::transformer::forward(
            &self.cfg,
            &tenant.mc,
            &self.base,
            factors,
            tokens,
        );
        Ok(cache.logits)
    }

    fn shape(&self) -> (usize, usize, usize) {
        (self.cfg.batch, self.cfg.seq, self.cfg.vocab)
    }
}

/// Serving knobs, grouped so `Server::new` stays stable as knobs grow.
#[derive(Debug, Clone)]
pub struct ServerCfg {
    /// Per-tenant batch released at this size.
    pub max_batch: usize,
    /// ... or when the oldest queued request reaches this age.
    pub max_wait: Duration,
    /// Materialization-cache capacity (tenants).
    pub cache_capacity: usize,
    /// Queue-depth bounds; past them `submit` returns `QueueFull`.
    pub admission: Admission,
}

impl Default for ServerCfg {
    fn default() -> ServerCfg {
        ServerCfg {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            cache_capacity: 64,
            admission: Admission::default(),
        }
    }
}

/// Client-side handle for one submitted request. Resolves exactly once.
pub struct ResponseHandle {
    id: RequestId,
    tenant: String,
    rx: mpsc::Receiver<ServeResult>,
    cancelled: Arc<AtomicBool>,
}

impl ResponseHandle {
    pub fn id(&self) -> RequestId {
        self.id
    }

    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Ask the coordinator to drop this request. Queued requests never
    /// reach an engine (they resolve to `Err(Cancelled)`); a request
    /// already decoding completes normally.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    /// Block until the request resolves.
    pub fn wait(&self) -> ServeResult {
        self.rx.recv().unwrap_or(Err(ServeError::ShuttingDown))
    }

    /// Block up to `timeout`; `None` means still in flight.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<ServeResult> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Some(Err(ServeError::ShuttingDown))
            }
        }
    }

    /// Non-blocking poll; `None` means still in flight.
    pub fn try_wait(&self) -> Option<ServeResult> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(ServeError::ShuttingDown))
            }
        }
    }
}

/// The coordinator server.
pub struct Server {
    pub registry: Arc<Registry>,
    pub batcher: Arc<Batcher>,
    pub metrics: Arc<Metrics>,
    pub cache: Arc<MaterializeCache>,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Server {
    pub fn new(registry: Arc<Registry>, cfg: ServerCfg) -> Server {
        let metrics = Arc::new(Metrics::new());
        Server {
            registry,
            batcher: Arc::new(Batcher::new(
                cfg.max_batch,
                cfg.max_wait,
                cfg.admission,
                Arc::clone(&metrics),
            )),
            metrics,
            cache: Arc::new(MaterializeCache::new(cfg.cache_capacity)),
            workers: Vec::new(),
            next_id: AtomicU64::new(0),
        }
    }

    /// Spawn `n` workers, each owning an engine built by `factory`.
    pub fn start<F, E>(&mut self, n: usize, factory: F)
    where
        F: Fn(usize) -> E + Send + Sync + 'static,
        E: ServeEngine + 'static,
    {
        let factory = Arc::new(factory);
        for wid in 0..n {
            let registry = Arc::clone(&self.registry);
            let batcher = Arc::clone(&self.batcher);
            let metrics = Arc::clone(&self.metrics);
            let cache = Arc::clone(&self.cache);
            let factory = Arc::clone(&factory);
            self.workers.push(
                thread::Builder::new()
                    .name(format!("mos-serve-{wid}"))
                    .spawn(move || {
                        let mut engine = factory(wid);
                        while let Some((tenant_id, batch)) = batcher.pop_batch()
                        {
                            process_batch(
                                &registry, &metrics, &cache, &mut engine,
                                &tenant_id, batch,
                            );
                        }
                    })
                    .expect("spawn worker"),
            );
        }
    }

    /// Build a tenant from a spec and register it (replacing any previous
    /// registration under this id — the version bump makes the next
    /// factor lookup rebuild). Returns LRU-evicted tenant ids.
    pub fn register(&self, id: &str, spec: TenantSpec) -> Result<Vec<String>> {
        let evicted = self.registry.register_spec(id, spec)?;
        self.cache.invalidate(id);
        for e in &evicted {
            self.cache.invalidate(e);
        }
        Ok(evicted)
    }

    /// Drop a tenant and its cached factors. Queued requests for it
    /// resolve to `Err(UnknownTenant)` when a worker picks them up.
    pub fn remove(&self, id: &str) -> bool {
        let removed = self.registry.remove(id);
        if removed {
            self.cache.invalidate(id);
        }
        removed
    }

    /// Ids of all registered tenants.
    pub fn tenant_ids(&self) -> Vec<String> {
        self.registry.ids()
    }

    /// Materialize dense factors for every registered tenant ahead of
    /// traffic, fanning the per-tenant (and, inside, per-block) precompute
    /// out over the shared math pool. First requests then hit a warm
    /// cache instead of paying materialization latency. Returns the
    /// number of tenants warmed.
    pub fn prewarm(&self) -> usize {
        let tenants: Vec<Arc<Tenant>> = self
            .registry
            .ids()
            .iter()
            .filter_map(|id| self.registry.get(id))
            .collect();
        let n = tenants.len();
        let cfg = &self.registry.cfg;
        let cache = &*self.cache;
        crate::model::math::pool().scoped_map(tenants, |t| {
            cache.get(cfg, &t);
        });
        n
    }

    /// Enqueue a request with per-request generation options. Fails fast
    /// with a typed error (unknown tenant, full queue, shutdown); on
    /// success the returned handle resolves exactly once.
    pub fn submit(
        &self,
        tenant: &str,
        prompt: &str,
        opts: GenOptions,
    ) -> std::result::Result<ResponseHandle, ServeError> {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        if self.registry.get(tenant).is_none() {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::UnknownTenant(tenant.to_string()));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let cancelled = Arc::new(AtomicBool::new(false));
        let deadline = opts.deadline.map(|budget| Instant::now() + budget);
        self.batcher.push(Request {
            id,
            tenant: tenant.to_string(),
            prompt: prompt.to_string(),
            opts,
            deadline,
            respond: tx,
            cancelled: Arc::clone(&cancelled),
            enqueued: Instant::now(),
        })?;
        Ok(ResponseHandle {
            id,
            tenant: tenant.to_string(),
            rx,
            cancelled,
        })
    }

    /// Drain and stop all workers.
    pub fn shutdown(&mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Can two requests share one decode call? Compares only the fields
/// `decode` reads: the deadline budget is enforced per-request before
/// decoding, and the sampling knobs (temperature/top_k/seed) only matter
/// when sampling is on — so distinct deadlines (or seeds under greedy)
/// must not fragment a tenant batch into per-request decodes.
fn same_decode_opts(a: &GenOptions, b: &GenOptions) -> bool {
    let sampling = |o: &GenOptions| o.temperature > 0.0;
    a.max_new_tokens == b.max_new_tokens
        && a.stop_tokens == b.stop_tokens
        && sampling(a) == sampling(b)
        && (!sampling(a)
            || (a.temperature == b.temperature
                && a.top_k == b.top_k
                && a.seed == b.seed))
}

fn process_batch<E: ServeEngine>(
    registry: &Registry,
    metrics: &Metrics,
    cache: &MaterializeCache,
    engine: &mut E,
    tenant_id: &str,
    batch: Vec<Request>,
) {
    metrics.record_batch(batch.len());
    let Some(tenant) = registry.get(tenant_id) else {
        for req in batch {
            metrics.errors.fetch_add(1, Ordering::Relaxed);
            let _ = req
                .respond
                .send(Err(ServeError::UnknownTenant(tenant_id.to_string())));
        }
        return;
    };
    let factors = cache.get(&registry.cfg, &tenant);
    let (bsz, seq, vocab) = engine.shape();
    let tk = Tokenizer::new();

    // a request may have been cancelled or expired between pop and now
    let now = Instant::now();
    let mut live: Vec<Request> = Vec::with_capacity(batch.len());
    for req in batch {
        if req.is_cancelled() {
            metrics.cancelled.fetch_add(1, Ordering::Relaxed);
            let _ = req.respond.send(Err(ServeError::Cancelled));
        } else if req.is_expired(now) {
            metrics.expired.fetch_add(1, Ordering::Relaxed);
            let _ = req.respond.send(Err(ServeError::Deadline));
        } else {
            live.push(req);
        }
    }

    // sub-batch by decode-equivalent options so each decode call runs
    // under one GenOptions (requests with distinct sampling knobs never
    // mix, but decode-irrelevant fields don't fragment batches)
    let mut groups: Vec<(GenOptions, Vec<Request>)> = Vec::new();
    for req in live {
        match groups
            .iter_mut()
            .find(|(o, _)| same_decode_opts(o, &req.opts))
        {
            Some((_, g)) => g.push(req),
            None => groups.push((req.opts.clone(), vec![req])),
        }
    }

    for (opts, reqs) in &groups {
        for chunk in reqs.chunks(bsz) {
            let mut prompts: Vec<Vec<i32>> = chunk
                .iter()
                .map(|r| tk.prompt_tokens(&r.prompt))
                .collect();
            while prompts.len() < bsz {
                prompts.push(vec![crate::data::tokenizer::BOS]);
            }
            let mut err: Option<ServeError> = None;
            let mut fwd = |tokens: &[i32]| -> Vec<f32> {
                match engine.forward(&tenant, &factors, tokens) {
                    Ok(l) => l,
                    Err(e) => {
                        err = Some(ServeError::Engine(e.to_string()));
                        vec![0.0; bsz * seq * vocab]
                    }
                }
            };
            let outs = decode(&mut fwd, &prompts, opts, seq, vocab);
            for (req, out) in chunk.iter().zip(&outs) {
                let latency = req.enqueued.elapsed();
                match &err {
                    None => {
                        metrics.record_latency(latency);
                        metrics
                            .generated_tokens
                            .fetch_add(out.len() as u64, Ordering::Relaxed);
                        let _ = req.respond.send(Ok(Response {
                            id: req.id,
                            tenant: tenant_id.to_string(),
                            prompt: req.prompt.clone(),
                            text: tk.decode(out),
                            tokens: out.len(),
                            latency,
                        }));
                    }
                    Some(e) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = req.respond.send(Err(e.clone()));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn make_server(capacity: usize) -> (Server, crate::config::ModelCfg) {
        let mut cfg = presets::tiny();
        cfg.batch = 4; // keep unit tests fast
        let registry = Arc::new(Registry::new(cfg.clone(), capacity));
        let server = Server::new(
            registry,
            ServerCfg {
                max_batch: 4,
                max_wait: Duration::from_millis(10),
                cache_capacity: 8,
                ..ServerCfg::default()
            },
        );
        (server, cfg)
    }

    fn spec(seed: u64) -> TenantSpec {
        TenantSpec::mos(4, 2, 2, 0).seed(seed)
    }

    #[test]
    fn serves_requests_end_to_end() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        server.register("bob", spec(2)).unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let mut handles = Vec::new();
        for i in 0..6 {
            let tenant = if i % 2 == 0 { "alice" } else { "bob" };
            handles.push(
                server
                    .submit(tenant, &format!("q:{i}"), GenOptions::greedy())
                    .unwrap(),
            );
        }
        for (i, h) in handles.into_iter().enumerate() {
            let resp = h.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
            assert_eq!(resp.prompt, format!("q:{i}"));
            assert_eq!(resp.id, i as RequestId);
        }
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 6);
        server.shutdown();
    }

    #[test]
    fn unknown_tenant_fails_at_submit() {
        let (server, _cfg) = make_server(1 << 30);
        let err = server
            .submit("ghost", "hello", GenOptions::greedy())
            .unwrap_err();
        assert_eq!(err, ServeError::UnknownTenant("ghost".into()));
    }

    #[test]
    fn tenant_removed_after_submit_errors_in_response() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let h = server
            .submit("alice", "q:x", GenOptions::greedy())
            .unwrap();
        assert!(server.remove("alice"));
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        assert_eq!(
            h.wait(),
            Err(ServeError::UnknownTenant("alice".into()))
        );
        server.shutdown();
    }

    #[test]
    fn queue_full_rejected_at_submit() {
        let mut cfg = presets::tiny();
        cfg.batch = 4;
        let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
        let server = Server::new(
            registry,
            ServerCfg {
                admission: Admission { per_tenant: 2, global: 100 },
                ..ServerCfg::default()
            },
        );
        server.register("alice", spec(1)).unwrap();
        // no workers: the queue only fills
        let _h1 = server.submit("alice", "q:0", GenOptions::greedy()).unwrap();
        let _h2 = server.submit("alice", "q:1", GenOptions::greedy()).unwrap();
        let err = server
            .submit("alice", "q:2", GenOptions::greedy())
            .unwrap_err();
        assert_eq!(err, ServeError::QueueFull { tenant: "alice".into() });
        assert_eq!(server.metrics.rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn cancelled_request_resolves_cancelled() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let h = server
            .submit("alice", "q:cancel", GenOptions::greedy())
            .unwrap();
        h.cancel();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        assert_eq!(h.wait(), Err(ServeError::Cancelled));
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 0);
        server.shutdown();
    }

    #[test]
    fn expired_deadline_resolves_deadline() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let h = server
            .submit(
                "alice",
                "q:late",
                GenOptions::greedy().deadline(Duration::ZERO),
            )
            .unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        assert_eq!(h.wait(), Err(ServeError::Deadline));
        server.shutdown();
    }

    #[test]
    fn sampling_deterministic_through_server() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let opts = GenOptions::sample(0.9, 8, 1234).max_new_tokens(12);
        let run = |prompt: &str| {
            server
                .submit("alice", prompt, opts.clone())
                .unwrap()
                .wait_timeout(Duration::from_secs(30))
                .unwrap()
                .unwrap()
        };
        let a = run("q:sample");
        let b = run("q:sample");
        assert_eq!(a.text, b.text, "same per-request seed must reproduce");
        server.shutdown();
    }

    #[test]
    fn reregister_serves_fresh_factors() {
        // regression for the stale-factors bug: re-registering a tenant
        // with new params must not serve the old dense factors
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let first = server
            .submit("alice", "q:00", GenOptions::greedy())
            .unwrap()
            .wait_timeout(Duration::from_secs(30))
            .unwrap()
            .unwrap();
        server.register("alice", spec(99)).unwrap();
        let tenant = server.registry.get("alice").unwrap();
        assert_eq!(tenant.version, 1);
        let refreshed = server
            .submit("alice", "q:00", GenOptions::greedy())
            .unwrap()
            .wait_timeout(Duration::from_secs(30))
            .unwrap()
            .unwrap();
        // the cache must have rebuilt for the new version (numeric factor
        // freshness is asserted in cache::tests::reregistered_tenant_...)
        let (_, misses) = server.cache.stats();
        assert_eq!(misses, 2, "re-registered tenant served stale factors");
        let _ = (first, refreshed);
        server.shutdown();
    }

    #[test]
    fn lifecycle_register_remove_ids() {
        let (server, _cfg) = make_server(1 << 30);
        server.register("a", spec(1)).unwrap();
        server.register("b", spec(2)).unwrap();
        let mut ids = server.tenant_ids();
        ids.sort();
        assert_eq!(ids, vec!["a".to_string(), "b".to_string()]);
        assert!(server.remove("a"));
        assert!(!server.remove("a"));
        assert_eq!(server.tenant_ids(), vec!["b".to_string()]);
    }

    #[test]
    fn prewarm_materializes_every_tenant_once() {
        let (mut server, cfg) = make_server(1 << 30);
        for (i, id) in ["alice", "bob", "carol"].iter().enumerate() {
            server.register(id, spec(i as u64 + 1)).unwrap();
        }
        assert_eq!(server.prewarm(), 3);
        assert_eq!(server.cache.stats(), (0, 3));
        // traffic after prewarm only hits the cache
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        for id in ["alice", "bob", "carol"] {
            let h = server.submit(id, "q:warm", GenOptions::greedy()).unwrap();
            assert!(h.wait_timeout(Duration::from_secs(30)).unwrap().is_ok());
        }
        let (hits, misses) = server.cache.stats();
        assert_eq!(misses, 3, "prewarmed tenants must not re-materialize");
        assert!(hits >= 3);
        server.shutdown();
    }

    #[test]
    fn cache_reused_across_requests() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        for _ in 0..3 {
            let h = server.submit("alice", "q:aa", GenOptions::greedy()).unwrap();
            h.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
        }
        let (hits, misses) = server.cache.stats();
        assert_eq!(misses, 1, "factors must be materialized exactly once");
        assert!(hits >= 1);
        server.shutdown();
    }

    #[test]
    fn mixed_options_in_one_tenant_batch() {
        // greedy and sampled requests for the same tenant land in one
        // batcher batch but must decode in separate option groups
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let h1 = server.submit("alice", "q:00", GenOptions::greedy()).unwrap();
        let h2 = server
            .submit(
                "alice",
                "q:00",
                GenOptions::sample(1.0, 0, 5).max_new_tokens(8),
            )
            .unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let r1 = h1.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
        let r2 = h2.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert!(r2.tokens <= 8);
        // both resolved; ids are distinct and stable
        assert_ne!(r1.id, r2.id);
        server.shutdown();
    }
}
