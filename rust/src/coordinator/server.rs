//! The serving event loop: worker threads pull per-tenant batches from the
//! batcher and run a persistent slot-table decode loop — KV-cached
//! single-position steps when the engine supports them, full-window
//! forwards otherwise. Between steps the loop admits newly queued
//! requests into freed slots (Orca/S-LoRA-style continuous batching via
//! [`Batcher::try_fill`]), enforces per-request deadlines and
//! cancellations, and streams each generated token through the request's
//! [`ResponseHandle`]. Engines are worker-owned (one PJRT executable or
//! host model per worker), so no engine needs to be `Sync`.
//!
//! Request lifecycle (see DESIGN.md §Serving API):
//! `submit(tenant, prompt, opts) -> Result<ResponseHandle, ServeError>`;
//! tokens stream through `recv_token` / `tokens()` as they decode, and
//! the handle still resolves exactly once to `Result<Response, ServeError>`
//! via `wait` / `wait_timeout` / `try_wait` (unchanged one-shot
//! semantics). `cancel` drops queued requests before they reach an engine
//! and stops mid-decode requests at the next step boundary.

use super::batcher::{
    Admission, Batcher, Request, RequestId, Response, ServeError, ServeResult,
};
use super::cache::{AdapterCache, TenantFactors};
use super::metrics::Metrics;
use super::registry::{QosSpec, Registry, Tenant, TenantSpec};
use crate::adapter::{Factors, ServingAdapter};
use crate::data::tokenizer::Tokenizer;
use crate::eval::{DecodeState, GenOptions};
use crate::model::math::scratch_put;
use crate::model::paged::{KvStats, PagedKvCache};
use crate::model::quant::QuantBase;
use crate::model::transformer::{
    decode_step_runs_base, infer_prefill_runs_base, paged_infer_runs_base,
    quantize_base, AdapterBinding, AdapterRef, BaseRef, KvCache,
};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

/// A contiguous run of batch elements served by one tenant's adapter:
/// `rows` request rows (`prefill_rows`) or decode entries (`decode_rows`)
/// share it. A mixed batch is a slice of runs whose `rows` sum to the
/// call's element count — the engine maps each run onto a per-run
/// [`AdapterBinding`], and canonical-order GEMMs keep every row's logits
/// bitwise independent of how the batch was grouped (PR 6 contract).
pub struct EngineRun<'a> {
    pub tenant: &'a Tenant,
    pub adapter: &'a ServingAdapter,
    pub rows: usize,
}

/// A per-worker inference engine.
///
/// `forward` (full-window) is the baseline every engine provides. Engines
/// that can decode incrementally also implement the KV-cached stepping
/// trio (`supports_steps` / `prefill_rows` / `decode_rows`), which the
/// worker decode loop prefers: one single-position step per generated
/// token instead of re-running a full-window forward — O(step) instead of
/// O(window · forward) per token. Fixed-graph PJRT artifact engines keep
/// the default full-window path.
///
/// Stepping engines may additionally manage per-row KV residency (the
/// paged pool, PR 7) through the `kv_*` hooks. The worker calls
/// `kv_admit` before occupying a slot — `false` means the pool cannot
/// cover the request *right now* and the worker keeps it queued
/// (degradation to queueing, never a mid-decode failure) — and
/// `kv_release` whenever a slot frees, including cancellations and
/// deadline expiries. The defaults are no-ops so fixed-cache engines
/// need not care.
pub trait ServeEngine {
    /// Batched forward for one tenant: padded tokens (batch*seq) -> logits
    /// (batch*seq*vocab).
    fn forward(
        &mut self,
        tenant: &Tenant,
        adapter: &ServingAdapter,
        tokens: &[i32],
    ) -> Result<Vec<f32>>;
    /// (batch, seq, vocab)
    fn shape(&self) -> (usize, usize, usize);
    /// Does this engine implement the KV-cached stepping path?
    fn supports_steps(&self) -> bool {
        false
    }
    /// (Re)build the engine's KV cache rows `rows[i]` from the padded
    /// window `tokens` (`rows.len() * seq`), returning **lean**
    /// next-token logits (`rows.len() * vocab`), one row per request
    /// projected at its `last[i]` window position. `runs` groups the
    /// rows by tenant (PR 7: the single `tenant`/`adapter` pair became
    /// a run slice so one batch serves mixed tenants — see DESIGN.md
    /// migration table).
    fn prefill_rows(
        &mut self,
        _runs: &[EngineRun],
        _rows: &[usize],
        _tokens: &[i32],
        _last: &[usize],
    ) -> Result<Vec<f32>> {
        anyhow::bail!("engine does not support KV-cached stepping")
    }
    /// One decode position per entry `(row, pos, token)` -> next-token
    /// logits (`entries.len() * vocab`). `runs` groups the entries by
    /// tenant, same contract as [`Self::prefill_rows`].
    fn decode_rows(
        &mut self,
        _runs: &[EngineRun],
        _entries: &[(usize, usize, i32)],
    ) -> Result<Vec<f32>> {
        anyhow::bail!("engine does not support KV-cached stepping")
    }
    /// Chunked prefill (PR 9): advance each row's prefill by at most
    /// `chunk` prompt positions, writing K/V for the computed span.
    /// `last[i]` is row `rows[i]`'s *final* prompt position. Returns the
    /// indices (into `rows`) whose prefill completed this call, paired
    /// with their lean next-token logits (`done.len() * vocab`, in the
    /// same order). Incomplete rows carry their cursor engine-side and
    /// finish across later calls; interleaving decode steps between
    /// calls must not change any logits (chunk N+1 reads chunk N's K/V
    /// through the same cache the decode path uses).
    ///
    /// The default completes everything in one shot via
    /// [`Self::prefill_rows`] — correct for engines without a prefill
    /// cursor; they just don't get the interleaving win.
    fn prefill_rows_partial(
        &mut self,
        runs: &[EngineRun],
        rows: &[usize],
        tokens: &[i32],
        last: &[usize],
        _chunk: usize,
    ) -> Result<(Vec<usize>, Vec<f32>)> {
        let logits = self.prefill_rows(runs, rows, tokens, last)?;
        Ok(((0..rows.len()).collect(), logits))
    }
    /// Reserve KV residency for `prompt` on cache row `row` before the
    /// worker occupies the slot. `false` = the pool cannot cover the
    /// request now; the worker parks it and retries as rows free.
    fn kv_admit(
        &mut self,
        _row: usize,
        _tenant: &Tenant,
        _prompt: &[i32],
    ) -> bool {
        true
    }
    /// Release every KV page reference `row` holds (idempotent; called on
    /// completion, cancellation, deadline expiry, and engine error).
    fn kv_release(&mut self, _row: usize) {}
    /// Measured resident KV bytes currently tagged to `tenant` (the
    /// ledger's per-tenant KV charge).
    fn kv_tenant_bytes(&self, _tenant: &Tenant) -> usize {
        0
    }
    /// Measured resident KV bytes across the whole pool.
    fn kv_resident_bytes(&self) -> usize {
        0
    }
}

/// Which KV residency scheme backs a [`HostEngine`]'s stepping path.
enum KvBackend {
    /// PR-4/5 fixed window: `batch × seq` slots resident regardless of
    /// occupancy. Kept as the comparison arm and oracle.
    Fixed(KvCache),
    /// PR-7 paged pool: refcounted fixed-size pages, per-row page tables,
    /// copy-on-write prefix sharing. Resident bytes track live tokens.
    Paged(PagedKvCache),
}

/// Lazily build the worker's KV backend. A free function over the
/// engine's disjoint fields so callers can keep `&self.cfg`/`&self.base`
/// borrowed across the `&mut self.kv` it hands back.
fn ensure_kv<'a>(
    kv: &'a mut Option<KvBackend>,
    cfg: &crate::config::ModelCfg,
    use_fixed: bool,
    share_prefix: bool,
    page_tokens: usize,
    capacity_pages: Option<usize>,
    stats: &Option<Arc<KvStats>>,
) -> &'a mut KvBackend {
    kv.get_or_insert_with(|| {
        if use_fixed {
            KvBackend::Fixed(KvCache::new(cfg, cfg.batch))
        } else {
            let cap = capacity_pages.unwrap_or_else(|| {
                // worst case for the slot table: every row at a full window
                cfg.batch * PagedKvCache::pages_per_row(cfg, page_tokens)
            });
            let mut c = PagedKvCache::new(cfg, cfg.batch, page_tokens, cap);
            if !share_prefix {
                c = c.without_sharing();
            }
            if let Some(s) = stats {
                c = c.with_stats(Arc::clone(s));
            }
            KvBackend::Paged(c)
        }
    })
}

/// The engine's frozen-base view for the stepping paths. A free function
/// over disjoint `HostEngine` fields (like [`ensure_kv`]) so callers can
/// keep it live across the `&mut self.kv` borrow.
fn base_ref<'a>(
    base: &'a crate::util::bank::Bank,
    quant: &'a Option<QuantBase>,
) -> BaseRef<'a> {
    match quant {
        Some(q) => BaseRef::int8(base, q),
        None => BaseRef::f32(base),
    }
}

/// Map engine runs onto per-run adapter bindings. `counts[i]` is run
/// `i`'s batch-element count for *this* call — request rows for the
/// fixed prefill, cache entries for the paged paths and decode.
fn run_bindings<'a>(
    runs: &[EngineRun<'a>],
    counts: &[usize],
) -> Vec<AdapterBinding<'a>> {
    runs.iter()
        .zip(counts)
        .map(|(run, &n)| {
            let adapter = match run.adapter {
                ServingAdapter::Dense(f) => AdapterRef::Dense(f.as_ref()),
                ServingAdapter::Pooled(p) => AdapterRef::Pooled(p.as_ref()),
                ServingAdapter::PooledInt8(p) => {
                    AdapterRef::PooledInt8(p.as_ref())
                }
            };
            AdapterBinding::new(n, &run.tenant.mc, adapter)
        })
        .collect()
}

/// Host-model serving engine: shared frozen base + cached tenant factors
/// + a lazily allocated KV backend for the stepping path.
///
/// Since PR 7 the default backend is the **paged pool**
/// ([`PagedKvCache`]): resident KV bytes track live tokens instead of
/// `slots × window`, identical prompt prefixes share pages copy-on-write
/// within a tenant, and admission degrades to queueing when the pool is
/// full. [`HostEngine::fixed_kv`] restores the PR-4/5 fixed window — the
/// bitwise oracle and the bench comparison arm.
///
/// Prefill runs the lean inference-only forward (K/V straight into the
/// cache, arena-only intermediates, last-position-only logits).
/// [`HostEngine::full_prefill`] re-enables the pre-PR-5 training-forward
/// prefill (full `ForwardCache` + full-window vocab projection, K/V
/// copied out) behind the *same* lean return contract — it exists so
/// `bench_serving` can measure the lean path's win and tests can pin
/// their equivalence; the logits are bitwise identical either way.
pub struct HostEngine {
    pub cfg: crate::config::ModelCfg,
    pub base: crate::util::bank::Bank,
    /// `MOS_SERVE_INT8=1` tier: the projection stacks and tied embedding
    /// quantized once at engine construction. When set, the f32 copies
    /// are *stripped* from `base` (norms stay — they are read f32 by
    /// every path), so the engine's resident base bytes are the int8
    /// ones, not both representations.
    quant: Option<QuantBase>,
    kv: Option<KvBackend>,
    full_prefill: bool,
    use_fixed: bool,
    share_prefix: bool,
    page_tokens: usize,
    capacity_pages: Option<usize>,
    stats: Option<Arc<KvStats>>,
    /// Engine-lifetime owner registry: the index of an `(id, version)`
    /// pair is the tag pages carry in the pool. A version bump mints a
    /// fresh tag, so re-registered tenants never share stale pages.
    owners: Vec<(String, u64)>,
    /// Per cache row: first prompt position prefill must compute (the
    /// positions below it were mapped from shared pages at admission).
    row_start: Vec<usize>,
    /// One-entry materialization memo for the full-forward arms, which
    /// still need dense factors even when the tenant is served pooled:
    /// `(id, version, factors)` — the worker-owned engine's scratch, not
    /// a second cache tier.
    dense_memo: Option<(String, u64, TenantFactors)>,
}

impl HostEngine {
    pub fn new(cfg: crate::config::ModelCfg, seed: u64) -> HostEngine {
        let base = crate::model::transformer::init_base(&cfg, seed);
        HostEngine::with_base(cfg, base)
    }

    /// Wrap an existing base bank (e.g. a just-trained model's).
    pub fn with_base(
        cfg: crate::config::ModelCfg,
        base: crate::util::bank::Bank,
    ) -> HostEngine {
        let int8 = std::env::var("MOS_SERVE_INT8")
            .map(|v| v == "1")
            .unwrap_or(false);
        let e = HostEngine {
            row_start: vec![0; cfg.batch],
            cfg,
            base,
            quant: None,
            kv: None,
            full_prefill: false,
            use_fixed: false,
            share_prefix: true,
            page_tokens: 16,
            capacity_pages: None,
            stats: None,
            owners: Vec::new(),
            dense_memo: None,
        };
        if int8 {
            e.serve_int8()
        } else {
            e
        }
    }

    /// Serve the frozen base int8-quantized (tests/benches pin it here;
    /// [`HostEngine::with_base`] reads `MOS_SERVE_INT8`). Quantizes the
    /// projection stacks and the tied embedding once, then drops their
    /// f32 copies from the bank. The full-window arms
    /// ([`ServeEngine::forward`], [`HostEngine::full_prefill`]) need the
    /// f32 base and error out on an int8 engine.
    pub fn serve_int8(mut self) -> HostEngine {
        if self.quant.is_none() {
            self.quant = Some(quantize_base(&self.cfg, &self.base));
            for t in crate::config::LAYER_TYPES {
                self.base.remove(&format!("w.{t}"));
            }
            self.base.remove("embed");
        }
        self
    }

    /// Measured resident bytes of the frozen base under the active
    /// representation: the bank's remaining f32 tensors plus the int8
    /// codes + scales when quantized (the `base_mb` bench column).
    pub fn base_resident_bytes(&self) -> usize {
        self.base.values().map(|t| t.nbytes()).sum::<usize>()
            + self.quant.as_ref().map_or(0, |q| q.nbytes())
    }

    /// Use the legacy full-forward prefill (bench/test comparison arm).
    /// Implies the fixed KV backend.
    pub fn full_prefill(mut self) -> HostEngine {
        self.full_prefill = true;
        self.use_fixed = true;
        self
    }

    /// Use the PR-4/5 fixed-window KV cache instead of the paged pool
    /// (bench comparison arm; bitwise oracle for the paged path).
    pub fn fixed_kv(mut self) -> HostEngine {
        self.use_fixed = true;
        self
    }

    /// Disable copy-on-write prefix sharing in the paged pool (cold
    /// comparison arm).
    pub fn no_prefix_share(mut self) -> HostEngine {
        self.share_prefix = false;
        self
    }

    /// Tokens per KV page (default 16; clamped to the window).
    pub fn kv_page_tokens(mut self, n: usize) -> HostEngine {
        self.page_tokens = n;
        self
    }

    /// Cap the paged pool at `n` pages (default: worst case for the slot
    /// table). Smaller pools degrade to queueing at admission.
    pub fn kv_capacity_pages(mut self, n: usize) -> HostEngine {
        self.capacity_pages = Some(n);
        self
    }

    /// Report pool residency into an externally owned probe so tests and
    /// benches can watch KV bytes from outside the worker thread.
    pub fn kv_stats(mut self, stats: Arc<KvStats>) -> HostEngine {
        self.stats = Some(stats);
        self
    }

    /// The pool tag for `tenant`'s pages (minted on first sight).
    fn owner_tag(&mut self, tenant: &Tenant) -> u32 {
        if let Some(i) = self
            .owners
            .iter()
            .position(|(id, v)| *id == tenant.id && *v == tenant.version)
        {
            return i as u32;
        }
        self.owners.push((tenant.id.clone(), tenant.version));
        (self.owners.len() - 1) as u32
    }

    /// Dense factors for the paths that need them (full-window forward,
    /// legacy prefill): Dense adapters pass straight through; a Pooled
    /// adapter is materialized once per (id, version) and memoized.
    fn dense_factors(
        &mut self,
        tenant: &Tenant,
        adapter: &ServingAdapter,
    ) -> TenantFactors {
        if let ServingAdapter::Dense(f) = adapter {
            return Arc::clone(f);
        }
        if let Some((id, v, f)) = &self.dense_memo {
            if *id == tenant.id && *v == tenant.version {
                return Arc::clone(f);
            }
        }
        let built: Vec<(String, Factors)> = crate::model::math::pool()
            .scoped_map(crate::config::LAYER_TYPES.to_vec(), |t| {
                (
                    t.to_string(),
                    crate::adapter::materialize(
                        &self.cfg,
                        &tenant.mc,
                        &tenant.params,
                        &tenant.aux,
                        t,
                    ),
                )
            });
        let f: TenantFactors = Arc::new(built.into_iter().collect());
        self.dense_memo =
            Some((tenant.id.clone(), tenant.version, Arc::clone(&f)));
        f
    }
}

impl ServeEngine for HostEngine {
    fn forward(
        &mut self,
        tenant: &Tenant,
        adapter: &ServingAdapter,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        if self.quant.is_some() {
            anyhow::bail!(
                "full-window forward needs the f32 base; an int8 engine \
                 (MOS_SERVE_INT8) serves the stepping path only"
            );
        }
        let factors = self.dense_factors(tenant, adapter);
        let (cache, _) = crate::model::transformer::forward(
            &self.cfg,
            &tenant.mc,
            &self.base,
            &factors,
            tokens,
        );
        Ok(cache.logits)
    }

    fn shape(&self) -> (usize, usize, usize) {
        (self.cfg.batch, self.cfg.seq, self.cfg.vocab)
    }

    fn supports_steps(&self) -> bool {
        true
    }

    fn prefill_rows(
        &mut self,
        runs: &[EngineRun],
        rows: &[usize],
        tokens: &[i32],
        last: &[usize],
    ) -> Result<Vec<f32>> {
        let seq = self.cfg.seq;
        if self.full_prefill {
            if self.quant.is_some() {
                anyhow::bail!(
                    "full_prefill needs the f32 base; an int8 engine \
                     (MOS_SERVE_INT8) serves the lean stepping path only"
                );
            }
            // legacy arm: the training forward (ForwardCache + full-window
            // vocab projection), K/V copied out, logits re-sliced to the
            // lean shape — bitwise identical rows, ~seq-fold more work
            let factors: Vec<TenantFactors> = runs
                .iter()
                .map(|run| self.dense_factors(run.tenant, run.adapter))
                .collect();
            let vocab = self.cfg.vocab;
            let kv = match ensure_kv(
                &mut self.kv,
                &self.cfg,
                self.use_fixed,
                self.share_prefix,
                self.page_tokens,
                self.capacity_pages,
                &self.stats,
            ) {
                KvBackend::Fixed(c) => c,
                KvBackend::Paged(_) => {
                    unreachable!("full_prefill implies the fixed backend")
                }
            };
            let mut lean = vec![0.0f32; rows.len() * vocab];
            let mut r0 = 0;
            for (run, f) in runs.iter().zip(&factors) {
                let n = run.rows;
                let (fc, _) = crate::model::transformer::forward(
                    &self.cfg,
                    &run.tenant.mc,
                    &self.base,
                    f,
                    &tokens[r0 * seq..(r0 + n) * seq],
                );
                kv.copy_from_forward(&fc, &rows[r0..r0 + n]);
                for i in 0..n {
                    let src = (i * seq + last[r0 + i]) * vocab;
                    lean[(r0 + i) * vocab..(r0 + i + 1) * vocab]
                        .copy_from_slice(&fc.logits[src..src + vocab]);
                }
                r0 += n;
            }
            return Ok(lean);
        }
        Ok(
            match ensure_kv(
                &mut self.kv,
                &self.cfg,
                self.use_fixed,
                self.share_prefix,
                self.page_tokens,
                self.capacity_pages,
                &self.stats,
            ) {
                KvBackend::Fixed(c) => {
                    let counts: Vec<usize> =
                        runs.iter().map(|b| b.rows).collect();
                    let bindings = run_bindings(runs, &counts);
                    infer_prefill_runs_base(
                        &self.cfg,
                        base_ref(&self.base, &self.quant),
                        &bindings,
                        tokens,
                        last,
                        c,
                        rows,
                    )
                }
                KvBackend::Paged(c) => {
                    // tail entries only: positions below row_start were
                    // mapped from shared pages at admission and are never
                    // recomputed (the warm-prefix win)
                    let mut entries: Vec<(usize, usize, i32)> = Vec::new();
                    let mut lean_idx: Vec<usize> =
                        Vec::with_capacity(rows.len());
                    let mut counts: Vec<usize> =
                        Vec::with_capacity(runs.len());
                    let mut i = 0;
                    for run in runs {
                        let before = entries.len();
                        for _ in 0..run.rows {
                            let r = rows[i];
                            for pos in self.row_start[r]..=last[i] {
                                entries.push((r, pos, tokens[i * seq + pos]));
                            }
                            lean_idx.push(entries.len() - 1);
                            i += 1;
                        }
                        counts.push(entries.len() - before);
                    }
                    let bindings = run_bindings(runs, &counts);
                    let out = paged_infer_runs_base(
                        &self.cfg,
                        base_ref(&self.base, &self.quant),
                        &bindings,
                        c,
                        &entries,
                        Some(&lean_idx),
                    );
                    // publish each full prompt so later identical prefixes
                    // admit warm (no-op when sharing is disabled)
                    for (i, &r) in rows.iter().enumerate() {
                        c.register_prefix(
                            r,
                            &tokens[i * seq..i * seq + last[i] + 1],
                        );
                    }
                    out
                }
            },
        )
    }

    fn decode_rows(
        &mut self,
        runs: &[EngineRun],
        entries: &[(usize, usize, i32)],
    ) -> Result<Vec<f32>> {
        let counts: Vec<usize> = runs.iter().map(|b| b.rows).collect();
        let bindings = run_bindings(runs, &counts);
        Ok(
            match ensure_kv(
                &mut self.kv,
                &self.cfg,
                self.use_fixed,
                self.share_prefix,
                self.page_tokens,
                self.capacity_pages,
                &self.stats,
            ) {
                KvBackend::Fixed(c) => decode_step_runs_base(
                    &self.cfg,
                    base_ref(&self.base, &self.quant),
                    &bindings,
                    c,
                    entries,
                ),
                KvBackend::Paged(c) => paged_infer_runs_base(
                    &self.cfg,
                    base_ref(&self.base, &self.quant),
                    &bindings,
                    c,
                    entries,
                    None,
                ),
            },
        )
    }

    fn prefill_rows_partial(
        &mut self,
        runs: &[EngineRun],
        rows: &[usize],
        tokens: &[i32],
        last: &[usize],
        chunk: usize,
    ) -> Result<(Vec<usize>, Vec<f32>)> {
        if self.full_prefill || self.use_fixed {
            // the fixed-window backends carry no prefill cursor: one-shot
            let logits = self.prefill_rows(runs, rows, tokens, last)?;
            return Ok(((0..rows.len()).collect(), logits));
        }
        let seq = self.cfg.seq;
        let chunk = chunk.max(1);
        let kv = ensure_kv(
            &mut self.kv,
            &self.cfg,
            self.use_fixed,
            self.share_prefix,
            self.page_tokens,
            self.capacity_pages,
            &self.stats,
        );
        let KvBackend::Paged(c) = kv else {
            unreachable!("chunked prefill requires the paged backend")
        };
        // each row advances from its cursor (`row_start`, seeded by
        // admission's warm-prefix mapping) by at most `chunk` positions;
        // lean logits only for rows that reach their final position —
        // chunk N+1's attention reads chunk N's K/V through the page
        // tables, the exact warm-prefix tail mechanism PR 7 proved
        // bitwise-identical
        let mut entries: Vec<(usize, usize, i32)> = Vec::new();
        let mut lean_idx: Vec<usize> = Vec::new();
        let mut done: Vec<usize> = Vec::new();
        let mut counts: Vec<usize> = Vec::with_capacity(runs.len());
        let mut i = 0;
        for run in runs {
            let before = entries.len();
            for _ in 0..run.rows {
                let r = rows[i];
                let start = self.row_start[r];
                let end = (start + chunk - 1).min(last[i]);
                for pos in start..=end {
                    entries.push((r, pos, tokens[i * seq + pos]));
                }
                if end == last[i] {
                    done.push(i);
                    lean_idx.push(entries.len() - 1);
                }
                self.row_start[r] = end + 1;
                i += 1;
            }
            counts.push(entries.len() - before);
        }
        let bindings = run_bindings(runs, &counts);
        let out = paged_infer_runs_base(
            &self.cfg,
            base_ref(&self.base, &self.quant),
            &bindings,
            c,
            &entries,
            Some(&lean_idx),
        );
        // publish completed prompts only: intermediate spans must not
        // enter the warm-prefix index as if they were whole prompts
        for &j in &done {
            let r = rows[j];
            c.register_prefix(r, &tokens[j * seq..j * seq + last[j] + 1]);
        }
        Ok((done, out))
    }

    fn kv_admit(
        &mut self,
        row: usize,
        tenant: &Tenant,
        prompt: &[i32],
    ) -> bool {
        let owner = self.owner_tag(tenant);
        let start = match ensure_kv(
            &mut self.kv,
            &self.cfg,
            self.use_fixed,
            self.share_prefix,
            self.page_tokens,
            self.capacity_pages,
            &self.stats,
        ) {
            // the fixed window pre-reserves every slot — always fits
            KvBackend::Fixed(_) => Some(0),
            KvBackend::Paged(c) => c.admit_row(row, prompt, owner),
        };
        match start {
            Some(s) => {
                self.row_start[row] = s;
                true
            }
            None => false,
        }
    }

    fn kv_release(&mut self, row: usize) {
        // don't force-create a backend just to release into it
        if let Some(KvBackend::Paged(c)) = self.kv.as_mut() {
            c.release_row(row);
        }
    }

    fn kv_tenant_bytes(&self, tenant: &Tenant) -> usize {
        let Some(KvBackend::Paged(c)) = self.kv.as_ref() else {
            return 0;
        };
        // sum across versions: a re-registered tenant's old-version
        // retentions still charge its id until they are evicted
        self.owners
            .iter()
            .enumerate()
            .filter(|(_, (id, _))| *id == tenant.id)
            .map(|(i, _)| c.owner_bytes(i as u32))
            .sum()
    }

    fn kv_resident_bytes(&self) -> usize {
        match self.kv.as_ref() {
            Some(KvBackend::Paged(c)) => c.resident_bytes(),
            _ => 0,
        }
    }
}

/// Wraps an engine, masking its stepping support so the worker decode
/// loop takes the full-window fallback (one whole-window forward per
/// generated token) — what a fixed-graph PJRT artifact engine looks
/// like. Used by `bench_serving` to measure the KV-step speedup against
/// the pre-PR-4 cost model, and by tests to pin the fallback path.
pub struct FullWindowEngine<E>(pub E);

impl<E: ServeEngine> ServeEngine for FullWindowEngine<E> {
    fn forward(
        &mut self,
        tenant: &Tenant,
        adapter: &ServingAdapter,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        self.0.forward(tenant, adapter, tokens)
    }

    fn shape(&self) -> (usize, usize, usize) {
        self.0.shape()
    }
}

/// Serving knobs, grouped so `Server::new` stays stable as knobs grow.
#[derive(Debug, Clone)]
pub struct ServerCfg {
    /// Per-tenant batch released at this size.
    pub max_batch: usize,
    /// ... or when the oldest queued request reaches this age.
    pub max_wait: Duration,
    /// Materialization-cache capacity (tenants).
    pub cache_capacity: usize,
    /// Queue-depth bounds; past them `submit` returns `QueueFull`.
    pub admission: Admission,
    /// Chunked prefill (PR 9): advance each prompt's prefill by at most
    /// this many positions per decode round, so one long prompt cannot
    /// monopolize the engine between decode steps. `None` keeps the
    /// one-shot prefill. Bitwise-identical output either way (the chunk
    /// boundary is just the warm-prefix tail mechanism applied
    /// repeatedly).
    pub prefill_chunk: Option<usize>,
}

impl Default for ServerCfg {
    fn default() -> ServerCfg {
        ServerCfg {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            cache_capacity: 64,
            admission: Admission::default(),
            prefill_chunk: None,
        }
    }
}

/// Client-side handle for one submitted request: a token stream plus the
/// one-shot final resolution.
pub struct ResponseHandle {
    id: RequestId,
    tenant: String,
    rx: mpsc::Receiver<ServeResult>,
    tokens_rx: mpsc::Receiver<i32>,
    cancelled: Arc<AtomicBool>,
    batcher: Arc<Batcher>,
}

impl ResponseHandle {
    pub fn id(&self) -> RequestId {
        self.id
    }

    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Ask the coordinator to drop this request, waking the queue so the
    /// `Cancelled` resolution is immediate even on an idle server. Queued
    /// requests never reach an engine; a request already decoding stops at
    /// the next step boundary.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
        self.batcher.notify();
    }

    /// Blocking receive of the next streamed token id; `None` once
    /// generation has finished and the stream is closed (the final result
    /// is then available through [`wait`](ResponseHandle::wait)).
    pub fn recv_token(&self) -> Option<i32> {
        self.tokens_rx.recv().ok()
    }

    /// [`recv_token`](ResponseHandle::recv_token) bounded by `timeout`;
    /// `None` on timeout or a closed stream.
    pub fn recv_token_timeout(&self, timeout: Duration) -> Option<i32> {
        self.tokens_rx.recv_timeout(timeout).ok()
    }

    /// Non-blocking poll of the token stream; `None` when nothing is
    /// buffered (or the stream has closed — use `try_wait` to tell apart).
    pub fn try_recv_token(&self) -> Option<i32> {
        self.tokens_rx.try_recv().ok()
    }

    /// Blocking iterator over the token stream, ending when generation
    /// finishes. `handle.tokens().collect::<Vec<_>>()` detokenizes to
    /// exactly the final `Response::text`.
    pub fn tokens(&self) -> mpsc::Iter<'_, i32> {
        self.tokens_rx.iter()
    }

    /// Block until the request resolves.
    pub fn wait(&self) -> ServeResult {
        self.rx.recv().unwrap_or(Err(ServeError::ShuttingDown))
    }

    /// Block up to `timeout`; `None` means still in flight.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<ServeResult> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Some(Err(ServeError::ShuttingDown))
            }
        }
    }

    /// Non-blocking poll; `None` means still in flight.
    pub fn try_wait(&self) -> Option<ServeResult> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(ServeError::ShuttingDown))
            }
        }
    }
}

/// The coordinator server.
pub struct Server {
    pub registry: Arc<Registry>,
    pub batcher: Arc<Batcher>,
    pub metrics: Arc<Metrics>,
    pub cache: Arc<AdapterCache>,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: AtomicU64,
    prefill_chunk: Option<usize>,
}

impl Server {
    pub fn new(registry: Arc<Registry>, cfg: ServerCfg) -> Server {
        let metrics = Arc::new(Metrics::new());
        let cache = Arc::new(
            AdapterCache::new(cfg.cache_capacity, registry.serve_dense())
                .with_int8(registry.serve_int8()),
        );
        // ledger eviction must invalidate the cache, or "evicted" tenants
        // keep serving from it (ledger<->cache coherence)
        let cache2 = Arc::clone(&cache);
        registry.set_evict_hook(move |id| cache2.invalidate(id));
        Server {
            registry,
            batcher: Arc::new(Batcher::new(
                cfg.max_batch,
                cfg.max_wait,
                cfg.admission,
                Arc::clone(&metrics),
            )),
            metrics,
            cache,
            workers: Vec::new(),
            next_id: AtomicU64::new(0),
            prefill_chunk: cfg.prefill_chunk,
        }
    }

    /// Spawn `n` workers, each owning an engine built by `factory`.
    pub fn start<F, E>(&mut self, n: usize, factory: F)
    where
        F: Fn(usize) -> E + Send + Sync + 'static,
        E: ServeEngine + 'static,
    {
        let factory = Arc::new(factory);
        for wid in 0..n {
            let registry = Arc::clone(&self.registry);
            let batcher = Arc::clone(&self.batcher);
            let metrics = Arc::clone(&self.metrics);
            let cache = Arc::clone(&self.cache);
            let factory = Arc::clone(&factory);
            let prefill_chunk = self.prefill_chunk;
            self.workers.push(
                thread::Builder::new()
                    .name(format!("mos-serve-{wid}"))
                    .spawn(move || {
                        let mut engine = factory(wid);
                        // stepping engines decode per-run adapters, so
                        // their batches may mix tenants; the full-window
                        // fallback forwards one tenant at a time
                        let mix = engine.supports_steps();
                        while let Some(batch) = batcher.pop_batch(mix) {
                            serve_batch(
                                &registry, &metrics, &cache, &batcher,
                                &mut engine, batch, prefill_chunk,
                            );
                        }
                    })
                    .expect("spawn worker"),
            );
        }
    }

    /// Build a tenant from a spec and register it (replacing any previous
    /// registration under this id — the version bump makes the next
    /// factor lookup rebuild). The spec's [`QosSpec`] (weight, rate
    /// limit) is installed in the batcher as the tenant's scheduling
    /// contract. Returns LRU-evicted tenant ids.
    pub fn register(&self, id: &str, spec: TenantSpec) -> Result<Vec<String>> {
        let qos: QosSpec = spec.qos();
        // eviction victims are invalidated by the registry's evict hook
        let evicted = self.registry.register_spec(id, spec)?;
        self.cache.invalidate(id);
        self.batcher.set_qos(id, qos);
        Ok(evicted)
    }

    /// Drop a tenant, its cached factors, and its scheduling contract.
    /// Queued requests for it resolve to `Err(UnknownTenant)` when a
    /// worker picks them up.
    pub fn remove(&self, id: &str) -> bool {
        let removed = self.registry.remove(id);
        if removed {
            self.cache.invalidate(id);
            self.batcher.clear_qos(id);
        }
        removed
    }

    /// Ids of all registered tenants.
    pub fn tenant_ids(&self) -> Vec<String> {
        self.registry.ids()
    }

    /// Build the serving adapter for every registered tenant ahead of
    /// traffic (a zero-copy wrap on the pooled tier; the full dense
    /// materialization fan-out on the legacy tier). First requests then
    /// hit a warm cache instead of paying build latency. Returns the
    /// number of tenants warmed.
    pub fn prewarm(&self) -> usize {
        let tenants: Vec<Arc<Tenant>> = self
            .registry
            .ids()
            .iter()
            .filter_map(|id| self.registry.get(id))
            .collect();
        let n = tenants.len();
        let cfg = &self.registry.cfg;
        let cache = &*self.cache;
        crate::model::math::pool().scoped_map(tenants, |t| {
            cache.get(cfg, &t);
        });
        n
    }

    /// Enqueue a request with per-request generation options. Fails fast
    /// with a typed error (unknown tenant, full queue, shutdown); on
    /// success the returned handle streams tokens as they decode and
    /// resolves exactly once.
    pub fn submit(
        &self,
        tenant: &str,
        prompt: &str,
        opts: GenOptions,
    ) -> std::result::Result<ResponseHandle, ServeError> {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        if self.registry.get(tenant).is_none() {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::UnknownTenant(tenant.to_string()));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let (stream_tx, tokens_rx) = mpsc::channel();
        let cancelled = Arc::new(AtomicBool::new(false));
        let deadline = opts.deadline.map(|budget| Instant::now() + budget);
        self.batcher.push(Request {
            id,
            tenant: tenant.to_string(),
            prompt: prompt.to_string(),
            opts,
            deadline,
            respond: tx,
            stream: stream_tx,
            cancelled: Arc::clone(&cancelled),
            enqueued: Instant::now(),
        })?;
        Ok(ResponseHandle {
            id,
            tenant: tenant.to_string(),
            rx,
            tokens_rx,
            cancelled,
            batcher: Arc::clone(&self.batcher),
        })
    }

    /// Drain and stop all workers.
    pub fn shutdown(&mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One occupied decode slot: the request, its resolved tenant + serving
/// adapter (mixed batches resolve per request, not per batch), and
/// stream bookkeeping.
struct Slot {
    req: Request,
    tenant: Arc<Tenant>,
    adapter: ServingAdapter,
    ttft_recorded: bool,
}

/// Coalesce a tenant-sorted sequence of occupied slot rows into engine
/// runs (one run per maximal same-`(id, version)` stretch).
fn build_runs(
    slots: &[Option<Slot>],
    rows: impl Iterator<Item = usize>,
) -> Vec<EngineRun<'_>> {
    let mut runs: Vec<EngineRun> = Vec::new();
    for r in rows {
        let s = slots[r].as_ref().expect("run row must be occupied");
        match runs.last_mut() {
            Some(run)
                if run.tenant.id == s.tenant.id
                    && run.tenant.version == s.tenant.version =>
            {
                run.rows += 1
            }
            _ => runs.push(EngineRun {
                tenant: &*s.tenant,
                adapter: &s.adapter,
                rows: 1,
            }),
        }
    }
    runs
}

/// Push the pool's measured per-tenant KV bytes into the registry ledger
/// (a no-op set of zeros for engines without a paged pool).
fn sync_kv_ledger<E: ServeEngine>(
    registry: &Registry,
    engine: &E,
    seen: &[Arc<Tenant>],
) {
    if seen.is_empty() {
        return;
    }
    let mut ledger = registry.ledger.lock().unwrap();
    for t in seen {
        ledger.set_kv(&t.id, engine.kv_tenant_bytes(t));
    }
}

/// Stream a freshly decoded token to its client, recording time-to-first-
/// token on the first one.
fn stream_token(metrics: &Metrics, slots: &mut [Option<Slot>], row: usize, tok: i32) {
    if let Some(slot) = slots[row].as_mut() {
        if !slot.ttft_recorded {
            slot.ttft_recorded = true;
            metrics.record_ttft(slot.req.enqueued.elapsed());
        }
        let _ = slot.req.stream.send(tok);
    }
}

/// Resolve every finished row: take its output, free the slot, and send
/// the typed result (Ok, Deadline, or Cancelled). Returns the freed rows
/// so the caller can drop their KV page references ([`ServeEngine::
/// kv_release`]) — including for cancellations and expiries, which is
/// what makes a cancel storm return the pool to baseline.
fn sweep_finished(
    st: &mut DecodeState,
    slots: &mut [Option<Slot>],
    metrics: &Metrics,
    tk: &Tokenizer,
) -> Vec<usize> {
    let mut freed = Vec::new();
    for row in 0..slots.len() {
        if slots[row].is_none() || !st.row_done(row) {
            continue;
        }
        let expired = st.row_expired(row);
        let slot = slots[row].take().unwrap();
        let cancelled = slot.req.is_cancelled();
        let out = st.release(row);
        freed.push(row);
        if expired {
            metrics.expired.fetch_add(1, Ordering::Relaxed);
            let _ = slot.req.respond.send(Err(ServeError::Deadline));
        } else if cancelled {
            metrics.cancelled.fetch_add(1, Ordering::Relaxed);
            let _ = slot.req.respond.send(Err(ServeError::Cancelled));
        } else {
            let latency = slot.req.enqueued.elapsed();
            metrics.record_latency(latency);
            metrics.record_served(&slot.req.tenant);
            if !slot.ttft_recorded {
                // zero-token generations: first (only) signal is resolution
                metrics.record_ttft(latency);
            }
            metrics
                .generated_tokens
                .fetch_add(out.len() as u64, Ordering::Relaxed);
            let _ = slot.req.respond.send(Ok(Response {
                id: slot.req.id,
                tenant: slot.req.tenant.clone(),
                prompt: slot.req.prompt.clone(),
                text: tk.decode(&out),
                tokens: out.len(),
                latency,
            }));
        }
    }
    freed
}

/// The worker decode loop for one popped batch: a slot table over the
/// engine's batch rows. KV-cached stepping when the engine supports it
/// (prefill per admission, then one single-position step per token);
/// full-window forwards otherwise. Between steps the loop admits newly
/// queued requests into freed slots (continuous batching via
/// [`Batcher::try_fill_any`] / [`Batcher::try_fill`]), enforces
/// deadlines and cancellations, and streams tokens.
///
/// Since PR 7 a stepping batch may **mix tenants**: each request
/// resolves its own tenant + adapter at admission, and every engine call
/// receives the batch as tenant-grouped [`EngineRun`]s (canonical GEMMs
/// make the grouping bitwise-invisible). KV residency is negotiated per
/// row through [`ServeEngine::kv_admit`]: a full pool parks the request
/// back in the queue until decode frees pages — bounded waiting, never
/// an OOM or a mid-decode failure — and only a request that could not
/// fit in an *empty* pool resolves `Err(Engine)` at admission.
///
/// An engine error short-circuits: every in-flight request resolves
/// `Err(Engine)` immediately instead of burning the remaining window of
/// forwards on garbage logits.
fn serve_batch<E: ServeEngine>(
    registry: &Registry,
    metrics: &Metrics,
    cache: &AdapterCache,
    batcher: &Batcher,
    engine: &mut E,
    batch: Vec<Request>,
    prefill_chunk: Option<usize>,
) {
    metrics.record_batch(batch.len());
    let (bsz, seq, vocab) = engine.shape();
    let tk = Tokenizer::new();
    let stepping = engine.supports_steps();

    let mut st = DecodeState::vacant(bsz, seq, vocab);
    let mut slots: Vec<Option<Slot>> = (0..bsz).map(|_| None).collect();
    let mut pending: VecDeque<Request> = batch.into();
    let mut engine_err: Option<ServeError> = None;
    // distinct tenant ids this batch touched — the ledger KV sync set
    let mut seen: Vec<Arc<Tenant>> = Vec::new();
    // rows whose prefill is mid-flight under chunking (PR 9): they
    // advance one chunk per loop iteration, interleaved with the decode
    // steps of already-prefilled rows, and emit no decode entries until
    // their first token arrives from the final chunk's lean logits
    let mut prefill_q: Vec<usize> = Vec::new();

    loop {
        // ---- between-step enforcement: deadlines + cancellations ----
        let now = Instant::now();
        st.expire_overdue(now);
        for (row, slot) in slots.iter().enumerate() {
            if let Some(s) = slot {
                if !st.row_done(row) && s.req.is_cancelled() {
                    st.finish_row(row);
                }
            }
        }
        // requests parked in the local overflow (popped batch larger than
        // the slot table, or waiting out a full KV pool) resolve
        // cancel/deadline now, not once a slot happens to free for them
        if !pending.is_empty() {
            let mut kept = VecDeque::with_capacity(pending.len());
            for req in pending.drain(..) {
                if req.is_cancelled() {
                    metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                    let _ = req.respond.send(Err(ServeError::Cancelled));
                } else if req.is_expired(now) {
                    metrics.expired.fetch_add(1, Ordering::Relaxed);
                    let _ = req.respond.send(Err(ServeError::Deadline));
                } else {
                    kept.push_back(req);
                }
            }
            pending = kept;
        }
        for r in sweep_finished(&mut st, &mut slots, metrics, &tk) {
            engine.kv_release(r);
        }

        // ---- drained? ----
        if slots.iter().all(|s| s.is_none()) && pending.is_empty() {
            sync_kv_ledger(registry, engine, &seen);
            return;
        }

        // ---- admit new work into free slots (continuous batching) ----
        let free: Vec<usize> =
            (0..bsz).filter(|&r| slots[r].is_none()).collect();
        if !free.is_empty() {
            let mut incoming: Vec<Request> = Vec::new();
            while incoming.len() < free.len() {
                match pending.pop_front() {
                    Some(r) => incoming.push(r),
                    None => break,
                }
            }
            // top up from the queue only while a batch is running here —
            // an empty table means this worker should return to pop_batch
            // (and its round-robin fairness) instead
            let running =
                slots.iter().any(|s| s.is_some()) || !incoming.is_empty();
            if running && incoming.len() < free.len() {
                let want = free.len() - incoming.len();
                let refill = if stepping {
                    // mixed batches: drain whichever tenants are queued
                    batcher.try_fill_any(want)
                } else {
                    // full-window batches are single-tenant (mix=false
                    // pops): refill from the batch's own tenant
                    let tid = slots
                        .iter()
                        .flatten()
                        .map(|s| s.req.tenant.as_str())
                        .chain(incoming.iter().map(|r| r.tenant.as_str()))
                        .next()
                        .map(str::to_string);
                    match tid {
                        Some(t) => batcher.try_fill(&t, want),
                        None => Vec::new(),
                    }
                };
                metrics.record_refill(refill.len());
                incoming.extend(refill);
            }
            let now = Instant::now();
            let mut free_iter = free.into_iter();
            let mut newly: Vec<usize> = Vec::new();
            // requests a full KV pool bounced this round — they go back
            // to the *front* of the overflow, in order, and retry as
            // decode frees pages (degradation to queueing)
            let mut parked: Vec<Request> = Vec::new();
            for req in incoming {
                if req.is_cancelled() {
                    metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                    let _ = req.respond.send(Err(ServeError::Cancelled));
                    continue;
                }
                if req.is_expired(now) {
                    metrics.expired.fetch_add(1, Ordering::Relaxed);
                    let _ = req.respond.send(Err(ServeError::Deadline));
                    continue;
                }
                // mixed batches resolve tenant + adapter per request
                let Some(tenant) = registry.get(&req.tenant) else {
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = req.respond.send(Err(ServeError::UnknownTenant(
                        req.tenant.clone(),
                    )));
                    continue;
                };
                let adapter = cache.get(&registry.cfg, &tenant);
                let row =
                    free_iter.next().expect("incoming exceeds free slots");
                let prompt = tk.prompt_tokens(&req.prompt);
                st.admit(row, &prompt, req.opts.clone(), req.deadline);
                if stepping && !st.row_done(row) {
                    let n = prompt.len().min(seq);
                    if !engine.kv_admit(row, &tenant, &prompt[..n]) {
                        // roll the admission back and decide: park while
                        // anything else holds pages (they free as it
                        // finishes), error only if even an empty pool
                        // cannot cover the request
                        let _ = st.release(row);
                        if slots.iter().any(|s| s.is_some())
                            || !newly.is_empty()
                        {
                            parked.push(req);
                        } else {
                            metrics.errors.fetch_add(1, Ordering::Relaxed);
                            let _ = req.respond.send(Err(ServeError::Engine(
                                "KV pool cannot fit request".to_string(),
                            )));
                        }
                        continue;
                    }
                }
                if !seen.iter().any(|t| t.id == tenant.id) {
                    seen.push(Arc::clone(&tenant));
                }
                slots[row] =
                    Some(Slot { req, tenant, adapter, ttft_recorded: false });
                newly.push(row);
            }
            for req in parked.into_iter().rev() {
                pending.push_front(req);
            }

            // KV path: prefill freshly admitted rows, emit first tokens.
            // Rows are sorted by tenant so the batch forms contiguous
            // engine runs; canonical GEMMs keep each row's logits bitwise
            // independent of the grouping.
            let mut live_new: Vec<usize> =
                newly.into_iter().filter(|&r| !st.row_done(r)).collect();
            if stepping && prefill_chunk.is_some() {
                // chunked mode: defer to the chunk-advance section below
                // so the prompt prefills chunk-by-chunk between decode
                // rounds instead of in one engine-monopolizing call
                prefill_q.extend(live_new.drain(..));
            }
            if stepping && !live_new.is_empty() {
                live_new.sort_by(|&a, &b| {
                    let ka = slots[a]
                        .as_ref()
                        .map(|s| (&s.tenant.id, s.tenant.version));
                    let kb = slots[b]
                        .as_ref()
                        .map(|s| (&s.tenant.id, s.tenant.version));
                    ka.cmp(&kb)
                });
                let mut toks = Vec::with_capacity(live_new.len() * seq);
                for &r in &live_new {
                    toks.extend_from_slice(&st.tokens()[r * seq..(r + 1) * seq]);
                }
                let last: Vec<usize> =
                    live_new.iter().map(|&r| st.last_pos(r)).collect();
                let t0 = Instant::now();
                let res = {
                    let runs = build_runs(&slots, live_new.iter().copied());
                    engine.prefill_rows(&runs, &live_new, &toks, &last)
                };
                match res {
                    Ok(logits) => {
                        metrics.record_prefill(t0.elapsed());
                        for (row, tok) in st.step_prefill(&live_new, &logits) {
                            stream_token(metrics, &mut slots, row, tok);
                        }
                        // lean logits are arena-backed: recycle them so the
                        // admission path stays allocation-free steady-state
                        scratch_put(logits);
                    }
                    Err(e) => {
                        engine_err = Some(ServeError::Engine(e.to_string()));
                    }
                }
            }
            for r in sweep_finished(&mut st, &mut slots, metrics, &tk) {
                engine.kv_release(r);
            }
        }

        // ---- chunked prefill: one chunk per pending prompt per round ----
        // cancelled/expired rows were swept above; drop them from the
        // queue before handing it to the engine
        prefill_q.retain(|&r| slots[r].is_some() && !st.row_done(r));
        if engine_err.is_none() && !prefill_q.is_empty() {
            let chunk = prefill_chunk.expect("prefill_q only fills chunked");
            // tenant-sorted like every engine call, so the queue forms
            // contiguous runs (stable sort keeps admission order within
            // a tenant)
            prefill_q.sort_by(|&a, &b| {
                let ka = slots[a]
                    .as_ref()
                    .map(|s| (&s.tenant.id, s.tenant.version));
                let kb = slots[b]
                    .as_ref()
                    .map(|s| (&s.tenant.id, s.tenant.version));
                ka.cmp(&kb)
            });
            let mut toks = Vec::with_capacity(prefill_q.len() * seq);
            for &r in &prefill_q {
                toks.extend_from_slice(&st.tokens()[r * seq..(r + 1) * seq]);
            }
            let last: Vec<usize> =
                prefill_q.iter().map(|&r| st.last_pos(r)).collect();
            let t0 = Instant::now();
            let res = {
                let runs = build_runs(&slots, prefill_q.iter().copied());
                engine.prefill_rows_partial(
                    &runs, &prefill_q, &toks, &last, chunk,
                )
            };
            match res {
                Ok((done_idx, logits)) => {
                    metrics.record_prefill(t0.elapsed());
                    let done_rows: Vec<usize> =
                        done_idx.iter().map(|&i| prefill_q[i]).collect();
                    for (row, tok) in st.step_prefill(&done_rows, &logits) {
                        stream_token(metrics, &mut slots, row, tok);
                    }
                    scratch_put(logits);
                    prefill_q.retain(|r| !done_rows.contains(r));
                }
                Err(e) => {
                    engine_err = Some(ServeError::Engine(e.to_string()));
                }
            }
            for r in sweep_finished(&mut st, &mut slots, metrics, &tk) {
                engine.kv_release(r);
            }
        }

        // ---- engine-error short-circuit ----
        if engine_err.is_none() {
            // ---- one decode step for every live row ----
            let live = st.live_rows();
            if !live.is_empty() {
                if stepping {
                    // rows still mid-chunked-prefill have no first token
                    // yet and emit no decode entry this round
                    let mut entries = st.step_entries_decoding();
                    // group by tenant for the run slice; step_rows pairs
                    // logits back by entry order, so the sort is safe
                    entries.sort_by(|a, b| {
                        let ka = slots[a.0]
                            .as_ref()
                            .map(|s| (&s.tenant.id, s.tenant.version));
                        let kb = slots[b.0]
                            .as_ref()
                            .map(|s| (&s.tenant.id, s.tenant.version));
                        ka.cmp(&kb)
                    });
                    let res = if entries.is_empty() {
                        // everything live is still prefilling
                        Ok(Vec::new())
                    } else {
                        let runs =
                            build_runs(&slots, entries.iter().map(|e| e.0));
                        engine.decode_rows(&runs, &entries)
                    };
                    match res {
                        Ok(logits) => {
                            for (row, tok) in st.step_rows(&entries, &logits) {
                                stream_token(metrics, &mut slots, row, tok);
                            }
                            // arena-backed (see decode_step_runs): recycle
                            scratch_put(logits);
                        }
                        Err(e) => {
                            engine_err =
                                Some(ServeError::Engine(e.to_string()));
                        }
                    }
                } else {
                    // full-window fallback: single-tenant by construction
                    // (mix=false pops), so any occupied slot names it
                    let (tenant, adapter) = {
                        let s = slots
                            .iter()
                            .flatten()
                            .next()
                            .expect("live rows require an occupied slot");
                        (Arc::clone(&s.tenant), s.adapter.clone())
                    };
                    match engine.forward(&tenant, &adapter, st.tokens()) {
                        Ok(logits) => {
                            for (row, tok) in st.step_full(&logits) {
                                stream_token(metrics, &mut slots, row, tok);
                            }
                            // engine-allocated (not arena-origin): Arena::put
                            // is capacity-capped, so parking these cannot
                            // grow the worker's free list without bound
                            scratch_put(logits);
                        }
                        Err(e) => {
                            engine_err =
                                Some(ServeError::Engine(e.to_string()));
                        }
                    }
                }
            }
        }
        if let Some(e) = engine_err.take() {
            // stop immediately: zeroed-logit decoding used to argmax PAD
            // and burn the whole remaining window before reporting
            for slot in slots.iter_mut().filter_map(Option::take) {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                let _ = slot.req.respond.send(Err(e.clone()));
            }
            for req in pending.drain(..) {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(e.clone()));
            }
            for r in 0..bsz {
                engine.kv_release(r);
            }
            sync_kv_ledger(registry, engine, &seen);
            return;
        }
        sync_kv_ledger(registry, engine, &seen);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use std::sync::atomic::AtomicUsize;

    fn make_server(capacity: usize) -> (Server, crate::config::ModelCfg) {
        let mut cfg = presets::tiny();
        cfg.batch = 4; // keep unit tests fast
        let registry = Arc::new(Registry::new(cfg.clone(), capacity));
        let server = Server::new(
            registry,
            ServerCfg {
                max_batch: 4,
                max_wait: Duration::from_millis(10),
                cache_capacity: 8,
                ..ServerCfg::default()
            },
        );
        (server, cfg)
    }

    fn spec(seed: u64) -> TenantSpec {
        TenantSpec::mos(4, 2, 2, 0).seed(seed)
    }

    /// Counts forwards; optionally fails every call.
    struct CountingEngine {
        inner: HostEngine,
        calls: Arc<AtomicUsize>,
        fail: bool,
    }

    impl ServeEngine for CountingEngine {
        fn forward(
            &mut self,
            tenant: &Tenant,
            adapter: &ServingAdapter,
            tokens: &[i32],
        ) -> Result<Vec<f32>> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            if self.fail {
                anyhow::bail!("injected engine failure");
            }
            self.inner.forward(tenant, adapter, tokens)
        }
        fn shape(&self) -> (usize, usize, usize) {
            self.inner.shape()
        }
    }

    #[test]
    fn serves_requests_end_to_end() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        server.register("bob", spec(2)).unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let mut handles = Vec::new();
        for i in 0..6 {
            let tenant = if i % 2 == 0 { "alice" } else { "bob" };
            handles.push(
                server
                    .submit(tenant, &format!("q:{i}"), GenOptions::greedy())
                    .unwrap(),
            );
        }
        for (i, h) in handles.into_iter().enumerate() {
            let resp = h.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
            assert_eq!(resp.prompt, format!("q:{i}"));
            assert_eq!(resp.id, i as RequestId);
        }
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 6);
        server.shutdown();
    }

    #[test]
    fn int8_serving_end_to_end() {
        // MOS_SERVE_INT8 wiring, pinned explicitly: registry charges the
        // analytic int8 bytes, the cache builds PooledInt8 entries, the
        // engine serves the quantized stepping path, and requests resolve
        let mut cfg = presets::tiny();
        cfg.batch = 4;
        let registry = Arc::new(
            Registry::with_serve_mode(cfg.clone(), 1 << 30, false)
                .with_int8(true),
        );
        let mut server = Server::new(
            registry,
            ServerCfg {
                max_batch: 4,
                max_wait: Duration::from_millis(10),
                cache_capacity: 8,
                ..ServerCfg::default()
            },
        );
        server.register("alice", spec(1)).unwrap();
        let cfg2 = cfg.clone();
        server
            .start(1, move |_| HostEngine::new(cfg2.clone(), 0).serve_int8());
        let handles: Vec<_> = (0..4)
            .map(|i| {
                server
                    .submit(
                        "alice",
                        &format!("q:{i}"),
                        GenOptions::greedy().max_new_tokens(8),
                    )
                    .unwrap()
            })
            .collect();
        for h in handles {
            h.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
        }
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 4);
        let t = server.registry.get("alice").unwrap();
        let a = server.cache.get(&cfg, &t);
        let q = a.pooled_int8().expect("int8 registry must serve PooledInt8");
        assert_eq!(
            q.resident_bytes(),
            server.registry.resident_bytes_for(&t),
            "ledger charge diverges from measured int8 residency"
        );
        server.shutdown();

        // the quantized base strips its f32 projections: well under the
        // f32 engine's residency, and the full-window arm refuses to run
        let f32_engine = HostEngine::new(cfg.clone(), 0);
        let mut int8_engine = HostEngine::new(cfg.clone(), 0).serve_int8();
        assert!(
            int8_engine.base_resident_bytes() * 100
                <= f32_engine.base_resident_bytes() * 35,
            "int8 base {} B vs f32 base {} B: > 0.35x",
            int8_engine.base_resident_bytes(),
            f32_engine.base_resident_bytes()
        );
        let toks = vec![0i32; cfg.batch * cfg.seq];
        assert!(
            int8_engine.forward(&t, &a, &toks).is_err(),
            "full-window forward must refuse the int8 base"
        );
    }

    #[test]
    fn kv_and_full_window_paths_agree() {
        // the KV-cached stepping path must serve exactly the text the
        // full-window fallback serves (bitwise logits => same tokens)
        let serve_with = |full_window: bool| -> Vec<String> {
            let (mut server, cfg) = make_server(1 << 30);
            server.register("alice", spec(7)).unwrap();
            let cfg2 = cfg.clone();
            if full_window {
                server.start(1, move |_| {
                    FullWindowEngine(HostEngine::new(cfg2.clone(), 0))
                });
            } else {
                server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
            }
            let handles: Vec<_> = (0..3)
                .map(|i| {
                    server
                        .submit(
                            "alice",
                            &format!("q:{i}"),
                            GenOptions::greedy().max_new_tokens(12),
                        )
                        .unwrap()
                })
                .collect();
            let texts = handles
                .into_iter()
                .map(|h| {
                    h.wait_timeout(Duration::from_secs(30))
                        .unwrap()
                        .unwrap()
                        .text
                })
                .collect();
            server.shutdown();
            texts
        };
        assert_eq!(serve_with(false), serve_with(true));
    }

    #[test]
    fn lean_and_full_forward_prefill_serve_identical_text() {
        // PR-5 contract: the lean inference-only prefill must serve
        // exactly what the legacy training-forward prefill serves
        // (bitwise logits => identical tokens), including mixed lengths
        let serve_with = |full_prefill: bool| -> Vec<String> {
            let (mut server, cfg) = make_server(1 << 30);
            server.register("alice", spec(13)).unwrap();
            let cfg2 = cfg.clone();
            server.start(1, move |_| {
                let e = HostEngine::new(cfg2.clone(), 0);
                if full_prefill {
                    e.full_prefill()
                } else {
                    e
                }
            });
            let handles: Vec<_> = ["q:a", "q:longer prompt", "q:b"]
                .iter()
                .map(|&p| {
                    server
                        .submit(
                            "alice",
                            p,
                            GenOptions::greedy().max_new_tokens(10),
                        )
                        .unwrap()
                })
                .collect();
            let texts = handles
                .into_iter()
                .map(|h| {
                    h.wait_timeout(Duration::from_secs(30))
                        .unwrap()
                        .unwrap()
                        .text
                })
                .collect();
            server.shutdown();
            texts
        };
        assert_eq!(serve_with(false), serve_with(true));
    }

    #[test]
    fn streamed_tokens_match_final_text() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let h = server
            .submit(
                "alice",
                "q:stream",
                GenOptions::greedy().max_new_tokens(8),
            )
            .unwrap();
        let streamed: Vec<i32> = h.tokens().collect();
        let resp = h.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(resp.tokens, streamed.len());
        assert_eq!(resp.text, Tokenizer::new().decode(&streamed));
        server.shutdown();
    }

    #[test]
    fn engine_error_short_circuits_decode() {
        // regression: zeroed logits after an engine error used to decode
        // PAD tokens to the full window (O(seq) wasted forwards) before
        // the error surfaced
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let calls = Arc::new(AtomicUsize::new(0));
        let calls2 = Arc::clone(&calls);
        let cfg2 = cfg.clone();
        server.start(1, move |_| CountingEngine {
            inner: HostEngine::new(cfg2.clone(), 0),
            calls: Arc::clone(&calls2),
            fail: true,
        });
        let h1 = server.submit("alice", "q:a", GenOptions::greedy()).unwrap();
        let h2 = server.submit("alice", "q:b", GenOptions::greedy()).unwrap();
        for h in [h1, h2] {
            match h.wait_timeout(Duration::from_secs(30)).unwrap() {
                Err(ServeError::Engine(msg)) => {
                    assert!(msg.contains("injected"), "{msg}")
                }
                other => panic!("expected engine error, got {other:?}"),
            }
        }
        assert!(
            calls.load(Ordering::Relaxed) <= 2,
            "engine error did not short-circuit: {} forwards",
            calls.load(Ordering::Relaxed)
        );
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 0);
        server.shutdown();
    }

    #[test]
    fn filler_rows_consume_no_decode_steps() {
        // regression: a 1-request batch on a batch-4 engine used to pad
        // with [BOS] rows that decoded garbage to the full window
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let calls = Arc::new(AtomicUsize::new(0));
        let calls2 = Arc::clone(&calls);
        let cfg2 = cfg.clone();
        // full-window fallback: each decode step is one counted forward
        server.start(1, move |_| {
            FullWindowEngine(CountingEngine {
                inner: HostEngine::new(cfg2.clone(), 0),
                calls: Arc::clone(&calls2),
                fail: false,
            })
        });
        let h = server
            .submit(
                "alice",
                "q:solo",
                GenOptions::greedy().max_new_tokens(2),
            )
            .unwrap();
        let resp = h.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert!(resp.tokens <= 2);
        // the live row needs at most max_new_tokens + 1 forwards; filler
        // rows decoding to the window would need ~seq
        let n = calls.load(Ordering::Relaxed);
        assert!(n <= 3, "filler rows consumed decode steps: {n} forwards");
        server.shutdown();
    }

    #[test]
    fn cancel_wakes_idle_queue_immediately() {
        // regression: cancel used to flip the flag without waking the
        // batcher, delaying resolution by up to max_wait on an idle queue
        let mut cfg = presets::tiny();
        cfg.batch = 4;
        let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
        let mut server = Server::new(
            registry,
            ServerCfg {
                max_batch: 4,
                max_wait: Duration::from_secs(30),
                ..ServerCfg::default()
            },
        );
        server.register("alice", spec(1)).unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        // give the worker a moment to go idle in pop_batch
        thread::sleep(Duration::from_millis(30));
        let h = server
            .submit("alice", "q:cancel", GenOptions::greedy())
            .unwrap();
        h.cancel();
        let t0 = Instant::now();
        assert_eq!(
            h.wait_timeout(Duration::from_secs(5)),
            Some(Err(ServeError::Cancelled)),
            "cancel resolution stalled behind max_wait"
        );
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 0);
        server.shutdown();
    }

    #[test]
    fn dead_requests_do_not_hold_admission_depth() {
        // regression: cancelled requests used to occupy Admission depth
        // until the next pop_batch, rejecting live submits as QueueFull
        let mut cfg = presets::tiny();
        cfg.batch = 4;
        let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
        let server = Server::new(
            registry,
            ServerCfg {
                admission: Admission { per_tenant: 2, global: 100 },
                ..ServerCfg::default()
            },
        );
        server.register("alice", spec(1)).unwrap();
        // no workers: the queue only fills
        let h1 = server.submit("alice", "q:0", GenOptions::greedy()).unwrap();
        let h2 = server.submit("alice", "q:1", GenOptions::greedy()).unwrap();
        h1.cancel();
        h2.cancel();
        let h3 = server
            .submit("alice", "q:2", GenOptions::greedy())
            .expect("dead requests held QueueFull against a live submit");
        assert_eq!(h1.wait(), Err(ServeError::Cancelled));
        assert_eq!(h2.wait(), Err(ServeError::Cancelled));
        assert_eq!(server.metrics.rejected.load(Ordering::Relaxed), 0);
        drop(h3);
    }

    #[test]
    fn unknown_tenant_fails_at_submit() {
        let (server, _cfg) = make_server(1 << 30);
        let err = server
            .submit("ghost", "hello", GenOptions::greedy())
            .unwrap_err();
        assert_eq!(err, ServeError::UnknownTenant("ghost".into()));
    }

    #[test]
    fn tenant_removed_after_submit_errors_in_response() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let h = server
            .submit("alice", "q:x", GenOptions::greedy())
            .unwrap();
        assert!(server.remove("alice"));
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        assert_eq!(
            h.wait(),
            Err(ServeError::UnknownTenant("alice".into()))
        );
        server.shutdown();
    }

    #[test]
    fn queue_full_rejected_at_submit() {
        let mut cfg = presets::tiny();
        cfg.batch = 4;
        let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
        let server = Server::new(
            registry,
            ServerCfg {
                admission: Admission { per_tenant: 2, global: 100 },
                ..ServerCfg::default()
            },
        );
        server.register("alice", spec(1)).unwrap();
        // no workers: the queue only fills
        let _h1 = server.submit("alice", "q:0", GenOptions::greedy()).unwrap();
        let _h2 = server.submit("alice", "q:1", GenOptions::greedy()).unwrap();
        let err = server
            .submit("alice", "q:2", GenOptions::greedy())
            .unwrap_err();
        assert_eq!(err, ServeError::QueueFull { tenant: "alice".into() });
        assert_eq!(server.metrics.rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn cancelled_request_resolves_cancelled() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let h = server
            .submit("alice", "q:cancel", GenOptions::greedy())
            .unwrap();
        h.cancel();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        assert_eq!(h.wait(), Err(ServeError::Cancelled));
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 0);
        server.shutdown();
    }

    #[test]
    fn expired_deadline_resolves_deadline() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let h = server
            .submit(
                "alice",
                "q:late",
                GenOptions::greedy().deadline(Duration::ZERO),
            )
            .unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        assert_eq!(h.wait(), Err(ServeError::Deadline));
        server.shutdown();
    }

    #[test]
    fn sampling_deterministic_through_server() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let opts = GenOptions::sample(0.9, 8, 1234).max_new_tokens(12);
        let run = |prompt: &str| {
            server
                .submit("alice", prompt, opts.clone())
                .unwrap()
                .wait_timeout(Duration::from_secs(30))
                .unwrap()
                .unwrap()
        };
        let a = run("q:sample");
        let b = run("q:sample");
        assert_eq!(a.text, b.text, "same per-request seed must reproduce");
        server.shutdown();
    }

    #[test]
    fn reregister_serves_fresh_factors() {
        // regression for the stale-factors bug: re-registering a tenant
        // with new params must not serve the old dense factors
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let first = server
            .submit("alice", "q:00", GenOptions::greedy())
            .unwrap()
            .wait_timeout(Duration::from_secs(30))
            .unwrap()
            .unwrap();
        server.register("alice", spec(99)).unwrap();
        let tenant = server.registry.get("alice").unwrap();
        assert_eq!(tenant.version, 1);
        let refreshed = server
            .submit("alice", "q:00", GenOptions::greedy())
            .unwrap()
            .wait_timeout(Duration::from_secs(30))
            .unwrap()
            .unwrap();
        // the cache must have rebuilt for the new version (numeric factor
        // freshness is asserted in cache::tests::reregistered_tenant_...)
        let (_, misses) = server.cache.stats();
        assert_eq!(misses, 2, "re-registered tenant served stale factors");
        let _ = (first, refreshed);
        server.shutdown();
    }

    #[test]
    fn lifecycle_register_remove_ids() {
        let (server, _cfg) = make_server(1 << 30);
        server.register("a", spec(1)).unwrap();
        server.register("b", spec(2)).unwrap();
        let mut ids = server.tenant_ids();
        ids.sort();
        assert_eq!(ids, vec!["a".to_string(), "b".to_string()]);
        assert!(server.remove("a"));
        assert!(!server.remove("a"));
        assert_eq!(server.tenant_ids(), vec!["b".to_string()]);
    }

    #[test]
    fn prewarm_materializes_every_tenant_once() {
        let (mut server, cfg) = make_server(1 << 30);
        for (i, id) in ["alice", "bob", "carol"].iter().enumerate() {
            server.register(id, spec(i as u64 + 1)).unwrap();
        }
        assert_eq!(server.prewarm(), 3);
        assert_eq!(server.cache.stats(), (0, 3));
        // traffic after prewarm only hits the cache
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        for id in ["alice", "bob", "carol"] {
            let h = server.submit(id, "q:warm", GenOptions::greedy()).unwrap();
            assert!(h.wait_timeout(Duration::from_secs(30)).unwrap().is_ok());
        }
        let (hits, misses) = server.cache.stats();
        assert_eq!(misses, 3, "prewarmed tenants must not re-materialize");
        assert!(hits >= 3);
        server.shutdown();
    }

    #[test]
    fn cache_reused_across_requests() {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        for _ in 0..3 {
            let h = server.submit("alice", "q:aa", GenOptions::greedy()).unwrap();
            h.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
        }
        let (hits, misses) = server.cache.stats();
        assert_eq!(misses, 1, "factors must be materialized exactly once");
        assert!(hits >= 1);
        server.shutdown();
    }

    #[test]
    fn ledger_eviction_invalidates_cache_entry() {
        // ledger<->cache coherence: when registering "c" LRU-evicts "a"
        // from the registry, the server's adapter cache must drop a's
        // entry too (via the evict hook) — otherwise the "evicted" tenant
        // keeps its adapter resident and the ledger's byte accounting lies
        let mut cfg = presets::tiny();
        cfg.batch = 4;
        let one = crate::adapter::params::serving_bytes(
            &cfg,
            spec(1).method_cfg(),
            4,
        );
        let registry = Arc::new(Registry::with_serve_mode(
            cfg.clone(),
            2 * one + one / 2,
            false,
        ));
        let server = Server::new(registry, ServerCfg::default());
        server.register("a", spec(1)).unwrap();
        server.register("b", spec(2)).unwrap();
        assert_eq!(server.prewarm(), 2);
        assert_eq!(server.cache.len(), 2);
        let _ = server.registry.get("b"); // touch b; a is LRU
        let evicted = server.register("c", spec(3)).unwrap();
        assert_eq!(evicted, vec!["a".to_string()]);
        assert_eq!(
            server.cache.len(),
            1,
            "evicted tenant's cache entry lingered"
        );
        // the survivor still hits its warm entry
        let (_, m0) = server.cache.stats();
        let b = server.registry.get("b").unwrap();
        server.cache.get(&server.registry.cfg, &b);
        let (_, m1) = server.cache.stats();
        assert_eq!(m1, m0, "survivor was needlessly rebuilt");
    }

    #[test]
    fn register_plumbs_qos_to_batcher() {
        // ISSUE 9 tentpole (a): the TenantSpec's scheduling contract must
        // reach the batcher at register and leave at remove
        let (server, _cfg) = make_server(1 << 30);
        server
            .register("alice", spec(1).weight(4).rate_limit(1000.0, 64.0))
            .unwrap();
        let q = server.batcher.qos_of("alice").unwrap();
        assert_eq!(q.weight, 4);
        assert_eq!(q.rate_tok_per_s, Some(1000.0));
        assert_eq!(q.burst, 64.0);
        // an unadorned spec installs the default contract
        server.register("bob", spec(2)).unwrap();
        assert_eq!(server.batcher.qos_of("bob").unwrap(), QosSpec::default());
        assert!(server.remove("alice"));
        assert!(server.batcher.qos_of("alice").is_none());
    }

    #[test]
    fn chunked_prefill_matches_one_shot_bitwise() {
        // ISSUE 9 acceptance: chunked prefill must serve exactly what the
        // one-shot prefill serves, through the full server, with prompts
        // that end on a chunk boundary, mid-chunk, and below one chunk —
        // and with mixed tenants so run grouping is exercised too
        let serve_with = |chunk: Option<usize>| -> Vec<String> {
            let mut cfg = presets::tiny();
            cfg.batch = 4;
            let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
            let mut server = Server::new(
                registry,
                ServerCfg {
                    max_batch: 4,
                    max_wait: Duration::from_millis(10),
                    cache_capacity: 8,
                    prefill_chunk: chunk,
                    ..ServerCfg::default()
                },
            );
            server.register("alice", spec(7)).unwrap();
            server.register("bob", spec(8)).unwrap();
            let prompts = [
                "q:a",
                "q:a considerably longer prompt",
                "q:bb",
                "q:medium length!",
            ];
            let mut hs = Vec::new();
            for (i, p) in prompts.iter().enumerate() {
                let t = if i % 2 == 0 { "alice" } else { "bob" };
                hs.push(
                    server
                        .submit(t, p, GenOptions::greedy().max_new_tokens(8))
                        .unwrap(),
                );
            }
            let cfg2 = cfg.clone();
            server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
            let texts = hs
                .into_iter()
                .map(|h| {
                    h.wait_timeout(Duration::from_secs(30))
                        .unwrap()
                        .unwrap()
                        .text
                })
                .collect();
            server.shutdown();
            texts
        };
        let oneshot = serve_with(None);
        assert!(!oneshot.iter().all(|t| t.is_empty()));
        for chunk in [1, 3, 5, 64] {
            assert_eq!(
                serve_with(Some(chunk)),
                oneshot,
                "chunk={chunk} diverged from one-shot prefill"
            );
        }
    }

    #[test]
    fn two_tenant_mixed_batch_matches_single_tenant_batches() {
        // PR-7 satellite: a mixed alice+bob batch must decode each
        // request bitwise-identically to serving its tenant alone —
        // per-run adapter bindings + canonical GEMMs make the batch
        // composition invisible (same contract the transformer-level
        // runs tests pin, here proven through the whole server stack)
        let opts = || GenOptions::greedy().max_new_tokens(10);
        let solo = |tenant: &str, seed: u64| -> Vec<String> {
            let (mut server, cfg) = make_server(1 << 30);
            server.register(tenant, spec(seed)).unwrap();
            let hs: Vec<_> = (0..2)
                .map(|i| {
                    server.submit(tenant, &format!("q:{i}"), opts()).unwrap()
                })
                .collect();
            let cfg2 = cfg.clone();
            server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
            let texts = hs
                .into_iter()
                .map(|h| {
                    h.wait_timeout(Duration::from_secs(30))
                        .unwrap()
                        .unwrap()
                        .text
                })
                .collect();
            server.shutdown();
            texts
        };
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        server.register("bob", spec(2)).unwrap();
        // submit interleaved before starting the worker: one aged pop
        // drains alice then tops up with bob — a genuinely mixed batch
        let mut hs = Vec::new();
        for i in 0..2 {
            hs.push(server.submit("alice", &format!("q:{i}"), opts()).unwrap());
            hs.push(server.submit("bob", &format!("q:{i}"), opts()).unwrap());
        }
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let mixed: Vec<String> = hs
            .into_iter()
            .map(|h| {
                h.wait_timeout(Duration::from_secs(30)).unwrap().unwrap().text
            })
            .collect();
        server.shutdown();
        let a = solo("alice", 1);
        let b = solo("bob", 2);
        assert_eq!(&mixed[0], &a[0], "alice q:0 diverged in the mixed batch");
        assert_eq!(&mixed[2], &a[1], "alice q:1 diverged in the mixed batch");
        assert_eq!(&mixed[1], &b[0], "bob q:0 diverged in the mixed batch");
        assert_eq!(&mixed[3], &b[1], "bob q:1 diverged in the mixed batch");
    }

    #[test]
    fn ledger_tracks_paged_kv_resident_bytes() {
        // PR-7 satellite: the registry ledger's KV side-table must equal
        // the pool's measured resident bytes — owner tags partition the
        // pool, so summing per-tenant charges reconstructs the total
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        server.register("bob", spec(2)).unwrap();
        let probe = Arc::new(KvStats::default());
        let probe2 = Arc::clone(&probe);
        let cfg2 = cfg.clone();
        // page_tokens 2: short prompts still fill whole pages, so prefix
        // retentions keep bytes resident after the requests finish
        server.start(1, move |_| {
            HostEngine::new(cfg2.clone(), 0)
                .kv_page_tokens(2)
                .kv_stats(Arc::clone(&probe2))
        });
        let hs: Vec<_> = (0..4)
            .map(|i| {
                let t = if i % 2 == 0 { "alice" } else { "bob" };
                server
                    .submit(
                        t,
                        &format!("q:{i}"),
                        GenOptions::greedy().max_new_tokens(6),
                    )
                    .unwrap()
            })
            .collect();
        for h in hs {
            h.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
        }
        // the worker's final ledger sync happens before it exits, so a
        // joined shutdown makes the comparison race-free
        server.shutdown();
        let ledger = server.registry.ledger.lock().unwrap();
        let total = probe.resident_bytes();
        assert!(total > 0, "prefix retentions should keep pages resident");
        assert_eq!(
            ledger.kv_used(),
            total,
            "ledger KV side-table != pool resident bytes"
        );
        assert!(ledger.kv_for("alice") > 0);
        assert!(ledger.kv_for("bob") > 0);
    }

    #[test]
    fn full_kv_pool_degrades_to_queueing() {
        // tentpole acceptance: a pool sized for a single row never OOMs
        // and never fails mid-decode — excess requests wait at admission
        // and every one of them eventually resolves Ok
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let cfg2 = cfg.clone();
        // seq 48, page_tokens 16 => a full window reserves exactly 3
        // pages: capacity 3 serves one request at a time
        server.start(1, move |_| {
            HostEngine::new(cfg2.clone(), 0)
                .kv_capacity_pages(3)
                .no_prefix_share()
        });
        let hs: Vec<_> = (0..5)
            .map(|i| {
                server
                    .submit(
                        "alice",
                        &format!("q:{i}"),
                        GenOptions::greedy().max_new_tokens(8),
                    )
                    .unwrap()
            })
            .collect();
        for h in hs {
            let r = h.wait_timeout(Duration::from_secs(60)).unwrap();
            assert!(r.is_ok(), "pool saturation must queue, not error: {r:?}");
        }
        assert_eq!(server.metrics.errors.load(Ordering::Relaxed), 0);
        server.shutdown();
    }

    #[test]
    fn cancel_storm_returns_kv_pool_to_baseline() {
        // PR-7 satellite: cancelling mid-decode must drop every page
        // reference — with sharing disabled there are no prefix
        // retentions either, so the pool drains to exactly zero
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let probe = Arc::new(KvStats::default());
        let probe2 = Arc::clone(&probe);
        let cfg2 = cfg.clone();
        server.start(1, move |_| {
            HostEngine::new(cfg2.clone(), 0)
                .no_prefix_share()
                .kv_stats(Arc::clone(&probe2))
        });
        let hs: Vec<_> = (0..6)
            .map(|i| {
                server
                    .submit(
                        "alice",
                        &format!("q:{i}"),
                        GenOptions::greedy().max_new_tokens(40),
                    )
                    .unwrap()
            })
            .collect();
        // let some requests reach mid-decode before the storm
        thread::sleep(Duration::from_millis(30));
        for h in &hs {
            h.cancel();
        }
        for h in hs {
            // cancelled or already finished — either way resolved
            let _ = h.wait_timeout(Duration::from_secs(30)).unwrap();
        }
        server.shutdown();
        assert_eq!(probe.resident_bytes(), 0, "cancel storm leaked KV pages");
        assert_eq!(server.registry.ledger.lock().unwrap().kv_used(), 0);
    }

    #[test]
    fn mixed_options_in_one_tenant_batch() {
        // greedy and sampled requests for the same tenant share one slot
        // table; per-row options decode correctly side by side
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let h1 = server.submit("alice", "q:00", GenOptions::greedy()).unwrap();
        let h2 = server
            .submit(
                "alice",
                "q:00",
                GenOptions::sample(1.0, 0, 5).max_new_tokens(8),
            )
            .unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let r1 = h1.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
        let r2 = h2.wait_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert!(r2.tokens <= 8);
        // both resolved; ids are distinct and stable
        assert_ne!(r1.id, r2.id);
        server.shutdown();
    }

    /// Stepping engine with a per-decode-step delay, so tests can observe
    /// (and interrupt) a generation mid-flight without racing the real
    /// decode speed.
    struct SlowStepEngine {
        inner: HostEngine,
        step_delay: Duration,
    }

    impl ServeEngine for SlowStepEngine {
        fn forward(
            &mut self,
            tenant: &Tenant,
            adapter: &ServingAdapter,
            tokens: &[i32],
        ) -> Result<Vec<f32>> {
            self.inner.forward(tenant, adapter, tokens)
        }
        fn shape(&self) -> (usize, usize, usize) {
            self.inner.shape()
        }
        fn supports_steps(&self) -> bool {
            true
        }
        fn prefill_rows(
            &mut self,
            runs: &[EngineRun],
            rows: &[usize],
            tokens: &[i32],
            last: &[usize],
        ) -> Result<Vec<f32>> {
            self.inner.prefill_rows(runs, rows, tokens, last)
        }
        fn decode_rows(
            &mut self,
            runs: &[EngineRun],
            entries: &[(usize, usize, i32)],
        ) -> Result<Vec<f32>> {
            thread::sleep(self.step_delay);
            self.inner.decode_rows(runs, entries)
        }
        fn kv_admit(
            &mut self,
            row: usize,
            tenant: &Tenant,
            prompt: &[i32],
        ) -> bool {
            self.inner.kv_admit(row, tenant, prompt)
        }
        fn kv_release(&mut self, row: usize) {
            self.inner.kv_release(row)
        }
        fn kv_tenant_bytes(&self, tenant: &Tenant) -> usize {
            self.inner.kv_tenant_bytes(tenant)
        }
        fn kv_resident_bytes(&self) -> usize {
            self.inner.kv_resident_bytes()
        }
    }

    fn slow_server(step_delay: Duration) -> (Server, crate::config::ModelCfg) {
        let (mut server, cfg) = make_server(1 << 30);
        server.register("alice", spec(1)).unwrap();
        let cfg2 = cfg.clone();
        server.start(1, move |_| SlowStepEngine {
            inner: HostEngine::new(cfg2.clone(), 0),
            step_delay,
        });
        (server, cfg)
    }

    /// Poll a handle the way a streaming front end does: bounded
    /// `recv_token_timeout` ticks, terminal-result check on every timeout,
    /// buffered tokens drained after resolution. Panics on a hang.
    fn pump_stream(h: &ResponseHandle) -> (usize, ServeResult) {
        let t0 = Instant::now();
        loop {
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "stream receiver hung: neither tokens nor a resolution"
            );
            let mut tokens = 0usize;
            match h.recv_token_timeout(Duration::from_millis(20)) {
                Some(_) => tokens = 1,
                None => {
                    if let Some(res) = h.try_wait() {
                        // tokens sent before the resolution may still be
                        // buffered — drain so the count is exact
                        while h.try_recv_token().is_some() {
                            tokens += 1;
                        }
                        return (tokens, res);
                    }
                }
            }
            if tokens > 0 {
                let (more, res) = pump_rest(h, t0);
                return (tokens + more, res);
            }
        }
    }

    fn pump_rest(h: &ResponseHandle, t0: Instant) -> (usize, ServeResult) {
        let mut tokens = 0usize;
        loop {
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "stream receiver hung mid-generation"
            );
            match h.recv_token_timeout(Duration::from_millis(20)) {
                Some(_) => tokens += 1,
                None => {
                    if let Some(res) = h.try_wait() {
                        while h.try_recv_token().is_some() {
                            tokens += 1;
                        }
                        return (tokens, res);
                    }
                }
            }
        }
    }

    #[test]
    fn recv_token_timeout_wakes_on_cancel_mid_stream() {
        // a streaming consumer blocked in recv_token_timeout must observe
        // a mid-decode cancel promptly: stream closes, handle resolves
        // Cancelled, admission depth returns, and the server keeps serving
        let (mut server, _cfg) = slow_server(Duration::from_millis(3));
        let h = server
            .submit(
                "alice",
                "q:cancel",
                GenOptions::greedy().max_new_tokens(40),
            )
            .unwrap();
        // wait until it is demonstrably mid-decode
        assert!(
            h.recv_token_timeout(Duration::from_secs(10)).is_some(),
            "no first token"
        );
        h.cancel();
        let t0 = Instant::now();
        let (_tokens, res) = pump_rest(&h, t0);
        assert_eq!(res, Err(ServeError::Cancelled));
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "cancel wakeup stalled"
        );
        assert_eq!(server.batcher.depth(), 0, "cancel leaked queue depth");
        let h2 = server
            .submit("alice", "q:after", GenOptions::greedy())
            .unwrap();
        assert!(h2.wait_timeout(Duration::from_secs(30)).unwrap().is_ok());
        server.shutdown();
    }

    #[test]
    fn recv_token_timeout_wakes_on_deadline_expiry() {
        // 3ms/step × 40 tokens against a 25ms budget: the deadline lapses
        // mid-decode and the blocked receiver must resolve Deadline, not
        // spin until max_new_tokens
        let (mut server, _cfg) = slow_server(Duration::from_millis(3));
        let h = server
            .submit(
                "alice",
                "q:tight",
                GenOptions::greedy()
                    .max_new_tokens(40)
                    .deadline(Duration::from_millis(25)),
            )
            .unwrap();
        let (tokens, res) = pump_stream(&h);
        assert_eq!(res, Err(ServeError::Deadline));
        assert!(tokens < 40, "deadline never fired: {tokens} tokens");
        assert_eq!(server.batcher.depth(), 0, "expiry leaked queue depth");
        assert_eq!(server.metrics.expired.load(Ordering::Relaxed), 1);
        server.shutdown();
    }

    #[test]
    fn shutdown_drains_in_flight_streams() {
        // shutdown is close + drain: a consumer blocked on the stream sees
        // the generation complete (every token, then Ok), never a hang or
        // a silently dropped channel
        let (mut server, _cfg) = slow_server(Duration::from_millis(2));
        let h = server
            .submit(
                "alice",
                "q:drain",
                GenOptions::greedy().max_new_tokens(8),
            )
            .unwrap();
        let reader = thread::spawn(move || {
            let out = pump_stream(&h);
            drop(h);
            out
        });
        server.shutdown(); // blocks until the worker drained the queue
        let (tokens, res) = reader.join().expect("reader panicked");
        let resp = res.expect("drained request must resolve Ok");
        assert_eq!(
            tokens, resp.tokens,
            "stream token count != final response count"
        );
        assert_eq!(server.batcher.depth(), 0);
        // post-shutdown submits fail fast instead of queueing forever
        assert_eq!(
            server
                .submit("alice", "q:late", GenOptions::greedy())
                .unwrap_err(),
            ServeError::ShuttingDown
        );
    }
}
