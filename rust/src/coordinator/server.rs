//! The serving event loop: worker threads pull per-tenant batches from the
//! batcher, materialize factors through the cache, run batched greedy
//! decoding, and deliver responses. Engines are worker-owned (one PJRT
//! executable or host model per worker), so no engine needs to be `Sync`.

use super::batcher::{Batcher, Request, Response};
use super::cache::{MaterializeCache, TenantFactors};
use super::metrics::Metrics;
use super::registry::{Registry, Tenant};
use crate::data::tokenizer::Tokenizer;
use crate::eval::greedy_decode;
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

/// A per-worker inference engine.
pub trait ServeEngine {
    /// Batched forward for one tenant: padded tokens (batch*seq) -> logits
    /// (batch*seq*vocab).
    fn forward(
        &mut self,
        tenant: &Tenant,
        factors: &TenantFactors,
        tokens: &[i32],
    ) -> Result<Vec<f32>>;
    /// (batch, seq, vocab)
    fn shape(&self) -> (usize, usize, usize);
}

/// Host-model serving engine: shared frozen base + cached tenant factors.
pub struct HostEngine {
    pub cfg: crate::config::ModelCfg,
    pub base: crate::util::bank::Bank,
}

impl HostEngine {
    pub fn new(cfg: crate::config::ModelCfg, seed: u64) -> HostEngine {
        let base = crate::model::transformer::init_base(&cfg, seed);
        HostEngine { cfg, base }
    }
}

impl ServeEngine for HostEngine {
    fn forward(
        &mut self,
        tenant: &Tenant,
        factors: &TenantFactors,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let (cache, _) = crate::model::transformer::forward(
            &self.cfg,
            &tenant.mc,
            &self.base,
            factors,
            tokens,
        );
        Ok(cache.logits)
    }

    fn shape(&self) -> (usize, usize, usize) {
        (self.cfg.batch, self.cfg.seq, self.cfg.vocab)
    }
}

/// The coordinator server.
pub struct Server {
    pub registry: Arc<Registry>,
    pub batcher: Arc<Batcher>,
    pub metrics: Arc<Metrics>,
    pub cache: Arc<MaterializeCache>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Server {
    pub fn new(
        registry: Arc<Registry>,
        max_batch: usize,
        max_wait: Duration,
        cache_capacity: usize,
    ) -> Server {
        Server {
            registry,
            batcher: Arc::new(Batcher::new(max_batch, max_wait)),
            metrics: Arc::new(Metrics::new()),
            cache: Arc::new(MaterializeCache::new(cache_capacity)),
            workers: Vec::new(),
        }
    }

    /// Spawn `n` workers, each owning an engine built by `factory`.
    pub fn start<F, E>(&mut self, n: usize, factory: F)
    where
        F: Fn(usize) -> E + Send + Sync + 'static,
        E: ServeEngine + 'static,
    {
        let factory = Arc::new(factory);
        for wid in 0..n {
            let registry = Arc::clone(&self.registry);
            let batcher = Arc::clone(&self.batcher);
            let metrics = Arc::clone(&self.metrics);
            let cache = Arc::clone(&self.cache);
            let factory = Arc::clone(&factory);
            self.workers.push(
                thread::Builder::new()
                    .name(format!("mos-serve-{wid}"))
                    .spawn(move || {
                        let mut engine = factory(wid);
                        while let Some((tenant_id, batch)) = batcher.pop_batch()
                        {
                            process_batch(
                                &registry, &metrics, &cache, &mut engine,
                                &tenant_id, batch,
                            );
                        }
                    })
                    .expect("spawn worker"),
            );
        }
    }

    /// Materialize dense factors for every registered tenant ahead of
    /// traffic, fanning the per-tenant (and, inside, per-block) precompute
    /// out over the shared math pool. First requests then hit a warm
    /// cache instead of paying materialization latency. Returns the
    /// number of tenants warmed.
    pub fn prewarm(&self) -> usize {
        let tenants: Vec<Arc<Tenant>> = self
            .registry
            .ids()
            .iter()
            .filter_map(|id| self.registry.get(id))
            .collect();
        let n = tenants.len();
        let cfg = &self.registry.cfg;
        let cache = &*self.cache;
        crate::model::math::pool().scoped_map(tenants, |t| {
            cache.get(cfg, &t);
        });
        n
    }

    /// Enqueue a request; returns the response channel.
    pub fn submit(&self, tenant: &str, prompt: &str) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.batcher.push(Request {
            tenant: tenant.to_string(),
            prompt: prompt.to_string(),
            respond: tx,
            enqueued: Instant::now(),
        });
        rx
    }

    /// Drain and stop all workers.
    pub fn shutdown(&mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn process_batch<E: ServeEngine>(
    registry: &Registry,
    metrics: &Metrics,
    cache: &MaterializeCache,
    engine: &mut E,
    tenant_id: &str,
    batch: Vec<Request>,
) {
    metrics.record_batch(batch.len());
    let Some(tenant) = registry.get(tenant_id) else {
        for req in batch {
            metrics.errors.fetch_add(1, Ordering::Relaxed);
            let _ = req.respond.send(Response {
                tenant: tenant_id.to_string(),
                prompt: req.prompt.clone(),
                text: String::new(),
                latency: req.enqueued.elapsed(),
                ok: false,
                error: Some(format!("unknown tenant '{tenant_id}'")),
            });
        }
        return;
    };
    let factors = cache.get(&registry.cfg, &tenant);
    let (bsz, seq, vocab) = engine.shape();
    let tk = Tokenizer::new();

    // chunk requests into engine-sized sub-batches
    for chunk in batch.chunks(bsz) {
        let mut prompts: Vec<Vec<i32>> =
            chunk.iter().map(|r| tk.prompt_tokens(&r.prompt)).collect();
        while prompts.len() < bsz {
            prompts.push(vec![crate::data::tokenizer::BOS]);
        }
        let mut err: Option<String> = None;
        let mut fwd = |tokens: &[i32]| -> Vec<f32> {
            match engine.forward(&tenant, &factors, tokens) {
                Ok(l) => l,
                Err(e) => {
                    err = Some(e.to_string());
                    vec![0.0; bsz * seq * vocab]
                }
            }
        };
        let outs = greedy_decode(&mut fwd, &prompts, seq, vocab);
        for (req, out) in chunk.iter().zip(&outs) {
            let latency = req.enqueued.elapsed();
            if err.is_none() {
                metrics.record_latency(latency);
                metrics
                    .generated_tokens
                    .fetch_add(out.len() as u64, Ordering::Relaxed);
            } else {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
            }
            let _ = req.respond.send(Response {
                tenant: tenant_id.to_string(),
                prompt: req.prompt.clone(),
                text: tk.decode(out),
                latency,
                ok: err.is_none(),
                error: err.clone(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter;
    use crate::config::{presets, MethodCfg};

    fn make_server(capacity: usize) -> (Server, crate::config::ModelCfg) {
        let mut cfg = presets::tiny();
        cfg.batch = 4; // keep unit tests fast
        let registry =
            Arc::new(Registry::new(cfg.clone(), capacity));
        let server = Server::new(
            registry,
            4,
            Duration::from_millis(10),
            8,
        );
        (server, cfg)
    }

    fn add_tenant(server: &Server, cfg: &crate::config::ModelCfg, id: &str, seed: u64) {
        let mc = MethodCfg::mos(4, 2, 2, 0);
        server
            .registry
            .register(Tenant {
                id: id.into(),
                mc: mc.clone(),
                params: adapter::init_params(cfg, &mc, seed),
                aux: adapter::mos::router::build_router(cfg, &mc, seed)
                    .into_bank(),
                router_seed: seed,
            })
            .unwrap();
    }

    #[test]
    fn serves_requests_end_to_end() {
        let (mut server, cfg) = make_server(1 << 30);
        add_tenant(&server, &cfg, "alice", 1);
        add_tenant(&server, &cfg, "bob", 2);
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let mut rxs = Vec::new();
        for i in 0..6 {
            let tenant = if i % 2 == 0 { "alice" } else { "bob" };
            rxs.push(server.submit(tenant, &format!("q:{i}")));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(resp.ok, "{:?}", resp.error);
        }
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 6);
        server.shutdown();
    }

    #[test]
    fn unknown_tenant_errors() {
        let (mut server, cfg) = make_server(1 << 30);
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let rx = server.submit("ghost", "hello");
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(!resp.ok);
        assert!(resp.error.unwrap().contains("unknown tenant"));
        server.shutdown();
    }

    #[test]
    fn prewarm_materializes_every_tenant_once() {
        let (mut server, cfg) = make_server(1 << 30);
        for (i, id) in ["alice", "bob", "carol"].iter().enumerate() {
            add_tenant(&server, &cfg, id, i as u64 + 1);
        }
        assert_eq!(server.prewarm(), 3);
        assert_eq!(server.cache.stats(), (0, 3));
        // traffic after prewarm only hits the cache
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        for id in ["alice", "bob", "carol"] {
            let rx = server.submit(id, "q:warm");
            assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().ok);
        }
        let (hits, misses) = server.cache.stats();
        assert_eq!(misses, 3, "prewarmed tenants must not re-materialize");
        assert!(hits >= 3);
        server.shutdown();
    }

    #[test]
    fn cache_reused_across_requests() {
        let (mut server, cfg) = make_server(1 << 30);
        add_tenant(&server, &cfg, "alice", 1);
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        for _ in 0..3 {
            let rx = server.submit("alice", "q:aa");
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        let (hits, misses) = server.cache.stats();
        assert_eq!(misses, 1, "factors must be materialized exactly once");
        assert!(hits >= 1);
        server.shutdown();
    }
}
