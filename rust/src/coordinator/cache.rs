//! Materialization cache: dense per-tenant low-rank factors, built once per
//! tenant version (index-based routing = pure precompute, paper Limitations
//! §C) and LRU-evicted under a capacity bound.
//!
//! This is the serving hot path's key optimization: gather+concat happens
//! once per tenant, not once per request. Entries are keyed by
//! `(tenant id, version)` — re-registering a tenant bumps its version in
//! the [`super::registry::Registry`], so a lookup for the new version
//! misses and rebuilds instead of serving the old dense factors.

use crate::adapter::{self, Factors};
use crate::config::{ModelCfg, LAYER_TYPES};
use crate::coordinator::registry::Tenant;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Mutex};

/// All dense factors for one tenant.
pub type TenantFactors = Arc<BTreeMap<String, Factors>>;

/// LRU cache of materialized factors, keyed by (tenant id, version).
pub struct MaterializeCache {
    capacity: usize,
    inner: Mutex<Inner>,
}

struct Inner {
    /// One slot per tenant id, tagged with the version it was built for.
    map: HashMap<String, (u64, TenantFactors)>,
    order: VecDeque<String>,
    hits: u64,
    misses: u64,
}

impl MaterializeCache {
    pub fn new(capacity: usize) -> MaterializeCache {
        assert!(capacity > 0);
        MaterializeCache {
            capacity,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: VecDeque::new(),
                hits: 0,
                misses: 0,
            }),
        }
    }

    /// Fetch (or build) the dense factors for a tenant. A version mismatch
    /// (tenant was re-registered since the entry was built) counts as a
    /// miss and rebuilds.
    pub fn get(&self, cfg: &ModelCfg, tenant: &Tenant) -> TenantFactors {
        {
            let mut inner = self.inner.lock().unwrap();
            let hit = inner
                .map
                .get(&tenant.id)
                .filter(|(version, _)| *version == tenant.version)
                .map(|(_, f)| Arc::clone(f));
            if let Some(f) = hit {
                inner.hits += 1;
                let id = tenant.id.clone();
                inner.order.retain(|x| x != &id);
                inner.order.push_back(id);
                return f;
            }
            inner.misses += 1;
        }
        // build outside the lock (materialization can be slow); the seven
        // layer types are independent, so fan them out on the shared math
        // pool (nested calls inside a pool worker run inline)
        let built: Vec<(String, Factors)> = crate::model::math::pool()
            .scoped_map(LAYER_TYPES.to_vec(), |t| {
                (
                    t.to_string(),
                    adapter::materialize(
                        cfg,
                        &tenant.mc,
                        &tenant.params,
                        &tenant.aux,
                        t,
                    ),
                )
            });
        let factors: TenantFactors =
            Arc::new(built.into_iter().collect::<BTreeMap<_, _>>());
        let mut inner = self.inner.lock().unwrap();
        // never let a racing build of an older version clobber a newer one
        let stale_winner = inner
            .map
            .get(&tenant.id)
            .is_some_and(|(v, _)| *v > tenant.version);
        if !stale_winner {
            let replacing = inner.map.contains_key(&tenant.id);
            while !replacing && inner.map.len() >= self.capacity {
                if let Some(victim) = inner.order.pop_front() {
                    inner.map.remove(&victim);
                } else {
                    break;
                }
            }
            inner
                .map
                .insert(tenant.id.clone(), (tenant.version, Arc::clone(&factors)));
            let id = tenant.id.clone();
            inner.order.retain(|x| x != &id);
            inner.order.push_back(id);
        }
        factors
    }

    /// Drop a tenant's entry (any version) — e.g. after removal.
    pub fn invalidate(&self, tenant_id: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.remove(tenant_id);
        inner.order.retain(|x| x != tenant_id);
    }

    pub fn stats(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.hits, inner.misses)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::coordinator::registry::{Registry, TenantSpec};

    fn tenant(cfg: &ModelCfg, id: &str, seed: u64) -> Tenant {
        TenantSpec::mos(4, 2, 2, 0)
            .seed(seed)
            .build(cfg, id)
            .unwrap()
    }

    #[test]
    fn hit_after_miss() {
        let cfg = presets::tiny();
        let cache = MaterializeCache::new(4);
        let t = tenant(&cfg, "a", 1);
        let f1 = cache.get(&cfg, &t);
        let f2 = cache.get(&cfg, &t);
        assert!(Arc::ptr_eq(&f1, &f2));
        assert_eq!(cache.stats(), (1, 1));
    }

    #[test]
    fn capacity_evicts_lru() {
        let cfg = presets::tiny();
        let cache = MaterializeCache::new(2);
        let (ta, tb, tc) = (tenant(&cfg, "a", 1), tenant(&cfg, "b", 2), tenant(&cfg, "c", 3));
        cache.get(&cfg, &ta);
        cache.get(&cfg, &tb);
        cache.get(&cfg, &ta); // b becomes LRU
        cache.get(&cfg, &tc); // evicts b
        assert_eq!(cache.len(), 2);
        let (h0, m0) = cache.stats();
        cache.get(&cfg, &tb); // miss again
        let (h1, m1) = cache.stats();
        assert_eq!(h1, h0);
        assert_eq!(m1, m0 + 1);
    }

    #[test]
    fn invalidate_forces_rebuild() {
        let cfg = presets::tiny();
        let cache = MaterializeCache::new(4);
        let t = tenant(&cfg, "a", 1);
        let f1 = cache.get(&cfg, &t);
        cache.invalidate("a");
        let f2 = cache.get(&cfg, &t);
        assert!(!Arc::ptr_eq(&f1, &f2));
    }

    #[test]
    fn version_bump_misses_and_replaces() {
        let cfg = presets::tiny();
        let cache = MaterializeCache::new(4);
        let mut t = tenant(&cfg, "a", 1);
        let f1 = cache.get(&cfg, &t);
        t.version = 1; // as the registry would assign on re-register
        let f2 = cache.get(&cfg, &t);
        assert!(!Arc::ptr_eq(&f1, &f2), "stale factors served after re-register");
        assert_eq!(cache.stats(), (0, 2));
        assert_eq!(cache.len(), 1, "old version must not linger");
        // the new version is now the cached one
        let f3 = cache.get(&cfg, &t);
        assert!(Arc::ptr_eq(&f2, &f3));
    }

    #[test]
    fn reregistered_tenant_serves_fresh_factors() {
        // regression: the cache doc promises (id, version) keying; before
        // the redesign a re-registered tenant kept serving the old dense
        // factors because the key was the id alone.
        let cfg = presets::tiny();
        let reg = Registry::new(cfg.clone(), 1 << 30);
        let cache = MaterializeCache::new(4);
        reg.register_spec("a", TenantSpec::mos(4, 2, 2, 0).seed(1))
            .unwrap();
        let f1 = cache.get(&cfg, &reg.get("a").unwrap());
        // re-register with different init: params change, id stays
        reg.register_spec("a", TenantSpec::mos(4, 2, 2, 0).seed(2))
            .unwrap();
        let f2 = cache.get(&cfg, &reg.get("a").unwrap());
        assert!(!Arc::ptr_eq(&f1, &f2));
        // the factors must actually differ numerically, not just be rebuilt
        let (k, old) = f1.iter().next().unwrap();
        let new = &f2[k];
        assert_ne!(old.a, new.a, "fresh registration served stale factors");
    }

    #[test]
    fn factors_cover_all_layer_types() {
        let cfg = presets::tiny();
        let cache = MaterializeCache::new(1);
        let f = cache.get(&cfg, &tenant(&cfg, "a", 1));
        for t in LAYER_TYPES {
            assert!(f.contains_key(t));
        }
    }
}
