//! Materialization cache: dense per-tenant low-rank factors, built once per
//! tenant (index-based routing = pure precompute, paper Limitations §C) and
//! LRU-evicted under a capacity bound.
//!
//! This is the serving hot path's key optimization: gather+concat happens
//! once per tenant, not once per request.

use crate::adapter::{self, Factors};
use crate::config::{ModelCfg, LAYER_TYPES};
use crate::coordinator::registry::Tenant;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Mutex};

/// All dense factors for one tenant.
pub type TenantFactors = Arc<BTreeMap<String, Factors>>;

/// LRU cache of materialized factors, keyed by (tenant id, version).
pub struct MaterializeCache {
    capacity: usize,
    inner: Mutex<Inner>,
}

struct Inner {
    map: HashMap<String, TenantFactors>,
    order: VecDeque<String>,
    hits: u64,
    misses: u64,
}

impl MaterializeCache {
    pub fn new(capacity: usize) -> MaterializeCache {
        assert!(capacity > 0);
        MaterializeCache {
            capacity,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: VecDeque::new(),
                hits: 0,
                misses: 0,
            }),
        }
    }

    /// Fetch (or build) the dense factors for a tenant.
    pub fn get(&self, cfg: &ModelCfg, tenant: &Tenant) -> TenantFactors {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(f) = inner.map.get(&tenant.id).cloned() {
                inner.hits += 1;
                let id = tenant.id.clone();
                inner.order.retain(|x| x != &id);
                inner.order.push_back(id);
                return f;
            }
            inner.misses += 1;
        }
        // build outside the lock (materialization can be slow); the seven
        // layer types are independent, so fan them out on the shared math
        // pool (nested calls inside a pool worker run inline)
        let built: Vec<(String, Factors)> = crate::model::math::pool()
            .scoped_map(LAYER_TYPES.to_vec(), |t| {
                (
                    t.to_string(),
                    adapter::materialize(
                        cfg,
                        &tenant.mc,
                        &tenant.params,
                        &tenant.aux,
                        t,
                    ),
                )
            });
        let factors: TenantFactors =
            Arc::new(built.into_iter().collect::<BTreeMap<_, _>>());
        let mut inner = self.inner.lock().unwrap();
        if !inner.map.contains_key(&tenant.id) {
            while inner.map.len() >= self.capacity {
                if let Some(victim) = inner.order.pop_front() {
                    inner.map.remove(&victim);
                } else {
                    break;
                }
            }
            inner.map.insert(tenant.id.clone(), Arc::clone(&factors));
            inner.order.push_back(tenant.id.clone());
        }
        factors
    }

    /// Drop a tenant (e.g. after re-training updated its params).
    pub fn invalidate(&self, tenant_id: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.remove(tenant_id);
        inner.order.retain(|x| x != tenant_id);
    }

    pub fn stats(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.hits, inner.misses)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::config::MethodCfg;

    fn tenant(cfg: &ModelCfg, id: &str, seed: u64) -> Tenant {
        let mc = MethodCfg::mos(4, 2, 2, 0);
        Tenant {
            id: id.into(),
            mc: mc.clone(),
            params: adapter::init_params(cfg, &mc, seed),
            aux: adapter::mos::router::build_router(cfg, &mc, seed).into_bank(),
            router_seed: seed,
        }
    }

    #[test]
    fn hit_after_miss() {
        let cfg = presets::tiny();
        let cache = MaterializeCache::new(4);
        let t = tenant(&cfg, "a", 1);
        let f1 = cache.get(&cfg, &t);
        let f2 = cache.get(&cfg, &t);
        assert!(Arc::ptr_eq(&f1, &f2));
        assert_eq!(cache.stats(), (1, 1));
    }

    #[test]
    fn capacity_evicts_lru() {
        let cfg = presets::tiny();
        let cache = MaterializeCache::new(2);
        let (ta, tb, tc) = (tenant(&cfg, "a", 1), tenant(&cfg, "b", 2), tenant(&cfg, "c", 3));
        cache.get(&cfg, &ta);
        cache.get(&cfg, &tb);
        cache.get(&cfg, &ta); // b becomes LRU
        cache.get(&cfg, &tc); // evicts b
        assert_eq!(cache.len(), 2);
        let (h0, m0) = cache.stats();
        cache.get(&cfg, &tb); // miss again
        let (h1, m1) = cache.stats();
        assert_eq!(h1, h0);
        assert_eq!(m1, m0 + 1);
    }

    #[test]
    fn invalidate_forces_rebuild() {
        let cfg = presets::tiny();
        let cache = MaterializeCache::new(4);
        let t = tenant(&cfg, "a", 1);
        let f1 = cache.get(&cfg, &t);
        cache.invalidate("a");
        let f2 = cache.get(&cfg, &t);
        assert!(!Arc::ptr_eq(&f1, &f2));
    }

    #[test]
    fn factors_cover_all_layer_types() {
        let cfg = presets::tiny();
        let cache = MaterializeCache::new(1);
        let f = cache.get(&cfg, &tenant(&cfg, "a", 1));
        for t in LAYER_TYPES {
            assert!(f.contains_key(t));
        }
    }
}
