//! Adapter cache: per-tenant serving representations, built once per
//! tenant version and LRU-evicted under a capacity bound. Three tiers:
//!
//! * **Pooled** (default, MoS tenants): the [`ServingAdapter::Pooled`]
//!   representation `Arc`-aliases the registry's own shard pools and index
//!   tables — building an entry copies nothing, and the tenant's resident
//!   adapter bytes stay O(pool), which is the paper's whole serving claim.
//! * **PooledInt8** (`MOS_SERVE_INT8=1`, MoS tenants): the pooled shard
//!   tensors quantized once per tenant version to int8 codes + per-shard
//!   scales (~0.28x the f32 pool); index/scale aux tables still alias the
//!   registry. Accuracy is gated by the logit budget in
//!   [`crate::model::quant`].
//! * **Dense** (non-MoS methods, or `MOS_SERVE_DENSE=1`): the legacy
//!   gather+concat materialization into per-block [`Factors`], built once
//!   per tenant version (index-based routing = pure precompute, paper
//!   Limitations §C). Dense stays f32 even under `MOS_SERVE_INT8` — the
//!   legacy tier is the accuracy oracle.
//!
//! Entries are keyed by `(tenant id, version)` — re-registering a tenant
//! bumps its version in the [`super::registry::Registry`], so a lookup for
//! the new version misses and rebuilds instead of serving the old adapter.
//! Concurrent misses for one id are single-flighted: the first caller
//! builds, the rest wait on a condvar and then hit — `misses` counts
//! builds exactly.

use crate::adapter::{
    self, Factors, PooledAdapter, QuantPooledAdapter, ServingAdapter,
};
use crate::config::{Method, ModelCfg, LAYER_TYPES};
use crate::coordinator::registry::Tenant;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

/// All dense factors for one tenant.
pub type TenantFactors = Arc<BTreeMap<String, Factors>>;

/// LRU cache of per-tenant serving adapters, keyed by (tenant id, version).
pub struct AdapterCache {
    capacity: usize,
    /// Build dense materialized entries for everyone (legacy tier).
    dense: bool,
    /// Quantize pooled entries to int8 (`MOS_SERVE_INT8=1` tier).
    int8: bool,
    inner: Mutex<Inner>,
    /// Signalled after every finished build (single-flight waiters).
    built: Condvar,
}

struct Inner {
    /// One slot per tenant id, tagged with the version it was built for.
    map: HashMap<String, (u64, ServingAdapter)>,
    order: VecDeque<String>,
    /// Ids with a build in flight (the single-flight guard), mapped to the
    /// version being built.
    building: HashMap<String, u64>,
    hits: u64,
    misses: u64,
}

impl AdapterCache {
    /// `dense` selects the legacy materialized tier for every tenant
    /// (normally driven by `Registry::serve_dense`, i.e. `MOS_SERVE_DENSE`).
    pub fn new(capacity: usize, dense: bool) -> AdapterCache {
        assert!(capacity > 0);
        AdapterCache {
            capacity,
            dense,
            int8: false,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: VecDeque::new(),
                building: HashMap::new(),
                hits: 0,
                misses: 0,
            }),
            built: Condvar::new(),
        }
    }

    /// Quantize pooled entries to int8 (normally driven by
    /// `Registry::serve_int8`, i.e. `MOS_SERVE_INT8`). No effect on the
    /// dense tier or non-MoS tenants, which stay f32.
    pub fn with_int8(mut self, int8: bool) -> AdapterCache {
        self.int8 = int8;
        self
    }

    /// Is this cache serving the dense materialized tier?
    pub fn serves_dense(&self) -> bool {
        self.dense
    }

    /// Are pooled entries quantized to int8?
    pub fn serves_int8(&self) -> bool {
        self.int8
    }

    /// Fetch (or build) the serving adapter for a tenant. A version
    /// mismatch (tenant was re-registered since the entry was built)
    /// counts as a miss and rebuilds. Two concurrent misses for one id
    /// run one build: the loser waits on the condvar and hits the entry
    /// the winner installed.
    pub fn get(&self, cfg: &ModelCfg, tenant: &Tenant) -> ServingAdapter {
        let mut inner = self.inner.lock().unwrap();
        loop {
            let hit = inner
                .map
                .get(&tenant.id)
                .filter(|(version, _)| *version == tenant.version)
                .map(|(_, a)| a.clone());
            if let Some(a) = hit {
                inner.hits += 1;
                let id = tenant.id.clone();
                inner.order.retain(|x| x != &id);
                inner.order.push_back(id);
                return a;
            }
            if inner.building.contains_key(&tenant.id) {
                // single-flight: a build for this id is already running —
                // wait for it instead of duplicating the materialization
                // (thundering herd on cold start / re-register)
                inner = self.built.wait(inner).unwrap();
                continue;
            }
            inner.misses += 1;
            inner.building.insert(tenant.id.clone(), tenant.version);
            break;
        }
        drop(inner);
        // build outside the lock (dense materialization can be slow)
        let built = self.build(cfg, tenant);
        let mut inner = self.inner.lock().unwrap();
        inner.building.remove(&tenant.id);
        // never let a racing build of an older version clobber a newer one
        let stale_winner = inner
            .map
            .get(&tenant.id)
            .is_some_and(|(v, _)| *v > tenant.version);
        if !stale_winner {
            let replacing = inner.map.contains_key(&tenant.id);
            while !replacing && inner.map.len() >= self.capacity {
                if let Some(victim) = inner.order.pop_front() {
                    inner.map.remove(&victim);
                } else {
                    break;
                }
            }
            inner
                .map
                .insert(tenant.id.clone(), (tenant.version, built.clone()));
            let id = tenant.id.clone();
            inner.order.retain(|x| x != &id);
            inner.order.push_back(id);
        }
        drop(inner);
        self.built.notify_all();
        built
    }

    /// Construct the representation for the active tier.
    fn build(&self, cfg: &ModelCfg, tenant: &Tenant) -> ServingAdapter {
        if !self.dense && tenant.mc.method == Method::MoS {
            // pooled tier: no copies — alias the registry's tensors
            let pooled = PooledAdapter::new(
                tenant.mc.clone(),
                Arc::clone(&tenant.params),
                Arc::clone(&tenant.aux),
            )
            .expect("registered MoS tenant must have pooled geometry");
            if self.int8 {
                // quantize once per tenant version; the codes+scales are
                // the only new allocation (aux tables still aliased)
                return ServingAdapter::PooledInt8(Arc::new(
                    QuantPooledAdapter::quantize(&pooled),
                ));
            }
            return ServingAdapter::Pooled(Arc::new(pooled));
        }
        // dense tier: the seven layer types are independent, so fan the
        // materialization out on the shared math pool (nested calls inside
        // a pool worker run inline)
        let built: Vec<(String, Factors)> = crate::model::math::pool()
            .scoped_map(LAYER_TYPES.to_vec(), |t| {
                (
                    t.to_string(),
                    adapter::materialize(
                        cfg,
                        &tenant.mc,
                        &tenant.params,
                        &tenant.aux,
                        t,
                    ),
                )
            });
        ServingAdapter::Dense(Arc::new(built.into_iter().collect()))
    }

    /// Drop a tenant's entry (any version) — e.g. after removal or ledger
    /// eviction (wired through `Registry::set_evict_hook`).
    pub fn invalidate(&self, tenant_id: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.remove(tenant_id);
        inner.order.retain(|x| x != tenant_id);
    }

    pub fn stats(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.hits, inner.misses)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total resident adapter bytes across cached entries (what the
    /// `adapter_mb` bench column reports).
    pub fn resident_bytes(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.map.values().map(|(_, a)| a.resident_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::coordinator::registry::{Registry, TenantSpec};

    fn tenant(cfg: &ModelCfg, id: &str, seed: u64) -> Tenant {
        TenantSpec::mos(4, 2, 2, 0)
            .seed(seed)
            .build(cfg, id)
            .unwrap()
    }

    /// Identity of a cached adapter (both tiers hand out `Arc` clones).
    fn ident(a: &ServingAdapter) -> usize {
        match a {
            ServingAdapter::Dense(f) => Arc::as_ptr(f) as usize,
            ServingAdapter::Pooled(p) => Arc::as_ptr(p) as usize,
            ServingAdapter::PooledInt8(p) => Arc::as_ptr(p) as usize,
        }
    }

    #[test]
    fn hit_after_miss() {
        let cfg = presets::tiny();
        let cache = AdapterCache::new(4, false);
        let t = tenant(&cfg, "a", 1);
        let f1 = cache.get(&cfg, &t);
        let f2 = cache.get(&cfg, &t);
        assert_eq!(ident(&f1), ident(&f2));
        assert_eq!(cache.stats(), (1, 1));
    }

    #[test]
    fn pooled_tier_aliases_registry_tensors() {
        // the pooled entry must share the tenant's tensors, not copy them:
        // its resident bytes equal the tenant's own (pool-sized), and the
        // params Arc gains a reference instead of a clone
        let cfg = presets::tiny();
        let cache = AdapterCache::new(4, false);
        let t = tenant(&cfg, "a", 1);
        let rc0 = Arc::strong_count(&t.params);
        let a = cache.get(&cfg, &t);
        let p = a.pooled().expect("MoS tenant must get the pooled tier");
        assert_eq!(p.resident_bytes(), t.actual_bytes());
        assert!(Arc::strong_count(&t.params) > rc0, "pool was copied");
    }

    #[test]
    fn dense_mode_materializes_for_mos() {
        let cfg = presets::tiny();
        let cache = AdapterCache::new(4, true);
        assert!(cache.serves_dense());
        // paper settings (r=8, e=2): materialized factors ~4x the pool
        let t = TenantSpec::mos(8, 2, 2, 1).seed(1).build(&cfg, "a").unwrap();
        let a = cache.get(&cfg, &t);
        let f = a.dense().expect("dense mode must materialize");
        for lt in LAYER_TYPES {
            assert!(f.contains_key(lt));
        }
        // dense residency is the materialized size: well above the pool
        assert!(a.resident_bytes() > 3 * t.actual_bytes());
    }

    #[test]
    fn int8_tier_quantizes_mos_and_leaves_dense_f32() {
        let cfg = presets::tiny();
        let cache = AdapterCache::new(4, false).with_int8(true);
        assert!(cache.serves_int8());
        let t = tenant(&cfg, "a", 1);
        let a = cache.get(&cfg, &t);
        let q = a.pooled_int8().expect("MoS tenant must get the int8 tier");
        // residency must sit well under the f32 pool the registry holds
        assert!(
            q.resident_bytes() < t.actual_bytes(),
            "int8 entry {} B not below f32 pool {} B",
            q.resident_bytes(),
            t.actual_bytes()
        );
        // non-MoS tenants still get dense f32 factors under int8 mode
        let l = TenantSpec::lora(4).seed(1).build(&cfg, "l").unwrap();
        let al = cache.get(&cfg, &l);
        assert!(al.dense().is_some(), "LoRA tenant cannot serve int8 pooled");
        // and the dense override wins over int8 for everyone
        let dense = AdapterCache::new(4, true).with_int8(true);
        let ad = dense.get(&cfg, &t);
        assert!(ad.dense().is_some(), "dense mode must stay f32 materialized");
    }

    #[test]
    fn non_mos_tenants_fall_back_to_dense() {
        let cfg = presets::tiny();
        let cache = AdapterCache::new(4, false);
        let t = TenantSpec::lora(4).seed(1).build(&cfg, "l").unwrap();
        let a = cache.get(&cfg, &t);
        assert!(a.dense().is_some(), "LoRA tenant cannot serve pooled");
    }

    #[test]
    fn capacity_evicts_lru() {
        let cfg = presets::tiny();
        let cache = AdapterCache::new(2, false);
        let (ta, tb, tc) = (tenant(&cfg, "a", 1), tenant(&cfg, "b", 2), tenant(&cfg, "c", 3));
        cache.get(&cfg, &ta);
        cache.get(&cfg, &tb);
        cache.get(&cfg, &ta); // b becomes LRU
        cache.get(&cfg, &tc); // evicts b
        assert_eq!(cache.len(), 2);
        let (h0, m0) = cache.stats();
        cache.get(&cfg, &tb); // miss again
        let (h1, m1) = cache.stats();
        assert_eq!(h1, h0);
        assert_eq!(m1, m0 + 1);
    }

    #[test]
    fn invalidate_forces_rebuild() {
        let cfg = presets::tiny();
        let cache = AdapterCache::new(4, false);
        let t = tenant(&cfg, "a", 1);
        let f1 = cache.get(&cfg, &t);
        cache.invalidate("a");
        let f2 = cache.get(&cfg, &t);
        assert_ne!(ident(&f1), ident(&f2));
    }

    #[test]
    fn version_bump_misses_and_replaces() {
        let cfg = presets::tiny();
        let cache = AdapterCache::new(4, false);
        let mut t = tenant(&cfg, "a", 1);
        let f1 = cache.get(&cfg, &t);
        t.version = 1; // as the registry would assign on re-register
        let f2 = cache.get(&cfg, &t);
        assert_ne!(ident(&f1), ident(&f2), "stale adapter served after re-register");
        assert_eq!(cache.stats(), (0, 2));
        assert_eq!(cache.len(), 1, "old version must not linger");
        // the new version is now the cached one
        let f3 = cache.get(&cfg, &t);
        assert_eq!(ident(&f2), ident(&f3));
    }

    #[test]
    fn reregistered_tenant_serves_fresh_factors() {
        // regression: the cache doc promises (id, version) keying; before
        // the redesign a re-registered tenant kept serving the old dense
        // factors because the key was the id alone. Dense tier so the
        // numeric-freshness assertion has factors to compare.
        let cfg = presets::tiny();
        let reg = Registry::with_serve_mode(cfg.clone(), 1 << 30, true);
        let cache = AdapterCache::new(4, true);
        reg.register_spec("a", TenantSpec::mos(4, 2, 2, 0).seed(1))
            .unwrap();
        let a1 = cache.get(&cfg, &reg.get("a").unwrap());
        // re-register with different init: params change, id stays
        reg.register_spec("a", TenantSpec::mos(4, 2, 2, 0).seed(2))
            .unwrap();
        let a2 = cache.get(&cfg, &reg.get("a").unwrap());
        assert_ne!(ident(&a1), ident(&a2));
        // the factors must actually differ numerically, not just be rebuilt
        let (f1, f2) = (a1.dense().unwrap(), a2.dense().unwrap());
        let (k, old) = f1.iter().next().unwrap();
        let new = &f2[k];
        assert_ne!(old.a, new.a, "fresh registration served stale factors");
    }

    #[test]
    fn concurrent_misses_build_once() {
        // single-flight regression: two concurrent misses for one
        // (id, version) used to both run the full materialization outside
        // the lock. With the in-flight guard, exactly one thread builds
        // and every other waits then hits — deterministically (1 miss,
        // n-1 hits), not just usually.
        let cfg = presets::tiny();
        let cache = Arc::new(AdapterCache::new(4, true));
        let t = Arc::new(tenant(&cfg, "a", 1));
        let n = 8;
        let barrier = Arc::new(std::sync::Barrier::new(n));
        let ids: Vec<usize> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|_| {
                    let (cache, t, cfg, barrier) =
                        (Arc::clone(&cache), Arc::clone(&t), cfg.clone(), Arc::clone(&barrier));
                    s.spawn(move || {
                        barrier.wait();
                        ident(&cache.get(&cfg, &t))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(ids.windows(2).all(|w| w[0] == w[1]), "threads saw different builds");
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 1, "concurrent misses were not single-flighted");
        assert_eq!(hits, n as u64 - 1);
    }

    #[test]
    fn factors_cover_all_layer_types() {
        let cfg = presets::tiny();
        let cache = AdapterCache::new(1, true);
        let a = cache.get(&cfg, &tenant(&cfg, "a", 1));
        let f = a.dense().unwrap();
        for t in LAYER_TYPES {
            assert!(f.contains_key(t));
        }
    }
}
