//! Serving metrics: latency + time-to-first-token histograms (log-spaced
//! buckets), counters, and percentile snapshots for the serving benches
//! and the front door's `/metrics` endpoint ([`Metrics::snapshot`]).

use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

const BUCKETS: usize = 40;
/// Lower bound of bucket 0, in microseconds.
const BASE_US: f64 = 10.0;
/// Log-spacing growth factor between bucket bounds.
const GROWTH: f64 = 1.5;

/// Lower bound of bucket `i`: `10 * 1.5^i` µs.
fn bucket_lower(i: usize) -> f64 {
    BASE_US * GROWTH.powi(i as i32)
}

/// Bucket index for a duration of `us` microseconds: the `i` with
/// `10 * 1.5^i <= us < 10 * 1.5^(i+1)`. Durations below the 10µs base are
/// clamped into bucket 0, anything past the last bound into the top
/// bucket — the two clamps are explicit, not an accident of the scan.
fn bucket_of(us: u64) -> usize {
    let us = us as f64;
    if us < BASE_US * GROWTH {
        return 0;
    }
    let mut bound = BASE_US * GROWTH;
    for i in 1..BUCKETS {
        bound *= GROWTH;
        if us < bound {
            return i;
        }
    }
    BUCKETS - 1
}

/// Log-spaced duration histogram from 10µs to ~100s.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Percentile estimate in microseconds: the *geometric midpoint*
    /// `sqrt(lower * upper)` of the bucket holding the p-th sample.
    /// (Reporting the upper bound, as this used to, overstates every
    /// percentile by up to the 1.5× bucket width.)
    pub fn percentile_us(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return (bucket_lower(i) * bucket_lower(i + 1)).sqrt();
            }
        }
        (bucket_lower(BUCKETS - 1) * bucket_lower(BUCKETS)).sqrt()
    }
}

/// Serving counters plus latency and time-to-first-token histograms.
#[derive(Debug, Default)]
pub struct Metrics {
    /// End-to-end request latency (submit -> resolution).
    pub latency: Histogram,
    /// Time to first streamed token (submit -> first token; requests that
    /// resolve without generating record their resolution latency).
    pub ttft: Histogram,
    /// Engine prefill latency (one `prefill_rows` call per admission
    /// round on the KV-stepping path; the full-window fallback records
    /// nothing here). Dominates TTFT — `bench_serving` reports its p50
    /// per case as `prefill_p50_ms`.
    pub prefill: Histogram,
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    /// admission-control rejections (`ServeError::QueueFull`)
    pub rejected: AtomicU64,
    /// requests resolved `Cancelled` — purged from the queue before
    /// reaching an engine, or stopped at a decode-step boundary
    pub cancelled: AtomicU64,
    /// requests resolved `Deadline` — budget lapsed in queue, or enforced
    /// between decode steps mid-generation
    pub expired: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// requests admitted into a *running* batch between decode steps
    /// (continuous batching refills)
    pub refilled: AtomicU64,
    pub generated_tokens: AtomicU64,
    total_latency_us: AtomicU64,
    /// Current batcher queue depth. A gauge, not a counter: the batcher
    /// sets it to the post-mutation depth under its own queue lock, so at
    /// any quiescent point it equals `Batcher::depth()` exactly.
    queue_depth: AtomicU64,
    /// Per-tenant outcome counters keyed by tenant id: requests resolved
    /// `Ok` (served), admission-control rejections (rejected), and the
    /// tenant's current queue depth (a gauge, batcher-maintained).
    per_tenant: Mutex<HashMap<String, TenantCounters>>,
}

/// Per-tenant slice of the serving counters.
#[derive(Debug, Default, Clone, Copy)]
pub struct TenantCounters {
    pub served: u64,
    pub rejected: u64,
    /// Requests currently queued for this tenant. Like the global
    /// `queue_depth` gauge, the batcher sets it to the post-mutation
    /// depth under its queue lock (push/pop/purge), so at quiescence it
    /// equals the tenant's actual queue length.
    pub queued: u64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_latency(&self, d: Duration) {
        self.latency.record(d);
        self.total_latency_us
            .fetch_add(d.as_micros() as u64, Ordering::Relaxed);
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_ttft(&self, d: Duration) {
        self.ttft.record(d);
    }

    pub fn record_prefill(&self, d: Duration) {
        self.prefill.record(d);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Continuous-batching refill: the requests joined an already-recorded
    /// batch, so they count toward `refilled` *and* fold into the
    /// batch-size accounting (otherwise `mean_batch_size` under-reports
    /// exactly when mid-flight admission is doing the most work).
    pub fn record_refill(&self, n: usize) {
        self.refilled.fetch_add(n as u64, Ordering::Relaxed);
        self.batched_requests.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Latency percentile estimate (geometric bucket midpoint), in
    /// microseconds.
    pub fn percentile_us(&self, p: f64) -> f64 {
        self.latency.percentile_us(p)
    }

    /// Time-to-first-token percentile estimate, in microseconds.
    pub fn ttft_percentile_us(&self, p: f64) -> f64 {
        self.ttft.percentile_us(p)
    }

    /// Engine-prefill percentile estimate, in microseconds (0 when the
    /// serving path never stepped, e.g. the full-window fallback).
    pub fn prefill_percentile_us(&self, p: f64) -> f64 {
        self.prefill.percentile_us(p)
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.total_latency_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Set the queue-depth gauge. Called by the batcher with the
    /// post-mutation depth while its queue lock is held.
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
    }

    /// Last queue depth published by the batcher.
    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Count a request for `tenant` resolved `Ok`.
    pub fn record_served(&self, tenant: &str) {
        let mut map = self.per_tenant.lock().unwrap();
        map.entry(tenant.to_string()).or_default().served += 1;
    }

    /// Count an admission-control rejection for `tenant`.
    pub fn record_tenant_rejected(&self, tenant: &str) {
        let mut map = self.per_tenant.lock().unwrap();
        map.entry(tenant.to_string()).or_default().rejected += 1;
    }

    /// Set `tenant`'s queue-depth gauge. Called by the batcher with the
    /// post-mutation per-tenant depth while its queue lock is held, from
    /// every path that changes a tenant's queue (push/pop/fill/purge).
    pub fn set_tenant_depth(&self, tenant: &str, depth: usize) {
        let mut map = self.per_tenant.lock().unwrap();
        map.entry(tenant.to_string()).or_default().queued = depth as u64;
    }

    /// Per-tenant counters for `tenant` (zeros when it has no traffic).
    pub fn tenant_counters(&self, tenant: &str) -> TenantCounters {
        self.per_tenant
            .lock()
            .unwrap()
            .get(tenant)
            .copied()
            .unwrap_or_default()
    }

    /// Point-in-time JSON export of every counter, the queue-depth gauge,
    /// latency/ttft/prefill percentiles (ms), and the per-tenant
    /// served/rejected table — the payload behind the front door's
    /// `GET /metrics`.
    pub fn snapshot(&self) -> Json {
        let c = |a: &AtomicU64| Json::num(a.load(Ordering::Relaxed) as f64);
        let hist = |h: &Histogram| {
            Json::obj(vec![
                ("p50_ms", Json::num(h.percentile_us(50.0) / 1e3)),
                ("p95_ms", Json::num(h.percentile_us(95.0) / 1e3)),
                ("p99_ms", Json::num(h.percentile_us(99.0) / 1e3)),
                ("count", Json::num(h.count() as f64)),
            ])
        };
        let tenants = self
            .per_tenant
            .lock()
            .unwrap()
            .iter()
            .map(|(id, t)| {
                (
                    id.clone(),
                    Json::obj(vec![
                        ("served", Json::num(t.served as f64)),
                        ("rejected", Json::num(t.rejected as f64)),
                        ("queued", Json::num(t.queued as f64)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("requests", c(&self.requests)),
            ("completed", c(&self.completed)),
            ("errors", c(&self.errors)),
            ("rejected", c(&self.rejected)),
            ("cancelled", c(&self.cancelled)),
            ("expired", c(&self.expired)),
            ("batches", c(&self.batches)),
            ("refilled", c(&self.refilled)),
            ("generated_tokens", c(&self.generated_tokens)),
            ("queue_depth", c(&self.queue_depth)),
            ("mean_latency_ms", Json::num(self.mean_latency_us() / 1e3)),
            ("mean_batch_size", Json::num(self.mean_batch_size())),
            ("latency", hist(&self.latency)),
            ("ttft", hist(&self.ttft)),
            ("prefill", hist(&self.prefill)),
            ("tenants", Json::Obj(tenants)),
        ])
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} completed={} errors={} rejected={} cancelled={} expired={} refilled={} mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms ttft_p50={:.1}ms prefill_p50={:.1}ms mean_batch={:.2} tokens={}",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.cancelled.load(Ordering::Relaxed),
            self.expired.load(Ordering::Relaxed),
            self.refilled.load(Ordering::Relaxed),
            self.mean_latency_us() / 1e3,
            self.percentile_us(50.0) / 1e3,
            self.percentile_us(95.0) / 1e3,
            self.percentile_us(99.0) / 1e3,
            self.ttft_percentile_us(50.0) / 1e3,
            self.prefill_percentile_us(50.0) / 1e3,
            self.mean_batch_size(),
            self.generated_tokens.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_match_documented_bounds() {
        // bucket i covers [10 * 1.5^i, 10 * 1.5^(i+1)); below-base clamps
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(9), 0);
        assert_eq!(bucket_of(10), 0);
        assert_eq!(bucket_of(14), 0);
        assert_eq!(bucket_of(15), 1);
        assert_eq!(bucket_of(22), 1); // [15, 22.5)
        assert_eq!(bucket_of(23), 2);
        // spot-check an interior bucket against the closed form
        for i in [5usize, 11, 20] {
            let lo = bucket_lower(i).ceil() as u64;
            let hi = bucket_lower(i + 1).floor() as u64;
            assert_eq!(bucket_of(lo), i, "lower bound of bucket {i}");
            assert_eq!(bucket_of(hi), i, "upper interior of bucket {i}");
        }
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn buckets_monotone() {
        assert!(bucket_of(5) <= bucket_of(50));
        assert!(bucket_of(50) <= bucket_of(5000));
    }

    #[test]
    fn percentile_reports_bucket_midpoint_not_upper_bound() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_latency(Duration::from_micros(100));
        }
        // 100µs lives in bucket 5 ([75.9, 113.9)); every percentile of a
        // single-bucket histogram is its geometric midpoint ~93µs
        let want = (bucket_lower(5) * bucket_lower(6)).sqrt();
        for p in [1.0, 50.0, 99.0] {
            let got = m.percentile_us(p);
            assert!((got - want).abs() < 1e-9, "p{p}: {got} vs {want}");
            assert!(
                got > bucket_lower(5) && got < bucket_lower(6),
                "p{p}={got} escaped the sample's bucket"
            );
        }
        // the old upper-bound estimate (~114µs) overstated by up to 1.5x
        assert!(m.percentile_us(50.0) < bucket_lower(6));
    }

    #[test]
    fn percentiles_ordered() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.record_latency(Duration::from_micros(i * 100));
        }
        let p50 = m.percentile_us(50.0);
        let p95 = m.percentile_us(95.0);
        let p99 = m.percentile_us(99.0);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of 100..10000µs is the 5000µs sample; the estimate must stay
        // inside that sample's own bucket (midpoint reporting), not just
        // "in the few-ms range"
        let b = bucket_of(5000);
        assert!(
            (bucket_lower(b)..bucket_lower(b + 1)).contains(&p50),
            "p50={p50} outside bucket {b} of the true median"
        );
        let b99 = bucket_of(9900);
        assert!(
            (bucket_lower(b99)..bucket_lower(b99 + 1)).contains(&p99),
            "p99={p99} outside bucket {b99}"
        );
    }

    #[test]
    fn ttft_histogram_independent_of_latency() {
        let m = Metrics::new();
        m.record_ttft(Duration::from_micros(200));
        m.record_latency(Duration::from_micros(9000));
        assert_eq!(m.ttft.count(), 1);
        assert_eq!(m.latency.count(), 1);
        let ttft = m.ttft_percentile_us(50.0);
        let lat = m.percentile_us(50.0);
        assert!(ttft < lat, "ttft {ttft} should sit well below latency {lat}");
        let b = bucket_of(200);
        assert!((bucket_lower(b)..bucket_lower(b + 1)).contains(&ttft));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.percentile_us(99.0), 0.0);
        assert_eq!(m.ttft_percentile_us(99.0), 0.0);
        assert_eq!(m.prefill_percentile_us(99.0), 0.0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
        let _ = m.summary();
    }

    #[test]
    fn prefill_histogram_independent_of_ttft() {
        let m = Metrics::new();
        m.record_prefill(Duration::from_micros(300));
        m.record_ttft(Duration::from_micros(4000));
        assert_eq!(m.prefill.count(), 1);
        assert_eq!(m.ttft.count(), 1);
        let p = m.prefill_percentile_us(50.0);
        let b = bucket_of(300);
        assert!((bucket_lower(b)..bucket_lower(b + 1)).contains(&p));
        assert!(p < m.ttft_percentile_us(50.0));
    }

    #[test]
    fn snapshot_shape_and_values() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_latency(Duration::from_micros(800));
        m.record_ttft(Duration::from_micros(100));
        m.record_batch(2);
        m.set_queue_depth(5);
        m.record_served("alice");
        m.record_served("alice");
        m.record_tenant_rejected("bob");
        let snap = m.snapshot();
        // every top-level key the /metrics consumers rely on
        for key in [
            "requests",
            "completed",
            "errors",
            "rejected",
            "cancelled",
            "expired",
            "batches",
            "refilled",
            "generated_tokens",
            "queue_depth",
            "mean_latency_ms",
            "mean_batch_size",
            "latency",
            "ttft",
            "prefill",
            "tenants",
        ] {
            assert!(snap.get(key).is_some(), "snapshot missing '{key}'");
        }
        assert_eq!(snap.req_usize("requests").unwrap(), 3);
        assert_eq!(snap.req_usize("completed").unwrap(), 1);
        assert_eq!(snap.req_usize("queue_depth").unwrap(), 5);
        for h in ["latency", "ttft", "prefill"] {
            let sub = snap.get(h).unwrap();
            for k in ["p50_ms", "p95_ms", "p99_ms", "count"] {
                assert!(sub.get(k).is_some(), "{h} missing '{k}'");
            }
        }
        assert_eq!(snap.get("latency").unwrap().req_usize("count").unwrap(), 1);
        let alice = snap.get("tenants").unwrap().get("alice").unwrap();
        assert_eq!(alice.req_usize("served").unwrap(), 2);
        assert_eq!(alice.req_usize("rejected").unwrap(), 0);
        let bob = snap.get("tenants").unwrap().get("bob").unwrap();
        assert_eq!(bob.req_usize("rejected").unwrap(), 1);
        // the export must round-trip through the hand-rolled serializer
        let parsed = Json::parse(&snap.to_string()).unwrap();
        assert_eq!(parsed.req_usize("queue_depth").unwrap(), 5);
    }

    #[test]
    fn tenant_counters_default_zero() {
        let m = Metrics::new();
        let t = m.tenant_counters("ghost");
        assert_eq!((t.served, t.rejected, t.queued), (0, 0, 0));
    }

    #[test]
    fn tenant_depth_gauge_tracks_last_set_and_survives_counters() {
        let m = Metrics::new();
        m.set_tenant_depth("alice", 3);
        assert_eq!(m.tenant_counters("alice").queued, 3);
        // a gauge: later sets replace, counters on the same entry keep
        m.record_served("alice");
        m.set_tenant_depth("alice", 1);
        let t = m.tenant_counters("alice");
        assert_eq!((t.served, t.queued), (1, 1));
        m.set_tenant_depth("alice", 0);
        assert_eq!(m.tenant_counters("alice").queued, 0);
        // snapshot carries the per-tenant depth
        let snap = m.snapshot();
        let alice = snap.get("tenants").unwrap().get("alice").unwrap();
        assert_eq!(alice.req_usize("queued").unwrap(), 0);
        assert_eq!(alice.req_usize("served").unwrap(), 1);
    }

    #[test]
    fn gauge_consistent_under_concurrent_updates() {
        // writers race set_queue_depth with disjoint values; the gauge
        // must always read one of the written values (no torn or stale-
        // forever reads) and settle on the final published depth
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for w in 0..4u64 {
            let m = std::sync::Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    m.set_queue_depth((w * 1000 + i) as usize);
                    m.record_served(&format!("t{w}"));
                    let d = m.queue_depth();
                    assert!(d < 4000, "impossible gauge value {d}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        m.set_queue_depth(7);
        assert_eq!(m.queue_depth(), 7);
        // per-tenant counters saw every increment despite the contention
        for w in 0..4u64 {
            assert_eq!(m.tenant_counters(&format!("t{w}")).served, 500);
        }
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.mean_batch_size(), 6.0);
        // mid-flight refills join existing batches: requests grow, the
        // batch count does not
        m.record_refill(4);
        assert_eq!(m.refilled.load(Ordering::Relaxed), 4);
        assert_eq!(m.mean_batch_size(), 8.0);
    }
}
