//! Serving metrics: latency histogram (log-spaced buckets), counters, and
//! percentile snapshots for the serving benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const BUCKETS: usize = 40;

/// Log-spaced latency histogram from 10µs to ~100s plus counters.
#[derive(Debug)]
pub struct Metrics {
    buckets: [AtomicU64; BUCKETS],
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    /// admission-control rejections (`ServeError::QueueFull`)
    pub rejected: AtomicU64,
    /// requests dropped by client cancellation before reaching an engine
    pub cancelled: AtomicU64,
    /// requests dropped because their deadline budget lapsed in queue
    pub expired: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub generated_tokens: AtomicU64,
    total_latency_us: AtomicU64,
}

fn bucket_of(us: u64) -> usize {
    // bucket i covers [10 * 1.5^i, 10 * 1.5^(i+1)) microseconds
    let mut bound = 10.0f64;
    for i in 0..BUCKETS {
        bound *= 1.5;
        if (us as f64) < bound {
            return i;
        }
    }
    BUCKETS - 1
}

fn bucket_upper(i: usize) -> f64 {
    10.0 * 1.5f64.powi(i as i32 + 1)
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            generated_tokens: AtomicU64::new(0),
            total_latency_us: AtomicU64::new(0),
        }
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.total_latency_us.fetch_add(us, Ordering::Relaxed);
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Latency percentile estimate (upper bucket bound), in microseconds.
    pub fn percentile_us(&self, p: f64) -> f64 {
        let total: u64 = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return bucket_upper(i);
            }
        }
        bucket_upper(BUCKETS - 1)
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.total_latency_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} completed={} errors={} rejected={} cancelled={} expired={} mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms mean_batch={:.2} tokens={}",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.cancelled.load(Ordering::Relaxed),
            self.expired.load(Ordering::Relaxed),
            self.mean_latency_us() / 1e3,
            self.percentile_us(50.0) / 1e3,
            self.percentile_us(95.0) / 1e3,
            self.percentile_us(99.0) / 1e3,
            self.mean_batch_size(),
            self.generated_tokens.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_monotone() {
        assert!(bucket_of(5) <= bucket_of(50));
        assert!(bucket_of(50) <= bucket_of(5000));
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn percentiles_ordered() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.record_latency(Duration::from_micros(i * 100));
        }
        let p50 = m.percentile_us(50.0);
        let p95 = m.percentile_us(95.0);
        let p99 = m.percentile_us(99.0);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of 100..10000us should land in the few-ms range
        assert!((1_000.0..20_000.0).contains(&p50), "p50={p50}");
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.percentile_us(99.0), 0.0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
        let _ = m.summary();
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.mean_batch_size(), 6.0);
    }
}
