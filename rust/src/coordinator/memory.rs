//! Accelerator-memory ledger: tracks per-tenant adapter bytes against a
//! budget and picks LRU eviction victims. This is where the paper's
//! parameter savings become *capacity*: at a fixed budget, ~8× smaller
//! adapters mean ~8× more resident tenants (fig_memory_scaling bench).
//!
//! Since PR 7 the ledger also carries a **KV side-table**: measured
//! resident page bytes per tenant, reported by the serving workers from
//! the paged KV pool ([`crate::model::paged::PagePool`]). KV bytes are
//! accounted *alongside* adapter bytes, not against the adapter budget —
//! the page pool is its own fixed-size slab whose capacity is enforced
//! at request admission (reservation-based, degrading to queueing), so
//! charging it against the adapter LRU would double-limit it. The
//! invariant servers assert: `kv_used()` equals the pool's resident
//! bytes, because per-page owner tags partition the pool exactly.
//!
//! Since PR 10 the adapter charge follows the serving representation:
//! under `MOS_SERVE_INT8=1` a pooled MoS tenant is admitted at its int8
//! bytes (codes + per-shard scales + f32 aux tables), which the registry
//! computes analytically and tests pin to the quantized entry's measured
//! `resident_bytes` — so the ~4× pool shrink buys ~4× more resident
//! tenants on top of the MoS ~8×, under the same budget.

use std::collections::HashMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitResult {
    /// fits without eviction
    Admitted,
    /// fits only after evicting one or more LRU tenants — [`MemoryLedger::admit`]
    /// picks the victims and returns their ids
    NeedsEviction,
    /// larger than the whole budget
    TooLarge,
}

/// Byte-accounting ledger with LRU ordering.
#[derive(Debug)]
pub struct MemoryLedger {
    pub capacity: usize,
    used: usize,
    entries: HashMap<String, usize>,
    /// access clock for LRU
    clock: u64,
    last_access: HashMap<String, u64>,
    /// Measured resident KV page bytes per tenant (see module docs).
    kv: HashMap<String, usize>,
}

impl MemoryLedger {
    pub fn new(capacity: usize) -> MemoryLedger {
        MemoryLedger {
            capacity,
            used: 0,
            entries: HashMap::new(),
            clock: 0,
            last_access: HashMap::new(),
            kv: HashMap::new(),
        }
    }

    pub fn used(&self) -> usize {
        self.used
    }

    pub fn resident(&self) -> usize {
        self.entries.len()
    }

    pub fn contains(&self, tenant: &str) -> bool {
        self.entries.contains_key(tenant)
    }

    /// Record an access (for LRU).
    pub fn touch(&mut self, tenant: &str) {
        self.clock += 1;
        if self.entries.contains_key(tenant) {
            self.last_access.insert(tenant.to_string(), self.clock);
        }
    }

    /// Can `bytes` be admitted? Does not mutate.
    pub fn classify(&self, bytes: usize) -> AdmitResult {
        if bytes > self.capacity {
            AdmitResult::TooLarge
        } else if self.used + bytes <= self.capacity {
            AdmitResult::Admitted
        } else {
            AdmitResult::NeedsEviction
        }
    }

    /// Admit a tenant, evicting LRU victims as needed. Returns the evicted
    /// tenant ids (callers drop their state).
    pub fn admit(&mut self, tenant: &str, bytes: usize) -> Option<Vec<String>> {
        if bytes > self.capacity {
            return None;
        }
        if let Some(old) = self.entries.remove(tenant) {
            self.used -= old;
            self.last_access.remove(tenant);
        }
        let mut evicted = Vec::new();
        while self.used + bytes > self.capacity {
            let victim = self
                .last_access
                .iter()
                .min_by_key(|(_, &t)| t)
                .map(|(k, _)| k.clone())?;
            let vb = self.entries.remove(&victim).unwrap();
            self.last_access.remove(&victim);
            self.used -= vb;
            evicted.push(victim);
        }
        self.clock += 1;
        self.entries.insert(tenant.to_string(), bytes);
        self.last_access.insert(tenant.to_string(), self.clock);
        self.used += bytes;
        Some(evicted)
    }

    pub fn release(&mut self, tenant: &str) {
        if let Some(b) = self.entries.remove(tenant) {
            self.used -= b;
            self.last_access.remove(tenant);
        }
    }

    /// Record `tenant`'s measured resident KV page bytes (serving workers
    /// report this from the paged pool's per-owner byte counts; `0`
    /// clears the entry). Does not count against the adapter budget —
    /// see the module docs.
    pub fn set_kv(&mut self, tenant: &str, bytes: usize) {
        if bytes == 0 {
            self.kv.remove(tenant);
        } else {
            self.kv.insert(tenant.to_string(), bytes);
        }
    }

    /// Total KV page bytes charged across tenants. Equals the page
    /// pool's resident bytes when every serving tenant has reported
    /// (owner tags partition the pool).
    pub fn kv_used(&self) -> usize {
        self.kv.values().sum()
    }

    /// KV page bytes charged to one tenant.
    pub fn kv_for(&self, tenant: &str) -> usize {
        self.kv.get(tenant).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_and_account() {
        let mut l = MemoryLedger::new(100);
        assert_eq!(l.admit("a", 40), Some(vec![]));
        assert_eq!(l.admit("b", 40), Some(vec![]));
        assert_eq!(l.used(), 80);
        assert_eq!(l.resident(), 2);
        assert_eq!(l.classify(30), AdmitResult::NeedsEviction);
        assert_eq!(l.classify(200), AdmitResult::TooLarge);
    }

    #[test]
    fn lru_eviction_order() {
        let mut l = MemoryLedger::new(100);
        l.admit("a", 40);
        l.admit("b", 40);
        l.touch("a"); // b becomes LRU
        let evicted = l.admit("c", 40).unwrap();
        assert_eq!(evicted, vec!["b".to_string()]);
        assert!(l.contains("a") && l.contains("c") && !l.contains("b"));
    }

    #[test]
    fn too_large_rejected() {
        let mut l = MemoryLedger::new(10);
        assert_eq!(l.admit("x", 11), None);
        assert_eq!(l.used(), 0);
    }

    #[test]
    fn readmit_replaces_size() {
        let mut l = MemoryLedger::new(100);
        l.admit("a", 90);
        l.admit("a", 20);
        assert_eq!(l.used(), 20);
        assert_eq!(l.resident(), 1);
    }

    #[test]
    fn multi_victim_eviction() {
        let mut l = MemoryLedger::new(100);
        l.admit("a", 30);
        l.admit("b", 30);
        l.admit("c", 30);
        let ev = l.admit("big", 90).unwrap();
        assert_eq!(ev.len(), 3);
        assert_eq!(l.resident(), 1);
    }

    #[test]
    fn release_frees() {
        let mut l = MemoryLedger::new(50);
        l.admit("a", 50);
        l.release("a");
        assert_eq!(l.used(), 0);
        assert_eq!(l.admit("b", 50), Some(vec![]));
    }

    #[test]
    fn kv_side_table_tracks_per_tenant_bytes() {
        let mut l = MemoryLedger::new(100);
        l.admit("a", 40);
        l.set_kv("a", 1024);
        l.set_kv("b", 512);
        assert_eq!(l.kv_for("a"), 1024);
        assert_eq!(l.kv_used(), 1536);
        // KV charges ride alongside the adapter budget, not inside it
        assert_eq!(l.used(), 40);
        assert_eq!(l.classify(60), AdmitResult::Admitted);
        // zero clears; re-reporting replaces rather than accumulating
        l.set_kv("a", 2048);
        assert_eq!(l.kv_used(), 2560);
        l.set_kv("b", 0);
        assert_eq!(l.kv_used(), 2048);
        assert_eq!(l.kv_for("b"), 0);
    }
}
