//! Dynamic batcher with admission control: requests are queued per tenant;
//! a batch is released when it reaches `max_batch` or the oldest request
//! exceeds `max_wait`. Per-tenant batching is what makes multi-LoRA serving
//! efficient — one forward pass per tenant per batch window
//! (S-LoRA/Punica-style).
//!
//! The queue is bounded ([`Admission`]): past the per-tenant or global
//! depth limit, `push` rejects with [`ServeError::QueueFull`] instead of
//! buffering forever. `pop_batch` rotates tenants round-robin so one hot
//! tenant cannot starve the ready queue, and drops cancelled or
//! deadline-expired requests before they ever reach an engine.
//!
//! Since the model layer serves mixed-tenant batches through per-run
//! [`AdapterBinding`](crate::model::transformer::AdapterBinding)s (PR 6),
//! per-tenant batching is a fallback, not a requirement: workers whose
//! engine supports the stepping path pop with `mix = true`, and a batch
//! released by one tenant is topped up with other tenants' queued
//! requests up to capacity. Canonical-order GEMMs make the mixed batch
//! decode bitwise-identically to per-tenant batches.
//!
//! Scheduling is **deficit-weighted round-robin** over per-tenant
//! [`QosSpec`] contracts (DESIGN.md §Scheduling-QoS): every scheduled
//! request debits its tenant's deficit counter by its token cost and
//! credits all backlogged tenants their weight share of that cost, so
//! shares of scheduled tokens converge to the weight ratio; selection
//! picks the max-deficit tenant (rotation order breaks ties). Tenants
//! with a token-bucket rate limit are *deferred* while the bucket cannot
//! cover their head request — never errored — and an aged-past-`max_wait`
//! head still overrides both deficit order and the bucket, preserving the
//! PR-3 starvation bound. `push` additionally rejects a deadline request
//! at submit with [`ServeError::Deadline`] when the budget provably
//! cannot be met at the current depth (estimated from the [`Metrics`]
//! prefill histogram).

use super::metrics::Metrics;
use super::registry::QosSpec;
use crate::eval::GenOptions;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Monotonically increasing request identifier, unique per server.
pub type RequestId = u64;

/// Typed failure for the request lifecycle, surfaced through `Result` both
/// at submit time (admission) and in the response channel (execution).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// No tenant with this id is registered.
    UnknownTenant(String),
    /// Admission control: the per-tenant or global queue depth is at its
    /// bound; retry later or shed load upstream.
    QueueFull { tenant: String },
    /// The request's deadline budget lapsed before an engine ran it.
    Deadline,
    /// The client cancelled the request via its [`super::server::ResponseHandle`].
    Cancelled,
    /// The server is shutting down (or shut down before responding).
    ShuttingDown,
    /// The engine's forward pass failed.
    Engine(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownTenant(id) => write!(f, "unknown tenant '{id}'"),
            ServeError::QueueFull { tenant } => {
                write!(f, "queue full for tenant '{tenant}'")
            }
            ServeError::Deadline => write!(f, "deadline exceeded"),
            ServeError::Cancelled => write!(f, "request cancelled"),
            ServeError::ShuttingDown => write!(f, "server shutting down"),
            ServeError::Engine(msg) => write!(f, "engine error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a request resolves to: a typed response or a typed error.
pub type ServeResult = Result<Response, ServeError>;

/// One generation request in flight inside the coordinator.
pub struct Request {
    pub id: RequestId,
    pub tenant: String,
    pub prompt: String,
    pub opts: GenOptions,
    /// Absolute deadline, computed from `opts.deadline` at submit time.
    pub deadline: Option<Instant>,
    pub respond: mpsc::Sender<ServeResult>,
    /// Streaming channel: workers send each generated token id as it is
    /// decoded; the sender drops (closing the stream) when the request
    /// resolves. Send errors are ignored — a client that never reads
    /// tokens costs nothing but the buffered ids.
    pub stream: mpsc::Sender<i32>,
    /// Set by the client's handle; the batcher drops flagged requests at
    /// the next pop (and on [`Batcher::notify`]), workers re-check between
    /// decode steps.
    pub cancelled: Arc<AtomicBool>,
    pub enqueued: Instant,
}

impl Request {
    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    pub fn is_expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| now >= d)
    }
}

/// One successful generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tenant: String,
    pub prompt: String,
    pub text: String,
    /// Number of generated tokens (before detokenization).
    pub tokens: usize,
    pub latency: Duration,
}

/// Queue-depth bounds enforced at `push`.
#[derive(Debug, Clone, Copy)]
pub struct Admission {
    pub per_tenant: usize,
    pub global: usize,
}

impl Default for Admission {
    fn default() -> Admission {
        Admission { per_tenant: 256, global: 1024 }
    }
}

/// Per-tenant DWRR + token-bucket state. Persistent across queue
/// emptiness (the bucket is a contract over wall time); only the deficit
/// resets when the tenant's queue drains — an idle tenant banks no
/// service credit (classic deficit round-robin).
#[derive(Debug, Clone)]
struct SchedState {
    /// Service credit in scheduled tokens. Can go negative (just served)
    /// or positive (waiting while others are served); conserved across
    /// the backlogged set, so it converges shares to the weight ratio.
    deficit: f64,
    /// Token-bucket level; only consulted when the tenant's [`QosSpec`]
    /// carries a rate. May go negative when an aged-head override or a
    /// request costing more than `burst` spends ahead of the refill — the
    /// tenant then pays the debt back at the refill rate.
    bucket: f64,
    last_refill: Instant,
}

struct Queues {
    /// Invariant: a tenant has a map entry iff its queue is non-empty, and
    /// appears in `ready` exactly once iff it has a map entry.
    by_tenant: HashMap<String, VecDeque<Request>>,
    /// Round-robin rotation order: pop scans from the front and moves the
    /// served tenant to the back. Under DWRR this is the tie-break and
    /// the aged-head service order, no longer the primary selector.
    ready: VecDeque<String>,
    /// Scheduling contracts installed by `set_qos` (absent = weight 1,
    /// unlimited — the pre-QoS behavior).
    qos: HashMap<String, QosSpec>,
    /// DWRR/bucket state, created lazily per scheduled tenant.
    sched: HashMap<String, SchedState>,
    total: usize,
    closed: bool,
}

/// Scheduled-token cost of one request, the unit both the deficit and the
/// bucket are kept in: prompt chars + BOS/SEP (the char-level tokenizer
/// makes chars ≈ prompt tokens) plus the decode budget, capped so
/// "decode to the window" doesn't blow up the accounting.
const DECODE_COST_CAP: usize = 64;

fn cost_tokens(req: &Request) -> f64 {
    (req.prompt.len() + 2 + req.opts.max_new_tokens.min(DECODE_COST_CAP))
        as f64
}

fn ensure_sched<'q>(
    q: &'q mut Queues,
    t: &str,
    now: Instant,
) -> &'q mut SchedState {
    let burst = q.qos.get(t).map_or(0.0, |s| s.burst);
    q.sched.entry(t.to_string()).or_insert_with(|| SchedState {
        deficit: 0.0,
        bucket: burst,
        last_refill: now,
    })
}

/// Refill `t`'s bucket on the monotonic clock (no-op without a rate).
fn refill_bucket(q: &mut Queues, t: &str, now: Instant) {
    let qos = q.qos.get(t).copied().unwrap_or_default();
    let s = ensure_sched(q, t, now);
    if let Some(rate) = qos.rate_tok_per_s {
        let dt = now.saturating_duration_since(s.last_refill).as_secs_f64();
        s.bucket = (s.bucket + dt * rate).min(qos.burst);
    }
    s.last_refill = now;
}

/// Can `t` spend `c` tokens now? The requirement is clamped to `burst` so
/// a request costing more than the whole bucket is schedulable at full
/// bucket (the overdraft is paid back at the refill rate) instead of
/// deferring forever.
fn bucket_covers(q: &Queues, t: &str, c: f64) -> bool {
    let Some(qos) = q.qos.get(t) else { return true };
    if qos.rate_tok_per_s.is_none() {
        return true;
    }
    q.sched
        .get(t)
        .map_or(true, |s| s.bucket + 1e-9 >= c.min(qos.burst))
}

/// Time until `t`'s bucket covers `c` (None = unlimited or covered now).
fn time_to_cover(q: &Queues, t: &str, c: f64) -> Option<Duration> {
    let qos = q.qos.get(t)?;
    let rate = qos.rate_tok_per_s?;
    let s = q.sched.get(t)?;
    let need = c.min(qos.burst) - s.bucket;
    if need <= 0.0 {
        return None;
    }
    Some(Duration::from_secs_f64(need / rate))
}

fn sched_deficit(q: &Queues, t: &str) -> f64 {
    q.sched.get(t).map_or(0.0, |s| s.deficit)
}

/// Charge `t` for scheduling a request of cost `c`: debit its deficit
/// (and bucket when rate-limited), credit every backlogged tenant —
/// including `t` — its weight share of `c`. Total deficit is conserved,
/// which is exactly what makes scheduled-token shares converge to the
/// weight ratio under saturation.
fn account(q: &mut Queues, t: &str, c: f64, now: Instant) {
    let weight =
        |q: &Queues, x: &str| q.qos.get(x).map_or(1.0, |s| f64::from(s.weight));
    let mut members: Vec<String> = q.ready.iter().cloned().collect();
    if !members.iter().any(|m| m == t) {
        members.push(t.to_string());
    }
    let w_total: f64 = members.iter().map(|m| weight(q, m)).sum();
    for m in &members {
        let share = c * weight(q, m) / w_total;
        ensure_sched(q, m, now).deficit += share;
    }
    let limited = q.qos.get(t).is_some_and(|s| s.rate_tok_per_s.is_some());
    let s = ensure_sched(q, t, now);
    s.deficit -= c;
    if limited {
        s.bucket -= c;
    }
}

/// `t`'s queue just emptied: drop it from the map and rotation, reset its
/// DWRR credit (idle tenants bank no service), zero its depth gauge. The
/// bucket is deliberately kept — the rate contract spans idle time.
fn tenant_drained(q: &mut Queues, t: &str, metrics: &Metrics) {
    q.by_tenant.remove(t);
    q.ready.retain(|x| x != t);
    if let Some(s) = q.sched.get_mut(t) {
        s.deficit = 0.0;
    }
    metrics.set_tenant_depth(t, 0);
}

/// Deficit-weighted drain of up to `max` requests across all tenants, one
/// head request at a time: aged heads go first in rotation order (the
/// starvation bound overrides both deficit and bucket), then the
/// max-deficit tenant whose bucket covers its head; rate-limited dry
/// tenants are skipped — deferred, never errored. Shared by
/// `try_fill_any` and `pop_batch`'s mixed top-up so the continuous-
/// batching path enforces the same contracts as the primary pop.
fn drain_weighted(
    q: &mut Queues,
    max: usize,
    max_wait: Duration,
    metrics: &Metrics,
    now: Instant,
) -> Vec<Request> {
    let ready: Vec<String> = q.ready.iter().cloned().collect();
    for t in &ready {
        refill_bucket(q, t, now);
    }
    let mut out = Vec::new();
    while out.len() < max {
        let mut aged_pick: Option<String> = None;
        let mut best: Option<(String, f64)> = None;
        for t in q.ready.iter() {
            let Some(reqs) = q.by_tenant.get(t) else { continue };
            let head = reqs.front().unwrap();
            let aged = now.saturating_duration_since(head.enqueued)
                >= max_wait
                || q.closed;
            if aged {
                aged_pick = Some(t.clone());
                break; // front-most aged tenant in rotation order wins
            }
            if !bucket_covers(q, t, cost_tokens(head)) {
                continue;
            }
            let d = sched_deficit(q, t);
            if best.as_ref().map_or(true, |(_, b)| d > *b) {
                best = Some((t.clone(), d));
            }
        }
        let Some(t) = aged_pick.or(best.map(|(t, _)| t)) else { break };
        let r = q.by_tenant.get_mut(&t).unwrap().pop_front().unwrap();
        q.total -= 1;
        account(q, &t, cost_tokens(&r), now);
        if q.by_tenant.get(&t).unwrap().is_empty() {
            tenant_drained(q, &t, metrics);
        } else {
            metrics.set_tenant_depth(&t, q.by_tenant[&t].len());
        }
        out.push(r);
    }
    out
}

/// Thread-safe dynamic batcher with bounded queues.
pub struct Batcher {
    q: Mutex<Queues>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub admission: Admission,
    metrics: Arc<Metrics>,
}

/// Drop cancelled / deadline-expired requests from every queue, responding
/// with the typed error, and restore the queue invariants.
fn purge(q: &mut Queues, metrics: &Metrics) {
    let now = Instant::now();
    let mut dropped = 0usize;
    for (t, reqs) in q.by_tenant.iter_mut() {
        if !reqs.iter().any(|r| r.is_cancelled() || r.is_expired(now)) {
            continue;
        }
        let before = reqs.len();
        let mut kept = VecDeque::with_capacity(before);
        for req in reqs.drain(..) {
            if req.is_cancelled() {
                metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(ServeError::Cancelled));
            } else if req.is_expired(now) {
                metrics.expired.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(ServeError::Deadline));
            } else {
                kept.push_back(req);
            }
        }
        dropped += before - kept.len();
        *reqs = kept;
        metrics.set_tenant_depth(t, reqs.len());
    }
    if dropped == 0 {
        return;
    }
    q.total -= dropped;
    metrics.set_queue_depth(q.total);
    let Queues { by_tenant, ready, sched, .. } = q;
    ready.retain(|t| by_tenant.get(t).is_some_and(|r| !r.is_empty()));
    by_tenant.retain(|t, r| {
        let keep = !r.is_empty();
        if !keep {
            // drained by purge: reset DWRR credit like any other drain
            if let Some(s) = sched.get_mut(t) {
                s.deficit = 0.0;
            }
        }
        keep
    });
}

impl Batcher {
    pub fn new(
        max_batch: usize,
        max_wait: Duration,
        admission: Admission,
        metrics: Arc<Metrics>,
    ) -> Batcher {
        assert!(max_batch > 0);
        Batcher {
            q: Mutex::new(Queues {
                by_tenant: HashMap::new(),
                ready: VecDeque::new(),
                qos: HashMap::new(),
                sched: HashMap::new(),
                total: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch,
            max_wait,
            admission,
            metrics,
        }
    }

    /// Install or replace `tenant`'s scheduling contract. Takes effect at
    /// the next scheduling decision; the token bucket starts full
    /// (= `burst`) and the DWRR credit starts at zero.
    pub fn set_qos(&self, tenant: &str, qos: QosSpec) {
        let mut guard = self.q.lock().unwrap();
        let q = &mut *guard;
        q.qos.insert(tenant.to_string(), qos);
        q.sched.insert(
            tenant.to_string(),
            SchedState {
                deficit: 0.0,
                bucket: qos.burst,
                last_refill: Instant::now(),
            },
        );
        self.cv.notify_all();
    }

    /// Drop `tenant`'s contract — back to the weight-1 unlimited default.
    pub fn clear_qos(&self, tenant: &str) {
        let mut guard = self.q.lock().unwrap();
        guard.qos.remove(tenant);
        guard.sched.remove(tenant);
    }

    /// The installed contract for `tenant`, if any.
    pub fn qos_of(&self, tenant: &str) -> Option<QosSpec> {
        self.q.lock().unwrap().qos.get(tenant).copied()
    }

    /// Admission-time lower bound on a new request's TTFT at queue depth
    /// `depth`, from the engine-prefill histogram: the queue ahead costs
    /// `depth / max_batch` admission rounds before ours, each at least one
    /// median prefill. `None` until the histogram has enough samples to
    /// mean anything — with no signal, admission never second-guesses a
    /// deadline.
    fn min_ttft_estimate(&self, depth: usize) -> Option<Duration> {
        const MIN_SAMPLES: u64 = 32;
        if self.metrics.prefill.count() < MIN_SAMPLES {
            return None;
        }
        let per_round_us = self.metrics.prefill_percentile_us(50.0);
        let rounds = 1 + depth / self.max_batch;
        Some(Duration::from_micros((per_round_us * rounds as f64) as u64))
    }

    /// Enqueue a request. Admission control rejects synchronously: the
    /// request never enters a queue on `Err`, so the caller can surface the
    /// error at submit time. A depth limit purges cancelled / expired
    /// requests before rejecting — dead requests must not hold `QueueFull`
    /// against live traffic until the next `pop_batch` happens by. A
    /// request whose deadline budget provably cannot be met at the current
    /// depth rejects with [`ServeError::Deadline`] *now* instead of
    /// burning queue slots and engine work on a doomed request.
    pub fn push(&self, req: Request) -> Result<(), ServeError> {
        let mut guard = self.q.lock().unwrap();
        if guard.closed {
            return Err(ServeError::ShuttingDown);
        }
        let at_limit = |q: &Queues| {
            q.total >= self.admission.global
                || q.by_tenant.get(&req.tenant).map_or(0, |d| d.len())
                    >= self.admission.per_tenant
        };
        if at_limit(&guard) {
            purge(&mut guard, &self.metrics);
            if at_limit(&guard) {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                self.metrics.record_tenant_rejected(&req.tenant);
                return Err(ServeError::QueueFull { tenant: req.tenant });
            }
        }
        if let Some(d) = req.deadline {
            if let Some(est) = self.min_ttft_estimate(guard.total) {
                if d.saturating_duration_since(Instant::now()) < est {
                    self.metrics.expired.fetch_add(1, Ordering::Relaxed);
                    self.metrics.record_tenant_rejected(&req.tenant);
                    return Err(ServeError::Deadline);
                }
            }
        }
        let q = &mut *guard;
        if q.by_tenant.get(&req.tenant).map_or(0, |d| d.len()) == 0 {
            q.ready.push_back(req.tenant.clone());
        }
        let tenant = req.tenant.clone();
        let reqs = q.by_tenant.entry(req.tenant.clone()).or_default();
        reqs.push_back(req);
        let depth = reqs.len();
        q.total += 1;
        self.metrics.set_tenant_depth(&tenant, depth);
        self.metrics.set_queue_depth(q.total);
        self.cv.notify_one();
        Ok(())
    }

    /// Non-blocking continuous-batching refill: pop up to `max` queued
    /// requests for `tenant` so a worker can admit them into its *running*
    /// decode batch between steps (Orca/S-LoRA-style iteration-level
    /// scheduling). Declines (returns empty) while any *other* tenant has
    /// a releasable batch — mid-flight refills must not starve the
    /// round-robin rotation that `pop_batch` provides.
    pub fn try_fill(&self, tenant: &str, max: usize) -> Vec<Request> {
        if max == 0 {
            return Vec::new();
        }
        let mut guard = self.q.lock().unwrap();
        purge(&mut guard, &self.metrics);
        let q = &mut *guard;
        let now = Instant::now();
        let ready: Vec<String> = q.ready.iter().cloned().collect();
        for t in &ready {
            refill_bucket(q, t, now);
        }
        for t in &ready {
            if t == tenant {
                continue;
            }
            let Some(reqs) = q.by_tenant.get(t) else { continue };
            let aged =
                reqs.front().unwrap().enqueued.elapsed() >= self.max_wait;
            let releasable = reqs.len() >= self.max_batch || aged;
            // a dry rate-limited tenant is not being starved by our
            // refill — it is deferred by its own bucket — so it does not
            // force a decline
            if releasable
                && (aged
                    || bucket_covers(
                        q,
                        t,
                        cost_tokens(reqs.front().unwrap()),
                    ))
            {
                return Vec::new();
            }
        }
        // drain our own queue: aged head overrides the bucket (starvation
        // bound), the rest only while the bucket keeps covering
        let mut out: Vec<Request> = Vec::new();
        while out.len() < max {
            let (aged, c) = match q.by_tenant.get(tenant) {
                Some(reqs) if !reqs.is_empty() => {
                    let head = reqs.front().unwrap();
                    (
                        head.enqueued.elapsed() >= self.max_wait,
                        cost_tokens(head),
                    )
                }
                _ => break,
            };
            if !(out.is_empty() && aged) && !bucket_covers(q, tenant, c) {
                break;
            }
            let r = q.by_tenant.get_mut(tenant).unwrap().pop_front().unwrap();
            q.total -= 1;
            account(q, tenant, c, now);
            out.push(r);
        }
        if !out.is_empty() {
            if q.by_tenant.get(tenant).is_some_and(|r| r.is_empty()) {
                tenant_drained(q, tenant, &self.metrics);
            } else {
                self.metrics.set_tenant_depth(
                    tenant,
                    q.by_tenant.get(tenant).map_or(0, |r| r.len()),
                );
            }
            self.metrics.set_queue_depth(q.total);
        }
        out
    }

    /// [`Self::try_fill`] without the tenant restriction: pop up to `max`
    /// queued requests across *all* tenants in deficit order, for a
    /// worker refilling a mixed decode batch. No fairness decline is
    /// needed — a mixed batch can absorb any tenant's requests, so
    /// nothing releasable is being starved; DWRR decides *whose* requests
    /// fill the free slots.
    pub fn try_fill_any(&self, max: usize) -> Vec<Request> {
        if max == 0 {
            return Vec::new();
        }
        let mut guard = self.q.lock().unwrap();
        purge(&mut guard, &self.metrics);
        let q = &mut *guard;
        let out =
            drain_weighted(q, max, self.max_wait, &self.metrics, Instant::now());
        self.metrics.set_queue_depth(q.total);
        out
    }

    /// Wake `pop_batch` sleepers so they re-run their purge pass. Called
    /// by `ResponseHandle::cancel`: without it, a cancellation on an
    /// otherwise idle queue sat unresolved until the `max_wait` timeout.
    pub fn notify(&self) {
        self.cv.notify_all();
    }

    /// Pop the next batch. Blocks until a batch is ready (some tenant's
    /// queue is full, or its oldest request aged past `max_wait`), or
    /// returns None when closed and drained. Among concurrently
    /// releasable tenants the max-deficit tenant whose bucket covers its
    /// head wins (DWRR); an aged head beats both, served in rotation
    /// order, and the served tenant still rotates to the back — the PR-3
    /// starvation bound is unchanged. A releasable tenant whose bucket is
    /// dry is deferred, and the sleep shortens to its refill horizon so
    /// the wait never overshoots the contract.
    ///
    /// With `mix = false` the batch is single-tenant (the full-window
    /// fallback engines require one adapter per forward). With
    /// `mix = true`, remaining capacity is topped up with *other*
    /// tenants' queued requests in deficit order — the stepping engines
    /// serve mixed rows through per-run adapter bindings, so waiting for
    /// a same-tenant fill would just waste slots.
    pub fn pop_batch(&self, mix: bool) -> Option<Vec<Request>> {
        let mut guard = self.q.lock().unwrap();
        loop {
            purge(&mut guard, &self.metrics);
            let q = &mut *guard;
            let now = Instant::now();
            let ready: Vec<String> = q.ready.iter().cloned().collect();
            for t in &ready {
                refill_bucket(q, t, now);
            }
            let mut aged_pick: Option<String> = None;
            let mut best: Option<(String, f64)> = None;
            let mut sleep = self.max_wait;
            for t in q.ready.iter() {
                let Some(reqs) = q.by_tenant.get(t) else { continue };
                let head = reqs.front().unwrap();
                let age = now.saturating_duration_since(head.enqueued);
                if age >= self.max_wait || q.closed {
                    aged_pick = Some(t.clone());
                    break; // front-most aged tenant in rotation order
                }
                sleep = sleep.min(self.max_wait - age);
                if reqs.len() < self.max_batch {
                    continue; // not releasable yet
                }
                let c = cost_tokens(head);
                if !bucket_covers(q, t, c) {
                    // deferred by its own rate contract: wake when the
                    // bucket refills (or the head ages), whichever first
                    if let Some(w) = time_to_cover(q, t, c) {
                        sleep = sleep.min(w);
                    }
                    continue;
                }
                let d = sched_deficit(q, t);
                if best.as_ref().map_or(true, |(_, b)| d > *b) {
                    best = Some((t.clone(), d));
                }
            }
            if let Some(t) = aged_pick.or(best.map(|(b, _)| b)) {
                q.ready.retain(|x| x != &t);
                // drain one head at a time: the first request is
                // unconditional (it is what made the tenant releasable —
                // aged or bucket-covered), the rest only while the bucket
                // keeps covering
                let mut batch: Vec<Request> = Vec::new();
                while batch.len() < self.max_batch {
                    let c = match q.by_tenant.get(&t) {
                        Some(reqs) if !reqs.is_empty() => {
                            cost_tokens(reqs.front().unwrap())
                        }
                        _ => break,
                    };
                    if !batch.is_empty() && !bucket_covers(q, &t, c) {
                        break;
                    }
                    let r =
                        q.by_tenant.get_mut(&t).unwrap().pop_front().unwrap();
                    q.total -= 1;
                    account(q, &t, c, now);
                    batch.push(r);
                }
                if q.by_tenant.get(&t).map_or(true, |r| r.is_empty()) {
                    tenant_drained(q, &t, &self.metrics);
                } else {
                    q.ready.push_back(t.clone());
                    self.metrics.set_tenant_depth(&t, q.by_tenant[&t].len());
                }
                if mix {
                    let fill = self.max_batch - batch.len();
                    batch.extend(drain_weighted(
                        q,
                        fill,
                        self.max_wait,
                        &self.metrics,
                        now,
                    ));
                }
                self.metrics.set_queue_depth(q.total);
                return Some(batch);
            }
            if q.closed && q.total == 0 {
                return None;
            }
            let (g, _timeout) = self
                .cv
                .wait_timeout(guard, sleep.max(Duration::from_millis(1)))
                .unwrap();
            guard = g;
        }
    }

    /// Current global queue depth.
    pub fn depth(&self) -> usize {
        self.q.lock().unwrap().total
    }

    /// Signal shutdown: pending requests are still drained by workers;
    /// subsequent `push` calls fail with `ShuttingDown`.
    pub fn close(&self) {
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn batcher(max_batch: usize, max_wait: Duration) -> Batcher {
        Batcher::new(
            max_batch,
            max_wait,
            Admission::default(),
            Arc::new(Metrics::new()),
        )
    }

    fn req(tenant: &str, prompt: &str) -> (Request, mpsc::Receiver<ServeResult>) {
        let (tx, rx) = mpsc::channel();
        let (stream_tx, _stream_rx) = mpsc::channel();
        (
            Request {
                id: 0,
                tenant: tenant.into(),
                prompt: prompt.into(),
                opts: GenOptions::greedy(),
                deadline: None,
                respond: tx,
                stream: stream_tx,
                cancelled: Arc::new(AtomicBool::new(false)),
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn full_batch_released_immediately() {
        let b = batcher(2, Duration::from_secs(60));
        let (r1, _rx1) = req("a", "p1");
        let (r2, _rx2) = req("a", "p2");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        let batch = b.pop_batch(false).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|r| r.tenant == "a"));
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn timeout_releases_partial_batch() {
        let b = batcher(8, Duration::from_millis(20));
        let (r1, _rx) = req("a", "p1");
        b.push(r1).unwrap();
        let t0 = Instant::now();
        let batch = b.pop_batch(false).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn tenants_batched_separately_without_mixing() {
        let b = batcher(2, Duration::from_millis(10));
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("b", "p2");
        let (r3, _x3) = req("a", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        let batch1 = b.pop_batch(false).unwrap();
        let batch2 = b.pop_batch(false).unwrap();
        let (t1, t2) = (batch1[0].tenant.clone(), batch2[0].tenant.clone());
        assert_ne!(t1, t2);
        assert_eq!(batch1.len() + batch2.len(), 3);
        // no cross-tenant mixing on the full-window fallback path
        for r in batch1 {
            assert_eq!(r.tenant, t1);
        }
        for r in batch2 {
            assert_eq!(r.tenant, t2);
        }
    }

    #[test]
    fn pop_batch_mixes_tenants_up_to_capacity() {
        let b = batcher(4, Duration::from_millis(5));
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("b", "p2");
        let (r3, _x3) = req("b", "p3");
        let (r4, _x4) = req("c", "p4");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        b.push(r4).unwrap();
        // one mixed pop drains everything: a's aged batch tops up with
        // b's and c's queued requests
        let batch = b.pop_batch(true).unwrap();
        assert_eq!(batch.len(), 4);
        let mut tenants: Vec<&str> =
            batch.iter().map(|r| r.tenant.as_str()).collect();
        tenants.sort();
        tenants.dedup();
        assert_eq!(tenants, vec!["a", "b", "c"]);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn mixed_pop_respects_max_batch() {
        let b = batcher(2, Duration::from_millis(5));
        for i in 0..2 {
            // dropped receivers are fine: responses to them are ignored
            let (r, _x) = req("a", &format!("a{i}"));
            b.push(r).unwrap();
        }
        let (rb, _xb) = req("b", "b0");
        b.push(rb).unwrap();
        // a fills the batch alone; b must wait for the next pop
        let batch = b.pop_batch(true).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|r| r.tenant == "a"));
        assert_eq!(b.pop_batch(true).unwrap().len(), 1);
    }

    #[test]
    fn try_fill_any_pops_across_tenants() {
        let b = batcher(8, Duration::from_secs(60));
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("b", "p2");
        let (r3, _x3) = req("b", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        let got = b.try_fill_any(2);
        assert_eq!(got.len(), 2);
        assert_eq!(b.depth(), 1);
        assert_eq!(b.try_fill_any(8).len(), 1);
        assert_eq!(b.depth(), 0);
        assert!(b.try_fill_any(8).is_empty());
        // invariants intact: a later push + pop still works
        let (r4, _x4) = req("a", "p4");
        b.push(r4).unwrap();
        b.close();
        assert_eq!(b.pop_batch(true).unwrap().len(), 1);
    }

    #[test]
    fn close_drains_then_none() {
        let b = Arc::new(batcher(4, Duration::from_millis(5)));
        let (r1, _x1) = req("a", "p1");
        b.push(r1).unwrap();
        b.close();
        assert!(b.pop_batch(false).is_some());
        assert!(b.pop_batch(false).is_none());
    }

    #[test]
    fn push_after_close_rejected() {
        let b = batcher(4, Duration::from_millis(5));
        b.close();
        let (r, _rx) = req("a", "p");
        assert_eq!(b.push(r), Err(ServeError::ShuttingDown));
    }

    #[test]
    fn concurrent_producers_consumer() {
        let b = Arc::new(batcher(4, Duration::from_millis(10)));
        let mut rxs = Vec::new();
        let mut handles = Vec::new();
        for i in 0..12 {
            let (r, rx) = req(&format!("t{}", i % 3), &format!("p{i}"));
            rxs.push(rx);
            let b2 = Arc::clone(&b);
            handles.push(std::thread::spawn(move || b2.push(r).unwrap()));
        }
        for h in handles {
            h.join().unwrap();
        }
        b.close();
        let mut total = 0;
        while let Some(batch) = b.pop_batch(false) {
            total += batch.len();
        }
        assert_eq!(total, 12);
    }

    #[test]
    fn per_tenant_depth_limit_rejects() {
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::new(
            8,
            Duration::from_secs(60),
            Admission { per_tenant: 2, global: 100 },
            Arc::clone(&metrics),
        );
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("a", "p2");
        let (r3, _x3) = req("a", "p3");
        let (r4, _x4) = req("b", "p4");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        assert_eq!(
            b.push(r3),
            Err(ServeError::QueueFull { tenant: "a".into() })
        );
        // other tenants are unaffected by a's full queue
        b.push(r4).unwrap();
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(b.depth(), 3);
    }

    #[test]
    fn global_depth_limit_rejects() {
        let b = Batcher::new(
            8,
            Duration::from_secs(60),
            Admission { per_tenant: 100, global: 2 },
            Arc::new(Metrics::new()),
        );
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("b", "p2");
        let (r3, _x3) = req("c", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        assert!(matches!(b.push(r3), Err(ServeError::QueueFull { .. })));
    }

    #[test]
    fn cancelled_request_never_batched() {
        let b = batcher(2, Duration::from_secs(60));
        let (r1, rx1) = req("a", "p1");
        let cancel_flag = Arc::clone(&r1.cancelled);
        let (r2, _x2) = req("a", "p2");
        let (r3, _x3) = req("a", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        cancel_flag.store(true, Ordering::Relaxed);
        let batch = b.pop_batch(false).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|r| r.prompt != "p1"));
        assert_eq!(rx1.recv().unwrap(), Err(ServeError::Cancelled));
    }

    #[test]
    fn expired_request_gets_deadline_error() {
        let b = batcher(2, Duration::from_secs(60));
        let (mut r1, rx1) = req("a", "p1");
        r1.deadline = Some(Instant::now()); // already lapsed
        let (r2, _x2) = req("a", "p2");
        let (r3, _x3) = req("a", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        let batch = b.pop_batch(false).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|r| r.prompt != "p1"));
        assert_eq!(rx1.recv().unwrap(), Err(ServeError::Deadline));
    }

    #[test]
    fn try_fill_pops_queued_requests_for_running_tenant() {
        let b = batcher(4, Duration::from_secs(60));
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("a", "p2");
        let (r3, _x3) = req("a", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        let got = b.try_fill("a", 2);
        assert_eq!(got.len(), 2);
        assert_eq!(b.depth(), 1);
        // draining the rest restores the empty-queue invariants
        assert_eq!(b.try_fill("a", 8).len(), 1);
        assert_eq!(b.depth(), 0);
        assert!(b.try_fill("a", 8).is_empty());
        // and a later push still works (ready-rotation entry restored)
        let (r4, _x4) = req("a", "p4");
        b.push(r4).unwrap();
        b.close(); // make the partial batch releasable without max_wait
        assert_eq!(b.pop_batch(false).unwrap().len(), 1);
    }

    #[test]
    fn try_fill_declines_while_other_tenant_releasable() {
        // tenant b has a full batch waiting: a's mid-flight refill must
        // yield so the rotation can serve b first
        let b = batcher(2, Duration::from_secs(60));
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("b", "p2");
        let (r3, _x3) = req("b", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        assert!(b.try_fill("a", 4).is_empty(), "starved tenant b's batch");
        // once b is drained, a's refill proceeds
        assert_eq!(b.pop_batch(false).unwrap()[0].tenant, "b");
        assert_eq!(b.try_fill("a", 4).len(), 1);
    }

    #[test]
    fn try_fill_skips_cancelled_requests() {
        let b = batcher(4, Duration::from_secs(60));
        let (r1, rx1) = req("a", "p1");
        let flag = Arc::clone(&r1.cancelled);
        let (r2, _x2) = req("a", "p2");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        flag.store(true, Ordering::Relaxed);
        let got = b.try_fill("a", 4);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].prompt, "p2");
        assert_eq!(rx1.recv().unwrap(), Err(ServeError::Cancelled));
    }

    #[test]
    fn admission_purges_dead_requests_before_rejecting() {
        // regression: cancelled requests used to occupy Admission depth
        // until the next pop_batch, rejecting live traffic as QueueFull
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::new(
            8,
            Duration::from_secs(60),
            Admission { per_tenant: 2, global: 100 },
            Arc::clone(&metrics),
        );
        let (r1, rx1) = req("a", "p1");
        let f1 = Arc::clone(&r1.cancelled);
        let (r2, rx2) = req("a", "p2");
        let f2 = Arc::clone(&r2.cancelled);
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        f1.store(true, Ordering::Relaxed);
        f2.store(true, Ordering::Relaxed);
        // queue "full" of dead requests: the push must purge and accept
        let (r3, _x3) = req("a", "p3");
        b.push(r3).expect("dead requests rejected live traffic");
        assert_eq!(rx1.recv().unwrap(), Err(ServeError::Cancelled));
        assert_eq!(rx2.recv().unwrap(), Err(ServeError::Cancelled));
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 0);
        assert_eq!(b.depth(), 1);
        // the global bound purges too
        let bg = Batcher::new(
            8,
            Duration::from_secs(60),
            Admission { per_tenant: 100, global: 1 },
            Arc::new(Metrics::new()),
        );
        let (r4, _x4) = req("a", "p4");
        let f4 = Arc::clone(&r4.cancelled);
        bg.push(r4).unwrap();
        f4.store(true, Ordering::Relaxed);
        let (r5, _x5) = req("b", "p5");
        bg.push(r5).expect("global bound ignored the purge");
    }

    #[test]
    fn notify_wakes_sleeping_pop_for_cancel_resolution() {
        // regression: with an otherwise idle queue, a cancelled request's
        // resolution used to wait out the full max_wait timeout
        let b = Arc::new(batcher(8, Duration::from_secs(30)));
        let (r1, rx1) = req("a", "p1");
        let flag = Arc::clone(&r1.cancelled);
        b.push(r1).unwrap();
        let b2 = Arc::clone(&b);
        let worker = std::thread::spawn(move || b2.pop_batch(false));
        // let the worker reach its cv sleep (the batch is not releasable
        // for 30s), then cancel + notify
        std::thread::sleep(Duration::from_millis(50));
        flag.store(true, Ordering::Relaxed);
        b.notify();
        let t0 = Instant::now();
        assert_eq!(
            rx1.recv_timeout(Duration::from_secs(5)).unwrap(),
            Err(ServeError::Cancelled)
        );
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "cancel resolution waited for max_wait"
        );
        b.close();
        assert!(worker.join().unwrap().is_none());
    }

    #[test]
    fn dwrr_converges_to_weight_ratio() {
        // saturated three-tenant run: scheduled shares must converge to
        // the weight ratio 1:2:4 (ISSUE 9 acceptance)
        let b = batcher(1, Duration::from_secs(60));
        b.set_qos("w1", QosSpec { weight: 1, ..QosSpec::default() });
        b.set_qos("w2", QosSpec { weight: 2, ..QosSpec::default() });
        b.set_qos("w4", QosSpec { weight: 4, ..QosSpec::default() });
        let mut _rxs = Vec::new();
        for i in 0..200 {
            for t in ["w1", "w2", "w4"] {
                // fixed-width prompts keep every request the same cost,
                // so request counts are token shares
                let (r, rx) = req(t, &format!("p{i:03}"));
                _rxs.push(rx);
                b.push(r).unwrap();
            }
        }
        let mut served: HashMap<String, usize> = HashMap::new();
        for _ in 0..300 {
            let got = b.try_fill_any(1);
            assert_eq!(got.len(), 1);
            *served.entry(got[0].tenant.clone()).or_default() += 1;
        }
        for (t, w) in [("w1", 1.0), ("w2", 2.0), ("w4", 4.0)] {
            let share = served[t] as f64 / 300.0;
            let expect = w / 7.0;
            assert!(
                (share - expect).abs() <= 0.15 * expect,
                "tenant {t}: share {share:.3} vs expected {expect:.3} \
                 (served {served:?})"
            );
        }
    }

    #[test]
    fn rate_limited_tenant_deferred_not_errored() {
        let b = batcher(4, Duration::from_secs(60));
        // burst covers exactly one request's cost (4 + 2 + 64); the
        // refill rate is negligible on test timescales
        b.set_qos(
            "rl",
            QosSpec {
                weight: 1,
                rate_tok_per_s: Some(0.001),
                burst: 70.0,
            },
        );
        let (r0, _x0) = req("rl", "pppp");
        let (r1, _x1) = req("rl", "pppp");
        b.push(r0).unwrap();
        b.push(r1).unwrap();
        // first fill spends the whole bucket on one request
        assert_eq!(b.try_fill_any(4).len(), 1);
        // the second is deferred — still queued, no error surfaced
        assert!(b.try_fill_any(4).is_empty());
        assert_eq!(b.depth(), 1);
    }

    #[test]
    fn token_accounting_respects_bucket_credits() {
        // scheduled tokens must stay within burst + rate×elapsed: the
        // debit side of the bucket is what enforces the contract
        let b = batcher(1, Duration::from_secs(60));
        b.set_qos(
            "rl",
            QosSpec {
                weight: 1,
                rate_tok_per_s: Some(4000.0),
                burst: 80.0,
            },
        );
        let t0 = Instant::now();
        let mut _rxs = Vec::new();
        for i in 0..6 {
            let (r, rx) = req("rl", &format!("p{i}")); // cost 68 each
            _rxs.push(rx);
            b.push(r).unwrap();
        }
        let mut scheduled = 0.0;
        let mut served = 0;
        while served < 6 {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "rate-limited queue never drained"
            );
            for r in b.try_fill_any(1) {
                scheduled += (r.prompt.len() + 2 + DECODE_COST_CAP) as f64;
                served += 1;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        let elapsed = t0.elapsed().as_secs_f64();
        assert!(
            scheduled <= 80.0 + 4000.0 * elapsed + 1.0,
            "scheduled {scheduled} tokens exceeds bucket credits \
             ({:.1} available)",
            80.0 + 4000.0 * elapsed
        );
    }

    #[test]
    fn aged_head_overrides_dry_bucket() {
        // starvation bound over the rate contract: a head aged past
        // max_wait is served even with the bucket deep in debt
        let b = batcher(2, Duration::from_millis(40));
        b.set_qos(
            "rl",
            QosSpec {
                weight: 1,
                rate_tok_per_s: Some(0.001),
                burst: 1.0,
            },
        );
        let mut _rxs = Vec::new();
        for i in 0..2 {
            let (r, rx) = req("rl", &format!("p{i}"));
            _rxs.push(rx);
            b.push(r).unwrap();
        }
        // releasable by size; the coverage requirement clamps to burst,
        // so the full bucket schedules the oversized head — but the drain
        // stops once the bucket is in debt: exactly one request comes out
        let t0 = Instant::now();
        assert_eq!(b.pop_batch(false).unwrap().len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(35));
        // the bucket now owes ~67 tokens at 0.001 tok/s (effectively
        // forever); only the aged-head override can serve the survivor
        assert_eq!(b.pop_batch(false).unwrap().len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn deadline_admission_rejects_unmeetable_budget() {
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::new(
            4,
            Duration::from_secs(60),
            Admission::default(),
            Arc::clone(&metrics),
        );
        // below the sample floor the estimator abstains: tight budgets
        // are admitted rather than second-guessed without signal
        let (mut r0, _x0) = req("a", "p");
        r0.deadline = Some(Instant::now() + Duration::from_millis(10));
        b.push(r0).unwrap();
        // with 64 samples of 100ms prefill, a 10ms budget is provably
        // unmeetable: rejected at submit, not after burning engine work
        for _ in 0..64 {
            metrics.record_prefill(Duration::from_millis(100));
        }
        let (mut r1, _x1) = req("a", "p");
        r1.deadline = Some(Instant::now() + Duration::from_millis(10));
        assert_eq!(b.push(r1), Err(ServeError::Deadline));
        assert_eq!(metrics.tenant_counters("a").rejected, 1);
        // a meetable budget is still admitted
        let (mut r2, _x2) = req("a", "p");
        r2.deadline = Some(Instant::now() + Duration::from_secs(5));
        b.push(r2).unwrap();
        assert_eq!(b.depth(), 2);
    }

    #[test]
    fn per_tenant_depth_gauge_follows_queue() {
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::new(
            8,
            Duration::from_secs(60),
            Admission::default(),
            Arc::clone(&metrics),
        );
        let mut _rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req("a", &format!("p{i}"));
            _rxs.push(rx);
            b.push(r).unwrap();
        }
        let (rb, _xb) = req("b", "p");
        b.push(rb).unwrap();
        assert_eq!(metrics.tenant_counters("a").queued, 3);
        assert_eq!(metrics.tenant_counters("b").queued, 1);
        assert_eq!(b.try_fill("a", 2).len(), 2);
        assert_eq!(metrics.tenant_counters("a").queued, 1);
        // cancellation purge updates the gauge too
        let (rc, _xc) = req("a", "pX");
        let flag = Arc::clone(&rc.cancelled);
        b.push(rc).unwrap();
        assert_eq!(metrics.tenant_counters("a").queued, 2);
        flag.store(true, Ordering::Relaxed);
        assert_eq!(b.try_fill_any(8).len(), 2);
        assert_eq!(metrics.tenant_counters("a").queued, 0);
        assert_eq!(metrics.tenant_counters("b").queued, 0);
    }

    #[test]
    fn round_robin_rotation_prevents_starvation() {
        // hot tenant always has a full batch ready; the cold tenant's
        // single request must still be served between hot batches once
        // releasable, because the served tenant rotates to the back.
        let b = batcher(2, Duration::from_millis(20));
        let mut hot_rx = Vec::new();
        for i in 0..4 {
            let (r, rx) = req("hot", &format!("h{i}"));
            hot_rx.push(rx);
            b.push(r).unwrap();
        }
        let (rc, _xc) = req("cold", "c0");
        b.push(rc).unwrap();
        // hot is at the front and has a full batch: served first, rotated
        assert_eq!(b.pop_batch(false).unwrap()[0].tenant, "hot");
        // age both past max_wait: now cold (front of rotation) wins even
        // though hot still holds a full batch
        std::thread::sleep(Duration::from_millis(25));
        let b2 = b.pop_batch(false).unwrap();
        assert_eq!(b2[0].tenant, "cold", "cold tenant starved by hot tenant");
        let batch3 = b.pop_batch(false).unwrap();
        assert_eq!(batch3[0].tenant, "hot");
        assert_eq!(batch3.len(), 2);
    }
}
