//! Dynamic batcher with admission control: requests are queued per tenant;
//! a batch is released when it reaches `max_batch` or the oldest request
//! exceeds `max_wait`. Per-tenant batching is what makes multi-LoRA serving
//! efficient — one forward pass per tenant per batch window
//! (S-LoRA/Punica-style).
//!
//! The queue is bounded ([`Admission`]): past the per-tenant or global
//! depth limit, `push` rejects with [`ServeError::QueueFull`] instead of
//! buffering forever. `pop_batch` rotates tenants round-robin so one hot
//! tenant cannot starve the ready queue, and drops cancelled or
//! deadline-expired requests before they ever reach an engine.
//!
//! Since the model layer serves mixed-tenant batches through per-run
//! [`AdapterBinding`](crate::model::transformer::AdapterBinding)s (PR 6),
//! per-tenant batching is a fallback, not a requirement: workers whose
//! engine supports the stepping path pop with `mix = true`, and a batch
//! released by one tenant is topped up with other tenants' queued
//! requests up to capacity. Canonical-order GEMMs make the mixed batch
//! decode bitwise-identically to per-tenant batches.

use super::metrics::Metrics;
use crate::eval::GenOptions;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Monotonically increasing request identifier, unique per server.
pub type RequestId = u64;

/// Typed failure for the request lifecycle, surfaced through `Result` both
/// at submit time (admission) and in the response channel (execution).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// No tenant with this id is registered.
    UnknownTenant(String),
    /// Admission control: the per-tenant or global queue depth is at its
    /// bound; retry later or shed load upstream.
    QueueFull { tenant: String },
    /// The request's deadline budget lapsed before an engine ran it.
    Deadline,
    /// The client cancelled the request via its [`super::server::ResponseHandle`].
    Cancelled,
    /// The server is shutting down (or shut down before responding).
    ShuttingDown,
    /// The engine's forward pass failed.
    Engine(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownTenant(id) => write!(f, "unknown tenant '{id}'"),
            ServeError::QueueFull { tenant } => {
                write!(f, "queue full for tenant '{tenant}'")
            }
            ServeError::Deadline => write!(f, "deadline exceeded"),
            ServeError::Cancelled => write!(f, "request cancelled"),
            ServeError::ShuttingDown => write!(f, "server shutting down"),
            ServeError::Engine(msg) => write!(f, "engine error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a request resolves to: a typed response or a typed error.
pub type ServeResult = Result<Response, ServeError>;

/// One generation request in flight inside the coordinator.
pub struct Request {
    pub id: RequestId,
    pub tenant: String,
    pub prompt: String,
    pub opts: GenOptions,
    /// Absolute deadline, computed from `opts.deadline` at submit time.
    pub deadline: Option<Instant>,
    pub respond: mpsc::Sender<ServeResult>,
    /// Streaming channel: workers send each generated token id as it is
    /// decoded; the sender drops (closing the stream) when the request
    /// resolves. Send errors are ignored — a client that never reads
    /// tokens costs nothing but the buffered ids.
    pub stream: mpsc::Sender<i32>,
    /// Set by the client's handle; the batcher drops flagged requests at
    /// the next pop (and on [`Batcher::notify`]), workers re-check between
    /// decode steps.
    pub cancelled: Arc<AtomicBool>,
    pub enqueued: Instant,
}

impl Request {
    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    pub fn is_expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| now >= d)
    }
}

/// One successful generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tenant: String,
    pub prompt: String,
    pub text: String,
    /// Number of generated tokens (before detokenization).
    pub tokens: usize,
    pub latency: Duration,
}

/// Queue-depth bounds enforced at `push`.
#[derive(Debug, Clone, Copy)]
pub struct Admission {
    pub per_tenant: usize,
    pub global: usize,
}

impl Default for Admission {
    fn default() -> Admission {
        Admission { per_tenant: 256, global: 1024 }
    }
}

struct Queues {
    /// Invariant: a tenant has a map entry iff its queue is non-empty, and
    /// appears in `ready` exactly once iff it has a map entry.
    by_tenant: HashMap<String, VecDeque<Request>>,
    /// Round-robin rotation order: pop scans from the front and moves the
    /// served tenant to the back.
    ready: VecDeque<String>,
    total: usize,
    closed: bool,
}

/// Thread-safe dynamic batcher with bounded queues.
pub struct Batcher {
    q: Mutex<Queues>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub admission: Admission,
    metrics: Arc<Metrics>,
}

/// Drop cancelled / deadline-expired requests from every queue, responding
/// with the typed error, and restore the queue invariants.
fn purge(q: &mut Queues, metrics: &Metrics) {
    let now = Instant::now();
    let mut dropped = 0usize;
    for reqs in q.by_tenant.values_mut() {
        if !reqs.iter().any(|r| r.is_cancelled() || r.is_expired(now)) {
            continue;
        }
        let before = reqs.len();
        let mut kept = VecDeque::with_capacity(before);
        for req in reqs.drain(..) {
            if req.is_cancelled() {
                metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(ServeError::Cancelled));
            } else if req.is_expired(now) {
                metrics.expired.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(Err(ServeError::Deadline));
            } else {
                kept.push_back(req);
            }
        }
        dropped += before - kept.len();
        *reqs = kept;
    }
    if dropped == 0 {
        return;
    }
    q.total -= dropped;
    metrics.set_queue_depth(q.total);
    let Queues { by_tenant, ready, .. } = q;
    ready.retain(|t| by_tenant.get(t).is_some_and(|r| !r.is_empty()));
    by_tenant.retain(|_, r| !r.is_empty());
}

impl Batcher {
    pub fn new(
        max_batch: usize,
        max_wait: Duration,
        admission: Admission,
        metrics: Arc<Metrics>,
    ) -> Batcher {
        assert!(max_batch > 0);
        Batcher {
            q: Mutex::new(Queues {
                by_tenant: HashMap::new(),
                ready: VecDeque::new(),
                total: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch,
            max_wait,
            admission,
            metrics,
        }
    }

    /// Enqueue a request. Admission control rejects synchronously: the
    /// request never enters a queue on `Err`, so the caller can surface the
    /// error at submit time. A depth limit purges cancelled / expired
    /// requests before rejecting — dead requests must not hold `QueueFull`
    /// against live traffic until the next `pop_batch` happens by.
    pub fn push(&self, req: Request) -> Result<(), ServeError> {
        let mut guard = self.q.lock().unwrap();
        if guard.closed {
            return Err(ServeError::ShuttingDown);
        }
        let at_limit = |q: &Queues| {
            q.total >= self.admission.global
                || q.by_tenant.get(&req.tenant).map_or(0, |d| d.len())
                    >= self.admission.per_tenant
        };
        if at_limit(&guard) {
            purge(&mut guard, &self.metrics);
            if at_limit(&guard) {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                self.metrics.record_tenant_rejected(&req.tenant);
                return Err(ServeError::QueueFull { tenant: req.tenant });
            }
        }
        let q = &mut *guard;
        if q.by_tenant.get(&req.tenant).map_or(0, |d| d.len()) == 0 {
            q.ready.push_back(req.tenant.clone());
        }
        q.by_tenant
            .entry(req.tenant.clone())
            .or_default()
            .push_back(req);
        q.total += 1;
        self.metrics.set_queue_depth(q.total);
        self.cv.notify_one();
        Ok(())
    }

    /// Non-blocking continuous-batching refill: pop up to `max` queued
    /// requests for `tenant` so a worker can admit them into its *running*
    /// decode batch between steps (Orca/S-LoRA-style iteration-level
    /// scheduling). Declines (returns empty) while any *other* tenant has
    /// a releasable batch — mid-flight refills must not starve the
    /// round-robin rotation that `pop_batch` provides.
    pub fn try_fill(&self, tenant: &str, max: usize) -> Vec<Request> {
        if max == 0 {
            return Vec::new();
        }
        let mut guard = self.q.lock().unwrap();
        purge(&mut guard, &self.metrics);
        let q = &mut *guard;
        for t in q.ready.iter() {
            if t == tenant {
                continue;
            }
            let Some(reqs) = q.by_tenant.get(t) else { continue };
            if reqs.len() >= self.max_batch
                || reqs.front().unwrap().enqueued.elapsed() >= self.max_wait
            {
                return Vec::new();
            }
        }
        let Some(reqs) = q.by_tenant.get_mut(tenant) else {
            return Vec::new();
        };
        let take = reqs.len().min(max);
        let out: Vec<Request> = reqs.drain(..take).collect();
        q.total -= take;
        self.metrics.set_queue_depth(q.total);
        if reqs.is_empty() {
            q.by_tenant.remove(tenant);
            q.ready.retain(|t| t != tenant);
        }
        out
    }

    /// [`Self::try_fill`] without the tenant restriction: pop up to `max`
    /// queued requests across *all* tenants in rotation order, for a
    /// worker refilling a mixed decode batch. No fairness decline is
    /// needed — a mixed batch can absorb any tenant's requests, so
    /// nothing releasable is being starved.
    pub fn try_fill_any(&self, max: usize) -> Vec<Request> {
        if max == 0 {
            return Vec::new();
        }
        let mut guard = self.q.lock().unwrap();
        purge(&mut guard, &self.metrics);
        let q = &mut *guard;
        let mut out = Vec::new();
        while out.len() < max {
            let Some(t) = q.ready.front().cloned() else { break };
            let reqs = q.by_tenant.get_mut(&t).unwrap();
            let take = reqs.len().min(max - out.len());
            out.extend(reqs.drain(..take));
            q.total -= take;
            if reqs.is_empty() {
                q.by_tenant.remove(&t);
                q.ready.pop_front();
            }
        }
        self.metrics.set_queue_depth(q.total);
        out
    }

    /// Wake `pop_batch` sleepers so they re-run their purge pass. Called
    /// by `ResponseHandle::cancel`: without it, a cancellation on an
    /// otherwise idle queue sat unresolved until the `max_wait` timeout.
    pub fn notify(&self) {
        self.cv.notify_all();
    }

    /// Pop the next batch. Blocks until a batch is ready (some tenant's
    /// queue is full, or its oldest request aged past `max_wait`), or
    /// returns None when closed and drained. The served tenant rotates to
    /// the back of the ready order, so concurrently-releasable tenants
    /// are served round-robin.
    ///
    /// With `mix = false` the batch is single-tenant (the full-window
    /// fallback engines require one adapter per forward). With
    /// `mix = true`, remaining capacity is topped up with *other*
    /// tenants' queued requests in rotation order — the stepping engines
    /// serve mixed rows through per-run adapter bindings, so waiting for
    /// a same-tenant fill would just waste slots.
    pub fn pop_batch(&self, mix: bool) -> Option<Vec<Request>> {
        let mut guard = self.q.lock().unwrap();
        loop {
            purge(&mut guard, &self.metrics);
            let q = &mut *guard;
            let mut candidate: Option<usize> = None;
            let mut sleep = self.max_wait;
            for (i, t) in q.ready.iter().enumerate() {
                let Some(reqs) = q.by_tenant.get(t) else { continue };
                let age = reqs.front().unwrap().enqueued.elapsed();
                if reqs.len() >= self.max_batch
                    || age >= self.max_wait
                    || q.closed
                {
                    candidate = Some(i);
                    break;
                }
                sleep = sleep.min(self.max_wait - age);
            }
            if let Some(i) = candidate {
                let t = q.ready.remove(i).unwrap();
                let reqs = q.by_tenant.get_mut(&t).unwrap();
                let take = reqs.len().min(self.max_batch);
                let mut batch: Vec<Request> = reqs.drain(..take).collect();
                q.total -= take;
                if reqs.is_empty() {
                    q.by_tenant.remove(&t);
                } else {
                    q.ready.push_back(t.clone());
                }
                if mix {
                    // top up with other tenants' requests, front of the
                    // rotation first; emptied tenants leave the rotation
                    while batch.len() < self.max_batch {
                        let Some(t) = q.ready.front().cloned() else { break };
                        let reqs = q.by_tenant.get_mut(&t).unwrap();
                        let take = reqs.len().min(self.max_batch - batch.len());
                        batch.extend(reqs.drain(..take));
                        q.total -= take;
                        if reqs.is_empty() {
                            q.by_tenant.remove(&t);
                            q.ready.pop_front();
                        }
                    }
                }
                self.metrics.set_queue_depth(q.total);
                return Some(batch);
            }
            if q.closed && q.total == 0 {
                return None;
            }
            let (g, _timeout) = self
                .cv
                .wait_timeout(guard, sleep.max(Duration::from_millis(1)))
                .unwrap();
            guard = g;
        }
    }

    /// Current global queue depth.
    pub fn depth(&self) -> usize {
        self.q.lock().unwrap().total
    }

    /// Signal shutdown: pending requests are still drained by workers;
    /// subsequent `push` calls fail with `ShuttingDown`.
    pub fn close(&self) {
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn batcher(max_batch: usize, max_wait: Duration) -> Batcher {
        Batcher::new(
            max_batch,
            max_wait,
            Admission::default(),
            Arc::new(Metrics::new()),
        )
    }

    fn req(tenant: &str, prompt: &str) -> (Request, mpsc::Receiver<ServeResult>) {
        let (tx, rx) = mpsc::channel();
        let (stream_tx, _stream_rx) = mpsc::channel();
        (
            Request {
                id: 0,
                tenant: tenant.into(),
                prompt: prompt.into(),
                opts: GenOptions::greedy(),
                deadline: None,
                respond: tx,
                stream: stream_tx,
                cancelled: Arc::new(AtomicBool::new(false)),
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn full_batch_released_immediately() {
        let b = batcher(2, Duration::from_secs(60));
        let (r1, _rx1) = req("a", "p1");
        let (r2, _rx2) = req("a", "p2");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        let batch = b.pop_batch(false).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|r| r.tenant == "a"));
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn timeout_releases_partial_batch() {
        let b = batcher(8, Duration::from_millis(20));
        let (r1, _rx) = req("a", "p1");
        b.push(r1).unwrap();
        let t0 = Instant::now();
        let batch = b.pop_batch(false).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn tenants_batched_separately_without_mixing() {
        let b = batcher(2, Duration::from_millis(10));
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("b", "p2");
        let (r3, _x3) = req("a", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        let batch1 = b.pop_batch(false).unwrap();
        let batch2 = b.pop_batch(false).unwrap();
        let (t1, t2) = (batch1[0].tenant.clone(), batch2[0].tenant.clone());
        assert_ne!(t1, t2);
        assert_eq!(batch1.len() + batch2.len(), 3);
        // no cross-tenant mixing on the full-window fallback path
        for r in batch1 {
            assert_eq!(r.tenant, t1);
        }
        for r in batch2 {
            assert_eq!(r.tenant, t2);
        }
    }

    #[test]
    fn pop_batch_mixes_tenants_up_to_capacity() {
        let b = batcher(4, Duration::from_millis(5));
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("b", "p2");
        let (r3, _x3) = req("b", "p3");
        let (r4, _x4) = req("c", "p4");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        b.push(r4).unwrap();
        // one mixed pop drains everything: a's aged batch tops up with
        // b's and c's queued requests
        let batch = b.pop_batch(true).unwrap();
        assert_eq!(batch.len(), 4);
        let mut tenants: Vec<&str> =
            batch.iter().map(|r| r.tenant.as_str()).collect();
        tenants.sort();
        tenants.dedup();
        assert_eq!(tenants, vec!["a", "b", "c"]);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn mixed_pop_respects_max_batch() {
        let b = batcher(2, Duration::from_millis(5));
        for i in 0..2 {
            // dropped receivers are fine: responses to them are ignored
            let (r, _x) = req("a", &format!("a{i}"));
            b.push(r).unwrap();
        }
        let (rb, _xb) = req("b", "b0");
        b.push(rb).unwrap();
        // a fills the batch alone; b must wait for the next pop
        let batch = b.pop_batch(true).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|r| r.tenant == "a"));
        assert_eq!(b.pop_batch(true).unwrap().len(), 1);
    }

    #[test]
    fn try_fill_any_pops_across_tenants() {
        let b = batcher(8, Duration::from_secs(60));
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("b", "p2");
        let (r3, _x3) = req("b", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        let got = b.try_fill_any(2);
        assert_eq!(got.len(), 2);
        assert_eq!(b.depth(), 1);
        assert_eq!(b.try_fill_any(8).len(), 1);
        assert_eq!(b.depth(), 0);
        assert!(b.try_fill_any(8).is_empty());
        // invariants intact: a later push + pop still works
        let (r4, _x4) = req("a", "p4");
        b.push(r4).unwrap();
        b.close();
        assert_eq!(b.pop_batch(true).unwrap().len(), 1);
    }

    #[test]
    fn close_drains_then_none() {
        let b = Arc::new(batcher(4, Duration::from_millis(5)));
        let (r1, _x1) = req("a", "p1");
        b.push(r1).unwrap();
        b.close();
        assert!(b.pop_batch(false).is_some());
        assert!(b.pop_batch(false).is_none());
    }

    #[test]
    fn push_after_close_rejected() {
        let b = batcher(4, Duration::from_millis(5));
        b.close();
        let (r, _rx) = req("a", "p");
        assert_eq!(b.push(r), Err(ServeError::ShuttingDown));
    }

    #[test]
    fn concurrent_producers_consumer() {
        let b = Arc::new(batcher(4, Duration::from_millis(10)));
        let mut rxs = Vec::new();
        let mut handles = Vec::new();
        for i in 0..12 {
            let (r, rx) = req(&format!("t{}", i % 3), &format!("p{i}"));
            rxs.push(rx);
            let b2 = Arc::clone(&b);
            handles.push(std::thread::spawn(move || b2.push(r).unwrap()));
        }
        for h in handles {
            h.join().unwrap();
        }
        b.close();
        let mut total = 0;
        while let Some(batch) = b.pop_batch(false) {
            total += batch.len();
        }
        assert_eq!(total, 12);
    }

    #[test]
    fn per_tenant_depth_limit_rejects() {
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::new(
            8,
            Duration::from_secs(60),
            Admission { per_tenant: 2, global: 100 },
            Arc::clone(&metrics),
        );
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("a", "p2");
        let (r3, _x3) = req("a", "p3");
        let (r4, _x4) = req("b", "p4");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        assert_eq!(
            b.push(r3),
            Err(ServeError::QueueFull { tenant: "a".into() })
        );
        // other tenants are unaffected by a's full queue
        b.push(r4).unwrap();
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(b.depth(), 3);
    }

    #[test]
    fn global_depth_limit_rejects() {
        let b = Batcher::new(
            8,
            Duration::from_secs(60),
            Admission { per_tenant: 100, global: 2 },
            Arc::new(Metrics::new()),
        );
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("b", "p2");
        let (r3, _x3) = req("c", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        assert!(matches!(b.push(r3), Err(ServeError::QueueFull { .. })));
    }

    #[test]
    fn cancelled_request_never_batched() {
        let b = batcher(2, Duration::from_secs(60));
        let (r1, rx1) = req("a", "p1");
        let cancel_flag = Arc::clone(&r1.cancelled);
        let (r2, _x2) = req("a", "p2");
        let (r3, _x3) = req("a", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        cancel_flag.store(true, Ordering::Relaxed);
        let batch = b.pop_batch(false).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|r| r.prompt != "p1"));
        assert_eq!(rx1.recv().unwrap(), Err(ServeError::Cancelled));
    }

    #[test]
    fn expired_request_gets_deadline_error() {
        let b = batcher(2, Duration::from_secs(60));
        let (mut r1, rx1) = req("a", "p1");
        r1.deadline = Some(Instant::now()); // already lapsed
        let (r2, _x2) = req("a", "p2");
        let (r3, _x3) = req("a", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        let batch = b.pop_batch(false).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|r| r.prompt != "p1"));
        assert_eq!(rx1.recv().unwrap(), Err(ServeError::Deadline));
    }

    #[test]
    fn try_fill_pops_queued_requests_for_running_tenant() {
        let b = batcher(4, Duration::from_secs(60));
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("a", "p2");
        let (r3, _x3) = req("a", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        let got = b.try_fill("a", 2);
        assert_eq!(got.len(), 2);
        assert_eq!(b.depth(), 1);
        // draining the rest restores the empty-queue invariants
        assert_eq!(b.try_fill("a", 8).len(), 1);
        assert_eq!(b.depth(), 0);
        assert!(b.try_fill("a", 8).is_empty());
        // and a later push still works (ready-rotation entry restored)
        let (r4, _x4) = req("a", "p4");
        b.push(r4).unwrap();
        b.close(); // make the partial batch releasable without max_wait
        assert_eq!(b.pop_batch(false).unwrap().len(), 1);
    }

    #[test]
    fn try_fill_declines_while_other_tenant_releasable() {
        // tenant b has a full batch waiting: a's mid-flight refill must
        // yield so the rotation can serve b first
        let b = batcher(2, Duration::from_secs(60));
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("b", "p2");
        let (r3, _x3) = req("b", "p3");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        b.push(r3).unwrap();
        assert!(b.try_fill("a", 4).is_empty(), "starved tenant b's batch");
        // once b is drained, a's refill proceeds
        assert_eq!(b.pop_batch(false).unwrap()[0].tenant, "b");
        assert_eq!(b.try_fill("a", 4).len(), 1);
    }

    #[test]
    fn try_fill_skips_cancelled_requests() {
        let b = batcher(4, Duration::from_secs(60));
        let (r1, rx1) = req("a", "p1");
        let flag = Arc::clone(&r1.cancelled);
        let (r2, _x2) = req("a", "p2");
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        flag.store(true, Ordering::Relaxed);
        let got = b.try_fill("a", 4);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].prompt, "p2");
        assert_eq!(rx1.recv().unwrap(), Err(ServeError::Cancelled));
    }

    #[test]
    fn admission_purges_dead_requests_before_rejecting() {
        // regression: cancelled requests used to occupy Admission depth
        // until the next pop_batch, rejecting live traffic as QueueFull
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::new(
            8,
            Duration::from_secs(60),
            Admission { per_tenant: 2, global: 100 },
            Arc::clone(&metrics),
        );
        let (r1, rx1) = req("a", "p1");
        let f1 = Arc::clone(&r1.cancelled);
        let (r2, rx2) = req("a", "p2");
        let f2 = Arc::clone(&r2.cancelled);
        b.push(r1).unwrap();
        b.push(r2).unwrap();
        f1.store(true, Ordering::Relaxed);
        f2.store(true, Ordering::Relaxed);
        // queue "full" of dead requests: the push must purge and accept
        let (r3, _x3) = req("a", "p3");
        b.push(r3).expect("dead requests rejected live traffic");
        assert_eq!(rx1.recv().unwrap(), Err(ServeError::Cancelled));
        assert_eq!(rx2.recv().unwrap(), Err(ServeError::Cancelled));
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 0);
        assert_eq!(b.depth(), 1);
        // the global bound purges too
        let bg = Batcher::new(
            8,
            Duration::from_secs(60),
            Admission { per_tenant: 100, global: 1 },
            Arc::new(Metrics::new()),
        );
        let (r4, _x4) = req("a", "p4");
        let f4 = Arc::clone(&r4.cancelled);
        bg.push(r4).unwrap();
        f4.store(true, Ordering::Relaxed);
        let (r5, _x5) = req("b", "p5");
        bg.push(r5).expect("global bound ignored the purge");
    }

    #[test]
    fn notify_wakes_sleeping_pop_for_cancel_resolution() {
        // regression: with an otherwise idle queue, a cancelled request's
        // resolution used to wait out the full max_wait timeout
        let b = Arc::new(batcher(8, Duration::from_secs(30)));
        let (r1, rx1) = req("a", "p1");
        let flag = Arc::clone(&r1.cancelled);
        b.push(r1).unwrap();
        let b2 = Arc::clone(&b);
        let worker = std::thread::spawn(move || b2.pop_batch(false));
        // let the worker reach its cv sleep (the batch is not releasable
        // for 30s), then cancel + notify
        std::thread::sleep(Duration::from_millis(50));
        flag.store(true, Ordering::Relaxed);
        b.notify();
        let t0 = Instant::now();
        assert_eq!(
            rx1.recv_timeout(Duration::from_secs(5)).unwrap(),
            Err(ServeError::Cancelled)
        );
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "cancel resolution waited for max_wait"
        );
        b.close();
        assert!(worker.join().unwrap().is_none());
    }

    #[test]
    fn round_robin_rotation_prevents_starvation() {
        // hot tenant always has a full batch ready; the cold tenant's
        // single request must still be served between hot batches once
        // releasable, because the served tenant rotates to the back.
        let b = batcher(2, Duration::from_millis(20));
        let mut hot_rx = Vec::new();
        for i in 0..4 {
            let (r, rx) = req("hot", &format!("h{i}"));
            hot_rx.push(rx);
            b.push(r).unwrap();
        }
        let (rc, _xc) = req("cold", "c0");
        b.push(rc).unwrap();
        // hot is at the front and has a full batch: served first, rotated
        assert_eq!(b.pop_batch(false).unwrap()[0].tenant, "hot");
        // age both past max_wait: now cold (front of rotation) wins even
        // though hot still holds a full batch
        std::thread::sleep(Duration::from_millis(25));
        let b2 = b.pop_batch(false).unwrap();
        assert_eq!(b2[0].tenant, "cold", "cold tenant starved by hot tenant");
        let batch3 = b.pop_batch(false).unwrap();
        assert_eq!(batch3[0].tenant, "hot");
        assert_eq!(batch3.len(), 2);
    }
}
