//! Dynamic batcher: requests are queued per tenant; a batch is released
//! when it reaches `max_batch` or the oldest request exceeds `max_wait`.
//! Per-tenant batching is what makes multi-LoRA serving efficient — one
//! forward pass per tenant per batch window (S-LoRA/Punica-style).

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One generation request.
pub struct Request {
    pub tenant: String,
    pub prompt: String,
    pub respond: mpsc::Sender<Response>,
    pub enqueued: Instant,
}

/// One generation response.
#[derive(Debug, Clone)]
pub struct Response {
    pub tenant: String,
    pub prompt: String,
    pub text: String,
    pub latency: Duration,
    pub ok: bool,
    pub error: Option<String>,
}

struct Queues {
    by_tenant: HashMap<String, VecDeque<Request>>,
    /// FIFO of tenants with pending work (may contain duplicates; filtered
    /// on pop)
    ready: VecDeque<String>,
    closed: bool,
}

/// Thread-safe dynamic batcher.
pub struct Batcher {
    q: Mutex<Queues>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        Batcher {
            q: Mutex::new(Queues {
                by_tenant: HashMap::new(),
                ready: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch,
            max_wait,
        }
    }

    pub fn push(&self, req: Request) {
        let mut q = self.q.lock().unwrap();
        if q.closed {
            let _ = req.respond.send(Response {
                tenant: req.tenant.clone(),
                prompt: req.prompt.clone(),
                text: String::new(),
                latency: Duration::ZERO,
                ok: false,
                error: Some("server shutting down".into()),
            });
            return;
        }
        q.ready.push_back(req.tenant.clone());
        q.by_tenant.entry(req.tenant.clone()).or_default().push_back(req);
        self.cv.notify_one();
    }

    /// Pop the next per-tenant batch. Blocks until a batch is ready (full,
    /// or oldest request aged past `max_wait`), or returns None when closed
    /// and drained.
    pub fn pop_batch(&self) -> Option<(String, Vec<Request>)> {
        let mut q = self.q.lock().unwrap();
        loop {
            // find a tenant whose batch should be released
            let mut candidate: Option<String> = None;
            let mut sleep = self.max_wait;
            for t in q.ready.iter() {
                let Some(reqs) = q.by_tenant.get(t) else { continue };
                if reqs.is_empty() {
                    continue;
                }
                let age = reqs.front().unwrap().enqueued.elapsed();
                if reqs.len() >= self.max_batch || age >= self.max_wait || q.closed {
                    candidate = Some(t.clone());
                    break;
                }
                sleep = sleep.min(self.max_wait - age);
            }
            if let Some(t) = candidate {
                let reqs = q.by_tenant.get_mut(&t).unwrap();
                let take = reqs.len().min(self.max_batch);
                let batch: Vec<Request> = reqs.drain(..take).collect();
                // drop stale ready markers for this tenant
                q.ready.retain(|x| x != &t);
                if !q.by_tenant.get(&t).map(|r| r.is_empty()).unwrap_or(true) {
                    q.ready.push_back(t.clone());
                }
                return Some((t, batch));
            }
            let has_pending =
                q.by_tenant.values().any(|r| !r.is_empty());
            if q.closed && !has_pending {
                return None;
            }
            let (q2, _timeout) = self
                .cv
                .wait_timeout(q, sleep.max(Duration::from_millis(1)))
                .unwrap();
            q = q2;
        }
    }

    /// Signal shutdown: pending requests are still drained.
    pub fn close(&self) {
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(tenant: &str, prompt: &str) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                tenant: tenant.into(),
                prompt: prompt.into(),
                respond: tx,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn full_batch_released_immediately() {
        let b = Batcher::new(2, Duration::from_secs(60));
        let (r1, _rx1) = req("a", "p1");
        let (r2, _rx2) = req("a", "p2");
        b.push(r1);
        b.push(r2);
        let (tenant, batch) = b.pop_batch().unwrap();
        assert_eq!(tenant, "a");
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn timeout_releases_partial_batch() {
        let b = Batcher::new(8, Duration::from_millis(20));
        let (r1, _rx) = req("a", "p1");
        b.push(r1);
        let t0 = Instant::now();
        let (_, batch) = b.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn tenants_batched_separately() {
        let b = Batcher::new(2, Duration::from_millis(10));
        let (r1, _x1) = req("a", "p1");
        let (r2, _x2) = req("b", "p2");
        let (r3, _x3) = req("a", "p3");
        b.push(r1);
        b.push(r2);
        b.push(r3);
        let (t1, batch1) = b.pop_batch().unwrap();
        let (t2, batch2) = b.pop_batch().unwrap();
        assert_ne!(t1, t2);
        assert_eq!(batch1.len() + batch2.len(), 3);
        // no cross-tenant mixing
        for r in batch1 {
            assert_eq!(r.tenant, t1);
        }
        for r in batch2 {
            assert_eq!(r.tenant, t2);
        }
    }

    #[test]
    fn close_drains_then_none() {
        let b = Arc::new(Batcher::new(4, Duration::from_millis(5)));
        let (r1, _x1) = req("a", "p1");
        b.push(r1);
        b.close();
        assert!(b.pop_batch().is_some());
        assert!(b.pop_batch().is_none());
    }

    #[test]
    fn push_after_close_errors_request() {
        let b = Batcher::new(4, Duration::from_millis(5));
        b.close();
        let (r, rx) = req("a", "p");
        b.push(r);
        let resp = rx.recv().unwrap();
        assert!(!resp.ok);
    }

    #[test]
    fn concurrent_producers_consumer() {
        let b = Arc::new(Batcher::new(4, Duration::from_millis(10)));
        let mut rxs = Vec::new();
        let mut handles = Vec::new();
        for i in 0..12 {
            let (r, rx) = req(&format!("t{}", i % 3), &format!("p{i}"));
            rxs.push(rx);
            let b2 = Arc::clone(&b);
            handles.push(std::thread::spawn(move || b2.push(r)));
        }
        for h in handles {
            h.join().unwrap();
        }
        b.close();
        let mut total = 0;
        while let Some((_, batch)) = b.pop_batch() {
            total += batch.len();
        }
        assert_eq!(total, 12);
    }
}
