//! Runtime layer: loads AOT artifacts (HLO text) and executes them on the
//! PJRT CPU client via the `xla` crate. Python is never on this path —
//! after `make artifacts`, the Rust binary is self-contained.
//!
//! * [`manifest`] — artifact index parsing (`artifacts/manifest.json`).
//! * [`pjrt`] — client wrapper: compile once, execute many, bind tensors
//!   by name against the manifest's io specs.

pub mod manifest;
pub mod pjrt;

pub use manifest::{Artifact, IoSpec, Manifest};
pub use pjrt::{Executable, Runtime};
