//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`). The manifest is the runtime source of truth
//! for model geometry and the flat input/output ordering of every HLO
//! program.

use crate::config::{Method, MethodCfg, ModelCfg};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One tensor slot in an artifact's flat signature.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" | "i32"
    pub dtype: String,
    /// base | param | opt_m | opt_v | scalar | data | aux | loss | logits | out
    pub role: String,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: j.req_str("name")?.to_string(),
            shape: j
                .req("shape")?
                .as_arr()
                .context("shape not an array")?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect(),
            dtype: j.req_str("dtype")?.to_string(),
            role: j.req_str("role")?.to_string(),
        })
    }
}

/// One AOT-compiled program.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    /// train | fwd | materialize
    pub kind: String,
    pub preset: String,
    pub method_cfg: MethodCfg,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl Artifact {
    /// Input specs with a given role, in signature order.
    pub fn inputs_with_role(&self, role: &str) -> Vec<&IoSpec> {
        self.inputs.iter().filter(|s| s.role == role).collect()
    }

    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }
}

/// The parsed artifact index.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub presets: BTreeMap<String, ModelCfg>,
    pub artifacts: BTreeMap<String, Artifact>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut presets = BTreeMap::new();
        for (name, pj) in j.req("presets")?.as_obj().context("presets")? {
            presets.insert(name.clone(), ModelCfg::from_manifest(name, pj)?);
        }

        let mut artifacts = BTreeMap::new();
        for aj in j.req("artifacts")?.as_arr().context("artifacts")? {
            let method = Method::parse(aj.req_str("method")?)?;
            let mut mc = match method {
                Method::LoRA => MethodCfg::lora(aj.req_usize("r")?),
                Method::MoS => MethodCfg::mos(
                    aj.req_usize("r")?,
                    aj.req_usize("l")?,
                    aj.req_usize("e")?,
                    0,
                ),
                Method::VeRA => MethodCfg::vera(aj.req_usize("r")?),
                Method::Tied => MethodCfg::tied(aj.req_usize("r")?),
                Method::PRoLoRA => MethodCfg::prolora(
                    aj.req_usize("r")?,
                    aj.req_usize("m")?,
                ),
            };
            mc.alpha = aj.req_f64("alpha")?;
            let art = Artifact {
                name: aj.req_str("name")?.to_string(),
                file: aj.req_str("file")?.to_string(),
                kind: aj.req_str("kind")?.to_string(),
                preset: aj.req_str("preset")?.to_string(),
                method_cfg: mc,
                inputs: aj
                    .req("inputs")?
                    .as_arr()
                    .context("inputs")?
                    .iter()
                    .map(IoSpec::parse)
                    .collect::<Result<_>>()?,
                outputs: aj
                    .req("outputs")?
                    .as_arr()
                    .context("outputs")?
                    .iter()
                    .map(IoSpec::parse)
                    .collect::<Result<_>>()?,
            };
            artifacts.insert(art.name.clone(), art);
        }
        Ok(Manifest { dir: dir.to_path_buf(), presets, artifacts })
    }

    /// Default artifacts directory (./artifacts or $MOS_ARTIFACTS).
    pub fn default_dir() -> PathBuf {
        std::env::var("MOS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// Artifact for (kind, method tag, preset), e.g. ("train", "mos_r8_l2_e2", "tiny").
    pub fn find(&self, kind: &str, tag: &str, preset: &str) -> Result<&Artifact> {
        self.get(&format!("{kind}_{tag}_{preset}"))
    }

    pub fn hlo_path(&self, art: &Artifact) -> PathBuf {
        self.dir.join(&art.file)
    }

    pub fn bank_path(&self, preset: &str) -> PathBuf {
        self.dir.join(format!("bank_{preset}.bin"))
    }

    pub fn init_path(&self, preset: &str, tag: &str) -> PathBuf {
        self.dir.join(format!("init_{preset}_{tag}.bin"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "presets": {"tiny": {"vocab": 64, "hidden": 64, "blocks": 4,
                            "heads": 4, "ff": 160, "seq": 48, "batch": 16,
                            "base_params": 999}},
      "artifacts": [{
        "name": "train_mos_r8_l2_e2_tiny", "file": "train.hlo.txt",
        "kind": "train", "preset": "tiny", "method": "mos",
        "r": 8, "l": 2, "e": 2, "m": 1, "alpha": 16.0,
        "inputs": [
          {"name": "embed", "shape": [64, 64], "dtype": "f32", "role": "base"},
          {"name": "q.pool_a", "shape": [16, 32], "dtype": "f32", "role": "param"},
          {"name": "tokens", "shape": [16, 48], "dtype": "i32", "role": "data"}
        ],
        "outputs": [
          {"name": "loss", "shape": [1], "dtype": "f32", "role": "loss"}
        ]
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("mos_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.presets["tiny"].hidden, 64);
        let a = m.get("train_mos_r8_l2_e2_tiny").unwrap();
        assert_eq!(a.method_cfg.method, Method::MoS);
        assert_eq!(a.method_cfg.tag(), "mos_r8_l2_e2");
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[2].dtype, "i32");
        assert_eq!(a.inputs_with_role("param").len(), 1);
        assert_eq!(a.input_index("tokens"), Some(2));
        assert!(m.find("train", "mos_r8_l2_e2", "tiny").is_ok());
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("mos_manifest_none");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
