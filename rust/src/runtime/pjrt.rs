//! PJRT execution: load HLO text -> compile once -> execute many.
//!
//! Two execution paths:
//! * [`Executable::execute_bank`] — host tensors in/out (simple, copies).
//! * [`Executable::execute_buffers`] — device-resident [`xla::PjRtBuffer`]s
//!   for state that survives across calls (params/opt-state in the training
//!   loop; adapter pools in serving). This is the hot path: only the small
//!   per-step tensors (tokens/lr) are re-uploaded. See EXPERIMENTS.md §Perf.

use super::manifest::{Artifact, IoSpec, Manifest};
use crate::util::bank::{Bank, Tensor};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Wrapper around the PJRT CPU client.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    /// Load + compile one artifact. Compilation happens once; the returned
    /// executable is reusable and cheap to call.
    pub fn load(&self, manifest: &Manifest, name: &str) -> Result<Executable> {
        let art = manifest.get(name)?.clone();
        let path = manifest.hlo_path(&art);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        Ok(Executable { exe, art })
    }
}

/// A compiled program plus its manifest signature.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub art: Artifact,
}

fn literal_for(spec: &IoSpec, t: &Tensor) -> Result<xla::Literal> {
    if t.shape() != spec.shape.as_slice() {
        bail!(
            "input '{}': shape {:?} != spec {:?}",
            spec.name,
            t.shape(),
            spec.shape
        );
    }
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    let lit = match (spec.dtype.as_str(), t) {
        ("f32", Tensor::F32 { data, .. }) => {
            xla::Literal::vec1(data.as_slice())
        }
        ("i32", Tensor::I32 { data, .. }) => {
            xla::Literal::vec1(data.as_slice())
        }
        (dt, _) => bail!("input '{}': dtype mismatch (spec {dt})", spec.name),
    };
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape '{}': {e:?}", spec.name))
}

fn tensor_from_literal(spec: &IoSpec, lit: &xla::Literal) -> Result<Tensor> {
    Ok(match spec.dtype.as_str() {
        "f32" => Tensor::from_f32(
            &spec.shape,
            lit.to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("read '{}': {e:?}", spec.name))?,
        ),
        "i32" => Tensor::from_i32(
            &spec.shape,
            lit.to_vec::<i32>()
                .map_err(|e| anyhow::anyhow!("read '{}': {e:?}", spec.name))?,
        ),
        dt => bail!("output '{}': unsupported dtype {dt}", spec.name),
    })
}

impl Executable {
    /// Execute with named host tensors. Inputs are bound by the manifest's
    /// signature order; missing names error out. Returns named outputs.
    pub fn execute_bank(&self, inputs: &Bank) -> Result<Bank> {
        let lits = self
            .art
            .inputs
            .iter()
            .map(|spec| {
                let t = inputs.get(&spec.name).with_context(|| {
                    format!("missing input '{}'", spec.name)
                })?;
                literal_for(spec, t)
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.art.name))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        self.unpack(out_lit)
    }

    /// Upload a host tensor as a device-resident buffer.
    pub fn upload(&self, spec: &IoSpec, t: &Tensor) -> Result<xla::PjRtBuffer> {
        if t.shape() != spec.shape.as_slice() {
            bail!(
                "upload '{}': shape {:?} != spec {:?}",
                spec.name,
                t.shape(),
                spec.shape
            );
        }
        let client = self.exe.client();
        let buf = match (spec.dtype.as_str(), t) {
            ("f32", Tensor::F32 { data, .. }) => {
                client.buffer_from_host_buffer(data, &spec.shape, None)
            }
            ("i32", Tensor::I32 { data, .. }) => {
                client.buffer_from_host_buffer(data, &spec.shape, None)
            }
            (dt, _) => bail!("upload '{}': dtype mismatch ({dt})", spec.name),
        };
        buf.map_err(|e| anyhow::anyhow!("upload '{}': {e:?}", spec.name))
    }

    /// Execute over device buffers (in signature order). Returns the raw
    /// output buffers so callers can keep state device-resident across
    /// steps (the tuple result is decomposed into per-output buffers by
    /// position; see `unpack` for the host path).
    pub fn execute_buffers(
        &self,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        if inputs.len() != self.art.inputs.len() {
            bail!(
                "{}: got {} buffers, want {}",
                self.art.name,
                inputs.len(),
                self.art.inputs.len()
            );
        }
        let bufs: Vec<&xla::PjRtBuffer> = inputs.to_vec();
        let mut result = self
            .exe
            .execute_b(&bufs)
            .map_err(|e| anyhow::anyhow!("execute_b {}: {e:?}", self.art.name))?;
        Ok(result.remove(0).remove(0))
    }

    /// Read a tuple result buffer back to named host tensors.
    pub fn read_outputs(&self, result: &xla::PjRtBuffer) -> Result<Bank> {
        let lit = result
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        self.unpack(lit)
    }

    fn unpack(&self, tuple: xla::Literal) -> Result<Bank> {
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        if parts.len() != self.art.outputs.len() {
            bail!(
                "{}: {} outputs returned, manifest says {}",
                self.art.name,
                parts.len(),
                self.art.outputs.len()
            );
        }
        let mut out = BTreeMap::new();
        for (spec, lit) in self.art.outputs.iter().zip(&parts) {
            out.insert(spec.name.clone(), tensor_from_literal(spec, lit)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT round-trip tests that need real artifacts live in
    // rust/tests/artifacts_roundtrip.rs (integration), since unit tests
    // must pass without `make artifacts`. Here we test the binding logic.

    fn spec(name: &str, shape: &[usize], dtype: &str) -> IoSpec {
        IoSpec {
            name: name.into(),
            shape: shape.to_vec(),
            dtype: dtype.into(),
            role: "data".into(),
        }
    }

    #[test]
    fn literal_shape_validation() {
        let s = spec("x", &[2, 3], "f32");
        let ok = Tensor::from_f32(&[2, 3], vec![0.0; 6]);
        assert!(literal_for(&s, &ok).is_ok());
        let bad_shape = Tensor::from_f32(&[3, 2], vec![0.0; 6]);
        assert!(literal_for(&s, &bad_shape).is_err());
        let bad_dtype = Tensor::from_i32(&[2, 3], vec![0; 6]);
        assert!(literal_for(&s, &bad_dtype).is_err());
    }

    #[test]
    fn literal_roundtrip_values() {
        let s = spec("x", &[4], "i32");
        let t = Tensor::from_i32(&[4], vec![1, -2, 3, 40]);
        let lit = literal_for(&s, &t).unwrap();
        let back = tensor_from_literal(&s, &lit).unwrap();
        assert_eq!(t, back);
    }
}
