//! Tiny arithmetic program VM — the executable substrate behind the
//! HumanEval-proxy task (`stackvm`): generated "programs" are scored by
//! *running* them (functional correctness / pass@1), exactly as HumanEval
//! scores synthesized Python against unit tests.
//!
//! Program syntax: a sequence of ops applied left-to-right to an integer
//! accumulator, e.g. `*2+3` maps x to 2x+3. Ops: `+k`, `-k`, `*k` with a
//! single digit k, and `n` (negate).

/// One VM operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Add(i64),
    Sub(i64),
    Mul(i64),
    Neg,
}

/// A parsed program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Program(pub Vec<Op>);

/// Parse error (position + message).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BadProgram(pub String);

impl Program {
    /// Parse `+3*2n-1` style source.
    pub fn parse(src: &str) -> Result<Program, BadProgram> {
        let mut ops = Vec::new();
        let mut it = src.chars().peekable();
        while let Some(c) = it.next() {
            match c {
                'n' => ops.push(Op::Neg),
                '+' | '-' | '*' => {
                    let d = it
                        .next()
                        .and_then(|d| d.to_digit(10))
                        .ok_or_else(|| {
                            BadProgram(format!("op '{c}' needs a digit"))
                        })? as i64;
                    ops.push(match c {
                        '+' => Op::Add(d),
                        '-' => Op::Sub(d),
                        _ => Op::Mul(d),
                    });
                }
                c => return Err(BadProgram(format!("bad char '{c}'"))),
            }
        }
        if ops.is_empty() {
            return Err(BadProgram("empty program".into()));
        }
        Ok(Program(ops))
    }

    /// Run on an input (saturating to avoid overflow on garbage programs).
    pub fn run(&self, x: i64) -> i64 {
        let mut acc = x;
        for op in &self.0 {
            acc = match *op {
                Op::Add(k) => acc.saturating_add(k),
                Op::Sub(k) => acc.saturating_sub(k),
                Op::Mul(k) => acc.saturating_mul(k),
                Op::Neg => acc.saturating_neg(),
            };
        }
        acc
    }

    /// Render back to source.
    pub fn source(&self) -> String {
        let mut s = String::new();
        for op in &self.0 {
            match *op {
                Op::Add(k) => s.push_str(&format!("+{k}")),
                Op::Sub(k) => s.push_str(&format!("-{k}")),
                Op::Mul(k) => s.push_str(&format!("*{k}")),
                Op::Neg => s.push('n'),
            }
        }
        s
    }
}

/// Functional-equivalence check on probe inputs — pass@1 semantics: a
/// generated program passes iff it matches the reference on every probe.
pub fn passes(reference: &Program, candidate: &str, probes: &[i64]) -> bool {
    match Program::parse(candidate) {
        Err(_) => false,
        Ok(p) => probes.iter().all(|&x| p.run(x) == reference.run(x)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_run() {
        let p = Program::parse("*2+3").unwrap();
        assert_eq!(p.run(2), 7);
        assert_eq!(p.run(5), 13);
        assert_eq!(p.run(0), 3);
        assert_eq!(p.source(), "*2+3");
    }

    #[test]
    fn negate() {
        let p = Program::parse("n+1").unwrap();
        assert_eq!(p.run(4), -3);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Program::parse("").is_err());
        assert!(Program::parse("+x").is_err());
        assert!(Program::parse("q").is_err());
        assert!(Program::parse("+").is_err());
    }

    #[test]
    fn pass_at_1_semantics() {
        let r = Program::parse("*2+3").unwrap();
        let probes = [0, 1, -2, 7, 11];
        assert!(passes(&r, "*2+3", &probes));
        // semantically equal but syntactically different program passes
        assert!(passes(&r, "*2+1+2", &probes));
        // wrong program fails
        assert!(!passes(&r, "*2+4", &probes));
        // unparseable fails (does not panic)
        assert!(!passes(&r, "hello", &probes));
    }

    #[test]
    fn saturating_no_panic() {
        let p = Program::parse("*9*9*9*9*9*9*9*9*9*9*9*9*9*9*9*9*9*9*9*9*9*9")
            .unwrap();
        let _ = p.run(i64::MAX);
        let _ = p.run(i64::MIN);
    }
}
