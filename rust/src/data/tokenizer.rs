//! Character-level tokenizer with the chatbot special tokens of the paper's
//! Tulu-style schema (Appendix A.1): BOS, SEP (= `<|assistant|>`), EOS, PAD.
//!
//! The charset fits the tiny preset's 64-token vocab; larger presets simply
//! leave the tail of the embedding unused.

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const SEP: i32 = 2;
pub const EOS: i32 = 3;
const SPECIALS: usize = 4;

const CHARSET: &str =
    " abcdefghijklmnopqrstuvwxyz0123456789+-*/=:,.?()[]><#@!%&";

/// Char-level tokenizer (stateless; the charset is fixed).
#[derive(Debug, Clone)]
pub struct Tokenizer {
    to_id: [i32; 128],
    to_char: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Tokenizer {
        let mut to_id = [-1i32; 128];
        let mut to_char = Vec::new();
        for (i, c) in CHARSET.chars().enumerate() {
            to_id[c as usize] = (SPECIALS + i) as i32;
            to_char.push(c);
        }
        Tokenizer { to_id, to_char }
    }

    /// Total vocabulary size (specials + charset).
    pub fn vocab_size(&self) -> usize {
        SPECIALS + self.to_char.len()
    }

    /// Encode a string; unknown characters map to '?'.
    pub fn encode(&self, s: &str) -> Vec<i32> {
        s.chars()
            .map(|c| {
                let idx = c as usize;
                if idx < 128 && self.to_id[idx] >= 0 {
                    self.to_id[idx]
                } else {
                    self.to_id['?' as usize]
                }
            })
            .collect()
    }

    /// Decode ids; specials are dropped, decoding stops at EOS.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            if id == EOS {
                break;
            }
            if id < SPECIALS as i32 {
                continue;
            }
            let idx = id as usize - SPECIALS;
            if idx < self.to_char.len() {
                out.push(self.to_char[idx]);
            }
        }
        out
    }

    /// Render one chatbot-style example:
    /// returns (tokens, loss_weight) both of length `seq`, PAD-filled.
    /// Loss covers completion + EOS only. Returns None if it doesn't fit.
    pub fn render(
        &self,
        prompt: &str,
        completion: &str,
        seq: usize,
    ) -> Option<(Vec<i32>, Vec<f32>)> {
        let mut toks = vec![BOS];
        toks.extend(self.encode(prompt));
        toks.push(SEP);
        let prompt_len = toks.len();
        toks.extend(self.encode(completion));
        toks.push(EOS);
        if toks.len() > seq {
            return None;
        }
        let mut weight = vec![0.0f32; seq];
        // next-token loss: position t predicts t+1, so weight[t] = 1 for
        // t in [prompt_len-1, len-2] (those predict completion tokens + EOS)
        for t in prompt_len - 1..toks.len() - 1 {
            weight[t] = 1.0;
        }
        toks.resize(seq, PAD);
        Some((toks, weight))
    }

    /// The prompt prefix used at generation time: `BOS <prompt> SEP`.
    pub fn prompt_tokens(&self, prompt: &str) -> Vec<i32> {
        let mut toks = vec![BOS];
        toks.extend(self.encode(prompt));
        toks.push(SEP);
        toks
    }
}

/// Shifted next-token targets for a token row (targets[t] = tokens[t+1]).
pub fn shift_targets(tokens: &[i32]) -> Vec<i32> {
    let mut tgt = tokens[1..].to_vec();
    tgt.push(PAD);
    tgt
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_fits_tiny_preset() {
        let tk = Tokenizer::new();
        assert!(tk.vocab_size() <= 64, "vocab {}", tk.vocab_size());
    }

    #[test]
    fn encode_decode_roundtrip() {
        let tk = Tokenizer::new();
        let s = "ab 3+4=7, x>y?";
        assert_eq!(tk.decode(&tk.encode(s)), s);
    }

    #[test]
    fn unknown_chars_become_question_mark() {
        let tk = Tokenizer::new();
        assert_eq!(tk.decode(&tk.encode("aΩb")), "a?b");
    }

    #[test]
    fn render_masks_prompt() {
        let tk = Tokenizer::new();
        let (toks, w) = tk.render("q", "ans", 12).unwrap();
        // layout: BOS q SEP a n s EOS PAD...
        assert_eq!(toks[0], BOS);
        assert_eq!(toks[2], SEP);
        assert_eq!(toks[6], EOS);
        assert_eq!(toks[7], PAD);
        // weights: positions 2..=5 predict (a, n, s, EOS)
        assert_eq!(&w[..8], &[0., 0., 1., 1., 1., 1., 0., 0.]);
    }

    #[test]
    fn render_rejects_overflow() {
        let tk = Tokenizer::new();
        assert!(tk.render("aaaaaaa", "bbbbbbb", 10).is_none());
    }

    #[test]
    fn decode_stops_at_eos() {
        let tk = Tokenizer::new();
        let mut ids = tk.encode("hi");
        ids.push(EOS);
        ids.extend(tk.encode("garbage"));
        assert_eq!(tk.decode(&ids), "hi");
    }

    #[test]
    fn shift_targets_basic() {
        assert_eq!(shift_targets(&[5, 6, 7]), vec![6, 7, PAD]);
    }
}
