//! Batch assembly: renders task examples into padded token/target/weight
//! batches for the train-step artifact (or the host trainer).

use super::tasks::Task;
use super::tokenizer::{shift_targets, Tokenizer};

/// One training batch, flattened row-major (batch, seq).
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub weight: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

/// Deterministic batch stream over a task split.
pub struct Loader {
    pub task: Task,
    pub tokenizer: Tokenizer,
    pub batch: usize,
    pub seq: usize,
    cursor: usize,
}

impl Loader {
    pub fn new(task: Task, batch: usize, seq: usize) -> Loader {
        Loader { task, tokenizer: Tokenizer::new(), batch, seq, cursor: 0 }
    }

    /// Next training batch (examples stream forever, index-deterministic).
    pub fn next_train(&mut self) -> Batch {
        let b = self.assemble("train", self.cursor);
        self.cursor += self.batch;
        b
    }

    /// The i-th eval batch.
    pub fn eval_batch(&self, index: usize) -> Batch {
        self.assemble("eval", index * self.batch)
    }

    fn assemble(&self, split: &str, start: usize) -> Batch {
        let (bsz, seq) = (self.batch, self.seq);
        let mut tokens = Vec::with_capacity(bsz * seq);
        let mut targets = Vec::with_capacity(bsz * seq);
        let mut weight = Vec::with_capacity(bsz * seq);
        let mut i = start;
        let mut filled = 0;
        while filled < bsz {
            let ex = self.task.example(split, i);
            i += 1;
            let Some((toks, w)) =
                self.tokenizer.render(&ex.prompt, &ex.completion, seq)
            else {
                continue; // skip over-long examples (paper truncates)
            };
            targets.extend(shift_targets(&toks));
            tokens.extend(toks);
            weight.extend(w);
            filled += 1;
        }
        Batch { tokens, targets, weight, batch: bsz, seq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::TaskKind;

    #[test]
    fn batch_shapes() {
        let mut l = Loader::new(Task::new(TaskKind::Arith, 0), 4, 48);
        let b = l.next_train();
        assert_eq!(b.tokens.len(), 4 * 48);
        assert_eq!(b.targets.len(), 4 * 48);
        assert_eq!(b.weight.len(), 4 * 48);
        // loss is masked somewhere but not everywhere
        let wsum: f32 = b.weight.iter().sum();
        assert!(wsum > 0.0 && wsum < (4 * 48) as f32);
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let mut l = Loader::new(Task::new(TaskKind::Recall, 0), 2, 32);
        let b = l.next_train();
        for row in 0..2 {
            for t in 0..31 {
                assert_eq!(
                    b.targets[row * 32 + t],
                    b.tokens[row * 32 + t + 1]
                );
            }
        }
    }

    #[test]
    fn stream_advances() {
        let mut l = Loader::new(Task::new(TaskKind::Chain, 0), 4, 48);
        let b1 = l.next_train();
        let b2 = l.next_train();
        assert_ne!(b1.tokens, b2.tokens);
    }

    #[test]
    fn eval_batches_deterministic() {
        let l1 = Loader::new(Task::new(TaskKind::Arith, 1), 4, 48);
        let l2 = Loader::new(Task::new(TaskKind::Arith, 1), 4, 48);
        assert_eq!(l1.eval_batch(2).tokens, l2.eval_batch(2).tokens);
        assert_ne!(l1.eval_batch(0).tokens, l1.eval_batch(1).tokens);
    }
}
