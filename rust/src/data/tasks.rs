//! The five synthetic task families (see `data::mod` docs for the mapping
//! to the paper's benchmarks). Each task is deterministic in its seed; the
//! train and eval splits are disjoint index ranges over the same generator,
//! except `recall`, which (like MMLU-after-SuperNI) evaluates memorized
//! facts.

use super::stackvm::{self, Program};
use crate::util::rng::Rng;

/// Scoring metric, matching the paper's per-benchmark choices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// exact match of the whole completion
    Em,
    /// exact match of the final number after '#' (GSM-style CoT)
    EmFinal,
    /// char-level F1 (TyDiQA-style) — EM also reported
    F1,
    /// run the generated program on probes (HumanEval-style)
    PassAt1,
}

/// One prompt/completion pair.
#[derive(Debug, Clone, PartialEq)]
pub struct Example {
    pub prompt: String,
    pub completion: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    Recall,
    Chain,
    Arith,
    CipherQa,
    StackVm,
}

impl TaskKind {
    pub fn all() -> [TaskKind; 5] {
        [
            TaskKind::Recall,
            TaskKind::Chain,
            TaskKind::Arith,
            TaskKind::CipherQa,
            TaskKind::StackVm,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Recall => "recall",
            TaskKind::Chain => "chain",
            TaskKind::Arith => "arith",
            TaskKind::CipherQa => "cipherqa",
            TaskKind::StackVm => "stackvm",
        }
    }

    pub fn parse(s: &str) -> Option<TaskKind> {
        TaskKind::all().into_iter().find(|t| t.name() == s)
    }

    /// The paper benchmark this task proxies.
    pub fn proxies(&self) -> &'static str {
        match self {
            TaskKind::Recall => "MMLU",
            TaskKind::Chain => "BBH",
            TaskKind::Arith => "GSM8K",
            TaskKind::CipherQa => "TyDiQA",
            TaskKind::StackVm => "HumanEval",
        }
    }
}

/// A task instance: generator + scorer, deterministic in `seed`.
pub struct Task {
    pub kind: TaskKind,
    pub seed: u64,
    /// recall fact table / cipher permutation etc.
    state: TaskState,
}

enum TaskState {
    Recall { facts: Vec<(String, String)> },
    Cipher { perm: [u8; 26] },
    Programs { family: Vec<Program> },
    None,
}

impl Task {
    pub fn new(kind: TaskKind, seed: u64) -> Task {
        let mut rng = Rng::new(seed, 0x7A5E ^ kind as u64);
        let state = match kind {
            TaskKind::Recall => {
                // 24 facts: 2-letter key -> 3-letter value. Values follow a
                // *task-seeded* letter permutation (val = σ(k0)σ(k1)σ(k0)),
                // so the table is consistent and systematically learnable —
                // the MMLU-proxy tests whether the adapter can instill a
                // new fact system over the pretrained base's wrong prior,
                // not rote low-rank memorization (DESIGN.md §1).
                let mut perm: Vec<u8> = (0..26).collect();
                rng.shuffle(&mut perm);
                let map = |c: u8| (b'a' + perm[(c - b'a') as usize]) as char;
                let mut facts = Vec::new();
                let mut used = std::collections::HashSet::new();
                while facts.len() < 24 {
                    let k = rand_word(&mut rng, 2);
                    if !used.insert(k.clone()) {
                        continue;
                    }
                    let kb = k.as_bytes();
                    let v: String = [map(kb[0]), map(kb[1]), map(kb[0])]
                        .into_iter()
                        .collect();
                    facts.push((k, v));
                }
                TaskState::Recall { facts }
            }
            TaskKind::CipherQa => {
                let mut perm: Vec<u8> = (0..26).collect();
                rng.shuffle(&mut perm);
                TaskState::Cipher { perm: perm.try_into().unwrap() }
            }
            TaskKind::StackVm => {
                // a finite program family the model can learn end-to-end
                let mut family = Vec::new();
                let mut seen = std::collections::HashSet::new();
                while family.len() < 16 {
                    let p = rand_program(&mut rng);
                    if seen.insert(p.source()) {
                        family.push(p);
                    }
                }
                TaskState::Programs { family }
            }
            _ => TaskState::None,
        };
        Task { kind, seed, state }
    }

    pub fn metric(&self) -> Metric {
        match self.kind {
            TaskKind::Recall | TaskKind::Chain => Metric::Em,
            TaskKind::Arith => Metric::EmFinal,
            TaskKind::CipherQa => Metric::F1,
            TaskKind::StackVm => Metric::PassAt1,
        }
    }

    /// The i-th example of a split ("train" uses even stream, "eval" odd) —
    /// deterministic, so eval sets are reproducible across methods/seeds.
    pub fn example(&self, split: &str, i: usize) -> Example {
        let stream = if split == "train" { 2 } else { 3 };
        let mut rng = Rng::new(
            self.seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15),
            stream,
        );
        match (&self.kind, &self.state) {
            (TaskKind::Recall, TaskState::Recall { facts }) => {
                let (k, v) = &facts[rng.range(0, facts.len())];
                Example {
                    prompt: format!("q:{k}"),
                    completion: v.clone(),
                }
            }
            (TaskKind::Chain, _) => {
                // 2 chained ops over a 4-char word: rev, rot1, swap ends
                let w: Vec<char> = rand_word(&mut rng, 4).chars().collect();
                let ops: Vec<usize> = (0..2).map(|_| rng.range(0, 3)).collect();
                let mut cur = w.clone();
                let mut names = Vec::new();
                for &op in &ops {
                    match op {
                        0 => {
                            cur.reverse();
                            names.push("rev");
                        }
                        1 => {
                            cur.rotate_left(1);
                            names.push("rot");
                        }
                        _ => {
                            let n = cur.len();
                            cur.swap(0, n - 1);
                            names.push("swp");
                        }
                    }
                }
                Example {
                    prompt: format!(
                        "{} {}:{}",
                        names[0],
                        names[1],
                        w.iter().collect::<String>()
                    ),
                    completion: cur.iter().collect(),
                }
            }
            (TaskKind::Arith, _) => {
                // a+b-c with CoT steps; final answer after '#'
                let a = rng.range(1, 20) as i64;
                let b = rng.range(1, 20) as i64;
                let c = rng.range(1, 15) as i64;
                let s1 = a + b;
                let s2 = s1 - c;
                Example {
                    prompt: format!("{a}+{b}-{c}="),
                    completion: format!("{a}+{b}={s1},{s1}-{c}={s2}#{s2}"),
                }
            }
            (TaskKind::CipherQa, TaskState::Cipher { perm }) => {
                let len = rng.range(3, 6);
                let w = rand_word(&mut rng, len);
                let enc: String = w
                    .chars()
                    .map(|c| (b'a' + perm[(c as u8 - b'a') as usize]) as char)
                    .collect();
                Example {
                    prompt: format!("enc:{w}"),
                    completion: enc,
                }
            }
            (TaskKind::StackVm, TaskState::Programs { family }) => {
                let p = &family[rng.range(0, family.len())];
                let x1 = rng.range(0, 9) as i64;
                let x2 = rng.range(0, 9) as i64;
                Example {
                    prompt: format!(
                        "f({x1})={},f({x2})={};f=",
                        p.run(x1),
                        p.run(x2)
                    ),
                    completion: p.source(),
                }
            }
            _ => unreachable!(),
        }
    }

    /// Score a generated completion against the reference example.
    /// Returns the metric value in [0, 1].
    pub fn score(&self, example: &Example, generated: &str) -> f64 {
        match self.metric() {
            Metric::Em => {
                if generated.trim() == example.completion {
                    1.0
                } else {
                    0.0
                }
            }
            Metric::EmFinal => {
                let want = final_answer(&example.completion);
                let got = final_answer(generated);
                if !want.is_empty() && want == got {
                    1.0
                } else {
                    0.0
                }
            }
            Metric::F1 => char_f1(&example.completion, generated.trim()),
            Metric::PassAt1 => {
                if let TaskState::Programs { .. } = &self.state {
                    // reference program reconstructed from the completion
                    let reference =
                        Program::parse(&example.completion).expect("ref");
                    let probes = [0, 1, 2, 3, 5, 8, -4, 13];
                    if stackvm::passes(&reference, generated.trim(), &probes) {
                        1.0
                    } else {
                        0.0
                    }
                } else {
                    0.0
                }
            }
        }
    }

    /// Exact-match variant (reported alongside F1 for cipherqa, paper
    /// TyDiQA style).
    pub fn score_em(&self, example: &Example, generated: &str) -> f64 {
        if generated.trim() == example.completion {
            1.0
        } else {
            0.0
        }
    }
}

fn rand_word(rng: &mut Rng, len: usize) -> String {
    (0..len)
        .map(|_| (b'a' + rng.below(26) as u8) as char)
        .collect()
}

fn rand_program(rng: &mut Rng) -> Program {
    let n = rng.range(2, 4);
    let ops = (0..n)
        .map(|_| match rng.range(0, 4) {
            0 => stackvm::Op::Add(rng.range(1, 10) as i64),
            1 => stackvm::Op::Sub(rng.range(1, 10) as i64),
            2 => stackvm::Op::Mul(rng.range(2, 4) as i64),
            _ => stackvm::Op::Neg,
        })
        .collect();
    Program(ops)
}

/// Text after the last '#' (GSM-style final answer extraction).
pub fn final_answer(s: &str) -> &str {
    match s.rfind('#') {
        Some(i) => s[i + 1..].trim(),
        None => "",
    }
}

/// Char-level F1 between reference and candidate (bag-of-chars overlap).
pub fn char_f1(reference: &str, candidate: &str) -> f64 {
    if reference.is_empty() && candidate.is_empty() {
        return 1.0;
    }
    if reference.is_empty() || candidate.is_empty() {
        return 0.0;
    }
    let mut counts = std::collections::HashMap::new();
    for c in reference.chars() {
        *counts.entry(c).or_insert(0i64) += 1;
    }
    let mut overlap = 0i64;
    for c in candidate.chars() {
        let e = counts.entry(c).or_insert(0);
        if *e > 0 {
            overlap += 1;
            *e -= 1;
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let p = overlap as f64 / candidate.chars().count() as f64;
    let r = overlap as f64 / reference.chars().count() as f64;
    2.0 * p * r / (p + r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_examples() {
        for kind in TaskKind::all() {
            let t1 = Task::new(kind, 7);
            let t2 = Task::new(kind, 7);
            for i in 0..10 {
                assert_eq!(t1.example("train", i), t2.example("train", i));
            }
            assert_ne!(
                (0..10).map(|i| t1.example("train", i)).collect::<Vec<_>>(),
                (0..10).map(|i| t1.example("eval", i)).collect::<Vec<_>>(),
            );
        }
    }

    #[test]
    fn chain_completions_are_correct() {
        let t = Task::new(TaskKind::Chain, 3);
        for i in 0..50 {
            let ex = t.example("train", i);
            // re-apply the ops named in the prompt
            let (ops_part, word) = ex.prompt.split_once(':').unwrap();
            let mut cur: Vec<char> = word.chars().collect();
            for op in ops_part.split_whitespace() {
                match op {
                    "rev" => cur.reverse(),
                    "rot" => cur.rotate_left(1),
                    "swp" => {
                        let n = cur.len();
                        cur.swap(0, n - 1)
                    }
                    _ => panic!("bad op {op}"),
                }
            }
            assert_eq!(cur.iter().collect::<String>(), ex.completion);
        }
    }

    #[test]
    fn arith_cot_is_consistent() {
        let t = Task::new(TaskKind::Arith, 1);
        for i in 0..50 {
            let ex = t.example("eval", i);
            // prompt "a+b-c=", final answer must equal a+b-c
            let body = ex.prompt.trim_end_matches('=');
            let (ab, c) = body.rsplit_once('-').unwrap();
            let (a, b) = ab.split_once('+').unwrap();
            let want = a.parse::<i64>().unwrap() + b.parse::<i64>().unwrap()
                - c.parse::<i64>().unwrap();
            assert_eq!(final_answer(&ex.completion), want.to_string());
            assert_eq!(t.score(&ex, &ex.completion), 1.0);
        }
    }

    #[test]
    fn recall_is_consistent_across_splits() {
        let t = Task::new(TaskKind::Recall, 5);
        // same key must always map to same value (it's a fact table)
        let mut map = std::collections::HashMap::new();
        for split in ["train", "eval"] {
            for i in 0..80 {
                let ex = t.example(split, i);
                let prev = map.insert(ex.prompt.clone(), ex.completion.clone());
                if let Some(p) = prev {
                    assert_eq!(p, ex.completion, "fact changed for {}", ex.prompt);
                }
            }
        }
        assert!(map.len() > 4, "should cover multiple facts");
    }

    #[test]
    fn cipher_is_a_permutation() {
        let t = Task::new(TaskKind::CipherQa, 9);
        let ex = t.example("train", 0);
        assert_eq!(
            ex.prompt.trim_start_matches("enc:").chars().count(),
            ex.completion.chars().count()
        );
        // score: perfect completion = 1.0 for both F1 and EM
        assert_eq!(t.score(&ex, &ex.completion), 1.0);
        assert_eq!(t.score_em(&ex, &ex.completion), 1.0);
        // partial overlap gives partial F1
        let partial = t.score(&ex, &ex.completion[1..]);
        assert!(partial > 0.0 && partial < 1.0);
    }

    #[test]
    fn stackvm_scores_functionally() {
        let t = Task::new(TaskKind::StackVm, 2);
        let ex = t.example("eval", 4);
        assert_eq!(t.score(&ex, &ex.completion), 1.0);
        assert_eq!(t.score(&ex, "not a program"), 0.0);
    }

    #[test]
    fn final_answer_extraction() {
        assert_eq!(final_answer("1+2=3,3-1=2#2"), "2");
        assert_eq!(final_answer("no marker"), "");
        assert_eq!(final_answer("a#b#c"), "c");
    }

    #[test]
    fn char_f1_properties() {
        assert_eq!(char_f1("abc", "abc"), 1.0);
        assert_eq!(char_f1("abc", "xyz"), 0.0);
        assert!(char_f1("abc", "abx") > 0.5);
        assert_eq!(char_f1("", ""), 1.0);
        assert_eq!(char_f1("a", ""), 0.0);
        // order-insensitive (bag of chars)
        assert_eq!(char_f1("abc", "cba"), 1.0);
    }

    #[test]
    fn prompts_fit_tiny_seq() {
        let tk = super::super::tokenizer::Tokenizer::new();
        for kind in TaskKind::all() {
            let t = Task::new(kind, 0);
            for i in 0..30 {
                let ex = t.example("train", i);
                assert!(
                    tk.render(&ex.prompt, &ex.completion, 48).is_some(),
                    "{:?} example too long: {:?}",
                    kind,
                    ex
                );
            }
        }
    }
}
