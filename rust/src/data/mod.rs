//! Synthetic-task data substrate.
//!
//! The paper finetunes LLaMA on SuperNI / Flan-V2 / CoT / CodeAlpaca and
//! evaluates on MMLU / BBH / GSM8K / TyDiQA / HumanEval. None of those are
//! usable at our scale, so each benchmark is replaced by a *synthetic task
//! family* stressing the same capability axis (DESIGN.md §1 substitution
//! table):
//!
//! | paper benchmark | proxy task   | capability            | metric |
//! |-----------------|--------------|------------------------|--------|
//! | MMLU            | [`recall`]   | factual memorization    | EM     |
//! | BBH             | [`chain`]    | multi-step symbolic ops | EM     |
//! | GSM8K           | [`arith`]    | arithmetic + CoT        | EM(final) |
//! | TyDiQA          | [`cipherqa`] | cross-"lingual" mapping | F1/EM  |
//! | HumanEval       | [`stackvm`]  | program synthesis       | pass@1 |
//!
//! Every example is rendered chatbot-style as
//! `BOS <prompt> SEP <completion> EOS` with the loss mask covering only
//! `<completion> EOS` (the paper's Tulu-style schema with `<|assistant|>`).

pub mod loader;
pub mod stackvm;
pub mod tasks;
pub mod tokenizer;

pub use loader::{Batch, Loader};
pub use tasks::{Example, Metric, Task, TaskKind};
pub use tokenizer::Tokenizer;
