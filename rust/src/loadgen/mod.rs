//! Load harness: replay seeded traffic shapes against the serving stack.
//!
//! [`shapes::plan`] expands a [`TrafficCfg`] into a deterministic arrival
//! schedule; [`run_shape`] replays it open-loop (arrivals fire on the
//! planned clock, one collector thread per in-flight request) against
//! either target:
//!
//! - [`InProcessClient`] — straight into `Server::submit`, measuring the
//!   coordinator alone;
//! - [`HttpClient`] — through the [`crate::frontend`] HTTP edge,
//!   measuring the full network path (cancellations become connection
//!   drops, exactly like a real client hanging up).
//!
//! Each replay aggregates into a [`ShapeReport`]: p50/p99 ttft and
//! latency, tok/s, and reject/expire/cancel counts — the rows of
//! `BENCH_traffic.json`.

pub mod shapes;

pub use shapes::{plan, Arrival, Shape, TrafficCfg, ALL_SHAPES};

use crate::coordinator::{ServeError, Server, TenantSpec};
use crate::frontend::http;
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Canonical id of the `i`-th tenant in a replay's registered universe.
pub fn tenant_id(i: usize) -> String {
    format!("t{i}")
}

/// Pooled-tier MoS spec for replay tenants: small ranks so a 1k+ Zipf
/// universe registers quickly, seeded per tenant so factors differ.
pub fn tenant_spec(i: usize) -> TenantSpec {
    TenantSpec::mos(4, 2, 2, 1).seed(i as u64 + 1)
}

/// DWRR weight of the `i`-th replay tenant: the [`Shape::Weighted`]
/// shape cycles weight classes 1/2/4 across its universe; every other
/// shape keeps the default weight 1.
pub fn tenant_weight(shape: Shape, i: usize) -> u32 {
    match shape {
        Shape::Weighted => 1 << (i % 3),
        _ => 1,
    }
}

/// Register `cfg`'s tenant universe (`t0..`) directly on `server`,
/// applying the shape's DWRR weights. Fails if any registration evicts
/// a peer — eviction thrash while building the universe means the
/// registry capacity is mis-sized for the experiment.
pub fn register_tenants(server: &Server, cfg: &TrafficCfg) -> Result<()> {
    for i in 0..cfg.tenants {
        let spec =
            tenant_spec(i).weight(tenant_weight(cfg.shape, i));
        let evicted = server.register(&tenant_id(i), spec)?;
        if !evicted.is_empty() {
            bail!(
                "eviction thrash: registering {} evicted {:?}",
                tenant_id(i),
                evicted
            );
        }
    }
    Ok(())
}

/// Register `cfg`'s tenant universe through the HTTP edge
/// (`POST /v1/tenants`) — the same specs and weights as
/// [`register_tenants`], driven over the wire.
pub fn register_tenants_http(addr: SocketAddr, cfg: &TrafficCfg) -> Result<()> {
    for i in 0..cfg.tenants {
        let mut fields = vec![
            ("id", Json::str(tenant_id(i))),
            ("method", Json::str("mos")),
            ("r", Json::num(4.0)),
            ("l", Json::num(2.0)),
            ("e", Json::num(2.0)),
            ("private_rank", Json::num(1.0)),
            ("seed", Json::num((i + 1) as f64)),
        ];
        let weight = tenant_weight(cfg.shape, i);
        if weight > 1 {
            fields.push(("weight", Json::num(weight as f64)));
        }
        let body = Json::obj(fields).to_string();
        let mut stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        let req = format!(
            "POST /v1/tenants HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        stream.write_all(req.as_bytes())?;
        let (status, headers) = http::read_response_head(&mut stream)
            .map_err(|e| anyhow::anyhow!("register {i}: {e:?}"))?;
        if status != 201 {
            bail!("register {}: HTTP {status}", tenant_id(i));
        }
        let resp = http::read_sized_body(&mut stream, &headers)
            .ok()
            .and_then(|b| String::from_utf8(b).ok())
            .and_then(|s| Json::parse(&s).ok());
        if let Some(evicted) = resp
            .as_ref()
            .and_then(|j| j.get("evicted"))
            .and_then(Json::as_arr)
        {
            if !evicted.is_empty() {
                bail!("eviction thrash registering {}", tenant_id(i));
            }
        }
    }
    Ok(())
}

/// How one replayed request resolved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Ok,
    /// Admission control turned it away (`QueueFull` / HTTP 429).
    Rejected,
    /// Deadline lapsed (`ServeError::Deadline` / HTTP 504).
    Expired,
    /// Cancelled by plan (in-process `cancel()`, or HTTP connection drop).
    Cancelled,
    /// Anything else: engine error, transport error, malformed stream.
    Error,
}

fn outcome_of(e: &ServeError) -> Outcome {
    match e {
        ServeError::QueueFull { .. } => Outcome::Rejected,
        ServeError::Deadline => Outcome::Expired,
        ServeError::Cancelled => Outcome::Cancelled,
        _ => Outcome::Error,
    }
}

/// One request's measurements.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub outcome: Outcome,
    /// Submit → first streamed token, when one arrived.
    pub ttft_ms: Option<f64>,
    /// Submit → resolution (or drop, for plan cancellations).
    pub latency_ms: f64,
    pub tokens: usize,
}

/// A blocking request executor: submit, stream, resolve, measure.
pub trait Client: Send + Sync {
    fn call(&self, tenant: &str, arrival: &Arrival) -> Sample;
}

fn ms_since(t0: Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e3
}

/// Drives `Server::submit` directly.
pub struct InProcessClient {
    server: Arc<Server>,
    /// Token-poll tick; also bounds cancellation-timing slop.
    poll: Duration,
}

impl InProcessClient {
    pub fn new(server: Arc<Server>) -> InProcessClient {
        InProcessClient { server, poll: Duration::from_millis(2) }
    }
}

impl Client for InProcessClient {
    fn call(&self, tenant: &str, a: &Arrival) -> Sample {
        let t0 = Instant::now();
        let handle =
            match self.server.submit(tenant, &a.prompt, a.opts.clone()) {
                Ok(h) => h,
                Err(e) => {
                    return Sample {
                        outcome: outcome_of(&e),
                        ttft_ms: None,
                        latency_ms: ms_since(t0),
                        tokens: 0,
                    }
                }
            };
        let cancel_at = a.cancel_after.map(|d| t0 + d);
        let mut cancelled = false;
        let mut ttft = None;
        let mut tokens = 0usize;
        loop {
            if let Some(at) = cancel_at {
                if !cancelled && Instant::now() >= at {
                    handle.cancel();
                    cancelled = true;
                }
            }
            // poll no further than the pending cancel instant
            let tick = match cancel_at {
                Some(at) if !cancelled => at
                    .saturating_duration_since(Instant::now())
                    .clamp(Duration::from_micros(100), self.poll),
                _ => self.poll,
            };
            match handle.recv_token_timeout(tick) {
                Some(_) => {
                    tokens += 1;
                    if ttft.is_none() {
                        ttft = Some(ms_since(t0));
                    }
                }
                None => {
                    if let Some(res) = handle.try_wait() {
                        while handle.try_recv_token().is_some() {
                            tokens += 1;
                        }
                        let outcome = match res {
                            Ok(_) => Outcome::Ok,
                            Err(e) => outcome_of(&e),
                        };
                        return Sample {
                            outcome,
                            ttft_ms: ttft,
                            latency_ms: ms_since(t0),
                            tokens,
                        };
                    }
                }
            }
        }
    }
}

/// Drives the HTTP edge: one connection per request, chunked ndjson
/// stream back, cancellation = dropping the connection.
pub struct HttpClient {
    addr: SocketAddr,
    io_timeout: Duration,
    /// Hard wall on one request's lifetime (queue waits included).
    max_wall: Duration,
}

impl HttpClient {
    pub fn new(addr: SocketAddr) -> HttpClient {
        HttpClient {
            addr,
            io_timeout: Duration::from_secs(5),
            max_wall: Duration::from_secs(120),
        }
    }
}

impl Client for HttpClient {
    fn call(&self, tenant: &str, a: &Arrival) -> Sample {
        let t0 = Instant::now();
        let mut ttft = None;
        let mut tokens = 0usize;
        let sample = |outcome, ttft, tokens, t0| Sample {
            outcome,
            ttft_ms: ttft,
            latency_ms: ms_since(t0),
            tokens,
        };
        let Ok(mut stream) = TcpStream::connect(self.addr) else {
            return sample(Outcome::Error, ttft, tokens, t0);
        };
        let _ = stream.set_read_timeout(Some(self.io_timeout));
        let _ = stream.set_write_timeout(Some(self.io_timeout));
        let mut fields = vec![
            ("tenant", Json::str(tenant)),
            ("prompt", Json::str(a.prompt.clone())),
            ("max_new_tokens", Json::num(a.opts.max_new_tokens as f64)),
        ];
        if let Some(d) = a.opts.deadline {
            fields.push(("deadline_ms", Json::num(d.as_millis() as f64)));
        }
        let body = Json::obj(fields).to_string();
        let req = format!(
            "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        if stream.write_all(req.as_bytes()).is_err() {
            return sample(Outcome::Error, ttft, tokens, t0);
        }
        let Ok((status, _headers)) = http::read_response_head(&mut stream)
        else {
            return sample(Outcome::Error, ttft, tokens, t0);
        };
        if status != 200 {
            let outcome = match status {
                429 => Outcome::Rejected,
                504 => Outcome::Expired,
                _ => Outcome::Error,
            };
            return sample(outcome, ttft, tokens, t0);
        }
        let cancel_at = a.cancel_after.map(|d| t0 + d);
        loop {
            if t0.elapsed() > self.max_wall {
                return sample(Outcome::Error, ttft, tokens, t0);
            }
            if let Some(at) = cancel_at {
                let now = Instant::now();
                if now >= at {
                    // dropping the connection IS the cancel signal
                    return sample(Outcome::Cancelled, ttft, tokens, t0);
                }
                let _ = stream.set_read_timeout(Some(
                    (at - now).min(self.io_timeout),
                ));
            }
            match http::read_chunk(&mut stream) {
                Ok(Some(line)) => {
                    let parsed = std::str::from_utf8(&line)
                        .ok()
                        .and_then(|s| Json::parse(s.trim()).ok());
                    let Some(json) = parsed else {
                        return sample(Outcome::Error, ttft, tokens, t0);
                    };
                    if json.get("token").is_some() {
                        tokens += 1;
                        if ttft.is_none() {
                            ttft = Some(ms_since(t0));
                        }
                    } else if json.get("done").is_some() {
                        let outcome = match json
                            .get("kind")
                            .and_then(Json::as_str)
                        {
                            None => Outcome::Ok,
                            Some("deadline") => Outcome::Expired,
                            Some("cancelled") => Outcome::Cancelled,
                            Some("queue_full") => Outcome::Rejected,
                            Some(_) => Outcome::Error,
                        };
                        return sample(outcome, ttft, tokens, t0);
                    }
                }
                Ok(None) => {
                    // terminal chunk without a done line
                    return sample(Outcome::Error, ttft, tokens, t0);
                }
                Err(http::ReadError::TimedOut) => {
                    // loop: re-check the cancel clock / wall cap
                }
                Err(_) => {
                    return sample(Outcome::Error, ttft, tokens, t0);
                }
            }
        }
    }
}

/// Exact percentile over a sorted slice (nearest-rank on the closed
/// index range — unlike the serving histograms there is no bucketing).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Aggregated result of one shape's replay.
#[derive(Debug, Clone)]
pub struct ShapeReport {
    pub shape: String,
    pub requests: usize,
    pub tenants: usize,
    pub completed: usize,
    pub rejected: usize,
    pub expired: usize,
    pub cancelled: usize,
    pub errors: usize,
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub tok_per_s: f64,
    pub duration_s: f64,
    /// Chunked-prefill budget the replay's server ran with (`None`:
    /// one-shot prefill). Recorded so the bench JSON names its arm.
    pub prefill_chunk: Option<usize>,
    /// ttft p99 of the unchunked control arm, when the bench ran one
    /// (the PR-9 chunked-prefill gate compares against it).
    pub ttft_p99_unchunked_ms: Option<f64>,
}

impl ShapeReport {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("shape", Json::str(self.shape.clone())),
            ("requests", Json::num(self.requests as f64)),
            ("tenants", Json::num(self.tenants as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("expired", Json::num(self.expired as f64)),
            ("cancelled", Json::num(self.cancelled as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("ttft_p50_ms", Json::num(self.ttft_p50_ms)),
            ("ttft_p99_ms", Json::num(self.ttft_p99_ms)),
            ("latency_p50_ms", Json::num(self.latency_p50_ms)),
            ("latency_p99_ms", Json::num(self.latency_p99_ms)),
            ("tok_per_s", Json::num(self.tok_per_s)),
            ("duration_s", Json::num(self.duration_s)),
        ];
        if let Some(chunk) = self.prefill_chunk {
            fields.push(("prefill_chunk", Json::num(chunk as f64)));
        }
        if let Some(p99) = self.ttft_p99_unchunked_ms {
            fields.push(("ttft_p99_unchunked_ms", Json::num(p99)));
        }
        Json::obj(fields)
    }
}

fn aggregate(
    cfg: &TrafficCfg,
    samples: &[Sample],
    duration_s: f64,
) -> ShapeReport {
    let count =
        |o: Outcome| samples.iter().filter(|s| s.outcome == o).count();
    let mut ttfts: Vec<f64> =
        samples.iter().filter_map(|s| s.ttft_ms).collect();
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // latency percentiles over completed requests only: folding in
    // instant rejections or early cancels would fake a faster server
    let mut lats: Vec<f64> = samples
        .iter()
        .filter(|s| s.outcome == Outcome::Ok)
        .map(|s| s.latency_ms)
        .collect();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total_tokens: usize = samples.iter().map(|s| s.tokens).sum();
    ShapeReport {
        shape: cfg.shape.name().to_string(),
        requests: samples.len(),
        tenants: cfg.tenants,
        completed: count(Outcome::Ok),
        rejected: count(Outcome::Rejected),
        expired: count(Outcome::Expired),
        cancelled: count(Outcome::Cancelled),
        errors: count(Outcome::Error),
        ttft_p50_ms: percentile(&ttfts, 50.0),
        ttft_p99_ms: percentile(&ttfts, 99.0),
        latency_p50_ms: percentile(&lats, 50.0),
        latency_p99_ms: percentile(&lats, 99.0),
        tok_per_s: if duration_s > 0.0 {
            total_tokens as f64 / duration_s
        } else {
            0.0
        },
        duration_s,
        prefill_chunk: None,
        ttft_p99_unchunked_ms: None,
    }
}

/// Replay one shape open-loop: sleep to each planned arrival offset, fire
/// the request on its own collector thread, join everything, aggregate.
pub fn run_shape(cfg: &TrafficCfg, client: Arc<dyn Client>) -> ShapeReport {
    let arrivals = plan(cfg);
    let start = Instant::now();
    let samples: Arc<Mutex<Vec<Sample>>> =
        Arc::new(Mutex::new(Vec::with_capacity(arrivals.len())));
    let mut collectors = Vec::with_capacity(arrivals.len());
    for a in arrivals {
        let target = start + a.at;
        let now = Instant::now();
        if target > now {
            thread::sleep(target - now);
        }
        let client = Arc::clone(&client);
        let samples = Arc::clone(&samples);
        let tenant = tenant_id(a.tenant);
        collectors.push(thread::spawn(move || {
            let s = client.call(&tenant, &a);
            samples.lock().unwrap().push(s);
        }));
    }
    for c in collectors {
        let _ = c.join();
    }
    let duration_s = start.elapsed().as_secs_f64();
    let samples = samples.lock().unwrap();
    aggregate(cfg, &samples, duration_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::coordinator::{HostEngine, Registry, Server, ServerCfg};

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert!((percentile(&v, 50.0) - 50.0).abs() <= 1.0);
        assert!(percentile(&v, 99.0) >= 98.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn outcome_mapping() {
        assert_eq!(
            outcome_of(&ServeError::QueueFull { tenant: "x".into() }),
            Outcome::Rejected
        );
        assert_eq!(outcome_of(&ServeError::Deadline), Outcome::Expired);
        assert_eq!(outcome_of(&ServeError::Cancelled), Outcome::Cancelled);
        assert_eq!(
            outcome_of(&ServeError::Engine("x".into())),
            Outcome::Error
        );
        assert_eq!(
            outcome_of(&ServeError::ShuttingDown),
            Outcome::Error
        );
    }

    #[test]
    fn steady_replay_in_process_completes_everything() {
        let cfg = presets::tiny();
        let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
        let mut server = Server::new(registry, ServerCfg::default());
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let server = Arc::new(server);
        let mut tcfg = TrafficCfg::named(Shape::Steady, 8, 11);
        tcfg.tenants = 4;
        tcfg.rate = 400.0;
        register_tenants(&server, &tcfg).unwrap();
        let report = run_shape(
            &tcfg,
            Arc::new(InProcessClient::new(Arc::clone(&server))),
        );
        assert_eq!(report.requests, 8);
        assert_eq!(report.completed, 8, "{report:?}");
        assert_eq!(report.errors, 0);
        assert!(report.tok_per_s > 0.0);
        assert!(report.ttft_p50_ms > 0.0);
        assert!(report.ttft_p50_ms <= report.ttft_p99_ms);
        assert!(report.latency_p50_ms <= report.latency_p99_ms);
    }

    #[test]
    fn weighted_shape_registration_installs_cycling_weights() {
        let cfg = presets::tiny();
        let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
        let mut server = Server::new(registry, ServerCfg::default());
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let tcfg = TrafficCfg::named(Shape::Weighted, 8, 1);
        register_tenants(&server, &tcfg).unwrap();
        for i in 0..tcfg.tenants {
            let qos = server.batcher.qos_of(&tenant_id(i)).unwrap();
            assert_eq!(qos.weight, 1 << (i % 3), "tenant {i}");
        }
        // other shapes keep every tenant at the default weight
        assert_eq!(tenant_weight(Shape::Steady, 5), 1);
        server.shutdown();
    }

    #[test]
    fn cancel_storm_replay_resolves_every_request() {
        let cfg = presets::tiny();
        let registry = Arc::new(Registry::new(cfg.clone(), 1 << 30));
        let mut server = Server::new(registry, ServerCfg::default());
        let cfg2 = cfg.clone();
        server.start(1, move |_| HostEngine::new(cfg2.clone(), 0));
        let server = Arc::new(server);
        let mut tcfg = TrafficCfg::named(Shape::CancelStorm, 12, 5);
        tcfg.tenants = 4;
        tcfg.max_new_tokens = 40;
        register_tenants(&server, &tcfg).unwrap();
        let report = run_shape(
            &tcfg,
            Arc::new(InProcessClient::new(Arc::clone(&server))),
        );
        assert_eq!(report.requests, 12);
        assert_eq!(
            report.completed
                + report.cancelled
                + report.rejected
                + report.expired,
            12,
            "unresolved requests: {report:?}"
        );
        assert_eq!(report.errors, 0, "{report:?}");
        // no admission depth leaked behind the storm
        assert_eq!(server.batcher.depth(), 0);
    }
}
