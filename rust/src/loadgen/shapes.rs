//! Seeded traffic-shape planning.
//!
//! [`plan`] is a pure function of [`TrafficCfg`] (shape + seed): it emits
//! the full arrival schedule — offsets, tenant picks, prompts, options,
//! cancellation plans — before any request is sent. Replaying a plan is
//! what makes `bench_traffic` deterministic: the *workload* is fixed by
//! the seed even though measured latencies are machine-dependent.

use crate::coordinator::GenOptions;
use crate::util::rng::Rng;
use std::time::Duration;

/// The seven named adversarial traffic shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    /// Poisson arrivals at a constant mean rate.
    Steady,
    /// Poisson-spaced bursts of 4–12 back-to-back requests.
    Bursty,
    /// Low → high → low rate ramp (a compressed diurnal cycle).
    Diurnal,
    /// Steady arrivals with hot-tenant Zipfian skew over a 1k+ tenant
    /// universe on the pooled tier.
    Zipf,
    /// Bursty arrivals where most requests are cancelled mid-flight.
    CancelStorm,
    /// Steady arrivals where half the requests carry tight deadlines.
    DeadlineMix,
    /// Saturating arrivals over a small universe whose tenants carry
    /// cycling DWRR weights 1/2/4 (see [`super::tenant_weight`]) — the
    /// contrast shape for the PR-9 weighted-fairness scheduler.
    Weighted,
}

// New shapes must be APPENDED: `Shape::stream()` is positional, so
// inserting in the middle would silently reseed every later shape.
pub const ALL_SHAPES: [Shape; 7] = [
    Shape::Steady,
    Shape::Bursty,
    Shape::Diurnal,
    Shape::Zipf,
    Shape::CancelStorm,
    Shape::DeadlineMix,
    Shape::Weighted,
];

impl Shape {
    pub fn name(self) -> &'static str {
        match self {
            Shape::Steady => "steady",
            Shape::Bursty => "bursty",
            Shape::Diurnal => "diurnal",
            Shape::Zipf => "zipf",
            Shape::CancelStorm => "cancel_storm",
            Shape::DeadlineMix => "deadline_mix",
            Shape::Weighted => "weighted",
        }
    }

    pub fn parse(name: &str) -> Option<Shape> {
        ALL_SHAPES.iter().copied().find(|s| s.name() == name)
    }

    /// Stable RNG stream id, so each shape's schedule is independent of
    /// which other shapes run.
    fn stream(self) -> u64 {
        ALL_SHAPES.iter().position(|s| *s == self).unwrap() as u64
    }
}

/// One shape's workload parameters.
#[derive(Debug, Clone)]
pub struct TrafficCfg {
    pub shape: Shape,
    /// Total requests in the schedule.
    pub requests: usize,
    /// Registered tenant universe the schedule draws from.
    pub tenants: usize,
    pub seed: u64,
    /// Mean arrival rate, requests/second.
    pub rate: f64,
    /// Generation length cap per request.
    pub max_new_tokens: usize,
    /// Deadline budget for the tight half of [`Shape::DeadlineMix`].
    pub deadline_ms: u64,
    /// How long after submit a [`Shape::CancelStorm`] victim is cancelled.
    pub cancel_after_ms: u64,
}

impl TrafficCfg {
    /// Per-shape defaults: the Zipf shape exercises a 1.2k-tenant pooled
    /// tier (the paper-scale claim), the Weighted shape a six-tenant
    /// universe (two tenants per weight class 1/2/4), everything else a
    /// small universe.
    pub fn named(shape: Shape, requests: usize, seed: u64) -> TrafficCfg {
        TrafficCfg {
            shape,
            requests,
            tenants: match shape {
                Shape::Zipf => 1200,
                Shape::Weighted => 6,
                _ => 8,
            },
            seed,
            rate: 150.0,
            max_new_tokens: 8,
            deadline_ms: 25,
            cancel_after_ms: 5,
        }
    }
}

/// One planned request.
#[derive(Debug)]
pub struct Arrival {
    /// Offset from the start of the replay.
    pub at: Duration,
    /// Index into the registered tenant universe (see
    /// [`super::tenant_id`]).
    pub tenant: usize,
    pub prompt: String,
    pub opts: GenOptions,
    /// `Some(d)`: cancel this request `d` after submitting it.
    pub cancel_after: Option<Duration>,
}

/// Exponential inter-arrival gap for a Poisson process at `rate`/s.
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    -(1.0 - rng.f64()).ln() / rate
}

/// Inverse-CDF Zipf(s) sampler over `n` ranks.
struct ZipfSampler {
    cum: Vec<f64>,
}

impl ZipfSampler {
    fn new(n: usize, s: f64) -> ZipfSampler {
        let mut cum = Vec::with_capacity(n);
        let mut total = 0.0;
        for i in 0..n {
            total += 1.0 / ((i + 1) as f64).powf(s);
            cum.push(total);
        }
        ZipfSampler { cum }
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64() * self.cum.last().copied().unwrap_or(1.0);
        self.cum.partition_point(|&c| c < u).min(self.cum.len() - 1)
    }
}

/// Short prompt over the char-level tokenizer's charset; with BOS/SEP
/// framing it stays far inside the tiny preset's 48-token window.
fn prompt(rng: &mut Rng) -> String {
    format!("q:{:06}", rng.below(1_000_000))
}

/// Long prompt for the prefill-contended shapes (bursty, deadline_mix):
/// 21–33 chars → 23–35 tokens with BOS/SEP framing, leaving ≥ 13
/// positions of the tiny 48-token window for generation. Long enough
/// that one-shot prefill visibly monopolizes the engine — the workload
/// chunked prefill (PR 9) exists to break up — and variable-length so
/// co-admitted rows finish at different times (slot churn, not
/// lock-step batches).
fn long_prompt(rng: &mut Rng) -> String {
    let pad = rng.range(12, 25);
    format!(
        "q:{:06}x{:0w$}",
        rng.below(1_000_000),
        rng.below(1_000_000),
        w = pad
    )
}

/// Expand `cfg` into its full deterministic arrival schedule, sorted by
/// offset.
pub fn plan(cfg: &TrafficCfg) -> Vec<Arrival> {
    assert!(cfg.tenants > 0 && cfg.requests > 0);
    let mut rng = Rng::new(cfg.seed, cfg.shape.stream());
    let zipf = ZipfSampler::new(cfg.tenants, 1.1);
    let mut out = Vec::with_capacity(cfg.requests);
    let mut t = 0.0f64;
    let mut burst_left = 0usize;
    for i in 0..cfg.requests {
        // arrival offset
        match cfg.shape {
            Shape::Steady
            | Shape::Zipf
            | Shape::DeadlineMix
            | Shape::Weighted => {
                t += exp_gap(&mut rng, cfg.rate);
            }
            Shape::Bursty | Shape::CancelStorm => {
                if burst_left == 0 {
                    burst_left = rng.range(4, 13);
                    // burst times spaced so the mean rate stays ~cfg.rate
                    t += exp_gap(&mut rng, cfg.rate / 8.0);
                }
                burst_left -= 1;
            }
            Shape::Diurnal => {
                // thirds: trough, 2.5x peak, trough
                let phase = i * 3 / cfg.requests;
                let mult = if phase == 1 { 2.5 } else { 0.3 };
                t += exp_gap(&mut rng, cfg.rate * mult);
            }
        }
        // tenant pick
        let tenant = match cfg.shape {
            Shape::Zipf => zipf.sample(&mut rng),
            _ => rng.below(cfg.tenants as u32) as usize,
        };
        // options
        let mut opts = GenOptions::greedy();
        opts.max_new_tokens = cfg.max_new_tokens;
        if cfg.shape == Shape::DeadlineMix && rng.bool(0.5) {
            opts.deadline =
                Some(Duration::from_millis(cfg.deadline_ms.max(1)));
        }
        // cancellation plan
        let cancel_after = if cfg.shape == Shape::CancelStorm
            && rng.bool(0.7)
        {
            let jitter = rng.below(1 + cfg.cancel_after_ms as u32) as u64;
            Some(Duration::from_millis(cfg.cancel_after_ms + jitter))
        } else {
            None
        };
        let prompt = match cfg.shape {
            Shape::Bursty | Shape::DeadlineMix => long_prompt(&mut rng),
            _ => prompt(&mut rng),
        };
        out.push(Arrival {
            at: Duration::from_secs_f64(t),
            tenant,
            prompt,
            opts,
            cancel_after,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(shape: Shape) -> TrafficCfg {
        TrafficCfg::named(shape, 64, 7)
    }

    #[test]
    fn names_round_trip() {
        for s in ALL_SHAPES {
            assert_eq!(Shape::parse(s.name()), Some(s));
        }
        assert_eq!(Shape::parse("nope"), None);
    }

    #[test]
    fn plan_is_deterministic_per_seed() {
        for shape in ALL_SHAPES {
            let a = plan(&cfg(shape));
            let b = plan(&cfg(shape));
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.at, y.at, "{shape:?}");
                assert_eq!(x.tenant, y.tenant, "{shape:?}");
                assert_eq!(x.prompt, y.prompt, "{shape:?}");
                assert_eq!(x.cancel_after, y.cancel_after, "{shape:?}");
                assert_eq!(
                    x.opts.deadline, y.opts.deadline,
                    "{shape:?}"
                );
            }
            let mut other = cfg(shape);
            other.seed = 8;
            let c = plan(&other);
            assert!(
                a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt
                    || x.at != y.at),
                "{shape:?}: different seed produced an identical plan"
            );
        }
    }

    #[test]
    fn arrivals_sorted_and_bounded() {
        for shape in ALL_SHAPES {
            let c = cfg(shape);
            let arrivals = plan(&c);
            assert_eq!(arrivals.len(), c.requests);
            let mut prev = Duration::ZERO;
            for a in &arrivals {
                assert!(a.at >= prev, "{shape:?}: arrivals out of order");
                prev = a.at;
                assert!(a.tenant < c.tenants, "{shape:?}: tenant oob");
                // BOS + prompt + SEP + 8 generated tokens must fit the
                // tiny 48-token window: prompt ≤ 33 chars
                assert!(a.prompt.len() <= 33, "{shape:?}: prompt too long");
            }
        }
    }

    #[test]
    fn prefill_contended_shapes_plan_long_prompts() {
        // bursty / deadline_mix make prefill the contended resource —
        // every prompt is long; steady keeps the short baseline
        for shape in [Shape::Bursty, Shape::DeadlineMix] {
            for a in plan(&cfg(shape)) {
                assert!(
                    a.prompt.len() > 16,
                    "{shape:?}: expected a long prompt, got {:?}",
                    a.prompt
                );
            }
        }
        for a in plan(&cfg(Shape::Steady)) {
            assert!(a.prompt.len() <= 16, "steady prompt grew: {:?}", a.prompt);
        }
    }

    #[test]
    fn weighted_shape_covers_small_universe_evenly() {
        let c = cfg(Shape::Weighted);
        assert_eq!(c.tenants, 6, "two tenants per weight class 1/2/4");
        let arrivals = plan(&TrafficCfg::named(Shape::Weighted, 300, 9));
        let distinct: std::collections::HashSet<_> =
            arrivals.iter().map(|a| a.tenant).collect();
        assert_eq!(distinct.len(), 6, "all weight classes must contend");
        for a in &arrivals {
            assert!(a.cancel_after.is_none());
            assert!(a.opts.deadline.is_none());
        }
    }

    #[test]
    fn zipf_skews_hot_and_covers_big_universe() {
        let c = TrafficCfg::named(Shape::Zipf, 2000, 3);
        assert!(c.tenants >= 1000, "zipf must exercise a 1k+ universe");
        let arrivals = plan(&c);
        let hot = arrivals.iter().filter(|a| a.tenant == 0).count();
        let cold = arrivals.iter().filter(|a| a.tenant == 500).count();
        assert!(
            hot > cold,
            "rank 0 ({hot}) should outdraw rank 500 ({cold})"
        );
        assert!(hot > arrivals.len() / 50, "hot tenant barely hot: {hot}");
        let distinct: std::collections::HashSet<_> =
            arrivals.iter().map(|a| a.tenant).collect();
        assert!(distinct.len() > 50, "tail too thin: {}", distinct.len());
    }

    #[test]
    fn cancel_storm_plans_cancels_and_deadline_mix_plans_deadlines() {
        let storm = plan(&cfg(Shape::CancelStorm));
        let cancels =
            storm.iter().filter(|a| a.cancel_after.is_some()).count();
        assert!(
            cancels * 10 >= storm.len() * 5,
            "storm is mostly cancels: {cancels}/{}",
            storm.len()
        );
        let mix = plan(&cfg(Shape::DeadlineMix));
        let tight =
            mix.iter().filter(|a| a.opts.deadline.is_some()).count();
        assert!(tight > 0 && tight < mix.len(), "mix half-tight: {tight}");
        // other shapes plan neither
        for a in plan(&cfg(Shape::Steady)) {
            assert!(a.cancel_after.is_none());
            assert!(a.opts.deadline.is_none());
        }
    }

    #[test]
    fn bursty_arrivals_cluster() {
        let arrivals = plan(&cfg(Shape::Bursty));
        let zero_gaps = arrivals
            .windows(2)
            .filter(|w| w[1].at == w[0].at)
            .count();
        assert!(
            zero_gaps > arrivals.len() / 2,
            "bursts should share arrival instants: {zero_gaps}"
        );
    }
}
