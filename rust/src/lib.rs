//! # mos — Mixture of Shards, as a deployable multi-tenant adapter framework
//!
//! Rust + JAX + Pallas reproduction of *"MoS: Unleashing Parameter Efficiency
//! of Low-Rank Adaptation with Mixture of Shards"* (ICLR 2025).
//!
//! Layering (Python never on the request path):
//! * **L3 (this crate)** — adapter pools + index-based router (the paper's
//!   contribution), multi-tenant serving coordinator, training orchestrator,
//!   synthetic-task substrates, stats, benches.
//! * **L2** — JAX transformer lowered AOT to HLO text (`python/compile/`).
//! * **L1** — Pallas kernels for shard gather / fused routed low-rank apply.
//!
//! See `DESIGN.md` for the system inventory and the experiment index mapping
//! every paper table/figure to a bench target.

// Unit tests run under the counting allocation probe so perf tests can
// assert the lean serving hot path is arena-only (see util::alloc;
// bench_serving registers its own instance for the RSS proxy).
#[cfg(test)]
#[global_allocator]
static ALLOC_PROBE: util::alloc::CountingAlloc = util::alloc::CountingAlloc;

pub mod adapter;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod frontend;
pub mod loadgen;
pub mod model;
pub mod runtime;
pub mod stats;
pub mod train;
pub mod util;
