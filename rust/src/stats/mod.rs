//! Statistics substrate: summary stats and Welch's t-test (used by the
//! Table 5 robustness and Table 7 significance benches).
//!
//! The p-value needs the regularized incomplete beta function; implemented
//! via the continued-fraction expansion (Lentz's algorithm), no deps.

use crate::adapter::mos::diversity::ln_gamma;

/// Sample mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var =
        xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Regularized incomplete beta I_x(a, b).
pub fn betainc(a: f64, b: f64, x: f64) -> f64 {
    assert!((0.0..=1.0).contains(&x), "x={x} out of [0,1]");
    if x == 0.0 || x == 1.0 {
        return x;
    }
    // symmetry for faster convergence
    if x > (a + 1.0) / (a + b + 2.0) {
        return 1.0 - betainc(b, a, 1.0 - x);
    }
    let ln_front =
        a * x.ln() + b * (1.0 - x).ln() + ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b);
    let front = ln_front.exp() / a;
    // Lentz continued fraction
    let tiny = 1e-300;
    let mut f = 1.0f64;
    let mut c = 1.0f64;
    let mut d = 0.0f64;
    for i in 0..400 {
        let m = i / 2;
        let numerator = if i == 0 {
            1.0
        } else if i % 2 == 0 {
            let m = m as f64;
            m * (b - m) * x / ((a + 2.0 * m - 1.0) * (a + 2.0 * m))
        } else {
            let m = m as f64;
            -(a + m) * (a + b + m) * x / ((a + 2.0 * m) * (a + 2.0 * m + 1.0))
        };
        d = 1.0 + numerator * d;
        if d.abs() < tiny {
            d = tiny;
        }
        d = 1.0 / d;
        c = 1.0 + numerator / c;
        if c.abs() < tiny {
            c = tiny;
        }
        let delta = c * d;
        f *= delta;
        if (delta - 1.0).abs() < 1e-12 {
            break;
        }
    }
    front * (f - 1.0)
}

/// Two-sided p-value of Student's t with `df` degrees of freedom.
pub fn t_pvalue(t: f64, df: f64) -> f64 {
    let x = df / (df + t * t);
    betainc(df / 2.0, 0.5, x)
}

/// Welch's unequal-variance t-test. Returns (t statistic, df, two-sided p).
pub fn welch_t_test(a: &[f64], b: &[f64]) -> (f64, f64, f64) {
    assert!(a.len() >= 2 && b.len() >= 2, "need >= 2 samples per group");
    let (ma, mb) = (mean(a), mean(b));
    let (va, vb) = (std_dev(a).powi(2), std_dev(b).powi(2));
    let (na, nb) = (a.len() as f64, b.len() as f64);
    let se2 = va / na + vb / nb;
    if se2 == 0.0 {
        // identical constant samples: no evidence of difference
        return (0.0, na + nb - 2.0, 1.0);
    }
    let t = (ma - mb) / se2.sqrt();
    let df = se2 * se2
        / ((va / na).powi(2) / (na - 1.0) + (vb / nb).powi(2) / (nb - 1.0));
    (t, df, t_pvalue(t, df))
}

/// Paired t-test over per-benchmark score pairs (the paper's Table 7 setup:
/// same benchmarks, two methods). Returns (t, df, two-sided p).
pub fn paired_t_test(a: &[f64], b: &[f64]) -> (f64, f64, f64) {
    assert_eq!(a.len(), b.len());
    assert!(a.len() >= 2);
    let d: Vec<f64> = a.iter().zip(b).map(|(x, y)| x - y).collect();
    let md = mean(&d);
    let sd = std_dev(&d);
    let n = d.len() as f64;
    if sd == 0.0 {
        return (0.0, n - 1.0, if md == 0.0 { 1.0 } else { 0.0 });
    }
    let t = md / (sd / n.sqrt());
    let df = n - 1.0;
    (t, df, t_pvalue(t, df))
}

/// mean ± std formatting, paper Table 5 style.
pub fn fmt_mean_std(xs: &[f64]) -> String {
    format!("{:.2}±{:.2}", mean(xs), std_dev(xs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn betainc_reference_values() {
        // I_x(a,b) reference values (scipy.special.betainc)
        assert!((betainc(2.0, 3.0, 0.5) - 0.6875).abs() < 1e-9);
        assert!((betainc(0.5, 0.5, 0.3) - 0.36901).abs() < 1e-4);
        assert!((betainc(5.0, 1.0, 0.8) - 0.32768).abs() < 1e-9);
        assert_eq!(betainc(1.0, 1.0, 0.0), 0.0);
        assert_eq!(betainc(1.0, 1.0, 1.0), 1.0);
    }

    #[test]
    fn t_pvalue_reference() {
        // scipy.stats.t.sf(2.0, 10)*2 = 0.07338...
        assert!((t_pvalue(2.0, 10.0) - 0.073388).abs() < 1e-4);
        // df=1 (Cauchy): p(t=1) = 0.5
        assert!((t_pvalue(1.0, 1.0) - 0.5).abs() < 1e-6);
        // symmetric in t
        assert!((t_pvalue(-2.5, 7.0) - t_pvalue(2.5, 7.0)).abs() < 1e-12);
    }

    #[test]
    fn welch_detects_difference() {
        let a = [5.1, 5.3, 4.9, 5.2, 5.0, 5.15];
        let b = [6.1, 6.0, 6.3, 5.9, 6.2, 6.05];
        let (t, _, p) = welch_t_test(&a, &b);
        assert!(t < -5.0);
        assert!(p < 0.001);
    }

    #[test]
    fn welch_no_difference() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.1, 1.9, 3.1, 3.9];
        let (_, _, p) = welch_t_test(&a, &b);
        assert!(p > 0.5);
    }

    #[test]
    fn welch_identical_constant() {
        let a = [2.0, 2.0, 2.0];
        let (t, _, p) = welch_t_test(&a, &a);
        assert_eq!(t, 0.0);
        assert_eq!(p, 1.0);
    }

    #[test]
    fn paired_test_sensitive_to_consistent_shift() {
        // small consistent improvement across benchmarks
        let lora = [44.77, 36.22, 26.28, 48.67, 35.70, 18.24];
        let mos = [46.09, 37.29, 28.43, 50.21, 37.19, 19.12];
        let (t, df, p) = paired_t_test(&mos, &lora);
        assert!(t > 3.0, "t={t}");
        assert_eq!(df, 5.0);
        assert!(p < 0.05, "p={p}"); // the paper's Table 7 conclusion
    }
}
