//! Evaluation harness: batched decoding through any forward function (host
//! model or PJRT artifact) + per-task scoring, reporting the paper's
//! metrics (EM / final-number EM / F1 / pass@1).
//!
//! [`decode`] is the one decoder shared by eval, the serving workers, and
//! the benches: greedy when `temperature == 0`, otherwise temperature /
//! top-k sampling driven by the per-request seed in [`GenOptions`].

use crate::data::tasks::{Metric, Task};
use crate::data::tokenizer::{Tokenizer, EOS, PAD};
use crate::util::rng::Rng;
use std::time::Duration;

/// Forward function: padded tokens (batch*seq) -> logits (batch*seq*vocab).
pub type ForwardFn<'a> = dyn FnMut(&[i32]) -> Vec<f32> + 'a;

/// RNG stream tag for generation sampling (distinct from router/task
/// streams so a shared seed never aliases them).
const GEN_STREAM: u64 = 0x6d6f735f67656e; // "mos_gen"

/// Per-request generation options, flowing `submit -> Batcher -> Request ->
/// ServeEngine/decode` (and used directly by [`evaluate`] with the greedy
/// defaults).
///
/// Determinism contract: a row's sample stream is derived from `seed` only
/// (not from its batch position), so the generated tokens for a given
/// `(prompt, GenOptions)` pair are reproducible regardless of how the
/// server batched the request.
#[derive(Debug, Clone, PartialEq)]
pub struct GenOptions {
    /// Cap on generated tokens per request (`usize::MAX` = until a stop
    /// token or the sequence window fills).
    pub max_new_tokens: usize,
    /// Tokens that terminate generation without being emitted. Default
    /// `[EOS]`; empty = run until `max_new_tokens`/window.
    pub stop_tokens: Vec<i32>,
    /// `0.0` = greedy argmax; `> 0` = softmax sampling at this temperature.
    pub temperature: f32,
    /// Restrict sampling to the `top_k` highest logits (`0` = full vocab).
    pub top_k: usize,
    /// Seed for the sampling stream (ignored when greedy).
    pub seed: u64,
    /// Serving deadline budget, measured from submit time. The decoder
    /// ignores it; the coordinator rejects requests whose budget lapses
    /// before they reach an engine (`ServeError::Deadline`).
    pub deadline: Option<Duration>,
}

impl Default for GenOptions {
    fn default() -> GenOptions {
        GenOptions {
            max_new_tokens: usize::MAX,
            stop_tokens: vec![EOS],
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            deadline: None,
        }
    }
}

impl GenOptions {
    /// Greedy decoding to EOS — the pre-redesign `greedy_decode` behavior.
    pub fn greedy() -> GenOptions {
        GenOptions::default()
    }

    /// Temperature/top-k sampling with a per-request seed.
    pub fn sample(temperature: f32, top_k: usize, seed: u64) -> GenOptions {
        GenOptions {
            temperature,
            top_k,
            seed,
            ..GenOptions::default()
        }
    }

    pub fn max_new_tokens(mut self, n: usize) -> GenOptions {
        self.max_new_tokens = n;
        self
    }

    pub fn stop_tokens(mut self, tokens: Vec<i32>) -> GenOptions {
        self.stop_tokens = tokens;
        self
    }

    pub fn seed(mut self, seed: u64) -> GenOptions {
        self.seed = seed;
        self
    }

    pub fn deadline(mut self, budget: Duration) -> GenOptions {
        self.deadline = Some(budget);
        self
    }
}

/// Batched decoding.
///
/// `prompts` are token prefixes (already `BOS .. SEP`). Each row decodes
/// until a stop token, `max_new_tokens`, or `seq` is full; every decode
/// step is one full forward pass (no KV cache — the presets are small; see
/// DESIGN.md §Perf for the decode-step artifact discussion).
///
/// Degenerate rows are safe: an empty prompt or a prompt that already
/// fills `seq` produces an empty generation instead of indexing out of
/// the logits.
pub fn decode(
    forward: &mut ForwardFn,
    prompts: &[Vec<i32>],
    opts: &GenOptions,
    seq: usize,
    vocab: usize,
) -> Vec<Vec<i32>> {
    let bsz = prompts.len();
    let mut tokens = vec![PAD; bsz * seq];
    let mut lens: Vec<usize> = Vec::with_capacity(bsz);
    let mut done = vec![false; bsz];
    for (row, p) in prompts.iter().enumerate() {
        let n = p.len().min(seq);
        tokens[row * seq..row * seq + n].copy_from_slice(&p[..n]);
        lens.push(n);
        // an empty prompt has no position to read next-token logits from
        if n == 0 {
            done[row] = true;
        }
    }
    let mut out: Vec<Vec<i32>> = vec![Vec::new(); bsz];
    if opts.max_new_tokens == 0 {
        return out;
    }
    // one RNG per row, all derived from the request seed alone, so a row's
    // samples do not depend on its batch position
    let mut rngs: Vec<Rng> =
        (0..bsz).map(|_| Rng::new(opts.seed, GEN_STREAM)).collect();
    loop {
        if (0..bsz).all(|r| done[r] || lens[r] >= seq) {
            break;
        }
        let logits = forward(&tokens);
        debug_assert_eq!(logits.len(), bsz * seq * vocab);
        let mut progressed = false;
        for row in 0..bsz {
            if done[row] || lens[row] >= seq {
                continue;
            }
            let pos = lens[row] - 1;
            let lrow =
                &logits[(row * seq + pos) * vocab..(row * seq + pos + 1) * vocab];
            let next = if opts.temperature > 0.0 {
                sample_token(lrow, opts.temperature, opts.top_k, &mut rngs[row])
                    as i32
            } else {
                (0..vocab)
                    .max_by(|&a, &b| lrow[a].total_cmp(&lrow[b]))
                    .unwrap() as i32
            };
            if opts.stop_tokens.contains(&next) {
                done[row] = true;
            } else {
                tokens[row * seq + lens[row]] = next;
                out[row].push(next);
                lens[row] += 1;
                if out[row].len() >= opts.max_new_tokens {
                    done[row] = true;
                }
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }
    out
}

/// Sample from softmax(logits / temperature) over the top-k logits.
/// Ties in the top-k cut are broken by ascending index so the candidate
/// set is deterministic.
fn sample_token(
    lrow: &[f32],
    temperature: f32,
    top_k: usize,
    rng: &mut Rng,
) -> usize {
    let k = if top_k == 0 {
        lrow.len()
    } else {
        top_k.min(lrow.len())
    };
    let mut idx: Vec<usize> = (0..lrow.len()).collect();
    idx.sort_unstable_by(|&a, &b| {
        lrow[b].total_cmp(&lrow[a]).then(a.cmp(&b))
    });
    idx.truncate(k);
    let max = lrow[idx[0]];
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((lrow[i] - max) / temperature) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.f64() * total;
    for (j, &i) in idx.iter().enumerate() {
        u -= weights[j];
        if u <= 0.0 {
            return i;
        }
    }
    idx[k - 1]
}

/// Scores for one task evaluation.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub task: String,
    pub metric: Metric,
    /// primary metric in [0, 100] (paper-style percentage)
    pub score: f64,
    /// exact match in [0, 100] (same as score for EM metrics)
    pub em: f64,
    pub n: usize,
}

/// Evaluate a task: generate completions for `n` eval examples with the
/// given forward function (greedy decoding) and aggregate the task metric.
pub fn evaluate(
    task: &Task,
    forward: &mut ForwardFn,
    n: usize,
    batch: usize,
    seq: usize,
    vocab: usize,
) -> EvalReport {
    let tk = Tokenizer::new();
    let opts = GenOptions::greedy();
    let mut scores = Vec::with_capacity(n);
    let mut ems = Vec::with_capacity(n);
    let mut idx = 0;
    while idx < n {
        let take = batch.min(n - idx);
        let mut examples = Vec::with_capacity(take);
        let mut prompts = Vec::with_capacity(batch);
        for i in idx..idx + take {
            let ex = task.example("eval", i);
            prompts.push(tk.prompt_tokens(&ex.prompt));
            examples.push(ex);
        }
        // pad the batch up to the artifact's fixed batch size
        while prompts.len() < batch {
            prompts.push(vec![crate::data::tokenizer::BOS]);
        }
        let generations = decode(forward, &prompts, &opts, seq, vocab);
        let debug = std::env::var("MOS_EVAL_DEBUG").is_ok();
        for (ex, gen) in examples.iter().zip(&generations) {
            let text = tk.decode(gen);
            if debug {
                eprintln!(
                    "[eval] prompt={:?} want={:?} got={:?}",
                    ex.prompt, ex.completion, text
                );
            }
            scores.push(task.score(ex, &text));
            ems.push(task.score_em(ex, &text));
        }
        idx += take;
    }
    EvalReport {
        task: task.kind.name().to_string(),
        metric: task.metric(),
        score: 100.0 * scores.iter().sum::<f64>() / scores.len().max(1) as f64,
        em: 100.0 * ems.iter().sum::<f64>() / ems.len().max(1) as f64,
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::TaskKind;
    use crate::data::tokenizer::SEP;

    /// A fake "model" that echoes the prompt chars after SEP — lets us test
    /// decoding mechanics without a trained model.
    fn echo_forward(vocab: usize, seq: usize) -> impl FnMut(&[i32]) -> Vec<f32> {
        move |tokens: &[i32]| {
            let bsz = tokens.len() / seq;
            let mut logits = vec![0.0f32; bsz * seq * vocab];
            for row in 0..bsz {
                let toks = &tokens[row * seq..(row + 1) * seq];
                let sep_pos = toks.iter().position(|&t| t == SEP);
                let len = toks.iter().position(|&t| t == PAD).unwrap_or(seq);
                if let Some(sp) = sep_pos {
                    let pos = len - 1; // position whose next token is queried
                    // number of generated tokens so far
                    let k = pos - sp;
                    // echo prompt token k+1 (after BOS), else EOS
                    let src = 1 + k;
                    let next = if src < sp { toks[src] } else { EOS };
                    logits[(row * seq + pos) * vocab + next as usize] = 10.0;
                }
            }
            logits
        }
    }

    /// Flat logits: every token equally likely — pure test of the sample
    /// stream (greedy argmax would always pick token 0).
    fn flat_forward(vocab: usize, seq: usize) -> impl FnMut(&[i32]) -> Vec<f32> {
        move |tokens: &[i32]| vec![0.0f32; (tokens.len() / seq) * seq * vocab]
    }

    #[test]
    fn greedy_decode_echo() {
        // temperature 0 must reproduce the pre-GenOptions greedy outputs
        let tk = Tokenizer::new();
        let vocab = tk.vocab_size();
        let seq = 24;
        let mut fwd = echo_forward(vocab, seq);
        let prompts =
            vec![tk.prompt_tokens("abc"), tk.prompt_tokens("hello")];
        let outs = decode(&mut fwd, &prompts, &GenOptions::greedy(), seq, vocab);
        assert_eq!(tk.decode(&outs[0]), "abc");
        assert_eq!(tk.decode(&outs[1]), "hello");
    }

    #[test]
    fn evaluate_echo_scores_cipher_partially() {
        // echo model returns the plaintext, which shares chars with the
        // cipher output only by chance -> F1 must be < 100
        let task = Task::new(TaskKind::CipherQa, 0);
        let tk = Tokenizer::new();
        let vocab = tk.vocab_size();
        let mut fwd = echo_forward(vocab, 32);
        let rep = evaluate(&task, &mut fwd, 8, 4, 32, vocab);
        assert_eq!(rep.n, 8);
        assert!(rep.score < 100.0);
    }

    #[test]
    fn decode_respects_seq_bound() {
        let vocab = 8;
        let seq = 6;
        // model that never emits EOS
        let mut fwd = |tokens: &[i32]| {
            let bsz = tokens.len() / seq;
            let mut l = vec![0.0f32; bsz * seq * vocab];
            for i in 0..bsz * seq {
                l[i * vocab + 5] = 1.0;
            }
            l
        };
        let outs =
            decode(&mut fwd, &[vec![1, 4, 2]], &GenOptions::greedy(), seq, vocab);
        assert_eq!(outs[0].len(), seq - 3);
    }

    #[test]
    fn degenerate_prompts_are_safe() {
        // empty prompt (tokenizes to zero tokens) and a prompt that already
        // overfills seq must both yield empty generations, not a panic
        let vocab = 8;
        let seq = 4;
        let mut fwd = echo_forward(vocab, seq);
        let prompts = vec![
            Vec::new(),            // empty
            vec![1, 4, 5, 6, 7, 4], // longer than seq
            vec![1, 4, 2],          // normal row still decodes
        ];
        let outs = decode(&mut fwd, &prompts, &GenOptions::greedy(), seq, vocab);
        assert!(outs[0].is_empty());
        assert!(outs[1].is_empty());
        assert_eq!(outs[2].len(), 1); // seq 4 leaves one slot
    }

    #[test]
    fn max_new_tokens_caps_generation() {
        let vocab = 8;
        let seq = 16;
        let mut fwd = flat_forward(vocab, seq);
        // flat logits + greedy always picks argmax 0 (= PAD, not a stop
        // token by default), so generation runs to the cap
        let opts = GenOptions::greedy().max_new_tokens(3);
        let outs = decode(&mut fwd, &[vec![1, 4]], &opts, seq, vocab);
        assert_eq!(outs[0].len(), 3);
    }

    #[test]
    fn custom_stop_tokens_halt() {
        let vocab = 8;
        let seq = 16;
        // model that always wants token 5
        let mut fwd = |tokens: &[i32]| {
            let bsz = tokens.len() / seq;
            let mut l = vec![0.0f32; bsz * seq * vocab];
            for i in 0..bsz * seq {
                l[i * vocab + 5] = 1.0;
            }
            l
        };
        let opts = GenOptions::greedy().stop_tokens(vec![5]);
        let outs = decode(&mut fwd, &[vec![1, 4]], &opts, seq, vocab);
        assert!(outs[0].is_empty(), "stop token must not be emitted");
    }

    #[test]
    fn sampling_deterministic_per_seed() {
        let vocab = 8;
        let seq = 16;
        let opts = |seed| {
            GenOptions::sample(1.0, 0, seed)
                .stop_tokens(Vec::new())
                .max_new_tokens(12)
        };
        let run = |o: &GenOptions| {
            let mut fwd = flat_forward(vocab, seq);
            decode(&mut fwd, &[vec![1, 4]], o, seq, vocab)
        };
        let a = run(&opts(7));
        let b = run(&opts(7));
        assert_eq!(a, b, "same seed must reproduce the same tokens");
        let c = run(&opts(8));
        assert_ne!(a, c, "different seeds should diverge on flat logits");
        // sampled tokens actually vary (not argmax-collapsed)
        assert!(a[0].iter().any(|&t| t != a[0][0]));
    }

    #[test]
    fn sampling_independent_of_batch_position() {
        // the per-request determinism contract: a request's output does not
        // depend on where the batcher placed it in a batch
        let vocab = 8;
        let seq = 16;
        let opts = GenOptions::sample(0.8, 4, 11)
            .stop_tokens(Vec::new())
            .max_new_tokens(10);
        let mut fwd = flat_forward(vocab, seq);
        let alone = decode(&mut fwd, &[vec![1, 4]], &opts, seq, vocab);
        let mut fwd = flat_forward(vocab, seq);
        let batched = decode(
            &mut fwd,
            &[vec![1, 6, 7], vec![1, 4]],
            &opts,
            seq,
            vocab,
        );
        assert_eq!(alone[0], batched[1]);
    }

    #[test]
    fn top_k_restricts_candidates() {
        let vocab = 8;
        let seq = 16;
        // token 6 and 7 dominate; top_k=2 must never sample anything else
        let mut fwd = |tokens: &[i32]| {
            let bsz = tokens.len() / seq;
            let mut l = vec![0.0f32; bsz * seq * vocab];
            for i in 0..bsz * seq {
                l[i * vocab + 6] = 5.0;
                l[i * vocab + 7] = 5.0;
            }
            l
        };
        let opts = GenOptions::sample(1.0, 2, 3)
            .stop_tokens(Vec::new())
            .max_new_tokens(12);
        let outs = decode(&mut fwd, &[vec![1, 4]], &opts, seq, vocab);
        assert!(outs[0].iter().all(|&t| t == 6 || t == 7), "{:?}", outs[0]);
    }
}
