//! Evaluation harness: batched greedy decoding through any forward function
//! (host model or PJRT artifact) + per-task scoring, reporting the paper's
//! metrics (EM / final-number EM / F1 / pass@1).

use crate::data::tasks::{Metric, Task};
use crate::data::tokenizer::{Tokenizer, EOS, PAD};

/// Forward function: padded tokens (batch*seq) -> logits (batch*seq*vocab).
pub type ForwardFn<'a> = dyn FnMut(&[i32]) -> Vec<f32> + 'a;

/// Batched greedy decoding.
///
/// `prompts` are token prefixes (already `BOS .. SEP`). Each row decodes
/// until EOS or `seq` is full; every decode step is one full forward pass
/// (no KV cache — the presets are small; see DESIGN.md §Perf for the
/// decode-step artifact discussion).
pub fn greedy_decode(
    forward: &mut ForwardFn,
    prompts: &[Vec<i32>],
    seq: usize,
    vocab: usize,
) -> Vec<Vec<i32>> {
    let bsz = prompts.len();
    let mut tokens = vec![PAD; bsz * seq];
    let mut lens: Vec<usize> = Vec::with_capacity(bsz);
    for (row, p) in prompts.iter().enumerate() {
        let n = p.len().min(seq);
        tokens[row * seq..row * seq + n].copy_from_slice(&p[..n]);
        lens.push(n);
    }
    let mut done = vec![false; bsz];
    let mut out: Vec<Vec<i32>> = vec![Vec::new(); bsz];
    loop {
        if done.iter().all(|&d| d) || lens.iter().all(|&l| l >= seq) {
            break;
        }
        let logits = forward(&tokens);
        debug_assert_eq!(logits.len(), bsz * seq * vocab);
        let mut progressed = false;
        for row in 0..bsz {
            if done[row] || lens[row] >= seq {
                continue;
            }
            let pos = lens[row] - 1;
            let lrow = &logits[(row * seq + pos) * vocab..(row * seq + pos + 1) * vocab];
            let next = (0..vocab)
                .max_by(|&a, &b| lrow[a].total_cmp(&lrow[b]))
                .unwrap() as i32;
            if next == EOS {
                done[row] = true;
            } else {
                tokens[row * seq + lens[row]] = next;
                out[row].push(next);
                lens[row] += 1;
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }
    out
}

/// Scores for one task evaluation.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub task: String,
    pub metric: Metric,
    /// primary metric in [0, 100] (paper-style percentage)
    pub score: f64,
    /// exact match in [0, 100] (same as score for EM metrics)
    pub em: f64,
    pub n: usize,
}

/// Evaluate a task: generate completions for `n` eval examples with the
/// given forward function and aggregate the task metric.
pub fn evaluate(
    task: &Task,
    forward: &mut ForwardFn,
    n: usize,
    batch: usize,
    seq: usize,
    vocab: usize,
) -> EvalReport {
    let tk = Tokenizer::new();
    let mut scores = Vec::with_capacity(n);
    let mut ems = Vec::with_capacity(n);
    let mut idx = 0;
    while idx < n {
        let take = batch.min(n - idx);
        let mut examples = Vec::with_capacity(take);
        let mut prompts = Vec::with_capacity(batch);
        for i in idx..idx + take {
            let ex = task.example("eval", i);
            prompts.push(tk.prompt_tokens(&ex.prompt));
            examples.push(ex);
        }
        // pad the batch up to the artifact's fixed batch size
        while prompts.len() < batch {
            prompts.push(vec![crate::data::tokenizer::BOS]);
        }
        let generations = greedy_decode(forward, &prompts, seq, vocab);
        let debug = std::env::var("MOS_EVAL_DEBUG").is_ok();
        for (ex, gen) in examples.iter().zip(&generations) {
            let text = tk.decode(gen);
            if debug {
                eprintln!(
                    "[eval] prompt={:?} want={:?} got={:?}",
                    ex.prompt, ex.completion, text
                );
            }
            scores.push(task.score(ex, &text));
            ems.push(task.score_em(ex, &text));
        }
        idx += take;
    }
    EvalReport {
        task: task.kind.name().to_string(),
        metric: task.metric(),
        score: 100.0 * scores.iter().sum::<f64>() / scores.len().max(1) as f64,
        em: 100.0 * ems.iter().sum::<f64>() / ems.len().max(1) as f64,
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::TaskKind;
    use crate::data::tokenizer::SEP;

    /// A fake "model" that echoes the prompt chars after SEP — lets us test
    /// decoding mechanics without a trained model.
    fn echo_forward(vocab: usize, seq: usize) -> impl FnMut(&[i32]) -> Vec<f32> {
        move |tokens: &[i32]| {
            let bsz = tokens.len() / seq;
            let mut logits = vec![0.0f32; bsz * seq * vocab];
            for row in 0..bsz {
                let toks = &tokens[row * seq..(row + 1) * seq];
                let sep_pos = toks.iter().position(|&t| t == SEP);
                let len = toks.iter().position(|&t| t == PAD).unwrap_or(seq);
                if let Some(sp) = sep_pos {
                    let pos = len - 1; // position whose next token is queried
                    // number of generated tokens so far
                    let k = pos - sp;
                    // echo prompt token k+1 (after BOS), else EOS
                    let src = 1 + k;
                    let next = if src < sp { toks[src] } else { EOS };
                    logits[(row * seq + pos) * vocab + next as usize] = 10.0;
                }
            }
            logits
        }
    }

    #[test]
    fn greedy_decode_echo() {
        let tk = Tokenizer::new();
        let vocab = tk.vocab_size();
        let seq = 24;
        let mut fwd = echo_forward(vocab, seq);
        let prompts =
            vec![tk.prompt_tokens("abc"), tk.prompt_tokens("hello")];
        let outs = greedy_decode(&mut fwd, &prompts, seq, vocab);
        assert_eq!(tk.decode(&outs[0]), "abc");
        assert_eq!(tk.decode(&outs[1]), "hello");
    }

    #[test]
    fn evaluate_echo_scores_cipher_partially() {
        // echo model returns the plaintext, which shares chars with the
        // cipher output only by chance -> F1 must be < 100
        let task = Task::new(TaskKind::CipherQa, 0);
        let tk = Tokenizer::new();
        let vocab = tk.vocab_size();
        let mut fwd = echo_forward(vocab, 32);
        let rep = evaluate(&task, &mut fwd, 8, 4, 32, vocab);
        assert_eq!(rep.n, 8);
        assert!(rep.score < 100.0);
    }

    #[test]
    fn decode_respects_seq_bound() {
        let vocab = 8;
        let seq = 6;
        // model that never emits EOS
        let mut fwd = |tokens: &[i32]| {
            let bsz = tokens.len() / seq;
            let mut l = vec![0.0f32; bsz * seq * vocab];
            for i in 0..bsz * seq {
                l[i * vocab + 5] = 1.0;
            }
            l
        };
        let outs = greedy_decode(&mut fwd, &[vec![1, 4, 2]], seq, vocab);
        assert_eq!(outs[0].len(), seq - 3);
    }
}
