//! Evaluation harness: batched decoding through any forward function (host
//! model or PJRT artifact) + per-task scoring, reporting the paper's
//! metrics (EM / final-number EM / F1 / pass@1).
//!
//! [`DecodeState`] is the one decode loop shared by eval, the serving
//! workers, and the benches: a resumable per-row state machine (admit a
//! prompt into a row, consume one logit row per step) that supports both
//! full-window forwards and KV-cached single-position steps, per-row
//! [`GenOptions`] (greedy when `temperature == 0`, otherwise temperature /
//! top-k sampling from the per-request seed), and per-row deadlines
//! enforced *between* steps. The batch [`decode`] runs it to completion
//! over a shared options struct — the pre-PR-4 surface, unchanged.

use crate::data::tasks::{Metric, Task};
use crate::data::tokenizer::{Tokenizer, EOS, PAD};
use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// Forward function: padded tokens (batch*seq) -> logits (batch*seq*vocab).
pub type ForwardFn<'a> = dyn FnMut(&[i32]) -> Vec<f32> + 'a;

/// RNG stream tag for generation sampling (distinct from router/task
/// streams so a shared seed never aliases them).
const GEN_STREAM: u64 = 0x6d6f735f67656e; // "mos_gen"

/// Per-request generation options, flowing `submit -> Batcher -> Request ->
/// ServeEngine/decode` (and used directly by [`evaluate`] with the greedy
/// defaults).
///
/// Determinism contract: a row's sample stream is derived from `seed` only
/// (not from its batch position), so the generated tokens for a given
/// `(prompt, GenOptions)` pair are reproducible regardless of how the
/// server batched the request.
#[derive(Debug, Clone, PartialEq)]
pub struct GenOptions {
    /// Cap on generated tokens per request (`usize::MAX` = until a stop
    /// token or the sequence window fills).
    pub max_new_tokens: usize,
    /// Tokens that terminate generation without being emitted. Default
    /// `[EOS]`; empty = run until `max_new_tokens`/window.
    pub stop_tokens: Vec<i32>,
    /// `0.0` = greedy argmax; `> 0` = softmax sampling at this temperature.
    pub temperature: f32,
    /// Restrict sampling to the `top_k` highest logits (`0` = full vocab).
    pub top_k: usize,
    /// Seed for the sampling stream (ignored when greedy).
    pub seed: u64,
    /// Serving deadline budget, measured from submit time. The batch
    /// [`decode`] ignores it; the coordinator rejects requests whose
    /// budget lapses in queue *and* enforces it between decode steps
    /// through [`DecodeState::expire_overdue`] (`ServeError::Deadline`).
    pub deadline: Option<Duration>,
}

impl Default for GenOptions {
    fn default() -> GenOptions {
        GenOptions {
            max_new_tokens: usize::MAX,
            stop_tokens: vec![EOS],
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            deadline: None,
        }
    }
}

impl GenOptions {
    /// Greedy decoding to EOS — the pre-redesign `greedy_decode` behavior.
    pub fn greedy() -> GenOptions {
        GenOptions::default()
    }

    /// Temperature/top-k sampling with a per-request seed.
    pub fn sample(temperature: f32, top_k: usize, seed: u64) -> GenOptions {
        GenOptions {
            temperature,
            top_k,
            seed,
            ..GenOptions::default()
        }
    }

    pub fn max_new_tokens(mut self, n: usize) -> GenOptions {
        self.max_new_tokens = n;
        self
    }

    pub fn stop_tokens(mut self, tokens: Vec<i32>) -> GenOptions {
        self.stop_tokens = tokens;
        self
    }

    pub fn seed(mut self, seed: u64) -> GenOptions {
        self.seed = seed;
        self
    }

    pub fn deadline(mut self, budget: Duration) -> GenOptions {
        self.deadline = Some(budget);
        self
    }
}

/// Per-row decode bookkeeping inside a [`DecodeState`].
struct RowState {
    opts: GenOptions,
    rng: Rng,
    /// Window positions filled (prompt + generated).
    len: usize,
    prompt_len: usize,
    done: bool,
    expired: bool,
    deadline: Option<Instant>,
    out: Vec<i32>,
}

impl RowState {
    fn vacant() -> RowState {
        RowState {
            opts: GenOptions::greedy(),
            rng: Rng::new(0, GEN_STREAM),
            len: 0,
            prompt_len: 0,
            done: true,
            expired: false,
            deadline: None,
            out: Vec::new(),
        }
    }
}

/// Resumable decoding over a fixed `(batch, seq)` window.
///
/// Prompts are admitted into rows (slots); each step consumes next-token
/// logits and advances every live row by at most one token. The serving
/// workers drive it one step at a time — KV-cached ([`step_entries`] /
/// [`step_rows`][DecodeState::step_rows]) or full-window
/// ([`step_full`][DecodeState::step_full]) — admit new requests into
/// released rows between steps (continuous batching), and enforce per-row
/// deadlines *between* steps via [`expire_overdue`][DecodeState::expire_overdue]
/// instead of only at admission. Vacant rows stay `PAD`-filled and done,
/// so batch-shape filler never consumes a decode step.
///
/// [`step_entries`]: DecodeState::step_entries
pub struct DecodeState {
    seq: usize,
    vocab: usize,
    tokens: Vec<i32>,
    rows: Vec<RowState>,
}

impl DecodeState {
    /// A state with `bsz` vacant rows (all done until admitted into).
    pub fn vacant(bsz: usize, seq: usize, vocab: usize) -> DecodeState {
        DecodeState {
            seq,
            vocab,
            tokens: vec![PAD; bsz * seq],
            rows: (0..bsz).map(|_| RowState::vacant()).collect(),
        }
    }

    /// One row per prompt, all sharing `opts` — the batch [`decode`] shape.
    pub fn new(
        prompts: &[Vec<i32>],
        opts: &GenOptions,
        seq: usize,
        vocab: usize,
    ) -> DecodeState {
        let mut st = DecodeState::vacant(prompts.len(), seq, vocab);
        for (row, p) in prompts.iter().enumerate() {
            st.admit(row, p, opts.clone(), None);
        }
        st
    }

    pub fn batch(&self) -> usize {
        self.rows.len()
    }

    /// (Re)occupy `row` with a fresh prompt and its own options/deadline.
    /// Degenerate prompts (empty, already filling the window) and
    /// `max_new_tokens == 0` are done immediately — there is no position
    /// to read next-token logits from (or no room to append), so such
    /// rows never consume a decode step.
    pub fn admit(
        &mut self,
        row: usize,
        prompt: &[i32],
        opts: GenOptions,
        deadline: Option<Instant>,
    ) {
        let n = prompt.len().min(self.seq);
        let w = &mut self.tokens[row * self.seq..(row + 1) * self.seq];
        w.fill(PAD);
        w[..n].copy_from_slice(&prompt[..n]);
        let done = n == 0 || n >= self.seq || opts.max_new_tokens == 0;
        self.rows[row] = RowState {
            // the sample stream derives from the request seed alone, so a
            // row's tokens do not depend on its batch position
            rng: Rng::new(opts.seed, GEN_STREAM),
            opts,
            len: n,
            prompt_len: n,
            done,
            expired: false,
            deadline,
            out: Vec::new(),
        };
    }

    /// The padded `(batch * seq)` token window a full-window forward
    /// consumes.
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// All rows done (vacant rows count as done)?
    pub fn is_done(&self) -> bool {
        self.rows.iter().all(|r| r.done)
    }

    pub fn row_done(&self, row: usize) -> bool {
        self.rows[row].done
    }

    /// Did `row` stop because its deadline lapsed mid-generation?
    pub fn row_expired(&self, row: usize) -> bool {
        self.rows[row].expired
    }

    /// Tokens generated so far for `row`.
    pub fn generated(&self, row: usize) -> &[i32] {
        &self.rows[row].out
    }

    /// Prompt length (clamped to the window) admitted into `row`.
    pub fn prompt_len(&self, row: usize) -> usize {
        self.rows[row].prompt_len
    }

    /// Take `row`'s output and mark the row vacant for reuse.
    pub fn release(&mut self, row: usize) -> Vec<i32> {
        let out = std::mem::take(&mut self.rows[row].out);
        self.rows[row] = RowState::vacant();
        self.tokens[row * self.seq..(row + 1) * self.seq].fill(PAD);
        out
    }

    /// Indices of rows still decoding.
    pub fn live_rows(&self) -> Vec<usize> {
        (0..self.rows.len()).filter(|&r| !self.rows[r].done).collect()
    }

    /// Mark live rows whose deadline has passed as done (`expired`) —
    /// deadline enforcement *between* decode steps. Returns the newly
    /// expired rows.
    pub fn expire_overdue(&mut self, now: Instant) -> Vec<usize> {
        let mut hit = Vec::new();
        for (r, row) in self.rows.iter_mut().enumerate() {
            if !row.done && row.deadline.is_some_and(|d| now >= d) {
                row.done = true;
                row.expired = true;
                hit.push(r);
            }
        }
        hit
    }

    /// Force `row` to stop decoding (client cancellation mid-generation).
    pub fn finish_row(&mut self, row: usize) {
        self.rows[row].done = true;
    }

    /// KV-path step inputs for every live row: `(row, position, token)` of
    /// the newest window token, whose position the next decode step runs.
    /// Only valid once the row's first token came from prefill logits
    /// (`step_prefill`), so a prompt position is never re-decoded.
    pub fn step_entries(&self) -> Vec<(usize, usize, i32)> {
        self.live_rows()
            .into_iter()
            .map(|r| {
                debug_assert!(
                    self.rows[r].len > self.rows[r].prompt_len,
                    "step_entries before prefill emitted row {r}'s first token"
                );
                let pos = self.rows[r].len - 1;
                (r, pos, self.tokens[r * self.seq + pos])
            })
            .collect()
    }

    /// [`step_entries`][DecodeState::step_entries] restricted to rows
    /// whose first token has already arrived (`len > prompt_len`). Rows
    /// still mid-prefill under chunking (PR 9) are live but have no
    /// position to decode yet — they are skipped instead of asserted on.
    pub fn step_entries_decoding(&self) -> Vec<(usize, usize, i32)> {
        self.live_rows()
            .into_iter()
            .filter(|&r| self.rows[r].len > self.rows[r].prompt_len)
            .map(|r| {
                let pos = self.rows[r].len - 1;
                (r, pos, self.tokens[r * self.seq + pos])
            })
            .collect()
    }

    /// Window position of the newest filled token for `row` — the
    /// position whose next-token logits a lean prefill must return
    /// (`ServeEngine::prefill_rows`'s `last` argument).
    pub fn last_pos(&self, row: usize) -> usize {
        debug_assert!(self.rows[row].len > 0, "last_pos of an empty row");
        self.rows[row].len - 1
    }

    /// Apply lean prefill logits (`rows.len() * vocab`: one next-token
    /// row per prefilled request, already projected at each row's last
    /// prompt position — see `transformer::infer_prefill`) to freshly
    /// admitted rows: samples each row's first token. Returns the
    /// `(row, token)` pairs actually emitted.
    ///
    /// Migration note (PR 5): this used to take full-window
    /// `(rows·seq·vocab)` logits and index each row's last position
    /// itself; the position selection now lives engine-side
    /// ([`last_pos`][DecodeState::last_pos] feeds it).
    pub fn step_prefill(
        &mut self,
        rows: &[usize],
        logits: &[f32],
    ) -> Vec<(usize, i32)> {
        debug_assert_eq!(logits.len(), rows.len() * self.vocab);
        let mut emitted = Vec::new();
        for (i, &row) in rows.iter().enumerate() {
            if self.rows[row].done {
                continue;
            }
            let off = i * self.vocab;
            if let Some(tok) = self.apply(row, &logits[off..off + self.vocab]) {
                emitted.push((row, tok));
            }
        }
        emitted
    }

    /// Consume full-window logits (`batch * seq * vocab`): advance every
    /// live row one position. Returns the `(row, token)` pairs emitted.
    pub fn step_full(&mut self, logits: &[f32]) -> Vec<(usize, i32)> {
        debug_assert_eq!(logits.len(), self.rows.len() * self.seq * self.vocab);
        let mut emitted = Vec::new();
        for row in 0..self.rows.len() {
            if self.rows[row].done {
                continue;
            }
            let pos = self.rows[row].len - 1;
            let off = (row * self.seq + pos) * self.vocab;
            if let Some(tok) = self.apply(row, &logits[off..off + self.vocab]) {
                emitted.push((row, tok));
            }
        }
        emitted
    }

    /// Consume KV-step logits (`entries.len() * vocab`, aligned with the
    /// [`step_entries`][DecodeState::step_entries] that produced the step).
    /// Returns the `(row, token)` pairs emitted.
    pub fn step_rows(
        &mut self,
        entries: &[(usize, usize, i32)],
        logits: &[f32],
    ) -> Vec<(usize, i32)> {
        debug_assert_eq!(logits.len(), entries.len() * self.vocab);
        let mut emitted = Vec::new();
        for (i, &(row, _, _)) in entries.iter().enumerate() {
            if self.rows[row].done {
                continue;
            }
            let off = i * self.vocab;
            if let Some(tok) = self.apply(row, &logits[off..off + self.vocab]) {
                emitted.push((row, tok));
            }
        }
        emitted
    }

    /// Consume one next-token logit row for `row`: sample (or argmax),
    /// honor stop tokens, the generation cap, and the window bound.
    fn apply(&mut self, row: usize, lrow: &[f32]) -> Option<i32> {
        let seq = self.seq;
        let st = &mut self.rows[row];
        let next = if st.opts.temperature > 0.0 {
            sample_token(lrow, st.opts.temperature, st.opts.top_k, &mut st.rng)
                as i32
        } else {
            (0..lrow.len())
                .max_by(|&a, &b| lrow[a].total_cmp(&lrow[b]))
                .unwrap() as i32
        };
        if st.opts.stop_tokens.contains(&next) {
            st.done = true;
            return None;
        }
        self.tokens[row * seq + st.len] = next;
        st.out.push(next);
        st.len += 1;
        if st.out.len() >= st.opts.max_new_tokens || st.len >= seq {
            st.done = true;
        }
        Some(next)
    }
}

/// Batched decoding to completion — a thin wrapper over [`DecodeState`]
/// driving full-window forwards (one per generated token; serving uses
/// the KV-cached step path instead, see `coordinator::server`).
///
/// `prompts` are token prefixes (already `BOS .. SEP`). Each row decodes
/// until a stop token, `max_new_tokens`, or `seq` is full. Degenerate
/// rows are safe: an empty prompt or a prompt that already fills `seq`
/// produces an empty generation instead of indexing out of the logits.
/// `opts.deadline` stays coordinator-enforced (ignored here).
pub fn decode(
    forward: &mut ForwardFn,
    prompts: &[Vec<i32>],
    opts: &GenOptions,
    seq: usize,
    vocab: usize,
) -> Vec<Vec<i32>> {
    let mut st = DecodeState::new(prompts, opts, seq, vocab);
    while !st.is_done() {
        let logits = forward(st.tokens());
        if st.step_full(&logits).is_empty() {
            // nothing emitted: every live row just stopped (defensive —
            // equivalent to the pre-step-API `progressed` guard)
            break;
        }
    }
    (0..prompts.len()).map(|r| st.release(r)).collect()
}

/// Sample from softmax(logits / temperature) over the top-k logits.
/// Ties in the top-k cut are broken by ascending index so the candidate
/// set is deterministic.
fn sample_token(
    lrow: &[f32],
    temperature: f32,
    top_k: usize,
    rng: &mut Rng,
) -> usize {
    let k = if top_k == 0 {
        lrow.len()
    } else {
        top_k.min(lrow.len())
    };
    let mut idx: Vec<usize> = (0..lrow.len()).collect();
    idx.sort_unstable_by(|&a, &b| {
        lrow[b].total_cmp(&lrow[a]).then(a.cmp(&b))
    });
    idx.truncate(k);
    let max = lrow[idx[0]];
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((lrow[i] - max) / temperature) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.f64() * total;
    for (j, &i) in idx.iter().enumerate() {
        u -= weights[j];
        if u <= 0.0 {
            return i;
        }
    }
    idx[k - 1]
}

/// Scores for one task evaluation.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub task: String,
    pub metric: Metric,
    /// primary metric in [0, 100] (paper-style percentage)
    pub score: f64,
    /// exact match in [0, 100] (same as score for EM metrics)
    pub em: f64,
    pub n: usize,
}

/// Evaluate a task: generate completions for `n` eval examples with the
/// given forward function (greedy decoding) and aggregate the task metric.
pub fn evaluate(
    task: &Task,
    forward: &mut ForwardFn,
    n: usize,
    batch: usize,
    seq: usize,
    vocab: usize,
) -> EvalReport {
    let tk = Tokenizer::new();
    let opts = GenOptions::greedy();
    let mut scores = Vec::with_capacity(n);
    let mut ems = Vec::with_capacity(n);
    let mut idx = 0;
    while idx < n {
        let take = batch.min(n - idx);
        let mut examples = Vec::with_capacity(take);
        let mut prompts = Vec::with_capacity(batch);
        for i in idx..idx + take {
            let ex = task.example("eval", i);
            prompts.push(tk.prompt_tokens(&ex.prompt));
            examples.push(ex);
        }
        // pad the batch up to the artifact's fixed batch size with empty
        // prompts: they are marked done at admission, so filler rows never
        // consume decode steps (a `[BOS]` filler used to decode garbage to
        // the full window, multiplying the eval's forward count)
        while prompts.len() < batch {
            prompts.push(Vec::new());
        }
        let generations = decode(forward, &prompts, &opts, seq, vocab);
        let debug = std::env::var("MOS_EVAL_DEBUG").is_ok();
        for (ex, gen) in examples.iter().zip(&generations) {
            let text = tk.decode(gen);
            if debug {
                eprintln!(
                    "[eval] prompt={:?} want={:?} got={:?}",
                    ex.prompt, ex.completion, text
                );
            }
            scores.push(task.score(ex, &text));
            ems.push(task.score_em(ex, &text));
        }
        idx += take;
    }
    EvalReport {
        task: task.kind.name().to_string(),
        metric: task.metric(),
        score: 100.0 * scores.iter().sum::<f64>() / scores.len().max(1) as f64,
        em: 100.0 * ems.iter().sum::<f64>() / ems.len().max(1) as f64,
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::TaskKind;
    use crate::data::tokenizer::SEP;

    /// A fake "model" that echoes the prompt chars after SEP — lets us test
    /// decoding mechanics without a trained model.
    fn echo_forward(vocab: usize, seq: usize) -> impl FnMut(&[i32]) -> Vec<f32> {
        move |tokens: &[i32]| {
            let bsz = tokens.len() / seq;
            let mut logits = vec![0.0f32; bsz * seq * vocab];
            for row in 0..bsz {
                let toks = &tokens[row * seq..(row + 1) * seq];
                let sep_pos = toks.iter().position(|&t| t == SEP);
                let len = toks.iter().position(|&t| t == PAD).unwrap_or(seq);
                if let Some(sp) = sep_pos {
                    let pos = len - 1; // position whose next token is queried
                    // number of generated tokens so far
                    let k = pos - sp;
                    // echo prompt token k+1 (after BOS), else EOS
                    let src = 1 + k;
                    let next = if src < sp { toks[src] } else { EOS };
                    logits[(row * seq + pos) * vocab + next as usize] = 10.0;
                }
            }
            logits
        }
    }

    /// Flat logits: every token equally likely — pure test of the sample
    /// stream (greedy argmax would always pick token 0).
    fn flat_forward(vocab: usize, seq: usize) -> impl FnMut(&[i32]) -> Vec<f32> {
        move |tokens: &[i32]| vec![0.0f32; (tokens.len() / seq) * seq * vocab]
    }

    #[test]
    fn greedy_decode_echo() {
        // temperature 0 must reproduce the pre-GenOptions greedy outputs
        let tk = Tokenizer::new();
        let vocab = tk.vocab_size();
        let seq = 24;
        let mut fwd = echo_forward(vocab, seq);
        let prompts =
            vec![tk.prompt_tokens("abc"), tk.prompt_tokens("hello")];
        let outs = decode(&mut fwd, &prompts, &GenOptions::greedy(), seq, vocab);
        assert_eq!(tk.decode(&outs[0]), "abc");
        assert_eq!(tk.decode(&outs[1]), "hello");
    }

    #[test]
    fn evaluate_echo_scores_cipher_partially() {
        // echo model returns the plaintext, which shares chars with the
        // cipher output only by chance -> F1 must be < 100
        let task = Task::new(TaskKind::CipherQa, 0);
        let tk = Tokenizer::new();
        let vocab = tk.vocab_size();
        let mut fwd = echo_forward(vocab, 32);
        let rep = evaluate(&task, &mut fwd, 8, 4, 32, vocab);
        assert_eq!(rep.n, 8);
        assert!(rep.score < 100.0);
    }

    #[test]
    fn decode_respects_seq_bound() {
        let vocab = 8;
        let seq = 6;
        // model that never emits EOS
        let mut fwd = |tokens: &[i32]| {
            let bsz = tokens.len() / seq;
            let mut l = vec![0.0f32; bsz * seq * vocab];
            for i in 0..bsz * seq {
                l[i * vocab + 5] = 1.0;
            }
            l
        };
        let outs =
            decode(&mut fwd, &[vec![1, 4, 2]], &GenOptions::greedy(), seq, vocab);
        assert_eq!(outs[0].len(), seq - 3);
    }

    #[test]
    fn degenerate_prompts_are_safe() {
        // empty prompt (tokenizes to zero tokens) and a prompt that already
        // overfills seq must both yield empty generations, not a panic
        let vocab = 8;
        let seq = 4;
        let mut fwd = echo_forward(vocab, seq);
        let prompts = vec![
            Vec::new(),            // empty
            vec![1, 4, 5, 6, 7, 4], // longer than seq
            vec![1, 4, 2],          // normal row still decodes
        ];
        let outs = decode(&mut fwd, &prompts, &GenOptions::greedy(), seq, vocab);
        assert!(outs[0].is_empty());
        assert!(outs[1].is_empty());
        assert_eq!(outs[2].len(), 1); // seq 4 leaves one slot
    }

    #[test]
    fn max_new_tokens_caps_generation() {
        let vocab = 8;
        let seq = 16;
        let mut fwd = flat_forward(vocab, seq);
        // flat logits + greedy always picks argmax 0 (= PAD, not a stop
        // token by default), so generation runs to the cap
        let opts = GenOptions::greedy().max_new_tokens(3);
        let outs = decode(&mut fwd, &[vec![1, 4]], &opts, seq, vocab);
        assert_eq!(outs[0].len(), 3);
    }

    #[test]
    fn custom_stop_tokens_halt() {
        let vocab = 8;
        let seq = 16;
        // model that always wants token 5
        let mut fwd = |tokens: &[i32]| {
            let bsz = tokens.len() / seq;
            let mut l = vec![0.0f32; bsz * seq * vocab];
            for i in 0..bsz * seq {
                l[i * vocab + 5] = 1.0;
            }
            l
        };
        let opts = GenOptions::greedy().stop_tokens(vec![5]);
        let outs = decode(&mut fwd, &[vec![1, 4]], &opts, seq, vocab);
        assert!(outs[0].is_empty(), "stop token must not be emitted");
    }

    #[test]
    fn sampling_deterministic_per_seed() {
        let vocab = 8;
        let seq = 16;
        let opts = |seed| {
            GenOptions::sample(1.0, 0, seed)
                .stop_tokens(Vec::new())
                .max_new_tokens(12)
        };
        let run = |o: &GenOptions| {
            let mut fwd = flat_forward(vocab, seq);
            decode(&mut fwd, &[vec![1, 4]], o, seq, vocab)
        };
        let a = run(&opts(7));
        let b = run(&opts(7));
        assert_eq!(a, b, "same seed must reproduce the same tokens");
        let c = run(&opts(8));
        assert_ne!(a, c, "different seeds should diverge on flat logits");
        // sampled tokens actually vary (not argmax-collapsed)
        assert!(a[0].iter().any(|&t| t != a[0][0]));
    }

    #[test]
    fn sampling_independent_of_batch_position() {
        // the per-request determinism contract: a request's output does not
        // depend on where the batcher placed it in a batch
        let vocab = 8;
        let seq = 16;
        let opts = GenOptions::sample(0.8, 4, 11)
            .stop_tokens(Vec::new())
            .max_new_tokens(10);
        let mut fwd = flat_forward(vocab, seq);
        let alone = decode(&mut fwd, &[vec![1, 4]], &opts, seq, vocab);
        let mut fwd = flat_forward(vocab, seq);
        let batched = decode(
            &mut fwd,
            &[vec![1, 6, 7], vec![1, 4]],
            &opts,
            seq,
            vocab,
        );
        assert_eq!(alone[0], batched[1]);
    }

    #[test]
    fn step_api_matches_batch_decode() {
        // driving DecodeState by hand must reproduce decode() exactly
        let tk = Tokenizer::new();
        let vocab = tk.vocab_size();
        let seq = 24;
        let prompts = vec![tk.prompt_tokens("abc"), tk.prompt_tokens("hello")];
        let opts = GenOptions::greedy();
        let mut fwd = echo_forward(vocab, seq);
        let want = decode(&mut fwd, &prompts, &opts, seq, vocab);

        let mut fwd = echo_forward(vocab, seq);
        let mut st = DecodeState::new(&prompts, &opts, seq, vocab);
        let mut streamed: Vec<Vec<i32>> = vec![Vec::new(); 2];
        while !st.is_done() {
            let logits = fwd(st.tokens());
            for (row, tok) in st.step_full(&logits) {
                streamed[row].push(tok);
            }
        }
        let got: Vec<Vec<i32>> = (0..2).map(|r| st.release(r)).collect();
        assert_eq!(got, want);
        assert_eq!(streamed, want, "streamed tokens diverge from outputs");
    }

    #[test]
    fn step_prefill_consumes_lean_logit_rows() {
        // lean prefill layout: one (vocab,) next-token row per prefilled
        // request, already projected at last_pos — no full-window indexing
        let vocab = 8;
        let seq = 6;
        let mut st = DecodeState::vacant(3, seq, vocab);
        st.admit(0, &[1, 4], GenOptions::greedy(), None);
        st.admit(2, &[1, 5, 6], GenOptions::greedy(), None);
        assert_eq!(st.last_pos(0), 1);
        assert_eq!(st.last_pos(2), 2);
        let mut logits = vec![0.0f32; 2 * vocab];
        logits[3] = 5.0; // row 0's lean row favors token 3
        logits[vocab + 5] = 5.0; // row 2's favors token 5
        let emitted = st.step_prefill(&[0, 2], &logits);
        assert_eq!(emitted, vec![(0, 3), (2, 5)]);
        assert_eq!(st.generated(0), &[3]);
        assert_eq!(st.generated(2), &[5]);
    }

    #[test]
    fn deadline_enforced_between_steps() {
        let vocab = 8;
        let seq = 16;
        let mut fwd = flat_forward(vocab, seq);
        let mut st = DecodeState::new(
            &[vec![1, 4], vec![1, 5]],
            &GenOptions::greedy().max_new_tokens(8),
            seq,
            vocab,
        );
        // row 1 gets a deadline in the past; row 0 none
        st.admit(
            1,
            &[1, 5],
            GenOptions::greedy().max_new_tokens(8),
            Some(Instant::now() - Duration::from_millis(1)),
        );
        let logits = fwd(st.tokens());
        st.step_full(&logits);
        assert_eq!(st.expire_overdue(Instant::now()), vec![1]);
        assert!(st.row_done(1) && st.row_expired(1));
        assert!(!st.row_done(0) && !st.row_expired(0));
        // row 1 stops exactly where it was; row 0 keeps decoding
        let gen1 = st.generated(1).len();
        while !st.is_done() {
            let logits = fwd(st.tokens());
            st.step_full(&logits);
        }
        assert_eq!(st.generated(1).len(), gen1);
        assert_eq!(st.generated(0).len(), 8);
    }

    #[test]
    fn released_row_can_be_readmitted_mid_flight() {
        // continuous-batching slot reuse: a finished row accepts a new
        // prompt while another row keeps decoding, and the relay produces
        // the same tokens as a standalone decode
        let vocab = 8;
        let seq = 16;
        let mut fwd = flat_forward(vocab, seq);
        let long = GenOptions::greedy().max_new_tokens(9);
        let short = GenOptions::greedy().max_new_tokens(2);
        let mut st = DecodeState::vacant(2, seq, vocab);
        st.admit(0, &[1, 4], long.clone(), None);
        st.admit(1, &[1, 5], short.clone(), None);
        let mut steps = 0;
        let mut readmitted = false;
        while !st.is_done() {
            let logits = fwd(st.tokens());
            st.step_full(&logits);
            steps += 1;
            if st.row_done(1) && !readmitted {
                assert_eq!(st.release(1).len(), 2);
                st.admit(1, &[1, 6], short.clone(), None);
                readmitted = true;
            }
        }
        assert!(readmitted);
        assert_eq!(st.generated(0).len(), 9);
        assert_eq!(st.release(1).len(), 2);
        assert_eq!(steps, 9, "slot reuse must not stall the batch");
    }

    #[test]
    fn evaluate_fillers_cost_no_extra_forwards() {
        // regression for the `[BOS]` filler-row bug: padding a 1-example
        // eval to a batch-4 engine must not add decode steps (fillers used
        // to generate to the full window)
        let task = Task::new(TaskKind::CipherQa, 0);
        let tk = Tokenizer::new();
        let vocab = tk.vocab_size();
        let seq = 32;
        let count_calls = |batch: usize| {
            let mut calls = 0usize;
            let mut inner = echo_forward(vocab, seq);
            let mut fwd = |tokens: &[i32]| {
                calls += 1;
                inner(tokens)
            };
            let rep = evaluate(&task, &mut fwd, 1, batch, seq, vocab);
            assert_eq!(rep.n, 1);
            calls
        };
        let alone = count_calls(1);
        let padded = count_calls(4);
        assert_eq!(
            padded, alone,
            "filler rows consumed decode steps (batch-4 padding took \
             {padded} forwards vs {alone} unpadded)"
        );
    }

    #[test]
    fn top_k_restricts_candidates() {
        let vocab = 8;
        let seq = 16;
        // token 6 and 7 dominate; top_k=2 must never sample anything else
        let mut fwd = |tokens: &[i32]| {
            let bsz = tokens.len() / seq;
            let mut l = vec![0.0f32; bsz * seq * vocab];
            for i in 0..bsz * seq {
                l[i * vocab + 6] = 5.0;
                l[i * vocab + 7] = 5.0;
            }
            l
        };
        let opts = GenOptions::sample(1.0, 2, 3)
            .stop_tokens(Vec::new())
            .max_new_tokens(12);
        let outs = decode(&mut fwd, &[vec![1, 4]], &opts, seq, vocab);
        assert!(outs[0].iter().all(|&t| t == 6 || t == 7), "{:?}", outs[0]);
    }
}
