//! Minimal property-testing harness (proptest is not vendored offline).
//!
//! `check(name, cases, |rng| ...)` runs a randomized predicate many times
//! with deterministic per-case seeds; on failure it reports the failing seed
//! so the case can be replayed with `check_seed`.

use super::rng::Rng;

/// Run `f` for `cases` deterministic random cases. Panics with the failing
/// case seed on the first violation.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(
    name: &str,
    cases: u64,
    mut f: F,
) {
    for case in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ case, case);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at case {case}: {msg}");
        }
    }
}

/// Replay a single failing case.
pub fn check_seed<F: FnMut(&mut Rng) -> Result<(), String>>(
    name: &str,
    case: u64,
    mut f: F,
) {
    let mut rng = Rng::new(0xC0FFEE ^ case, case);
    if let Err(msg) = f(&mut rng) {
        panic!("property '{name}' failed at replayed case {case}: {msg}");
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!(
                "element {i}: {x} vs {y} (|diff|={}, tol={tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 50, |rng| {
            let (a, b) = (rng.f32(), rng.f32());
            if (a + b - (b + a)).abs() < 1e-9 {
                Ok(())
            } else {
                Err("not commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn allclose() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 1e-6)
            .is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-6, 1e-6).is_err());
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-6, 1e-6).is_err());
    }
}
