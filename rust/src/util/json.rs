//! Minimal JSON parser/serializer (serde is not vendored in the offline
//! image). Parses the artifact manifest and config files; serializes
//! checkpoints metadata and bench reports.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required-field helpers returning descriptive errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_f64()
            .map(|n| n as usize)
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    nl(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    nl(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn nl(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"xs":[1,2.5,-3],"s":"q\"uote","t":true,"n":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }
}
