//! Tiny CLI argument parser (clap is not vendored in the offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed accessors and a generated usage string.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse raw argv (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminates flag parsing
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required --{key}"))
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key}: '{v}' is not an integer")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key}: '{v}' is not an integer")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key}: '{v}' is not a number")),
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("--{key}: '{v}' is not a bool"),
        }
    }

    /// Comma-separated list.
    pub fn list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().to_string())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse("serve --port 8080 --verbose --name=foo input.txt");
        assert_eq!(a.positional, vec!["serve", "input.txt"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("name"), Some("foo"));
        assert!(a.has("verbose"));
        assert_eq!(a.usize("port", 0).unwrap(), 8080);
    }

    #[test]
    fn typed_errors() {
        let a = parse("--n abc");
        assert!(a.usize("n", 0).is_err());
        assert!(a.req("missing").is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize("n", 7).unwrap(), 7);
        assert_eq!(a.f64("x", 1.5).unwrap(), 1.5);
        assert!(!a.bool("b", false).unwrap());
        assert_eq!(a.list("l", &["x", "y"]), vec!["x", "y"]);
    }

    #[test]
    fn list_parsing() {
        let a = parse("--tasks recall,arith , chain");
        assert_eq!(a.list("tasks", &[]), vec!["recall", "arith"]);
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse("cmd -- --not-a-flag");
        assert_eq!(a.positional, vec!["cmd", "--not-a-flag"]);
    }
}
