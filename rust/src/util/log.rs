//! Leveled stderr logger with wallclock timestamps (no external deps).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        _ => Level::Error,
    }
}

pub fn log(l: Level, module: &str, msg: &str) {
    if l < level() {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = t.as_secs();
    let ms = t.subsec_millis();
    let tag = match l {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!(
        "[{:02}:{:02}:{:02}.{:03} {} {}] {}",
        (secs / 3600) % 24,
        (secs / 60) % 60,
        secs % 60,
        ms,
        tag,
        module,
        msg
    );
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnlog {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debuglog {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn set_and_get() {
        let prev = level();
        set_level(Level::Warn);
        assert_eq!(level(), Level::Warn);
        set_level(prev);
    }
}
