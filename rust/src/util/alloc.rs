//! Counting allocation probe: a [`GlobalAlloc`] wrapper around the system
//! allocator that tallies allocation events per thread and allocated bytes
//! process-wide.
//!
//! Two consumers:
//! * the crate's unit-test binary registers it (see `lib.rs`) so perf
//!   tests can assert the lean serving hot path — `infer_prefill` +
//!   `decode_step` — is arena-only in steady state
//!   ([`thread_allocs`] delta == 0 over N iterations);
//! * `bench_serving` registers it to report a peak-RSS proxy
//!   ([`total_bytes`] delta) per scenario into `BENCH_serving.json`.
//!
//! The per-thread counter is a `const`-initialized thread-local `Cell`
//! (no lazy init, so reading it never allocates), accessed with
//! `try_with` so allocations during TLS teardown don't panic; the byte
//! counter is a relaxed atomic. Overhead is a couple of adds per
//! allocation — negligible next to the allocator call itself, and the
//! probe is only ever registered in test/bench binaries.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

static TOTAL_BYTES: AtomicU64 = AtomicU64::new(0);
static TOTAL_ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Allocation events made by the *current thread* since it started.
/// Always 0 when no [`CountingAlloc`] is registered as the global
/// allocator — probe liveness is worth asserting before trusting a delta.
pub fn thread_allocs() -> u64 {
    THREAD_ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

/// Bytes requested from the allocator across all threads since process
/// start (allocations only; frees are not subtracted — a cumulative
/// churn / peak-RSS proxy, not a live-heap gauge).
pub fn total_bytes() -> u64 {
    TOTAL_BYTES.load(Ordering::Relaxed)
}

/// Allocation events across all threads since process start.
pub fn total_allocs() -> u64 {
    TOTAL_ALLOCS.load(Ordering::Relaxed)
}

fn count(bytes: usize) {
    TOTAL_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
    TOTAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

/// The probe allocator. Register with
/// `#[global_allocator] static A: CountingAlloc = CountingAlloc;`.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        count(layout.size());
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        count(layout.size());
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        count(new_size);
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_counts_thread_and_global_allocations() {
        // the test binary registers CountingAlloc (lib.rs), so a fresh
        // allocation must move both counters
        let (t0, b0, a0) = (thread_allocs(), total_bytes(), total_allocs());
        let v = vec![0u8; 8192];
        std::hint::black_box(&v);
        drop(v);
        assert!(thread_allocs() > t0, "thread counter did not move");
        assert!(total_allocs() > a0, "global counter did not move");
        assert!(total_bytes() >= b0 + 8192, "byte counter missed the vec");
    }

    #[test]
    fn other_threads_do_not_move_this_threads_counter() {
        let before = thread_allocs();
        std::thread::spawn(|| {
            let v = vec![0u8; 4096];
            std::hint::black_box(&v);
        })
        .join()
        .unwrap();
        // joining may or may not allocate on this thread; the spawned
        // thread's vec itself must not be attributed here. Allow the small
        // constant join/spawn bookkeeping but catch gross misattribution.
        let delta = thread_allocs() - before;
        assert!(delta < 64, "cross-thread allocations bled in: {delta}");
    }
}
